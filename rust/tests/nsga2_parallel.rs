//! Determinism tests for the parallel NSGA-II selection pipeline
//! (ISSUE 9): the two contracts of `Nsga2Config::selection_threads`.
//!
//! * `selection_threads <= 1` — the **legacy bitwise contract**: full
//!   runs replay the golden seeds bit-for-bit against the frozen
//!   pre-parallelization oracle (`bench::suite::legacy_nsga2`).
//! * `selection_threads >= 2` — the **self-deterministic parallel
//!   contract**: fronts are a pure function of the seed, identical
//!   across repeats and across any thread count in the parallel regime.
//!
//! Plus parallel-vs-serial equivalence for the sort/crowding fan-outs
//! (pure performance knobs: same fronts, same distances at any width),
//! the odd-`pop_size` offspring path, and the NaN-rejection boundary.

use afarepart::bench::suite::{front_fingerprint as key, legacy_nsga2};
use afarepart::nsga2::{
    crowding_distance, fast_non_dominated_sort, fast_non_dominated_sort_threads, Individual,
    Nsga2, Nsga2Config, Problem,
};
use afarepart::spec::ExperimentSpec;
use afarepart::util::prng::Rng;

const GOLDEN_SEEDS: [u64; 3] = [7, 11, 23];

/// Deterministic two-objective toy with real front structure: minimize
/// (gene sum, count of non-2 genes).
struct Toy;
impl Problem for Toy {
    fn genome_len(&self) -> usize {
        10
    }
    fn alphabet(&self) -> usize {
        3
    }
    fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
        let sum = g.iter().sum::<usize>() as f64;
        let twos = g.iter().filter(|&&x| x == 2).count() as f64;
        vec![sum, 10.0 - twos]
    }
}

fn run_front(selection_threads: usize, seed: u64) -> Vec<(Vec<usize>, Vec<u64>)> {
    let mut opt = Nsga2::new(Nsga2Config {
        pop_size: 20,
        generations: 10,
        seed,
        selection_threads,
        ..Default::default()
    });
    key(&opt.run(&mut Toy, |_| {}))
}

#[test]
fn serial_path_matches_frozen_pre_pr_oracle_on_golden_seeds() {
    for &seed in &GOLDEN_SEEDS {
        let cfg = Nsga2Config {
            pop_size: 20,
            generations: 10,
            seed,
            ..Default::default()
        };
        assert_eq!(cfg.selection_threads, 1, "default must stay the legacy serial path");
        let current = key(&Nsga2::new(cfg.clone()).run(&mut Toy, |_| {}));
        let legacy = key(&legacy_nsga2::run(&cfg, &mut Toy));
        assert_eq!(
            current, legacy,
            "selection_threads=1 front at seed {seed} is not bitwise identical \
             to the pre-PR serial NSGA-II"
        );
    }
}

#[test]
fn forked_path_is_self_deterministic_across_repeats_and_widths() {
    for &seed in &GOLDEN_SEEDS {
        let reference = run_front(2, seed);
        // repeats
        assert_eq!(reference, run_front(2, seed), "seed {seed}: repeat diverged");
        // any thread count in the parallel regime
        for threads in [3usize, 4, 8] {
            assert_eq!(
                reference,
                run_front(threads, seed),
                "seed {seed}: front depends on thread count {threads}"
            );
        }
        // and it is genuinely seeded
        assert_ne!(reference, run_front(2, seed + 1), "seed {seed}: seed ignored");
    }
}

#[test]
fn sort_and_crowding_fanouts_match_serial_at_any_width() {
    let mut rng = Rng::new(0xFACE);
    for n in [3usize, 33, 130] {
        let objs: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..3).map(|_| (rng.below(10) as f64) * 0.5).collect())
            .collect();
        let views: Vec<&[f64]> = objs.iter().map(|o| o.as_slice()).collect();
        let serial_fronts = fast_non_dominated_sort(&views);
        for threads in [1usize, 2, 4] {
            assert_eq!(
                fast_non_dominated_sort_threads(&views, threads),
                serial_fronts,
                "fronts diverge at n={n} threads={threads}"
            );
        }
        // crowding is per-front and must agree front by front
        for front in &serial_fronts {
            let front_objs: Vec<&[f64]> = front.iter().map(|&i| views[i]).collect();
            let d = crowding_distance(&front_objs);
            assert_eq!(d.len(), front.len());
        }
        // whole-population ranking (sort + per-front crowding fan-out)
        let mk_pop = || -> Vec<Individual> {
            objs.iter()
                .map(|o| Individual {
                    genome: vec![0; 4],
                    objectives: o.clone(),
                    rank: usize::MAX,
                    crowding: 0.0,
                })
                .collect()
        };
        let mut serial_pop = mk_pop();
        Nsga2::rank_population(&mut serial_pop);
        for threads in [2usize, 4] {
            let mut par_pop = mk_pop();
            Nsga2::rank_population_threads(&mut par_pop, threads);
            for (i, (a, b)) in serial_pop.iter().zip(&par_pop).enumerate() {
                assert_eq!(a.rank, b.rank, "rank diverges at n={n} i={i} threads={threads}");
                assert_eq!(
                    a.crowding.to_bits(),
                    b.crowding.to_bits(),
                    "crowding diverges at n={n} i={i} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn odd_pop_size_produces_full_generations_on_both_paths() {
    struct OddToy;
    impl Problem for OddToy {
        fn genome_len(&self) -> usize {
            6
        }
        fn alphabet(&self) -> usize {
            2
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            let ones = g.iter().filter(|&&x| x == 1).count() as f64;
            vec![ones, 6.0 - ones]
        }
    }
    for threads in [1usize, 2, 4] {
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 9, // odd: every variation round drops the last pair's second child
            generations: 6,
            seed: 13,
            selection_threads: threads,
            ..Default::default()
        });
        let front = opt.run(&mut OddToy, |_| {});
        assert!(!front.is_empty(), "threads={threads}");
        assert!(
            front.iter().all(|i| i.genome.len() == 6),
            "malformed genome at threads={threads}"
        );
        // 9 initial + 9 per generation, nothing lost to the odd pairing
        assert_eq!(opt.evaluations(), 9 + 6 * 9, "threads={threads}");
    }
}

#[test]
fn nan_objectives_abort_with_genome_context() {
    struct Poisoned;
    impl Problem for Poisoned {
        fn genome_len(&self) -> usize {
            5
        }
        fn alphabet(&self) -> usize {
            2
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            // every genome with gene[0] == 1 is poisoned
            if g[0] == 1 {
                vec![f64::INFINITY, f64::NAN]
            } else {
                vec![g.iter().sum::<usize>() as f64, 1.0]
            }
        }
    }
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // silence the expected panic
    let result = std::panic::catch_unwind(|| {
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 12,
            generations: 3,
            ..Default::default()
        });
        opt.run(&mut Poisoned, |_| {});
    });
    std::panic::set_hook(prev);
    let err = result.expect_err("non-finite objectives must abort the run");
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("non-finite objective"), "no context in panic: {msg:?}");
    assert!(msg.contains("genome"), "offending genome not named: {msg:?}");
}

#[test]
fn nan_crowding_regression_no_panic() {
    // the old partial_cmp().unwrap() comparator aborted here
    let pts: Vec<&[f64]> = vec![&[0.0, 2.0], &[f64::NAN, 1.0], &[2.0, 0.0]];
    let d = crowding_distance(&pts);
    assert_eq!(d.len(), 3);
    assert!(d.iter().all(|x| !x.is_nan()));
}

#[test]
fn selection_threads_env_override_reaches_the_optimizer() {
    // AFARE_SELECTION_THREADS must flow through the precedence chain into
    // Nsga2Config (spec layer, injectable environment — no process-env
    // mutation needed).
    let raw: Vec<String> = vec!["offline".into()];
    let args = afarepart::cli::Args::parse(&raw, &[]);
    let spec = ExperimentSpec::resolve_with(&args, |k| match k {
        "AFARE_SELECTION_THREADS" => Some("4".into()),
        _ => None,
    })
    .unwrap();
    assert_eq!(spec.optimizer.selection_threads, 4);
    assert_eq!(spec.to_config().nsga2.selection_threads, 4);
}
