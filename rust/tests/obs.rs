//! Integration tests for the observability subsystem (ISSUE 7): the
//! JSONL trace of a chaos-perturbed online serving run — reconfigs,
//! degradation, supervision and all — must be bitwise identical across
//! repeats *and* across `eval_threads`, every line must be schema-valid
//! and free of wall-clock values, the registry must agree with the
//! run's `Metrics` end to end, and attaching telemetry must not perturb
//! a single serving result.
//!
//! Everything runs on the artifact-free synthetic backend (the same
//! harness as `rust/tests/chaos.rs`), so no PJRT artifacts are needed.

use std::path::{Path, PathBuf};
use std::time::Duration;

use afarepart::bench::suite::{synthetic_eval_set, synthetic_manifest, synthetic_sensitivity};
use afarepart::coordinator::{
    BackendSpec, InferenceServer, OnlineConfig, OnlineOutcome, OnlineRunner, TimelinePoint,
};
use afarepart::faults::{
    ChaosComponent, ChaosEngine, DeviceFaultProfile, FaultEnv, FaultScenario,
};
use afarepart::hw::Platform;
use afarepart::nsga2::Nsga2Config;
use afarepart::obs::{Telemetry, TRACE_SCHEMA_VERSION};
use afarepart::partition::{DaccMode, Mapping, PartitionEvaluator};
use afarepart::util::json;

const UNITS: usize = 6;
const DIMS: (usize, usize, usize) = (4, 4, 3);
const BATCH: usize = 8;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("afare_obs_it_{}_{name}.jsonl", std::process::id()));
    p
}

/// Online config that exercises every instrumented path: θ re-optimizations
/// (small window + corrupt chaos), pipelined speculation, and a guaranteed
/// terminal failure window that forces safe-mapping degradation.
fn online_cfg() -> OnlineConfig {
    OnlineConfig {
        ticks: 26,
        window: 4,
        theta: 0.05,
        cooldown: 6,
        lookahead: 2,
        backoff_ms: 0,
        health_cooldown: 3,
        reopt: Nsga2Config { pop_size: 8, generations: 3, ..Default::default() },
        ..Default::default()
    }
}

/// Corruption drives the θ trigger; the windowed rate-1.0 crash guarantees
/// a worker respawn; the windowed transient burst (far past the retry
/// budget) guarantees one degradation episode.
fn chaos() -> ChaosEngine {
    ChaosEngine::new(
        99,
        vec![
            ChaosComponent::corrupt(0.6),
            ChaosComponent::crash(1.0).window(4, 5),
            ChaosComponent::transient(1.0, 9).window(14, 15),
        ],
    )
}

/// Run the synthetic online pipeline with `telemetry` at an evaluation
/// engine width of `threads`.
fn run_online(threads: usize, telemetry: Telemetry) -> OnlineOutcome {
    let manifest = synthetic_manifest(UNITS);
    let table = synthetic_sensitivity(UNITS);
    let platform = Platform::default_two_device();
    let env = FaultEnv {
        base_rate: 0.08,
        profiles: DeviceFaultProfile::default_two_device(),
        drift: Vec::new(),
    };
    let eval = synthetic_eval_set(BATCH * 4, DIMS.0, DIMS.1, DIMS.2, 10, 42);
    let cfg = online_cfg();
    let server = InferenceServer::spawn_with(
        BackendSpec::Synthetic { manifest: manifest.clone(), exec_cost: Duration::ZERO },
        DIMS,
        cfg.supervisor_policy(),
    )
    .unwrap();
    server.set_telemetry(telemetry.clone());
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        FaultScenario::InputWeight,
        table.clean_acc,
        false,
        DaccMode::SyntheticExact { table: &table, cost: Duration::ZERO },
    )
    .with_parallelism(threads)
    .with_telemetry(telemetry.clone());
    let mut runner = OnlineRunner {
        cfg,
        server: &server,
        evaluator: &mut ev,
        clean_acc: table.clean_acc,
        chaos: chaos(),
        safe_mapping: Some(Mapping::all_on(1, UNITS)),
        telemetry,
    };
    let out = runner.run(&eval, &env, Mapping::all_on(0, UNITS), |_| {}).unwrap();
    server.shutdown().unwrap();
    out
}

fn run_traced(threads: usize, path: &Path) -> OnlineOutcome {
    run_online(threads, Telemetry::with_trace(path).expect("trace file opens"))
}

fn fingerprint(tl: &[TimelinePoint]) -> Vec<(usize, u64, Vec<usize>, bool, bool)> {
    tl.iter()
        .map(|p| {
            (p.tick, p.batch_accuracy.to_bits(), p.mapping.0.clone(), p.reconfigured, p.degraded)
        })
        .collect()
}

/// ISSUE acceptance: same seed + `--trace` => identical JSONL at any
/// `eval_threads`, and across repeats.
#[test]
fn trace_is_bitwise_identical_across_eval_threads_and_repeats() {
    let paths: Vec<PathBuf> =
        ["t1", "t2", "t4", "t1_repeat"].iter().map(|n| tmp(n)).collect();
    let outs = [
        run_traced(1, &paths[0]),
        run_traced(2, &paths[1]),
        run_traced(4, &paths[2]),
        run_traced(1, &paths[3]),
    ];
    // the run must actually exercise the instrumented paths
    assert!(outs[0].metrics.reconfigurations > 0, "corrupt chaos must trigger θ");
    assert!(outs[0].metrics.degradations > 0, "the transient burst must degrade");
    for o in &outs[1..] {
        assert_eq!(fingerprint(&outs[0].timeline), fingerprint(&o.timeline));
    }

    let reference = std::fs::read(&paths[0]).unwrap();
    assert!(!reference.is_empty());
    for p in &paths[1..] {
        let bytes = std::fs::read(p).unwrap();
        assert_eq!(
            reference,
            bytes,
            "DETERMINISM VIOLATION: trace {} differs from {}",
            p.display(),
            paths[0].display()
        );
    }
    let text = String::from_utf8(reference).unwrap();
    assert!(text.contains("\"span\":\"online.reconfig\""), "reconfig spans must be traced");
    assert!(text.contains("\"kind\":\"degrade_enter\""), "degradation entry must be traced");
    assert!(text.contains("\"kind\":\"degrade_exit\""), "degradation exit must be traced");
    assert!(text.contains("\"span\":\"opt.generation\""), "optimizer generations must be traced");
    assert!(text.contains("\"span\":\"eval.batch\""), "evaluation batches must be traced");
    assert!(text.contains("\"kind\":\"server_retry\""), "supervision retries must be traced");
    assert!(text.contains("\"kind\":\"server_respawn\""), "worker respawns must be traced");
    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

/// Every trace line is a self-describing JSON object: schema-stamped,
/// strictly sequenced from 0, and free of wall-clock fields (wall times
/// belong to registry histograms only).
#[test]
fn trace_lines_are_schema_valid_and_wall_clock_free() {
    let path = tmp("schema");
    run_traced(2, &path);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() > online_cfg().ticks, "at least one event per tick plus the header");
    for (i, line) in lines.iter().enumerate() {
        let v = json::parse(line).unwrap_or_else(|e| panic!("line {i} is not JSON: {e:#}"));
        assert_eq!(
            v.get("schema").and_then(|x| x.as_f64()),
            Some(TRACE_SCHEMA_VERSION as f64),
            "line {i} schema"
        );
        assert_eq!(v.get("seq").and_then(|x| x.as_f64()), Some(i as f64), "line {i} seq");
        let kind = v.get("kind").and_then(|x| x.as_str()).expect("every event has a kind");
        if i == 0 {
            assert_eq!(kind, "trace_start");
        }
        if let Some(fields) = v.as_obj() {
            for key in fields.keys() {
                assert!(
                    !key.ends_with("_ms") && key != "ms" && !key.contains("wall"),
                    "line {i} carries wall-clock field {key:?}"
                );
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Attaching telemetry (registry + trace) must not change a single
/// serving result vs the disabled handle.
#[test]
fn telemetry_does_not_perturb_serving_results() {
    let path = tmp("perturb");
    let plain = run_online(2, Telemetry::disabled());
    let traced = run_traced(2, &path);
    assert_eq!(fingerprint(&plain.timeline), fingerprint(&traced.timeline));
    assert_eq!(plain.metrics.reconfigurations, traced.metrics.reconfigurations);
    assert_eq!(plain.metrics.degraded_intervals, traced.metrics.degraded_intervals);
    assert_eq!(plain.final_mapping, traced.final_mapping);
    std::fs::remove_file(&path).ok();
}

/// Registry counters, report-field mirrors, span histograms, and the
/// Prometheus rendering agree with the run's `Metrics` end to end.
#[test]
fn registry_counters_match_run_metrics_end_to_end() {
    let t = Telemetry::enabled();
    let out = run_online(2, t.clone());
    let m = &out.metrics;
    assert_eq!(t.counter_get("serve_batches_total"), m.batches_served as u64);
    assert_eq!(t.counter_get("serve_samples_total"), m.samples_served as u64);
    assert_eq!(t.counter_get("serve_reconfigurations_total"), m.reconfigurations as u64);
    assert_eq!(t.counter_get("serve_degradations_total"), m.degradations as u64);
    assert_eq!(t.counter_get("serve_degraded_ticks_total"), m.degraded_ticks as u64);
    assert_eq!(
        t.counter_get("serve_degraded_intervals_total"),
        m.degraded_intervals.len() as u64
    );
    assert_eq!(
        t.counter_get("serve_speculative_discarded_total"),
        m.speculative_discarded as u64
    );
    // the server mirrors its supervision stats live (not via Metrics)
    assert_eq!(t.counter_get("server_retries_total"), m.retries as u64);
    assert_eq!(t.counter_get("server_transient_errors_total"), m.transient_errors as u64);
    assert_eq!(t.counter_get("server_respawns_total"), m.worker_respawns as u64);
    assert!(t.counter_get("opt_generations_total") > 0, "re-optimizations ran generations");
    assert!(t.counter_get("eval_batch_calls_total") > 0);

    let snap = t.snapshot().unwrap();
    assert_eq!(
        snap.histograms["span_online_tick_ms"].count,
        online_cfg().ticks as u64,
        "one online.tick span per tick"
    );
    assert_eq!(
        snap.histograms["span_online_reconfig_ms"].count,
        m.reconfigurations as u64
    );

    let prom = t.prometheus().unwrap();
    assert!(prom.contains(&format!("afare_serve_batches_total {}", m.batches_served)));
    assert!(prom.contains("afare_span_online_tick_ms_bucket"));
    assert!(prom.contains("afare_span_online_tick_ms_p95"));
}

/// The disabled handle never materializes a snapshot, so reports keep
/// their pre-observability shape (no `telemetry` key) bit for bit.
#[test]
fn disabled_handle_yields_no_export() {
    let out = run_online(1, Telemetry::disabled());
    assert!(out.metrics.batches_served > 0);
    let t = Telemetry::disabled();
    assert!(t.snapshot().is_none());
    assert!(t.prometheus().is_none());
}
