//! Determinism and concurrency tests for the batched parallel evaluation
//! engine (ISSUE 1): the parallel batched Pareto front must be bitwise
//! identical to the serial inline-evaluation front for any seed, and the
//! sharded ΔAcc cache must stay consistent under concurrent hammering.

use std::time::Duration;

use afarepart::bench::suite::{
    front_fingerprint as key, synthetic_manifest, synthetic_sensitivity,
};
use afarepart::coordinator::offline::optimize_partitions;
use afarepart::faults::{FaultScenario, RateVectors};
use afarepart::hw::Platform;
use afarepart::nsga2::{Nsga2, Nsga2Config, Problem};
use afarepart::partition::{DaccCache, DaccMode, Mapping, PartitionEvaluator};

const UNITS: usize = 8;

fn evaluator<'a>(
    platform: &'a Platform,
    table: &'a afarepart::partition::SensitivityTable,
    manifest: &afarepart::model::Manifest,
    cost_us: u64,
    threads: usize,
) -> PartitionEvaluator<'a> {
    PartitionEvaluator::new(
        manifest,
        platform,
        vec![0.25, 0.04],
        vec![0.25, 0.04],
        FaultScenario::InputWeight,
        0.9,
        false,
        DaccMode::SyntheticExact { table, cost: Duration::from_micros(cost_us) },
    )
    .with_parallelism(threads)
}

/// Serial reference: a Problem that evaluates every genome inline, one
/// at a time, through the serial `objectives3` path (no batching, no
/// dedup, no threads) — the evaluation *structure* of the legacy NSGA-II
/// loop. Note this reference shares today's cost functions; the
/// prefix-sum lat/en rewrite reassociates float additions, so objective
/// values can differ from a pre-refactor *build* in the last ulps. The
/// property guaranteed (and asserted here) is: for a fixed seed, inline
/// serial == batched serial == batched parallel, bit for bit.
struct InlineSerialProblem<'a, 'b> {
    ev: &'b mut PartitionEvaluator<'a>,
}

impl Problem for InlineSerialProblem<'_, '_> {
    fn genome_len(&self) -> usize {
        self.ev.num_units()
    }
    fn alphabet(&self) -> usize {
        self.ev.num_devices()
    }
    fn evaluate(&mut self, genome: &[usize]) -> Vec<f64> {
        self.ev.objectives3(&Mapping(genome.to_vec())).unwrap()
    }
}

/// The headline determinism property: for several seeds, the parallel
/// batched front is identical (genomes AND objective bits) to both the
/// single-threaded batched front and the inline serial front.
#[test]
fn parallel_batched_front_identical_to_serial() {
    let platform = Platform::default_two_device();
    let table = synthetic_sensitivity(UNITS);
    let manifest = synthetic_manifest(UNITS);
    for seed in [1u64, 7, 42, 1234] {
        let nsga2 = Nsga2Config { pop_size: 16, generations: 6, seed, ..Default::default() };

        let mut ev_inline = evaluator(&platform, &table, &manifest, 0, 1);
        let mut inline_problem = InlineSerialProblem { ev: &mut ev_inline };
        let front_inline = Nsga2::new(nsga2.clone()).run(&mut inline_problem, |_| {});

        let mut ev1 = evaluator(&platform, &table, &manifest, 0, 1);
        let front_1t = optimize_partitions(&mut ev1, &nsga2, true, vec![], |_| {});

        let mut ev4 = evaluator(&platform, &table, &manifest, 50, 4);
        let front_4t = optimize_partitions(&mut ev4, &nsga2, true, vec![], |_| {});

        assert_eq!(key(&front_inline), key(&front_1t), "seed {seed}: batched(1T) != inline");
        assert_eq!(key(&front_1t), key(&front_4t), "seed {seed}: batched(4T) != batched(1T)");
    }
}

/// Different seeds still explore differently (the engine must not have
/// collapsed the stochastic search).
#[test]
fn different_seeds_differ() {
    let platform = Platform::default_two_device();
    let table = synthetic_sensitivity(UNITS);
    let manifest = synthetic_manifest(UNITS);
    let run = |seed| {
        let nsga2 =
            Nsga2Config { pop_size: 12, generations: 3, seed, ..Default::default() };
        let mut ev = evaluator(&platform, &table, &manifest, 0, 4);
        let (h, m, _) = ev.cache_stats();
        assert_eq!((h, m), (0, 0));
        key(&optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {}))
    };
    // tiny budgets can coincide; three distinct seeds all colliding would
    // mean the seed is ignored
    let (a, b, c) = (run(1), run(2), run(3));
    assert!(a != b || b != c, "fronts identical across seeds 1/2/3");
}

/// Batch-dedup stats semantics: repeats of an uncached key inside one
/// batch count as cache hits, the unique first occurrence as the miss.
#[test]
fn batch_dedup_counts_as_hits() {
    let platform = Platform::default_two_device();
    let table = synthetic_sensitivity(UNITS);
    let manifest = synthetic_manifest(UNITS);
    let mut ev = evaluator(&platform, &table, &manifest, 0, 1);
    let m1 = Mapping::all_on(0, UNITS);
    let m2 = Mapping::all_on(1, UNITS);
    let batch = vec![m1.clone(), m1.clone(), m2.clone(), m1];
    let objs = ev.objectives_batch(&batch, true).unwrap();
    assert_eq!(objs.len(), 4);
    assert_eq!(objs[0], objs[1]);
    assert_eq!(objs[0], objs[3]);
    let (hits, misses, rate) = ev.cache_stats();
    assert_eq!((hits, misses), (2, 2), "2 dedup hits, 2 unique misses");
    assert!((rate - 0.5).abs() < 1e-12);
    assert_eq!(ev.counters.exact_evals, 2, "only unique misses hit the backend");
    assert_eq!(ev.counters.batch_calls, 1);
    assert_eq!(ev.counters.batch_genomes, 4);

    // a prefix of the same batch again: all answered by the cache
    ev.objectives_batch(&batch[..2], true).unwrap();
    let (hits, misses, _) = ev.cache_stats();
    assert_eq!((hits, misses), (4, 2));
    assert_eq!(ev.counters.exact_evals, 2);
}

/// Hammer the sharded cache from many threads with overlapping keys:
/// values must stay consistent (each key always maps to its canonical
/// value) and the hit/miss accounting must add up.
#[test]
fn sharded_cache_concurrent_hammer() {
    let cache = DaccCache::new();
    let n_threads = 8;
    let ops_per_thread = 2_000;
    let n_keys = 24; // far fewer keys than ops -> heavy overlap
    let rv = |k: usize| RateVectors {
        w_rates: vec![(k % 6) as f32 / 8.0, (k / 6) as f32 / 8.0],
        a_rates: vec![0.125, 0.25],
    };
    let canonical = |k: usize| k as f64 / 100.0;

    std::thread::scope(|scope| {
        for t in 0..n_threads {
            let cache = &cache;
            scope.spawn(move || {
                for i in 0..ops_per_thread {
                    let k = (t * 7 + i * 13) % n_keys;
                    match cache.get(&rv(k)) {
                        Some(v) => assert_eq!(v, canonical(k), "stale value for key {k}"),
                        None => cache.put(&rv(k), canonical(k)),
                    }
                }
            });
        }
    });

    // every key is present with its canonical value
    assert_eq!(cache.len(), n_keys);
    for k in 0..n_keys {
        assert_eq!(cache.probe(&rv(k).cache_key()), Some(canonical(k)));
    }
    // accounting: every get() was counted exactly once
    let stats = cache.stats();
    assert_eq!(stats.lookups(), n_threads * ops_per_thread);
    // misses only happen while a key is unpublished: at least one per key,
    // bounded by the race window (every thread can miss each key at most
    // the once it observes it unpublished before any put lands)
    assert!(stats.misses >= n_keys);
    assert!(stats.misses <= n_keys * n_threads);
    assert_eq!(cache.lifetime_stats(), stats);
}

/// Lifetime stats survive environment rollovers; epoch stats reset.
#[test]
fn lifetime_stats_across_env_epochs() {
    let platform = Platform::default_two_device();
    let table = synthetic_sensitivity(UNITS);
    let manifest = synthetic_manifest(UNITS);
    let mut ev = evaluator(&platform, &table, &manifest, 0, 2);
    let nsga2 = Nsga2Config { pop_size: 12, generations: 3, ..Default::default() };
    optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {});
    let (h1, m1, _) = ev.cache_stats();
    assert!(h1 + m1 > 0);

    let rollover = ev.set_env_rates(vec![0.4, 0.04], vec![0.4, 0.04]);
    assert_eq!((rollover.ended_epoch.hits, rollover.ended_epoch.misses), (h1, m1));
    assert_eq!((rollover.lifetime.hits, rollover.lifetime.misses), (h1, m1));
    assert!(rollover.entries_dropped > 0);
    assert_eq!(ev.cache_stats(), (0, 0, 0.0), "epoch resets");

    optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {});
    let (h2, m2, _) = ev.cache_stats();
    let lifetime = ev.cache_lifetime_stats();
    assert_eq!(lifetime.hits, h1 + h2, "lifetime accumulates across epochs");
    assert_eq!(lifetime.misses, m1 + m2);
}

/// The engine honors seeds injected into the initial population (online
/// re-optimization seeds the incumbent mapping).
#[test]
fn seeded_batched_optimization_matches_serial() {
    let platform = Platform::default_two_device();
    let table = synthetic_sensitivity(UNITS);
    let manifest = synthetic_manifest(UNITS);
    let nsga2 = Nsga2Config { pop_size: 12, generations: 4, ..Default::default() };
    let seed_mapping = Mapping(vec![1; UNITS]);

    let mut ev1 = evaluator(&platform, &table, &manifest, 0, 1);
    let f1 = optimize_partitions(&mut ev1, &nsga2, true, vec![seed_mapping.clone()], |_| {});
    let mut ev4 = evaluator(&platform, &table, &manifest, 50, 4);
    let f4 = optimize_partitions(&mut ev4, &nsga2, true, vec![seed_mapping], |_| {});
    assert_eq!(key(&f1), key(&f4));
}
