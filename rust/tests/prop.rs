//! Randomized property tests (proptest is unavailable offline; these use
//! the in-tree PRNG with fixed seeds — failures print the seed so cases
//! are reproducible).

use afarepart::faults::{FaultScenario, RateVectors};
use afarepart::nsga2::{dominates, fast_non_dominated_sort, Nsga2, Nsga2Config, Problem};
use afarepart::partition::Mapping;
use afarepart::util::bits;
use afarepart::util::json;
use afarepart::util::prng::Rng;

const TRIALS: usize = 50;

/// Non-dominated sorting invariants on random objective sets.
#[test]
fn prop_front0_is_mutually_non_dominated() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(seed);
        let n = rng.range(2, 40);
        let m = rng.range(2, 4);
        let objs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..m).map(|_| (rng.below(6)) as f64).collect()).collect();
        let refs: Vec<&[f64]> = objs.iter().map(|o| o.as_slice()).collect();
        let fronts = fast_non_dominated_sort(&refs);
        // every point appears exactly once
        let total: usize = fronts.iter().map(|f| f.len()).sum();
        assert_eq!(total, n, "seed {seed}");
        // front 0: no member dominates another
        for &a in &fronts[0] {
            for &b in &fronts[0] {
                assert!(!dominates(&objs[a], &objs[b]), "seed {seed}: {a} dominates {b}");
            }
        }
        // every member of front k>0 is dominated by someone in front k-1
        for k in 1..fronts.len() {
            for &q in &fronts[k] {
                assert!(
                    fronts[k - 1].iter().any(|&p| dominates(&objs[p], &objs[q])),
                    "seed {seed}: front {k} member {q} not dominated by front {}",
                    k - 1
                );
            }
        }
    }
}

/// The returned NSGA-II front is internally non-dominated, genomes valid.
#[test]
fn prop_nsga2_front_valid() {
    struct P {
        len: usize,
        alpha: usize,
    }
    impl Problem for P {
        fn genome_len(&self) -> usize {
            self.len
        }
        fn alphabet(&self) -> usize {
            self.alpha
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            // two lumpy objectives
            let a: usize = g.iter().sum();
            let b: usize = g.iter().enumerate().map(|(i, &x)| (i + 1) * (self.alpha - 1 - x)).sum();
            vec![a as f64, b as f64]
        }
    }
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed + 100);
        let len = rng.range(3, 12);
        let alpha = rng.range(2, 4);
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 16,
            generations: 8,
            seed,
            ..Default::default()
        });
        let front = opt.run(&mut P { len, alpha }, |_| {});
        assert!(!front.is_empty(), "seed {seed}");
        for ind in &front {
            assert_eq!(ind.genome.len(), len);
            assert!(ind.genome.iter().all(|&g| g < alpha), "seed {seed}");
        }
        for a in &front {
            for b in &front {
                assert!(
                    !dominates(&a.objectives, &b.objectives),
                    "seed {seed}: returned front not mutually non-dominated"
                );
            }
        }
    }
}

/// Rust bit-flip mirror matches the golden vectors generated from ref.py
/// (the Pallas/jnp/rust three-way contract).
#[test]
fn prop_bitflip_matches_python_golden() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/data/bitflip_golden.json");
    let text = std::fs::read_to_string(path).expect("golden vectors present");
    let v = json::parse(&text).unwrap();
    let cases = v.as_arr().unwrap();
    assert!(cases.len() >= 18);
    for c in cases {
        let rate = c.get("rate").unwrap().as_f64().unwrap() as f32;
        let nbits = c.get("bits").unwrap().as_u64().unwrap() as u32;
        let q: Vec<i32> = c
            .get("q")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        let rnd: Vec<u32> = c
            .get("rnd")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect();
        let expected: Vec<i32> = c
            .get("expected")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(
            bits::bitflip(&q, &rnd, rate, nbits),
            expected,
            "rate={rate} bits={nbits}"
        );
    }
}

/// JSON writer/parser round-trip on random documents.
#[test]
fn prop_json_roundtrip() {
    fn random_value(rng: &mut Rng, depth: usize) -> json::Value {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.chance(0.5)),
            2 => json::Value::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => json::Value::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(10))),
            4 => json::Value::Arr((0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => json::Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(seed + 500);
        let v = random_value(&mut rng, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

/// RateVectors invariants: mapping-driven rates pick exactly the mapped
/// device's rate; cache keys are permutation-sensitive.
#[test]
fn prop_rate_vectors_follow_mapping() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(seed + 900);
        let l = rng.range(2, 12);
        let d = rng.range(2, 4);
        let mapping = Mapping::random(&mut rng, l, d);
        let dev_w: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let dev_a: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let rv = RateVectors::from_mapping(&mapping.0, &dev_w, &dev_a, FaultScenario::InputWeight);
        for (l_i, &dev) in mapping.0.iter().enumerate() {
            assert_eq!(rv.w_rates[l_i], dev_w[dev], "seed {seed}");
            assert_eq!(rv.a_rates[l_i], dev_a[dev], "seed {seed}");
        }
        // scenario masks zero the right domain
        let w_only = RateVectors::from_mapping(&mapping.0, &dev_w, &dev_a, FaultScenario::WeightOnly);
        assert!(w_only.a_rates.iter().all(|&r| r == 0.0));
        let a_only = RateVectors::from_mapping(&mapping.0, &dev_w, &dev_a, FaultScenario::InputOnly);
        assert!(a_only.w_rates.iter().all(|&r| r == 0.0));
    }
}

/// Expected element-flip fraction formula matches a Monte-Carlo estimate
/// of the actual bit-flip implementation.
#[test]
fn prop_flip_fraction_formula_matches_simulation() {
    let mut rng = Rng::new(4242);
    for &rate in &[0.05f32, 0.2, 0.5] {
        for bits_n in 1..=4u32 {
            let n = 40_000;
            let q = vec![0i32; n];
            let rnd: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let out = bits::bitflip(&q, &rnd, rate, bits_n);
            let frac = out.iter().filter(|&&x| x != 0).count() as f64 / n as f64;
            let expect = bits::expected_element_flip_fraction(rate, bits_n);
            assert!(
                (frac - expect).abs() < 0.015,
                "rate={rate} bits={bits_n}: {frac} vs {expect}"
            );
        }
    }
}

/// Mapping display/boundaries invariants.
#[test]
fn prop_mapping_boundaries_bounds() {
    for seed in 0..TRIALS as u64 {
        let mut rng = Rng::new(seed + 1300);
        let l = rng.range(1, 16);
        let d = rng.range(1, 4);
        let m = Mapping::random(&mut rng, l, d);
        assert!(m.boundaries() < l.max(1));
        assert_eq!(m.display().len(), l);
        let on_devices: usize = (0..d).map(|dev| m.units_on(dev).len()).sum();
        assert_eq!(on_devices, l, "every unit on exactly one device");
    }
}
