//! Parallel campaign scheduler integration tests (tier 1, artifact-free):
//! the determinism contract of `run_campaign_with` — the consolidated
//! report is bitwise identical at any `campaign_workers`, `on_cell`
//! fires in cell-index order at any worker count, and the cross-cell
//! shared ΔAcc cache actually saves backend evaluations on grids with
//! coincident rate vectors.

use std::sync::{Arc, Mutex};

use afarepart::obs::Telemetry;
use afarepart::spec::campaign::{run_campaign, run_campaign_with, CampaignOptions, CampaignReport};
use afarepart::spec::CampaignSpec;
use afarepart::util::json;

/// A 3×2 synthetic campaign (no artifacts): 3 fault rates × 2 scenarios.
fn grid_3x2() -> CampaignSpec {
    CampaignSpec::from_json_str(
        r#"{
            "base": {"eval_threads": 1,
                     "optimizer": {"pop_size": 8, "generations": 2}},
            "grid": {"models": ["synthetic-L6"],
                     "fault_rates": [0.1, 0.2, 0.4],
                     "scenarios": ["w", "iw"]}
        }"#,
    )
    .unwrap()
}

/// Render a report with the one nondeterministic field (wall clock)
/// zeroed, for bitwise comparison.
fn fingerprint(mut report: CampaignReport) -> String {
    report.wall_ms = 0.0;
    json::to_string(&report.to_json())
}

#[test]
fn parallel_campaign_report_is_bitwise_identical_to_serial() {
    let mut reference: Option<String> = None;
    for workers in [1usize, 2, 4] {
        let mut spec = grid_3x2();
        spec.base.campaign_workers = workers;
        let order = Arc::new(Mutex::new(Vec::new()));
        let order2 = Arc::clone(&order);
        let report = run_campaign_with(&spec, &CampaignOptions::default(), |i, total, cell| {
            assert_eq!(total, 6);
            assert!(!cell.offline.deployed.mapping.is_empty());
            order2.lock().unwrap().push(i);
        })
        .unwrap();
        // on_cell fires exactly once per cell, in cell-index order
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4, 5], "at {workers} workers");
        assert_eq!(report.cells.len(), 6);
        let fp = fingerprint(report);
        match &reference {
            None => reference = Some(fp),
            Some(r) => assert_eq!(
                r, &fp,
                "report at {workers} workers differs from campaign_workers = 1"
            ),
        }
    }
}

#[test]
fn default_entry_point_matches_explicit_options() {
    let spec = grid_3x2();
    let a = fingerprint(run_campaign(&spec, |_, _, _| {}).unwrap());
    let b = fingerprint(
        run_campaign_with(&spec, &CampaignOptions::default(), |_, _, _| {}).unwrap(),
    );
    assert_eq!(a, b);
}

#[test]
fn duplicate_rate_cells_share_backend_evaluations() {
    // Two drifts with identical components and eval times produce
    // pairwise-identical rate vectors per (fault_rate, scenario) pair —
    // every key the second drift's cells need is already in the shared
    // cache, whichever cell of each pair ran first.
    let spec = CampaignSpec::from_json_str(
        r#"{
            "base": {"eval_threads": 1, "campaign_workers": 2,
                     "optimizer": {"pop_size": 8, "generations": 2}},
            "grid": {"models": ["synthetic-L6"],
                     "fault_rates": [0.2],
                     "scenarios": ["w", "iw"],
                     "drifts": [{"name": "a"}, {"name": "b"}]}
        }"#,
    )
    .unwrap();
    let report = run_campaign(&spec, |_, _, _| {}).unwrap();
    assert_eq!(report.cells.len(), 4);
    assert_eq!(report.cache_sharing.len(), 1);
    let sh = &report.cache_sharing[0];
    assert_eq!(sh.model, "synthetic-L6");
    assert!(sh.requests >= sh.private_misses);
    assert!(sh.unique_keys > 0 && sh.unique_keys <= sh.private_misses);
    // the duplicated drift means at least one cross-cell hit was possible
    assert!(
        sh.saved_backend_evals > 0,
        "expected cross-cell savings on duplicated-rate cells, got {sh:?}"
    );
    // report-level backend evals stay the schedule-invariant sum of
    // private misses (sharing shows up only in cache_sharing)
    assert_eq!(
        report.total_backend_evals,
        sh.private_misses,
        "single-model campaign: total_backend_evals == that model's private misses"
    );
}

#[test]
fn campaign_telemetry_counts_cells_and_savings() {
    let mut spec = grid_3x2();
    spec.base.campaign_workers = 2;
    let telemetry = Telemetry::enabled();
    let opts = CampaignOptions { telemetry: telemetry.clone(), ..CampaignOptions::default() };
    let report = run_campaign_with(&spec, &opts, |_, _, _| {}).unwrap();
    assert_eq!(telemetry.counter_get("campaign_cells_total"), 6);
    assert_eq!(
        telemetry.counter_get("campaign_backend_evals_total") as usize
            + telemetry.counter_get("campaign_cross_cell_hits_total") as usize,
        report.total_backend_evals,
        "actual backend calls + cross-cell hits account for every private miss"
    );
    let snap = telemetry.snapshot().unwrap();
    assert_eq!(snap.histograms["span_campaign_cell_ms"].count, 6);
    assert_eq!(snap.gauges["campaign_workers"], 2.0);
}

#[test]
fn bad_cell_fails_whole_campaign_with_lowest_index_error() {
    // drift component targets a device the 2-device platform lacks:
    // every cell is invalid; the reported error must be cell 0's
    // (serial-equivalent) at any worker count.
    let spec = CampaignSpec::from_json_str(
        r#"{
            "base": {"campaign_workers": 4,
                     "optimizer": {"pop_size": 8, "generations": 2}},
            "grid": {"models": ["synthetic-L6"],
                     "fault_rates": [0.1, 0.2],
                     "drifts": [{"name": "bad",
                                 "components": [{"kind": "step", "device": 9,
                                                 "at_s": 1.0, "factor": 2.0}]}]}
        }"#,
    )
    .unwrap();
    let err = run_campaign(&spec, |_, _, _| {}).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("device 9"), "{msg}");
}
