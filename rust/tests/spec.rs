//! Declarative-API tests (ISSUE 2): spec JSON round-trip, strict
//! unknown-key rejection, the documented precedence chain
//! (CLI > env > file > defaults), a golden check that the default
//! `ExperimentSpec` reproduces the legacy hardcoded platform bit-for-bit
//! (cost tables, fault profiles, and the offline Pareto front), and a
//! 3-model × 2-scenario campaign running end-to-end through the batched
//! evaluation engine.

use afarepart::bench::suite::{front_fingerprint, synthetic_manifest, synthetic_sensitivity};
use afarepart::cli::Args;
use afarepart::coordinator::offline::optimize_partitions;
use afarepart::faults::{DeviceFaultProfile, DriftComponent, FaultScenario};
use afarepart::hw::Platform;
use afarepart::nsga2::Nsga2Config;
use afarepart::partition::{DaccMode, PartitionEvaluator};
use afarepart::spec::campaign::run_campaign;
use afarepart::spec::{CampaignSpec, ExperimentSpec, SelectionPolicy};
use afarepart::util::json;

fn args(raw: &[&str]) -> Args {
    let raw: Vec<String> = raw.iter().map(|s| s.to_string()).collect();
    Args::parse(&raw, &["surrogate", "link-cost", "verbose", "help"])
}

// ---------------------------------------------------------------- round-trip

/// parse → serialize → parse must be the identity, and the serialized
/// text must be stable across cycles.
#[test]
fn spec_json_round_trip_identity() {
    // a spec that exercises every section with non-default values
    let text = r#"{
        "model": "resnet18",
        "eval_limit": 128,
        "surrogate": true,
        "eval_threads": 4,
        "seed": 99,
        "platform": {
            "devices": [
                {"kind": "eyeriss", "w_mult": 0.8, "a_mult": 0.9},
                {"kind": "simba"},
                {"kind": "cpu", "name": "host0"}
            ],
            "link": {"bandwidth_gbps": 4.0}
        },
        "fault_env": {
            "fault_rate": 0.3,
            "scenario": "weight-only",
            "drift": [
                {"kind": "step", "device": 0, "at_s": 30.0, "factor": 2.0},
                {"kind": "sinusoid", "device": 0, "period_s": 8.0, "amp": 0.25},
                {"kind": "decay", "device": 1, "factor": 3.0, "tau_s": 10.0}
            ]
        },
        "optimizer": {"pop_size": 24, "generations": 12},
        "selection": {"policy": "knee"},
        "online": {"ticks": 60, "reopt_pop": 8, "reopt_seed": 3, "lookahead": 2}
    }"#;
    let spec = ExperimentSpec::from_json_str(text).unwrap();
    assert_eq!(spec.model, "resnet18");
    assert_eq!(spec.platform.num_devices(), 3);
    assert_eq!(spec.fault_env.drift.len(), 3);
    assert_eq!(spec.selection.policy, SelectionPolicy::Knee);
    assert_eq!(spec.online.ticks, 60);
    assert_eq!(spec.online.reopt_seed, 3);

    let serialized = spec.to_json_string();
    let reparsed = ExperimentSpec::from_json_str(&serialized).unwrap();
    assert_eq!(reparsed, spec, "parse → serialize → parse must be identity");
    assert_eq!(reparsed.to_json_string(), serialized, "serialized form must be stable");
}

#[test]
fn default_spec_round_trips() {
    let spec = ExperimentSpec::default();
    let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(back, spec);
}

// ------------------------------------------------------- unknown-key policy

#[test]
fn unknown_keys_rejected_at_every_level() {
    for (bad, needle) in [
        (r#"{"modle": "alexnet"}"#, "modle"),
        (r#"{"platform": {"device": []}}"#, "device"),
        (r#"{"platform": {"devices": [{"kind": "eyeriss", "wmult": 1.0}, {"kind": "simba"}]}}"#, "wmult"),
        (r#"{"fault_env": {"rate": 0.2}}"#, "rate"),
        (r#"{"fault_env": {"drift": [{"kind": "step", "device": 0, "at_s": 1.0, "factor": 2.0, "amp": 0.1}]}}"#, "amp"),
        (r#"{"optimizer": {"popsize": 10}}"#, "popsize"),
        (r#"{"selection": {"latency_budget": 2.0}}"#, "latency_budget"),
        (r#"{"online": {"thetaa": 0.1}}"#, "thetaa"),
    ] {
        let err = ExperimentSpec::from_json_str(bad)
            .err()
            .unwrap_or_else(|| panic!("accepted bad spec: {bad}"));
        assert!(
            format!("{err:#}").contains(needle),
            "error for {bad} should name {needle:?}: {err:#}"
        );
    }
}

#[test]
fn type_errors_rejected() {
    assert!(ExperimentSpec::from_json_str(r#"{"eval_limit": "many"}"#).is_err());
    assert!(ExperimentSpec::from_json_str(r#"{"eval_limit": 2.5}"#).is_err());
    assert!(ExperimentSpec::from_json_str(r#"{"eval_limit": 1e30}"#).is_err());
    assert!(ExperimentSpec::from_json_str(r#"{"surrogate": 1}"#).is_err());
    assert!(ExperimentSpec::from_json_str(r#"{"fault_env": {"scenario": "bogus"}}"#).is_err());
    assert!(ExperimentSpec::from_json_str(r#"{"selection": {"policy": "best"}}"#).is_err());
}

// ------------------------------------------------------------- precedence

/// The regression the redesign fixes: main.rs used to run apply_args()
/// *before* apply_env(), so AFARE_* env vars silently overrode explicit
/// CLI flags, contradicting the documented CLI > env > file > defaults.
#[test]
fn precedence_cli_beats_env_beats_file_beats_defaults() {
    let dir = std::env::temp_dir().join(format!("afare_spec_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("layers.json");
    std::fs::write(
        &path,
        r#"{"eval_limit": 32, "optimizer": {"pop_size": 40, "generations": 20}}"#,
    )
    .unwrap();

    let a = args(&["offline", "--spec", path.to_str().unwrap(), "--pop", "10"]);
    let env = |k: &str| match k {
        "AFARE_POP" => Some("99".to_string()),
        "AFARE_EVAL_LIMIT" => Some("64".to_string()),
        _ => None,
    };
    let spec = ExperimentSpec::resolve_with(&a, env).unwrap();
    // CLI --pop beats AFARE_POP beats the file's 40
    assert_eq!(spec.optimizer.pop_size, 10, "CLI must beat env and file");
    // env beats the file
    assert_eq!(spec.eval_limit, 64, "env must beat file");
    // file beats defaults where neither CLI nor env speaks
    assert_eq!(spec.optimizer.generations, 20, "file must beat defaults");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ticks_and_online_settings_fold_into_spec() {
    // the stray --ticks arg and the reopt budget/seed are spec data now
    let a = args(&["online", "--ticks", "33", "--theta", "0.02", "--lookahead", "3"]);
    let spec = ExperimentSpec::resolve_with(&a, |_| None).unwrap();
    assert_eq!(spec.online.ticks, 33);
    assert_eq!(spec.online.theta, 0.02);
    assert_eq!(spec.online.lookahead, 3);
    let cfg = spec.online.to_online_config(8);
    assert_eq!(cfg.ticks, 33);
    assert_eq!(cfg.lookahead, 3);
    // defaults preserved for everything not overridden
    assert_eq!(cfg.reopt.pop_size, 16);
    assert_eq!(cfg.reopt.generations, 6);
    assert_eq!(cfg.reopt.seed, Nsga2Config::default().seed);
}

// ------------------------------------------------------------------ golden

/// The default spec's platform must reproduce the legacy
/// `default_two_device()` latency/energy tables bit-for-bit.
#[test]
fn golden_default_platform_tables_bitwise_equal() {
    let (spec_platform, spec_profiles) = ExperimentSpec::default().platform.build();
    let legacy_platform = Platform::default_two_device();
    let legacy_profiles = DeviceFaultProfile::default_two_device();

    let units = synthetic_manifest(12).units;
    let lat_spec = spec_platform.latency_table(&units);
    let lat_legacy = legacy_platform.latency_table(&units);
    let en_spec = spec_platform.energy_table(&units);
    let en_legacy = legacy_platform.energy_table(&units);
    for l in 0..units.len() {
        for d in 0..2 {
            assert_eq!(
                lat_spec[l][d].to_bits(),
                lat_legacy[l][d].to_bits(),
                "latency[{l}][{d}] differs"
            );
            assert_eq!(
                en_spec[l][d].to_bits(),
                en_legacy[l][d].to_bits(),
                "energy[{l}][{d}] differs"
            );
        }
    }
    assert_eq!(spec_profiles.len(), legacy_profiles.len());
    for (s, l) in spec_profiles.iter().zip(&legacy_profiles) {
        assert_eq!(s.device, l.device);
        assert_eq!(s.w_mult.to_bits(), l.w_mult.to_bits());
        assert_eq!(s.a_mult.to_bits(), l.a_mult.to_bits());
    }
    // link parameters too
    assert_eq!(spec_platform.link.bandwidth_gbps, legacy_platform.link.bandwidth_gbps);
    assert_eq!(spec_platform.link.setup_us, legacy_platform.link.setup_us);
    assert_eq!(spec_platform.link.e_pj_byte, legacy_platform.link.e_pj_byte);
}

/// The seed offline Pareto front must be bitwise identical whether the
/// platform comes from the default spec or the legacy constructors.
#[test]
fn golden_default_spec_reproduces_offline_front() {
    let manifest = synthetic_manifest(10);
    let table = synthetic_sensitivity(10);
    let nsga2 = Nsga2Config { pop_size: 24, generations: 10, ..Default::default() };

    let run = |platform: &Platform, profiles: &[DeviceFaultProfile]| {
        let base = 0.2f32;
        let dev_w: Vec<f32> = profiles.iter().map(|p| base * p.w_mult).collect();
        let dev_a: Vec<f32> = profiles.iter().map(|p| base * p.a_mult).collect();
        let mut ev = PartitionEvaluator::new(
            &manifest,
            platform,
            dev_w,
            dev_a,
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {})
    };

    let (spec_platform, spec_profiles) = ExperimentSpec::default().platform.build();
    let front_spec = run(&spec_platform, &spec_profiles);
    let legacy_platform = Platform::default_two_device();
    let legacy_profiles = DeviceFaultProfile::default_two_device();
    let front_legacy = run(&legacy_platform, &legacy_profiles);

    assert_eq!(
        front_fingerprint(&front_spec),
        front_fingerprint(&front_legacy),
        "default spec must reproduce the legacy offline Pareto front bitwise"
    );
}

/// The default spec's drift stack (demo step attack at t = 30 s) must
/// not alter the offline (t = 0) environment.
#[test]
fn golden_default_drift_is_invisible_offline() {
    let spec = ExperimentSpec::default();
    let (_, profiles) = spec.platform.build();
    let env = spec.fault_env.build(profiles.clone()).unwrap();
    let constant = afarepart::faults::FaultEnv::constant(spec.fault_env.fault_rate, profiles);
    assert_eq!(env.dev_w_rates(0.0), constant.dev_w_rates(0.0));
    assert_eq!(env.dev_a_rates(0.0), constant.dev_a_rates(0.0));
    // ... and does fire later (it is the online demo attack)
    assert!(env.dev_w_rates(31.0)[0] > constant.dev_w_rates(31.0)[0]);
}

// ---------------------------------------------------------------- campaign

/// 3 models × 2 scenarios end-to-end through the batched evaluation
/// engine, with a consolidated JSON report.
#[test]
fn campaign_3x2_runs_through_batched_engine() {
    let cspec = CampaignSpec::from_json_str(
        r#"{
            "base": {
                "eval_threads": 2,
                "optimizer": {"pop_size": 12, "generations": 3}
            },
            "grid": {
                "models": ["synthetic-L6", "synthetic-L8", "synthetic-L10"],
                "scenarios": ["w", "iw"]
            }
        }"#,
    )
    .unwrap();
    assert_eq!(cspec.num_cells(), 6);

    let mut progressed = 0;
    let report = run_campaign(&cspec, |i, total, _| {
        assert_eq!(total, 6);
        assert!(i < 6);
        progressed += 1;
    })
    .unwrap();
    assert_eq!(progressed, 6);
    assert_eq!(report.cells.len(), 6);
    assert_eq!(report.engine_threads, 2);

    // every cell ran the full NSGA-II budget through the batched engine
    let per_cell_evals = 12 * (3 + 1);
    assert_eq!(report.total_evaluations, 6 * per_cell_evals);
    // caching + in-batch dedup means no more backend evaluations than
    // submissions (on the small L6 grid, strictly fewer in practice)
    assert!(report.total_backend_evals > 0);
    assert!(report.total_backend_evals <= report.total_evaluations);

    for cell in &report.cells {
        assert!(!cell.offline.front.is_empty());
        assert!(!cell.offline.deployed.mapping.is_empty());
        assert_eq!(cell.offline.evaluations, per_cell_evals);
    }

    // the consolidated report is valid JSON and carries every cell
    let doc = report.to_json();
    let text = json::to_string(&doc);
    let parsed = json::parse(&text).unwrap();
    assert_eq!(parsed.get("num_cells").unwrap().as_usize(), Some(6));
    assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 6);
}

/// Campaigns are deterministic: the same spec yields the same deployed
/// mappings and objectives.
#[test]
fn campaign_is_deterministic() {
    let text = r#"{
        "base": {"eval_threads": 3, "optimizer": {"pop_size": 8, "generations": 2}, "seed": 21},
        "grid": {
            "models": ["synthetic-L6"],
            "fault_rates": [0.1, 0.4],
            "drifts": [
                {"name": "ambient"},
                {"name": "attacked", "eval_at_s": 60.0,
                 "components": [{"kind": "step", "device": 0, "at_s": 30.0, "factor": 2.0}]}
            ]
        }
    }"#;
    let cspec = CampaignSpec::from_json_str(text).unwrap();
    let r1 = run_campaign(&cspec, |_, _, _| {}).unwrap();
    let r2 = run_campaign(&cspec, |_, _, _| {}).unwrap();
    assert_eq!(r1.cells.len(), 4);
    for (a, b) in r1.cells.iter().zip(&r2.cells) {
        assert_eq!(a.offline.deployed.mapping, b.offline.deployed.mapping);
        assert_eq!(a.offline.deployed.dacc.to_bits(), b.offline.deployed.dacc.to_bits());
        assert_eq!(a.offline.front.len(), b.offline.front.len());
    }
    // the attacked drift cell at its probe time sees a harsher dev0 and
    // must not be *less* robust in its deployment than ambient
    let ambient = &r1.cells[0];
    let attacked = &r1.cells[1];
    assert_eq!(ambient.drift, "ambient");
    assert_eq!(attacked.drift, "attacked");
}

/// Builder → spec → campaign composition: a builder-produced spec can
/// seed a campaign base.
#[test]
fn builder_spec_feeds_campaign() {
    let spec = afarepart::experiment::Experiment::builder()
        .fault_rate(0.25)
        .scenario(FaultScenario::WeightOnly)
        .eval_threads(2)
        .pop(8)
        .gens(2)
        .drift(vec![DriftComponent::sinusoid(0, 8.0, 0.5)])
        .into_spec();
    assert_eq!(spec.fault_env.drift.len(), 1);
    let mut cspec = CampaignSpec::singleton(spec);
    // the base drift stack becomes the default drift axis, not ambient
    assert_eq!(cspec.drifts.len(), 1);
    assert_eq!(cspec.drifts[0].name, "base");
    assert_eq!(cspec.drifts[0].components.len(), 1);
    cspec.models = vec!["synthetic-L6".into()];
    let report = run_campaign(&cspec, |_, _, _| {}).unwrap();
    assert_eq!(report.cells.len(), 1);
    assert_eq!(report.cells[0].offline.scenario, "weight-only");
    assert_eq!(report.cells[0].drift, "base");
}
