//! Resilience tests for the chaos-injection layer, the supervised
//! inference server, and graceful degradation (ISSUE 6): every injected
//! failure class must be survived (or surfaced as its typed terminal
//! error) with deterministic counters, chaos-enabled timelines must be
//! bitwise identical across repeats and pipeline depths, and terminal
//! failures must degrade to the safe mapping and recover.
//!
//! Everything runs on the artifact-free synthetic backend, so the suite
//! needs no PJRT artifacts and no wall-clock luck: predictions are a
//! pure function of (images, rates, key).

use std::time::Duration;

use afarepart::bench::suite::{synthetic_eval_set, synthetic_manifest, synthetic_sensitivity};
use afarepart::coordinator::{
    BackendSpec, InferError, InferJob, InferenceServer, OnlineConfig, OnlineOutcome,
    OnlineRunner, ServerStats, SupervisorPolicy, TimelinePoint,
};
use afarepart::faults::{
    ChaosComponent, ChaosEngine, ChaosPlan, DeviceFaultProfile, FaultEnv, FaultScenario,
    RateVectors,
};
use afarepart::hw::Platform;
use afarepart::obs::Telemetry;
use afarepart::partition::{DaccMode, Mapping, PartitionEvaluator};

const UNITS: usize = 6;
const DIMS: (usize, usize, usize) = (4, 4, 3);
const BATCH: usize = 8;

/// Fast supervision policy for the server-level tests: no backoff sleep.
fn fast_policy() -> SupervisorPolicy {
    SupervisorPolicy { backoff_ms: 0, ..SupervisorPolicy::default() }
}

fn synth_server(policy: SupervisorPolicy) -> InferenceServer {
    InferenceServer::spawn_with(
        BackendSpec::Synthetic { manifest: synthetic_manifest(UNITS), exec_cost: Duration::ZERO },
        DIMS,
        policy,
    )
    .expect("synthetic server spawns without artifacts")
}

/// One batch of synthetic images plus the predictions a fault-free
/// worker must return for them (the ground-truth labels).
fn one_batch() -> (Vec<f32>, Vec<usize>) {
    let eval = synthetic_eval_set(BATCH, DIMS.0, DIMS.1, DIMS.2, 10, 42);
    let expect = eval.labels.iter().map(|&l| l as usize).collect();
    (eval.images, expect)
}

#[test]
fn worker_crash_respawns_and_serves_identical_predictions() {
    let server = synth_server(fast_policy());
    let (images, expect) = one_batch();
    let zeros = RateVectors::zeros(UNITS);

    let plan = ChaosPlan { crash: true, ..Default::default() };
    let crashed = server
        .infer_blocking_with(images.clone(), BATCH, zeros.clone(), [3, 7], plan)
        .expect("crash is absorbed by respawn");
    let clean = server.infer_blocking(images, BATCH, zeros, [3, 7]).unwrap();
    assert_eq!(crashed.preds, clean.preds, "respawned worker must compute the same batch");
    assert_eq!(crashed.preds, expect);

    let s = server.stats();
    assert_eq!(s.crashes, 1);
    assert_eq!(s.respawns, 1);
    assert_eq!((s.retries, s.transient_errors, s.timeouts), (0, 0, 0));
    server.shutdown().unwrap();
}

#[test]
fn transient_burst_is_retried_to_success() {
    let server = synth_server(fast_policy());
    let (images, expect) = one_batch();

    let plan = ChaosPlan { transient_failures: 2, ..Default::default() };
    let reply = server
        .infer_blocking_with(images, BATCH, RateVectors::zeros(UNITS), [1, 2], plan)
        .expect("burst of 2 fits in the retry budget of 3");
    assert_eq!(reply.preds, expect);

    let s = server.stats();
    assert_eq!(s.transient_errors, 2);
    assert_eq!(s.retries, 2);
    assert_eq!((s.respawns, s.crashes, s.timeouts), (0, 0, 0));
    server.shutdown().unwrap();
}

#[test]
fn transient_exhaustion_is_a_typed_terminal_error() {
    let server = synth_server(fast_policy());
    let (images, _) = one_batch();

    let plan = ChaosPlan { transient_failures: 10, ..Default::default() };
    let ticket = server
        .submit(InferJob {
            images,
            n_valid: BATCH,
            rates: RateVectors::zeros(UNITS),
            key: [1, 2],
            plan,
        })
        .unwrap();
    match server.wait(ticket) {
        Err(InferError::Exhausted { attempts, .. }) => assert_eq!(attempts, 4),
        other => panic!("expected Exhausted after max_retries, got {other:?}"),
    }
    let s = server.stats();
    assert_eq!(s.transient_errors, 4); // initial attempt + 3 retries
    assert_eq!(s.retries, 3);
    assert_eq!(s.respawns, 0);
    server.shutdown().unwrap();
}

#[test]
fn dropped_reply_times_out_then_respawn_recovers() {
    let server = synth_server(SupervisorPolicy {
        recv_timeout_ms: 50,
        backoff_ms: 0,
        ..SupervisorPolicy::default()
    });
    let (images, expect) = one_batch();

    let plan = ChaosPlan { drop_replies: 1, ..Default::default() };
    let reply = server
        .infer_blocking_with(images, BATCH, RateVectors::zeros(UNITS), [5, 9], plan)
        .expect("one lost reply is retried after the recv timeout");
    assert_eq!(reply.preds, expect);

    let s = server.stats();
    assert_eq!(s.timeouts, 1);
    assert_eq!(s.respawns, 1);
    assert_eq!(s.crashes, 0, "a lost reply is a timeout, not a crash");
    assert_eq!(s.retries, 1);
    server.shutdown().unwrap();
}

#[test]
fn persistent_reply_loss_is_a_typed_timeout() {
    let server = synth_server(SupervisorPolicy {
        recv_timeout_ms: 25,
        max_retries: 2,
        backoff_ms: 0,
        ..SupervisorPolicy::default()
    });
    let (images, _) = one_batch();

    let plan = ChaosPlan { drop_replies: 10, ..Default::default() };
    let ticket = server
        .submit(InferJob {
            images,
            n_valid: BATCH,
            rates: RateVectors::zeros(UNITS),
            key: [5, 9],
            plan,
        })
        .unwrap();
    match server.wait(ticket) {
        Err(InferError::TimedOut { waited_ms, attempts }) => {
            assert_eq!(waited_ms, 25);
            assert_eq!(attempts, 3);
        }
        other => panic!("expected TimedOut, got {other:?}"),
    }
    let s = server.stats();
    assert_eq!(s.timeouts, 3);
    assert_eq!(s.retries, 2);
    assert_eq!(s.respawns, 2);
    server.shutdown().unwrap();
}

#[test]
fn link_delay_inflates_reported_latency() {
    let server = synth_server(fast_policy());
    let (images, expect) = one_batch();

    let plan = ChaosPlan { delay_ms: 25.0, ..Default::default() };
    let reply = server
        .infer_blocking_with(images, BATCH, RateVectors::zeros(UNITS), [2, 4], plan)
        .unwrap();
    assert!(reply.exec_ms >= 25.0, "delay must feed exec_ms (got {})", reply.exec_ms);
    assert_eq!(reply.preds, expect, "delay must not change predictions");
    server.shutdown().unwrap();
}

#[test]
fn reply_corruption_is_deterministic_and_always_wrong() {
    let server = synth_server(fast_policy());
    let (images, _) = one_batch();
    let zeros = RateVectors::zeros(UNITS);

    let plan = ChaosPlan { corrupt: true, ..Default::default() };
    let a = server
        .infer_blocking_with(images.clone(), BATCH, zeros.clone(), [8, 8], plan.clone())
        .unwrap();
    let b = server
        .infer_blocking_with(images.clone(), BATCH, zeros.clone(), [8, 8], plan)
        .unwrap();
    let clean = server.infer_blocking(images, BATCH, zeros, [8, 8]).unwrap();
    assert_eq!(a.preds, b.preds, "corruption is keyed, not time-dependent");
    assert_eq!(a.preds.len(), clean.preds.len());
    for (c, k) in a.preds.iter().zip(&clean.preds) {
        assert_ne!(c, k, "every corrupted prediction lands on a different class");
    }
    server.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// Online-runner level: full serving loops under chaos.
// ---------------------------------------------------------------------------

/// Run a synthetic online serving loop and return (outcome, final server
/// stats). The world mirrors the `synthetic-L<n>` campaign cells.
fn run_online(
    chaos: ChaosEngine,
    safe: Option<Mapping>,
    cfg: OnlineConfig,
    initial: Mapping,
) -> (OnlineOutcome, ServerStats) {
    let manifest = synthetic_manifest(UNITS);
    let table = synthetic_sensitivity(UNITS);
    let platform = Platform::default_two_device();
    let env = FaultEnv {
        base_rate: 0.08,
        profiles: DeviceFaultProfile::default_two_device(),
        drift: Vec::new(),
    };
    let eval = synthetic_eval_set(BATCH * 4, DIMS.0, DIMS.1, DIMS.2, 10, 42);
    let server = InferenceServer::spawn_with(
        BackendSpec::Synthetic { manifest: manifest.clone(), exec_cost: Duration::ZERO },
        DIMS,
        cfg.supervisor_policy(),
    )
    .unwrap();
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        FaultScenario::InputWeight,
        table.clean_acc,
        false,
        DaccMode::SyntheticExact { table: &table, cost: Duration::ZERO },
    );
    let mut runner = OnlineRunner {
        cfg,
        server: &server,
        evaluator: &mut ev,
        clean_acc: table.clean_acc,
        chaos,
        safe_mapping: safe,
        telemetry: Telemetry::disabled(),
    };
    let out = runner.run(&eval, &env, initial, |_| {}).unwrap();
    let stats = server.stats();
    server.shutdown().unwrap();
    (out, stats)
}

/// Bitwise timeline fingerprint: the comparison key of every
/// determinism assertion below.
fn fingerprint(tl: &[TimelinePoint]) -> Vec<(usize, u64, u64, Vec<usize>, bool, bool)> {
    tl.iter()
        .map(|p| {
            (
                p.tick,
                p.batch_accuracy.to_bits(),
                p.rolling_accuracy.to_bits(),
                p.mapping.0.clone(),
                p.reconfigured,
                p.degraded,
            )
        })
        .collect()
}

/// Crash/transient/delay/corrupt mix (no drops: their recv timeouts are
/// real wall-clock waits and belong to the server-level tests above).
/// The windowed rate-1.0 crash guarantees at least one worker death
/// regardless of what the probabilistic streams roll.
fn busy_chaos() -> ChaosEngine {
    ChaosEngine::new(
        99,
        vec![
            ChaosComponent::crash(1.0).window(4, 5),
            ChaosComponent::crash(0.15),
            ChaosComponent::transient(0.25, 1),
            ChaosComponent::delay(0.3, 5.0),
            ChaosComponent::corrupt(0.2),
        ],
    )
}

fn chaos_cfg(lookahead: usize) -> OnlineConfig {
    OnlineConfig { ticks: 30, lookahead, backoff_ms: 0, health_cooldown: 3, ..Default::default() }
}

#[test]
fn chaos_timeline_is_deterministic_and_lookahead_invariant() {
    let initial = Mapping::all_on(0, UNITS);
    let safe = Some(Mapping::all_on(1, UNITS));
    let (a, stats_a) = run_online(busy_chaos(), safe.clone(), chaos_cfg(1), initial.clone());
    let (b, _) = run_online(busy_chaos(), safe.clone(), chaos_cfg(3), initial.clone());
    let (c, stats_c) = run_online(busy_chaos(), safe, chaos_cfg(1), initial);

    assert!(
        a.timeline.iter().any(|p| p.batch_accuracy < 1.0),
        "the mix must actually perturb some batches"
    );
    assert_eq!(
        fingerprint(&a.timeline),
        fingerprint(&b.timeline),
        "timeline must be bitwise identical at any pipeline depth"
    );
    assert_eq!(
        fingerprint(&a.timeline),
        fingerprint(&c.timeline),
        "timeline must be bitwise identical across repeats"
    );
    assert_eq!(stats_a, stats_c, "supervision counters must repeat exactly");
    assert!(stats_a.crashes > 0, "the windowed rate-1.0 crash must fire");
    assert_eq!(stats_a.respawns, stats_a.crashes, "no timeouts in this mix");
    assert_eq!(a.metrics.worker_respawns, stats_a.respawns);
    assert_eq!(a.metrics.transient_errors, stats_a.transient_errors);
}

#[test]
fn disabled_chaos_leaves_serving_untouched_at_any_lookahead() {
    let initial = Mapping::all_on(0, UNITS);
    let (a, stats_a) = run_online(ChaosEngine::disabled(), None, chaos_cfg(1), initial.clone());
    let (b, stats_b) = run_online(ChaosEngine::disabled(), None, chaos_cfg(3), initial);

    assert_eq!(fingerprint(&a.timeline), fingerprint(&b.timeline));
    for stats in [stats_a, stats_b] {
        assert_eq!(stats, ServerStats::default(), "chaos off => no supervision events");
    }
    for out in [&a, &b] {
        assert!(out.timeline.iter().all(|p| !p.degraded));
        assert_eq!(out.metrics.degradations, 0);
        assert_eq!(out.metrics.degraded_ticks, 0);
        assert!(out.metrics.degraded_intervals.is_empty());
        assert_eq!(out.metrics.worker_respawns, 0);
        assert_eq!(out.metrics.retries, 0);
    }
}

#[test]
fn terminal_failure_degrades_to_safe_mapping_and_recovers() {
    // tick 5 fires a transient burst far past the retry budget of 1 —
    // a guaranteed terminal Exhausted — then the environment is quiet.
    let chaos = ChaosEngine::new(7, vec![ChaosComponent::transient(1.0, 9).window(5, 6)]);
    let cfg = OnlineConfig {
        ticks: 12,
        lookahead: 2,
        theta: 10.0, // never repartition: isolate the degradation path
        max_retries: 1,
        backoff_ms: 0,
        health_cooldown: 3,
        ..Default::default()
    };
    let initial = Mapping::all_on(0, UNITS);
    let safe = Mapping::all_on(1, UNITS);
    let (out, _) = run_online(chaos, Some(safe.clone()), cfg, initial.clone());

    // entry: the failed tick serves nothing, switches to the safe mapping
    assert!(out.timeline[5].degraded);
    assert_eq!(out.timeline[5].batch_accuracy, 0.0);
    assert_eq!(out.timeline[5].mapping, safe);
    // ticks 6..9 serve on the safe mapping under the health-probe cooldown
    for t in 6..9 {
        assert!(out.timeline[t].degraded, "tick {t} still degraded");
        assert_eq!(out.timeline[t].mapping, safe);
    }
    // re-admission at tick 9 = 5 + 1 + health_cooldown restores P*
    assert!(!out.timeline[9].degraded);
    assert_eq!(out.timeline[9].mapping, initial);
    assert!(out.timeline[10..].iter().all(|p| !p.degraded));

    assert_eq!(out.metrics.degradations, 1);
    assert_eq!(out.metrics.degraded_ticks, 4);
    assert_eq!(out.metrics.degraded_intervals, vec![(5, 9)]);
    assert_eq!(out.metrics.transient_errors, 2); // initial attempt + 1 retry
    assert_eq!(out.metrics.retries, 1);
    assert_eq!(out.final_mapping, initial);
}

#[test]
fn terminal_failure_without_safe_mapping_is_a_run_error() {
    let chaos = ChaosEngine::new(7, vec![ChaosComponent::transient(1.0, 9).window(2, 3)]);
    let manifest = synthetic_manifest(UNITS);
    let table = synthetic_sensitivity(UNITS);
    let platform = Platform::default_two_device();
    let env = FaultEnv {
        base_rate: 0.08,
        profiles: DeviceFaultProfile::default_two_device(),
        drift: Vec::new(),
    };
    let eval = synthetic_eval_set(BATCH * 4, DIMS.0, DIMS.1, DIMS.2, 10, 42);
    let cfg = OnlineConfig {
        ticks: 8,
        max_retries: 1,
        backoff_ms: 0,
        ..Default::default()
    };
    let server = InferenceServer::spawn_with(
        BackendSpec::Synthetic { manifest: manifest.clone(), exec_cost: Duration::ZERO },
        DIMS,
        cfg.supervisor_policy(),
    )
    .unwrap();
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        FaultScenario::InputWeight,
        table.clean_acc,
        false,
        DaccMode::SyntheticExact { table: &table, cost: Duration::ZERO },
    );
    let mut runner = OnlineRunner {
        cfg,
        server: &server,
        evaluator: &mut ev,
        clean_acc: table.clean_acc,
        chaos,
        safe_mapping: None,
        telemetry: Telemetry::disabled(),
    };
    let err = runner
        .run(&eval, &env, Mapping::all_on(0, UNITS), |_| {})
        .expect_err("no safe mapping configured: terminal failures abort the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("tick 2"), "error must carry the failing tick: {msg}");
    assert!(msg.contains("no safe mapping"), "error must explain the policy: {msg}");
    server.shutdown().unwrap();
}
