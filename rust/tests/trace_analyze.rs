//! Acceptance tests for the fault-attribution ledger + offline analyzer
//! (ISSUE 10): a seeded 120-tick chaos run's `trace analyze` blame
//! counts must reconcile *exactly* with the `ServerStats` supervision
//! counters and the run's `Metrics` degradation records, and the
//! analyzer report must be bitwise identical across repeats and across
//! `eval_threads` / `campaign_workers` ∈ {1, 2, 4}.
//!
//! Everything runs on the artifact-free synthetic backend (the same
//! harness as `rust/tests/obs.rs`), so no PJRT artifacts are needed.

use std::path::{Path, PathBuf};
use std::time::Duration;

use afarepart::bench::suite::{synthetic_eval_set, synthetic_manifest, synthetic_sensitivity};
use afarepart::coordinator::{
    BackendSpec, InferenceServer, OnlineConfig, OnlineOutcome, OnlineRunner, ServerStats,
};
use afarepart::faults::{
    ChaosComponent, ChaosEngine, DeviceFaultProfile, FaultEnv, FaultScenario,
};
use afarepart::hw::Platform;
use afarepart::nsga2::Nsga2Config;
use afarepart::obs::analyze::BlameCounts;
use afarepart::obs::{analyze_file, Telemetry, TraceAnalysis, TRACE_SCHEMA_VERSION};
use afarepart::partition::{DaccMode, Mapping, PartitionEvaluator};
use afarepart::spec::campaign::{run_campaign_with, CampaignOptions};
use afarepart::spec::CampaignSpec;
use afarepart::util::json;

const UNITS: usize = 6;
const DIMS: (usize, usize, usize) = (4, 4, 3);
const BATCH: usize = 8;
const TICKS: usize = 120;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("afare_analyze_it_{}_{name}.jsonl", std::process::id()));
    p
}

fn online_cfg() -> OnlineConfig {
    OnlineConfig {
        ticks: TICKS,
        window: 4,
        theta: 0.05,
        cooldown: 6,
        lookahead: 2,
        backoff_ms: 0,
        health_cooldown: 3,
        reopt: Nsga2Config { pop_size: 8, generations: 3, ..Default::default() },
        ..Default::default()
    }
}

/// A 120-tick chaos schedule exercising every ledger path: corruption
/// drives θ re-optimizations throughout, two windowed rate-1.0 crashes
/// guarantee crashed respawns, two windowed transient bursts (far past
/// the retry budget) guarantee `exhausted` terminals + degradation
/// episodes, a low-rate background transient scatters plain retries,
/// and the delay component feeds `injected_delay`. No drop component:
/// its recv timeouts would make wall time part of the schedule (the
/// dedicated timeout tests in `rust/tests/chaos.rs` cover that path).
fn chaos() -> ChaosEngine {
    ChaosEngine::new(
        99,
        vec![
            ChaosComponent::corrupt(0.5),
            ChaosComponent::crash(1.0).window(4, 5),
            ChaosComponent::crash(1.0).window(70, 71),
            ChaosComponent::transient(1.0, 9).window(14, 15),
            ChaosComponent::transient(1.0, 9).window(90, 91),
            ChaosComponent::transient(0.25, 2),
            ChaosComponent::delay(0.2, 2.0),
        ],
    )
}

/// Run the synthetic online pipeline with a trace at evaluation-engine
/// width `threads`; returns the outcome plus the server's supervision
/// counters (which `Metrics` only partially mirrors — `crashes` lives
/// on the server alone).
fn run_traced(threads: usize, path: &Path) -> (OnlineOutcome, ServerStats) {
    let telemetry = Telemetry::with_trace(path).expect("trace file opens");
    let manifest = synthetic_manifest(UNITS);
    let table = synthetic_sensitivity(UNITS);
    let platform = Platform::default_two_device();
    let env = FaultEnv {
        base_rate: 0.08,
        profiles: DeviceFaultProfile::default_two_device(),
        drift: Vec::new(),
    };
    let eval = synthetic_eval_set(BATCH * 4, DIMS.0, DIMS.1, DIMS.2, 10, 42);
    let cfg = online_cfg();
    let server = InferenceServer::spawn_with(
        BackendSpec::Synthetic { manifest: manifest.clone(), exec_cost: Duration::ZERO },
        DIMS,
        cfg.supervisor_policy(),
    )
    .unwrap();
    server.set_telemetry(telemetry.clone());
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        FaultScenario::InputWeight,
        table.clean_acc,
        false,
        DaccMode::SyntheticExact { table: &table, cost: Duration::ZERO },
    )
    .with_parallelism(threads)
    .with_telemetry(telemetry.clone());
    let mut runner = OnlineRunner {
        cfg,
        server: &server,
        evaluator: &mut ev,
        clean_acc: table.clean_acc,
        chaos: chaos(),
        safe_mapping: Some(Mapping::all_on(1, UNITS)),
        telemetry,
    };
    let out = runner.run(&eval, &env, Mapping::all_on(0, UNITS), |_| {}).unwrap();
    let stats = server.stats();
    server.shutdown().unwrap();
    (out, stats)
}

fn report_fingerprint(a: &TraceAnalysis) -> String {
    json::to_string(&a.to_json())
}

/// `Metrics` merges contiguous degraded intervals (`end == next start`);
/// the trace keeps one `degrade_exit` per episode. Apply the same merge
/// to the analyzer's intervals before comparing.
fn merged(intervals: &[(usize, usize)]) -> Vec<(usize, usize)> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    for &(lo, hi) in intervals {
        match out.last_mut() {
            Some(last) if last.1 == lo => last.1 = hi,
            _ => out.push((lo, hi)),
        }
    }
    out
}

/// ISSUE acceptance: every analyzer blame counter reconciles exactly
/// with the supervision stats and degradation records of the run that
/// produced the trace.
#[test]
fn blame_counts_reconcile_with_server_stats_and_metrics() {
    let path = tmp("reconcile");
    let (out, stats) = run_traced(2, &path);
    let a = analyze_file(&path).unwrap();
    let m = &out.metrics;

    // the run must actually exercise every ledger path
    assert!(stats.crashes >= 2, "both crash windows must fire");
    assert!(stats.transient_errors > 0, "transient bursts must fire");
    assert!(m.degradations > 0, "exhausted bursts must degrade");
    assert!(m.reconfigurations > 0, "corruption must trigger θ");

    // the trace itself is clean and schema-current
    assert_eq!(a.parsed_events, a.total_lines);
    assert!(!a.truncated_tail);
    assert_eq!((a.malformed_lines, a.seq_gaps, a.newer_schema_lines), (0, 0, 0));
    let versions: Vec<u64> = a.schema_versions.keys().copied().collect();
    assert_eq!(versions, [TRACE_SCHEMA_VERSION]);
    assert!(a.unknown_kind_counts.is_empty(), "{:?}", a.unknown_kind_counts);

    // supervision events: one trace line per counter increment
    let kind = |k: &str| a.kind_counts.get(k).copied().unwrap_or(0);
    assert_eq!(kind("server_retry"), stats.retries);
    assert_eq!(kind("server_retry"), m.retries);
    assert_eq!(kind("server_respawn"), stats.respawns);
    assert_eq!(kind("server_respawn"), m.worker_respawns);
    assert_eq!(a.attribution.crashed_respawns, stats.crashes);

    // every transient error surfaced as a transient retry or an
    // exhausted terminal; every timeout as a timeout retry or terminal
    let attr = &a.attribution;
    let reason = |map: &std::collections::BTreeMap<String, usize>, k: &str| {
        map.get(k).copied().unwrap_or(0)
    };
    assert_eq!(
        reason(&attr.retry_reasons, "transient") + reason(&attr.terminal_reasons, "exhausted"),
        stats.transient_errors,
    );
    assert_eq!(stats.transient_errors, m.transient_errors);
    assert_eq!(
        reason(&attr.retry_reasons, "timeout") + reason(&attr.terminal_reasons, "timeout"),
        stats.timeouts,
    );
    assert_eq!(stats.timeouts, m.timeouts);

    // blame rolls up losslessly: per-class + unattributed == totals
    let sum = |f: fn(&BlameCounts) -> usize| {
        attr.blame_by_class.values().map(f).sum::<usize>() + f(&attr.unattributed)
    };
    assert_eq!(sum(|b| b.retries), stats.retries);
    assert_eq!(sum(|b| b.respawns), stats.respawns);
    assert_eq!(sum(|b| b.terminals), kind("server_terminal"));
    assert_eq!(sum(|b| b.degradations), m.degradations);
    // the injection pre-pass means no consumed fault id lacks its class
    assert!(!attr.blame_by_class.contains_key("unknown"), "{:?}", attr.blame_by_class);

    // degradation records: each terminal-induced transition is exactly
    // one enter-or-extend; each closed episode is one exit interval
    assert_eq!(attr.degrade_enters + attr.degrade_extends, m.degradations);
    assert_eq!(attr.degrade_exits, attr.intervals.len());
    let ours = merged(&attr.intervals);
    match attr.open_interval_start {
        None => assert_eq!(ours, m.degraded_intervals),
        Some(s) => {
            // the run ended degraded: Metrics closes the open episode at
            // the run boundary with no degrade_exit event
            let glued = !ours.is_empty() && ours.last().unwrap().1 == s;
            let (closed, last_start) = if glued {
                (&ours[..ours.len() - 1], ours.last().unwrap().0)
            } else {
                (&ours[..], s)
            };
            assert_eq!(m.degraded_intervals.len(), closed.len() + 1);
            assert_eq!(&m.degraded_intervals[..closed.len()], closed);
            let last = *m.degraded_intervals.last().unwrap();
            assert_eq!(last.0, last_start);
            assert!(last.1 > s && last.1 <= TICKS);
        }
    }

    // injections: both guaranteed classes present, crash windows = 2
    assert_eq!(attr.injected_by_class.get("crash").copied(), Some(2));
    assert!(attr.injected_by_class.get("transient").copied().unwrap_or(0) >= 2);
    assert!(attr.injected_by_class.get("corrupt").copied().unwrap_or(0) > 0);
    // chains carry terminal outcomes and degradation flags
    assert!(attr.chains.iter().any(|c| c.terminal.as_deref() == Some("exhausted")));
    assert!(attr.chains.iter().any(|c| c.degraded));
    assert!(attr.chains.iter().all(|c| c.class != "unknown"));

    // serving-loop rollup mirrors Metrics tick for tick
    assert_eq!(a.online.ticks, TICKS);
    assert_eq!(a.online.degraded_ticks, m.degraded_ticks);
    assert_eq!(a.online.reopt_triggers, m.reconfigurations);
    assert_eq!(a.online.reopt_evaluations, m.reopt_evaluations);
    assert!(a.online.reconfigurations <= a.online.reopt_triggers);
    assert_eq!(
        a.span_counts.get("online.reconfig").copied().unwrap_or(0),
        m.reconfigurations
    );

    // every θ re-optimization leaves one complete convergence curve
    assert_eq!(a.convergence.len(), m.reconfigurations);
    for run in &a.convergence {
        assert_eq!(run.generations, online_cfg().reopt.generations);
        assert_eq!(run.curve.len(), run.generations);
        assert!(run.final_hypervolume.is_finite());
    }

    assert!(a.cache.batch_calls > 0);
    assert!(!a.critical_path.is_empty());
    std::fs::remove_file(&path).ok();
}

/// ISSUE acceptance: the analyzer report (not just the trace) is
/// bitwise identical across repeats and across `eval_threads`.
#[test]
fn analyzer_report_is_bitwise_identical_across_eval_threads_and_repeats() {
    let paths: Vec<PathBuf> =
        ["e1", "e2", "e4", "e1_repeat"].iter().map(|n| tmp(n)).collect();
    run_traced(1, &paths[0]);
    run_traced(2, &paths[1]);
    run_traced(4, &paths[2]);
    run_traced(1, &paths[3]);
    let reports: Vec<String> = paths
        .iter()
        .map(|p| report_fingerprint(&analyze_file(p).unwrap()))
        .collect();
    for (p, r) in paths.iter().zip(&reports).skip(1) {
        assert_eq!(
            &reports[0],
            r,
            "DETERMINISM VIOLATION: analyzer report for {} differs",
            p.display()
        );
    }
    // and the report is non-trivial: the blame section actually rolled up
    assert!(reports[0].contains("\"blame_by_class\":{\""));
    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

/// Campaign traces (coordinator-side `campaign.cell` spans, strictly in
/// cell order) analyze to the same report at any `campaign_workers`.
#[test]
fn campaign_analyzer_report_is_identical_across_workers() {
    let mut reference: Option<String> = None;
    for workers in [1usize, 2, 4] {
        let mut spec = CampaignSpec::from_json_str(
            r#"{
                "base": {"eval_threads": 1,
                         "optimizer": {"pop_size": 8, "generations": 2}},
                "grid": {"models": ["synthetic-L6"],
                         "fault_rates": [0.1, 0.2, 0.4],
                         "scenarios": ["w", "iw"]}
            }"#,
        )
        .unwrap();
        spec.base.campaign_workers = workers;
        let path = tmp(&format!("campaign_w{workers}"));
        let telemetry = Telemetry::with_trace(&path).expect("trace file opens");
        let opts = CampaignOptions { telemetry, ..CampaignOptions::default() };
        run_campaign_with(&spec, &opts, |_, _, _| {}).unwrap();
        let a = analyze_file(&path).unwrap();
        assert_eq!(a.campaign.cells, 6, "at {workers} workers");
        assert_eq!(a.campaign.cells_by_model.get("synthetic-L6").copied(), Some(6));
        assert!(a.campaign.evaluations > 0);
        assert_eq!((a.malformed_lines, a.seq_gaps), (0, 0));
        let fp = report_fingerprint(&a);
        match &reference {
            None => reference = Some(fp),
            Some(r) => assert_eq!(
                r, &fp,
                "analyzer report at {workers} workers differs from campaign_workers = 1"
            ),
        }
        std::fs::remove_file(&path).ok();
    }
}
