//! PJRT end-to-end tests: load every compiled artifact and execute it.
//! These are the tests that prove the three-layer stack composes:
//! Pallas kernel (L1) → jax model (L2) → HLO text → rust PJRT (L3).
//!
//! Skipped politely when `make artifacts` hasn't run. One shared PJRT
//! client per test process; tests are combined to amortize compile time.

use std::path::Path;

use afarepart::config::ExperimentConfig;
use afarepart::coordinator::server::InferenceServer;
use afarepart::dataset::EvalSet;
use afarepart::experiment::Experiment;
use afarepart::faults::{FaultScenario, RateVectors};
use afarepart::model::Manifest;
use afarepart::runtime::{AccuracyEvaluator, ArtifactIndex, Runtime};

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/index.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    }
    ok
}

/// Everything about one model in a single test (compile once):
/// clean accuracy, fault degradation, determinism, per-layer effects.
fn exercise_model(model: &str, min_clean: f64) {
    let idx = ArtifactIndex::load(Path::new("artifacts")).unwrap();
    let manifest = Manifest::load(&idx.manifest_path(model)).unwrap();
    let rt = Runtime::cpu().unwrap();
    let compiled = rt.load_model(Path::new("artifacts"), manifest).unwrap();
    let eval = EvalSet::load(&idx.eval_data_path()).unwrap();
    let acc_eval = AccuracyEvaluator::new(&compiled, &eval, 128).unwrap();
    let l = compiled.num_units();

    // (1) clean accuracy matches the python-side export measurement
    let clean = acc_eval.clean_accuracy(&compiled, 0).unwrap();
    assert!(
        (clean - compiled.manifest.clean_acc_quant).abs() < 0.08,
        "{model}: rust clean {clean} vs python {}",
        compiled.manifest.clean_acc_quant
    );
    assert!(clean >= min_clean, "{model}: clean {clean}");

    // (2) clean accuracy is key-independent (rates = 0)
    let zero = RateVectors::zeros(l);
    let a = acc_eval.accuracy(&compiled, &zero, 1, 1).unwrap();
    let b = acc_eval.accuracy(&compiled, &zero, 999, 1).unwrap();
    assert_eq!(a, b, "{model}: clean accuracy depends on PRNG key");

    // (3) same key → same faulty accuracy; different keys may differ
    let faulty = RateVectors { w_rates: vec![0.3; l], a_rates: vec![0.3; l] };
    let f1 = acc_eval.accuracy(&compiled, &faulty, 7, 1).unwrap();
    let f2 = acc_eval.accuracy(&compiled, &faulty, 7, 1).unwrap();
    assert_eq!(f1, f2, "{model}: faulty eval not deterministic");

    // (4) heavy combined faults must degrade accuracy well below clean
    let heavy = acc_eval.accuracy(&compiled, &faulty, 3, 0).unwrap();
    assert!(
        heavy < clean - 0.1,
        "{model}: FR=0.3 input+weight barely degrades ({clean} -> {heavy})"
    );

    // (5) per-unit rates matter: faulting only the last unit differs from
    // faulting only the first (both domains)
    let mut first = RateVectors::zeros(l);
    first.a_rates[0] = 0.4;
    let mut last = RateVectors::zeros(l);
    last.a_rates[l - 1] = 0.4;
    let acc_first = acc_eval.accuracy(&compiled, &first, 5, 0).unwrap();
    let acc_last = acc_eval.accuracy(&compiled, &last, 5, 0).unwrap();
    // they *can* coincide by luck on tiny eval sets, but the big spatial
    // input vs the 10-class logits input should behave very differently
    assert!(
        (acc_first - acc_last).abs() > 1e-9 || acc_first == clean,
        "{model}: unit-local faults indistinguishable"
    );
}

#[test]
fn alexnet_end_to_end() {
    if !have_artifacts() {
        return;
    }
    exercise_model("alexnet", 0.9);
}

#[test]
fn squeezenet_end_to_end() {
    if !have_artifacts() {
        return;
    }
    exercise_model("squeezenet", 0.75);
}

#[test]
fn resnet18_end_to_end() {
    if !have_artifacts() {
        return;
    }
    exercise_model("resnet18", 0.9);
}

/// The experiment harness + threaded inference server compose: spawn the
/// server, push two batches through it, check predictions arrive.
#[test]
fn inference_server_round_trip() {
    if !have_artifacts() {
        return;
    }
    let cfg = ExperimentConfig { model: "squeezenet".into(), eval_limit: 64, ..Default::default() };
    let exp = Experiment::load(&cfg).unwrap();
    let manifest = Manifest::load(&exp.index.manifest_path("squeezenet")).unwrap();
    let server =
        InferenceServer::spawn("artifacts".into(), manifest, exp.img_dims()).unwrap();
    let b = server.batch;
    let l = server.num_units;

    let images = exp.eval_set.batch_images(0, b).to_vec();
    let clean = server
        .infer_blocking(images.clone(), b, RateVectors::zeros(l), [1, 2])
        .unwrap();
    assert_eq!(clean.preds.len(), b);
    assert!(clean.exec_ms > 0.0);

    // same batch under heavy faults: different predictions expected
    let heavy = RateVectors { w_rates: vec![0.4; l], a_rates: vec![0.4; l] };
    let noisy = server.infer_blocking(images, b, heavy, [3, 4]).unwrap();
    assert_eq!(noisy.preds.len(), b);
    let diff = clean.preds.iter().zip(&noisy.preds).filter(|(a, b)| a != b).count();
    assert!(diff > 0, "heavy faults changed no predictions");

    // clean accuracy through the server matches the direct evaluator path
    let labels = exp.eval_set.batch_labels(0, b);
    let hits = clean.preds.iter().zip(labels).filter(|(p, &l)| **p as i32 == l).count();
    assert!(hits as f64 / b as f64 > 0.6);
}

/// Exact-mode partition evaluation works against the real runtime and
/// produces device-placement-dependent ΔAcc.
#[test]
fn exact_dacc_depends_on_mapping() {
    if !have_artifacts() {
        return;
    }
    let cfg = ExperimentConfig {
        model: "alexnet".into(),
        fault_rate: 0.3,
        eval_limit: 64,
        dacc_batches: 1,
        ..Default::default()
    };
    let exp = Experiment::load(&cfg).unwrap();
    let mut ev = exp.partition_evaluator(FaultScenario::InputWeight);
    let n = exp.model.num_units();
    let all_risky = afarepart::partition::Mapping::all_on(0, n);
    let all_safe = afarepart::partition::Mapping::all_on(1, n);
    let d_risky = ev.dacc(&all_risky).unwrap();
    let d_safe = ev.dacc(&all_safe).unwrap();
    assert!(
        d_safe < d_risky,
        "shielded device should preserve accuracy: risky {d_risky} vs safe {d_safe}"
    );
    assert!(d_risky > 0.1, "FR=0.3 on the fault-prone device must hurt");
}
