//! Cross-module integration tests that do not require PJRT execution
//! (those live in runtime_e2e.rs). Artifact-dependent tests skip politely
//! when `make artifacts` hasn't run.

use std::path::Path;

use afarepart::baselines::{greedy_latency_mapping, CnnParted, FaultUnaware};
use afarepart::config::ExperimentConfig;
use afarepart::coordinator::offline::optimize_partitions;
use afarepart::coordinator::server::Batcher;
use afarepart::faults::{DeviceFaultProfile, DriftComponent, FaultEnv, FaultScenario};
use afarepart::hw::Platform;
use afarepart::model::Manifest;
use afarepart::nsga2::Nsga2Config;
use afarepart::partition::{DaccMode, Mapping, PartitionEvaluator, SensitivityTable};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("index.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Real manifests parse, validate, and agree with index.json.
#[test]
fn real_manifests_parse_and_validate() {
    let Some(dir) = artifacts() else { return };
    let idx = afarepart::runtime::ArtifactIndex::load(dir).unwrap();
    assert_eq!(idx.models, vec!["alexnet", "squeezenet", "resnet18"]);
    for m in &idx.models {
        let man = Manifest::load(&idx.manifest_path(m)).unwrap();
        assert_eq!(&man.model, m);
        assert!(man.clean_acc_quant > 0.5, "{m} trained badly");
        assert_eq!(man.precision, idx.precision);
        // weights blob consistent with manifest
        let tensors = afarepart::model::load_weights(&dir.join(&man.weights_file)).unwrap();
        assert_eq!(tensors.len(), man.weight_tensors.len());
        for (t, wt) in tensors.iter().zip(&man.weight_tensors) {
            assert_eq!(t.shape, wt.shape, "{m}: {}/{}", wt.unit, wt.prefix);
            // int8 deployment: all values fit the quant range
            let lim = 1i32 << (man.precision - 1);
            assert!(t.data.iter().all(|&x| x >= -lim && x < lim));
        }
    }
}

/// Real eval data loads and matches the index metadata.
#[test]
fn real_eval_data_loads() {
    let Some(dir) = artifacts() else { return };
    let idx = afarepart::runtime::ArtifactIndex::load(dir).unwrap();
    let ev = afarepart::dataset::EvalSet::load(&idx.eval_data_path()).unwrap();
    assert_eq!(ev.n, idx.n_eval);
    assert_eq!((ev.h, ev.w, ev.c), (32, 32, 3));
    assert!(ev.labels.iter().all(|&l| (0..10).contains(&l)));
    // images normalized
    assert!(ev.images.iter().all(|&x| (0.0..=1.0).contains(&x)));
    // class balance within 2x
    let mut counts = [0usize; 10];
    for &l in &ev.labels {
        counts[l as usize] += 1;
    }
    assert!(counts.iter().all(|&c| c > 0));
}

fn toy_manifest(n: usize) -> Manifest {
    let units = (0..n)
        .map(|i| afarepart::model::UnitCost {
            name: format!("u{i}"),
            kind: if i % 3 == 2 { "dense".into() } else { "conv".into() },
            macs: 500_000 * (i as u64 % 5 + 1),
            w_params: 20_000,
            w_bytes: 20_000,
            in_bytes: 4_096,
            out_bytes: 4_096,
            out_shape: vec![1],
        })
        .collect();
    Manifest {
        model: "toy".into(),
        num_units: n,
        num_classes: 10,
        precision: 8,
        faulty_bits: 4,
        batch: 8,
        hlo_file: "x".into(),
        weights_file: "x".into(),
        clean_acc_f32: 0.95,
        clean_acc_quant: 0.9,
        weight_scale: 0.01,
        units,
        weight_tensors: vec![],
        act_scales: vec![0.01; n],
    }
}

fn toy_sensitivity(n: usize) -> SensitivityTable {
    SensitivityTable {
        rate_grid: vec![0.1, 0.2, 0.4],
        w_drop: (0..n)
            .map(|i| {
                let s = 0.25 / (1.0 + i as f64);
                vec![0.5 * s, s, 1.5 * s]
            })
            .collect(),
        a_drop: (0..n).map(|i| vec![0.02 / (1.0 + i as f64); 3]).collect(),
        clean_acc: 0.9,
    }
}

/// Full offline pipeline on the surrogate: AFarePart must beat both
/// baselines on ΔAcc while staying within sane latency/energy bounds.
#[test]
fn offline_pipeline_afarepart_beats_baselines_on_dacc() {
    let manifest = toy_manifest(8);
    let platform = Platform::default_two_device();
    let table = toy_sensitivity(8);
    let mk = |link: bool| {
        PartitionEvaluator::new(
            &manifest,
            &platform,
            vec![0.25, 0.03],
            vec![0.25, 0.03],
            FaultScenario::InputWeight,
            0.9,
            link,
            DaccMode::Surrogate(&table),
        )
    };
    let nsga2 = Nsga2Config { pop_size: 24, generations: 15, ..Default::default() };

    let mut ev = mk(true);
    let cp = CnnParted::new(nsga2.clone()).partition(&mut ev).unwrap();
    let mut ev = mk(false);
    let fu = FaultUnaware::new(nsga2.clone()).partition(&mut ev).unwrap();
    let mut ev = mk(false);
    let runner = afarepart::coordinator::OfflineRunner { nsga2, ..Default::default() };
    let afp = runner.run(&mut ev, vec![], |_| {}).unwrap().deployed;

    let mut scorer = mk(false);
    let d_cp = scorer.dacc(&cp).unwrap();
    let d_fu = scorer.dacc(&fu).unwrap();
    let d_afp = scorer.dacc(&afp).unwrap();
    assert!(
        d_afp <= d_cp.min(d_fu) + 1e-9,
        "AFarePart dAcc {d_afp} vs CNNParted {d_cp} / fault-unaware {d_fu}"
    );
}

/// Greedy baseline produces a valid mapping on a real-size manifest.
#[test]
fn greedy_valid_mapping() {
    let manifest = toy_manifest(10);
    let platform = Platform::default_two_device();
    let ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        vec![0.2, 0.03],
        vec![0.2, 0.03],
        FaultScenario::WeightOnly,
        0.9,
        false,
        DaccMode::None,
    );
    let m = greedy_latency_mapping(&ev, 0.7);
    assert_eq!(m.len(), 10);
    assert!(m.0.iter().all(|&d| d < 2));
}

/// Drifting environment + surrogate evaluator: after a step attack on
/// device 0, re-optimization must migrate sensitive units away from it.
#[test]
fn reoptimization_reacts_to_attack() {
    let manifest = toy_manifest(6);
    let platform = Platform::default_two_device();
    let table = toy_sensitivity(6);
    let env = FaultEnv {
        base_rate: 0.15,
        profiles: DeviceFaultProfile::default_two_device(),
        drift: vec![DriftComponent::step(0, 10.0, 3.0)],
    };
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        FaultScenario::InputWeight,
        0.9,
        false,
        DaccMode::Surrogate(&table),
    );
    let nsga2 = Nsga2Config { pop_size: 24, generations: 12, ..Default::default() };
    let front = optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {});
    let before = afarepart::partition::select_min_dacc_within_budget(&front, 1.6, 1.6)
        .unwrap()
        .clone();

    // attack: device 0 now 3x worse
    ev.set_env_rates(env.dev_w_rates(20.0), env.dev_a_rates(20.0));
    let front = optimize_partitions(
        &mut ev,
        &nsga2,
        true,
        vec![Mapping(before.genome.clone())],
        |_| {},
    );
    // robustness-first selection (the toy units are so small that SIMBA's
    // static-power toll makes any migration blow a 1.6x energy budget —
    // budgeted selection correctly falls back to cheap mappings there, so
    // the migration property is asserted on the unconstrained policy)
    let after = afarepart::partition::select_min_dacc(&front).unwrap();
    // the most sensitive unit (u0) must not sit on the attacked device
    assert_eq!(after.genome[0], 1, "sensitive unit left on attacked device");
    // and the re-optimized dAcc must be no worse than keeping `before`
    let d_before = ev.dacc(&Mapping(before.genome.clone())).unwrap();
    let d_after = ev.dacc(&Mapping(after.genome.clone())).unwrap();
    assert!(d_after <= d_before + 1e-9);
}

/// Batcher + config plumbing smoke.
#[test]
fn batcher_and_config_integration() {
    let mut b = Batcher::new(4, 3);
    for i in 0..3 {
        assert!(b.push(&[i as f32; 3]).is_none());
    }
    let (imgs, n) = b.push(&[9.0; 3]).unwrap();
    assert_eq!((imgs.len(), n), (12, 4));

    let cfg = ExperimentConfig::default();
    assert_eq!(cfg.scenario, FaultScenario::InputWeight);
    assert!(cfg.nsga2.pop_size >= 2);
}

/// Evaluator counters and cache telemetry flow through an optimization.
#[test]
fn cache_telemetry_counts() {
    let manifest = toy_manifest(5);
    let platform = Platform::default_two_device();
    let table = toy_sensitivity(5);
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        vec![0.2, 0.03],
        vec![0.2, 0.03],
        FaultScenario::InputWeight,
        0.9,
        false,
        DaccMode::Surrogate(&table),
    );
    let nsga2 = Nsga2Config { pop_size: 16, generations: 10, ..Default::default() };
    optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {});
    let (hits, misses, rate) = ev.cache_stats();
    // 2^5 = 32 distinct mappings max -> misses bounded, hits plentiful
    assert!(misses <= 32, "misses {misses}");
    assert!(hits > misses, "hits {hits} misses {misses}");
    assert!(rate > 0.5);
    assert_eq!(ev.counters.surrogate_evals, misses);
}

/// Three-device platform (paper §I: accelerators + ECC host core): the
/// fault-immune CPU lets the optimizer buy resilience for tiny sensitive
/// units at negligible latency cost — the front's min-ΔAcc must be no
/// worse than on the two-device platform.
#[test]
fn three_device_platform_extends_front() {
    use afarepart::nsga2::front_hypervolume;

    let manifest = toy_manifest(6);
    let table = toy_sensitivity(6);
    let nsga2 = Nsga2Config { pop_size: 24, generations: 15, ..Default::default() };

    let run = |platform: &Platform, rates_w: Vec<f32>, rates_a: Vec<f32>| {
        let mut ev = PartitionEvaluator::new(
            &manifest,
            platform,
            rates_w,
            rates_a,
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {})
    };

    let p2 = Platform::default_two_device();
    let front2 = run(&p2, vec![0.25, 0.04], vec![0.25, 0.04]);
    let p3 = Platform::default_three_device();
    let front3 = run(&p3, vec![0.25, 0.04, 0.0], vec![0.25, 0.04, 0.0]);

    let min_dacc = |front: &[afarepart::nsga2::Individual]| {
        front.iter().map(|i| i.objectives[2]).fold(f64::INFINITY, f64::min)
    };
    assert!(min_dacc(&front3) <= min_dacc(&front2) + 1e-9);
    // genomes actually use the third device somewhere on the front
    assert!(front3.iter().any(|i| i.genome.contains(&2)));
    // hypervolume sanity: both fronts dominate a nonzero volume
    assert!(front_hypervolume(&front2, 1.1) > 0.0);
    assert!(front_hypervolume(&front3, 1.1) > 0.0);
}
