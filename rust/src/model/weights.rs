//! AFWB weight blob loader (`<model>_weights.bin`).
//!
//! Layout (little-endian), produced by python/compile/aot.py:
//!   magic "AFWB" | u32 version=1 | u32 n_tensors
//!   per tensor: u32 ndim | u32 dims[ndim] | i32 data[prod(dims)]

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One quantized weight tensor (int32 lanes holding fixed-point values).
#[derive(Clone, Debug)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn read_u32(buf: &[u8], off: &mut usize) -> Result<u32> {
    if *off + 4 > buf.len() {
        bail!("weights blob truncated at offset {}", off);
    }
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

/// Load all tensors from an AFWB blob.
pub fn load_weights(path: &Path) -> Result<Vec<QTensor>> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading weights {}", path.display()))?;
    parse_weights(&buf)
}

/// Parse an AFWB blob from memory (separated for tests).
pub fn parse_weights(buf: &[u8]) -> Result<Vec<QTensor>> {
    if buf.len() < 12 || &buf[..4] != b"AFWB" {
        bail!("not an AFWB weights blob");
    }
    let mut off = 4usize;
    let version = read_u32(buf, &mut off)?;
    if version != 1 {
        bail!("unsupported AFWB version {version}");
    }
    let n = read_u32(buf, &mut off)? as usize;
    let mut tensors = Vec::with_capacity(n);
    for t in 0..n {
        let ndim = read_u32(buf, &mut off)? as usize;
        if ndim > 8 {
            bail!("tensor {t}: implausible ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(buf, &mut off)? as usize);
        }
        let count: usize = shape.iter().product();
        let bytes = count
            .checked_mul(4)
            .context("tensor size overflow")?;
        if off + bytes > buf.len() {
            bail!("tensor {t}: data truncated");
        }
        let mut data = vec![0i32; count];
        for (i, ch) in buf[off..off + bytes].chunks_exact(4).enumerate() {
            data[i] = i32::from_le_bytes(ch.try_into().unwrap());
        }
        off += bytes;
        tensors.push(QTensor { shape, data });
    }
    if off != buf.len() {
        bail!("trailing bytes in weights blob ({} extra)", buf.len() - off);
    }
    Ok(tensors)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(tensors: &[(&[u32], &[i32])]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"AFWB");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (shape, data) in tensors {
            b.extend_from_slice(&(shape.len() as u32).to_le_bytes());
            for d in *shape {
                b.extend_from_slice(&d.to_le_bytes());
            }
            for x in *data {
                b.extend_from_slice(&x.to_le_bytes());
            }
        }
        b
    }

    #[test]
    fn roundtrip() {
        let b = blob(&[(&[2, 3], &[1, -2, 3, -4, 5, -6]), (&[4], &[7, 8, 9, 10])]);
        let ts = parse_weights(&b).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].shape, vec![2, 3]);
        assert_eq!(ts[0].data, vec![1, -2, 3, -4, 5, -6]);
        assert_eq!(ts[1].shape, vec![4]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = blob(&[(&[1], &[1])]);
        b[0] = b'X';
        assert!(parse_weights(&b).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let b = blob(&[(&[4], &[1, 2, 3, 4])]);
        assert!(parse_weights(&b[..b.len() - 2]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut b = blob(&[(&[1], &[1])]);
        b.push(0);
        assert!(parse_weights(&b).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let mut b = blob(&[(&[1], &[1])]);
        b[4] = 2;
        assert!(parse_weights(&b).is_err());
    }
}
