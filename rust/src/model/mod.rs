//! Model artifact manifests: the L3 view of an AOT-compiled model.
//!
//! python/compile/aot.py emits `<model>_manifest.json` describing the
//! partitioning units (cost descriptors for the hardware models), the
//! quantized weight tensor order (mirroring the HLO parameter order), and
//! quantization metadata. This module parses and validates it.

mod manifest;
mod weights;

pub use manifest::{Manifest, UnitCost, WeightTensor};
pub use weights::load_weights;
