//! Manifest parsing (`<model>_manifest.json`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// Cost descriptor of one partitioning unit (per single sample), the input
/// of the Eyeriss/SIMBA analytical models and the link cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct UnitCost {
    pub name: String,
    pub kind: String,
    /// Multiply-accumulates per sample.
    pub macs: u64,
    /// Quantized weight parameter count.
    pub w_params: u64,
    /// Weight bytes at deployment precision.
    pub w_bytes: u64,
    /// Input activation bytes (quantized) — also the link transfer size
    /// when the previous unit lives on a different device.
    pub in_bytes: u64,
    /// Output activation bytes (quantized).
    pub out_bytes: u64,
    pub out_shape: Vec<usize>,
}

/// One quantized weight tensor in HLO-parameter / weights.bin order.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightTensor {
    /// Owning unit name (faults on this tensor follow the unit's device).
    pub unit: String,
    /// Conv sub-name within the unit ("", "s", "e1", "c1", "p", ...).
    pub prefix: String,
    pub shape: Vec<usize>,
    pub scale: f64,
}

/// Parsed `<model>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub num_units: usize,
    pub num_classes: usize,
    pub precision: u32,
    pub faulty_bits: u32,
    /// Export batch size of the HLO artifact.
    pub batch: usize,
    pub hlo_file: String,
    pub weights_file: String,
    pub clean_acc_f32: f64,
    pub clean_acc_quant: f64,
    pub weight_scale: f64,
    pub units: Vec<UnitCost>,
    pub weight_tensors: Vec<WeightTensor>,
    /// Per-unit input-activation dequantization scales.
    pub act_scales: Vec<f64>,
}

fn need<'v>(v: &'v Value, key: &str) -> Result<&'v Value> {
    v.get(key).with_context(|| format!("manifest: missing key {key:?}"))
}

fn need_f64(v: &Value, key: &str) -> Result<f64> {
    need(v, key)?.as_f64().with_context(|| format!("manifest: {key:?} not a number"))
}

fn need_str(v: &Value, key: &str) -> Result<String> {
    Ok(need(v, key)?
        .as_str()
        .with_context(|| format!("manifest: {key:?} not a string"))?
        .to_string())
}

impl Manifest {
    /// Parse and validate a manifest JSON document.
    pub fn from_json(text: &str) -> Result<Manifest> {
        let v = json::parse(text).context("manifest: invalid json")?;
        let units_v = need(&v, "units")?
            .as_arr()
            .context("manifest: units not an array")?;
        let mut units = Vec::with_capacity(units_v.len());
        for u in units_v {
            units.push(UnitCost {
                name: need_str(u, "name")?,
                kind: need_str(u, "kind")?,
                macs: need_f64(u, "macs")? as u64,
                w_params: need_f64(u, "w_params")? as u64,
                w_bytes: need_f64(u, "w_bytes")? as u64,
                in_bytes: need_f64(u, "in_bytes")? as u64,
                out_bytes: need_f64(u, "out_bytes")? as u64,
                out_shape: need(u, "out_shape")?
                    .as_arr()
                    .context("out_shape not array")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
            });
        }
        let wts_v = need(&v, "weight_tensors")?
            .as_arr()
            .context("manifest: weight_tensors not an array")?;
        let mut weight_tensors = Vec::with_capacity(wts_v.len());
        for w in wts_v {
            weight_tensors.push(WeightTensor {
                unit: need_str(w, "unit")?,
                prefix: need_str(w, "prefix")?,
                shape: need(w, "shape")?
                    .as_arr()
                    .context("shape not array")?
                    .iter()
                    .map(|x| x.as_usize().unwrap_or(0))
                    .collect(),
                scale: need_f64(w, "scale")?,
            });
        }
        let act_obj = need(&v, "act_scales")?
            .as_obj()
            .context("manifest: act_scales not an object")?;
        let mut act_scales = Vec::with_capacity(units.len());
        for u in &units {
            let s = act_obj
                .get(&u.name)
                .and_then(|x| x.as_f64())
                .with_context(|| format!("manifest: act_scale missing for {}", u.name))?;
            act_scales.push(s);
        }

        let m = Manifest {
            model: need_str(&v, "model")?,
            num_units: need_f64(&v, "num_units")? as usize,
            num_classes: need_f64(&v, "num_classes")? as usize,
            precision: need_f64(&v, "precision")? as u32,
            faulty_bits: need_f64(&v, "faulty_bits")? as u32,
            batch: need_f64(&v, "batch")? as usize,
            hlo_file: need_str(&v, "hlo")?,
            weights_file: need_str(&v, "weights")?,
            clean_acc_f32: need_f64(&v, "clean_acc_f32")?,
            clean_acc_quant: need_f64(&v, "clean_acc_quant")?,
            weight_scale: need_f64(&v, "weight_scale")?,
            units,
            weight_tensors,
            act_scales,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Manifest::from_json(&text)
    }

    fn validate(&self) -> Result<()> {
        if self.units.len() != self.num_units {
            bail!(
                "manifest {}: num_units {} != units.len() {}",
                self.model,
                self.num_units,
                self.units.len()
            );
        }
        if !(1..=32).contains(&self.precision) || self.faulty_bits > self.precision {
            bail!("manifest {}: bad precision/faulty_bits", self.model);
        }
        let unit_names: Vec<&str> = self.units.iter().map(|u| u.name.as_str()).collect();
        for wt in &self.weight_tensors {
            if !unit_names.contains(&wt.unit.as_str()) {
                bail!("manifest {}: weight tensor for unknown unit {}", self.model, wt.unit);
            }
            if wt.shape.iter().product::<usize>() == 0 {
                bail!("manifest {}: empty weight tensor {}/{}", self.model, wt.unit, wt.prefix);
            }
        }
        // activation chain consistency (unit i out == unit i+1 in)
        for (a, b) in self.units.iter().zip(self.units.iter().skip(1)) {
            if a.out_bytes != b.in_bytes {
                bail!("manifest {}: broken activation chain {} -> {}", self.model, a.name, b.name);
            }
        }
        Ok(())
    }

    /// Index of a unit by name.
    pub fn unit_index(&self, name: &str) -> Option<usize> {
        self.units.iter().position(|u| u.name == name)
    }

    /// Map each weight tensor to its owning unit index (for rate vectors).
    pub fn weight_tensor_units(&self) -> Vec<usize> {
        self.weight_tensors
            .iter()
            .map(|wt| self.unit_index(&wt.unit).expect("validated"))
            .collect()
    }

    /// Total MACs per sample (for throughput estimates).
    pub fn total_macs(&self) -> u64 {
        self.units.iter().map(|u| u.macs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn toy_manifest_json() -> String {
        r#"{
          "model": "toy", "num_units": 2, "num_classes": 10,
          "precision": 8, "faulty_bits": 4, "batch": 4,
          "hlo": "toy.hlo.txt", "weights": "toy_weights.bin",
          "clean_acc_f32": 0.9, "clean_acc_quant": 0.88, "weight_scale": 0.0078125,
          "units": [
            {"name": "conv1", "kind": "conv", "macs": 1000, "w_params": 10,
             "w_bytes": 10, "in_bytes": 100, "out_bytes": 50, "out_shape": [4,4,2]},
            {"name": "fc", "kind": "dense", "macs": 320, "w_params": 320,
             "w_bytes": 320, "in_bytes": 50, "out_bytes": 10, "out_shape": [10]}
          ],
          "weight_tensors": [
            {"unit": "conv1", "prefix": "", "shape": [3,3,1,2], "scale": 0.0078125},
            {"unit": "fc", "prefix": "", "shape": [32,10], "scale": 0.0078125}
          ],
          "act_scales": {"conv1": 0.0078125, "fc": 0.25}
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let m = Manifest::from_json(&toy_manifest_json()).unwrap();
        assert_eq!(m.model, "toy");
        assert_eq!(m.units.len(), 2);
        assert_eq!(m.weight_tensors.len(), 2);
        assert_eq!(m.act_scales, vec![0.0078125, 0.25]);
        assert_eq!(m.weight_tensor_units(), vec![0, 1]);
        assert_eq!(m.total_macs(), 1320);
        assert_eq!(m.unit_index("fc"), Some(1));
    }

    #[test]
    fn rejects_unit_count_mismatch() {
        let bad = toy_manifest_json().replace("\"num_units\": 2", "\"num_units\": 3");
        assert!(Manifest::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_broken_activation_chain() {
        let bad = toy_manifest_json().replace("\"in_bytes\": 50", "\"in_bytes\": 51");
        assert!(Manifest::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_weight_unit() {
        let bad = toy_manifest_json().replace("{\"unit\": \"fc\"", "{\"unit\": \"nope\"");
        assert!(Manifest::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_missing_act_scale() {
        let bad = toy_manifest_json().replace("\"fc\": 0.25", "\"other\": 0.25");
        assert!(Manifest::from_json(&bad).is_err());
    }
}
