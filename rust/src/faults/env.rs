//! Time-varying fault environment for the online phase.
//!
//! Models the scenarios of the paper's threat model (§III-A): an ambient
//! soft-error baseline plus drifting or adversarial components (EM attack
//! ramp on one device, supply-noise oscillation, decay after mitigation).
//! The online coordinator samples the environment each monitoring tick;
//! a drift past the θ threshold is what triggers dynamic repartitioning.
//!
//! Drift is *composable*: the environment carries a stack of
//! [`DriftComponent`]s, each targeting one device, and a device's rate
//! multiplier at time `t` is the product of its components' multipliers.
//! A step attack and a supply-noise sinusoid can therefore act on the
//! same device simultaneously — the paper's Table II scenarios are all
//! single-component stacks, but the campaign API (crate::spec) builds
//! arbitrary ones.

use super::profile::DeviceFaultProfile;

/// The time-varying shape of one drift component (t in seconds).
#[derive(Clone, Debug, PartialEq)]
pub enum DriftWave {
    /// Step attack: rate multiplies by `factor` at t >= at_s.
    Step { at_s: f64, factor: f32 },
    /// Sinusoidal supply noise: rate * (1 + amp*sin(2πt/period)).
    Sinusoid { period_s: f64, amp: f32 },
    /// Exponential decay back to ambient after an incident at t=0:
    /// rate * (1 + (factor-1)*exp(-t/tau)).
    Decay { factor: f32, tau_s: f64 },
}

/// One drift component acting on one device. Components targeting the
/// same device stack multiplicatively.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftComponent {
    pub device: usize,
    pub wave: DriftWave,
}

impl DriftComponent {
    pub fn step(device: usize, at_s: f64, factor: f32) -> DriftComponent {
        DriftComponent { device, wave: DriftWave::Step { at_s, factor } }
    }

    pub fn sinusoid(device: usize, period_s: f64, amp: f32) -> DriftComponent {
        DriftComponent { device, wave: DriftWave::Sinusoid { period_s, amp } }
    }

    pub fn decay(device: usize, factor: f32, tau_s: f64) -> DriftComponent {
        DriftComponent { device, wave: DriftWave::Decay { factor, tau_s } }
    }

    /// Rate multiplier this component contributes on `device` at time t.
    fn mult(&self, device: usize, t_s: f64) -> f32 {
        if device != self.device {
            return 1.0;
        }
        match &self.wave {
            DriftWave::Step { at_s, factor } => {
                if t_s >= *at_s {
                    *factor
                } else {
                    1.0
                }
            }
            DriftWave::Sinusoid { period_s, amp } => {
                1.0 + amp * (2.0 * std::f64::consts::PI * t_s / period_s).sin() as f32
            }
            DriftWave::Decay { factor, tau_s } => 1.0 + (factor - 1.0) * (-t_s / tau_s).exp() as f32,
        }
    }
}

/// The complete fault environment: base rate, per-device profiles, and a
/// composable stack of drift components.
#[derive(Clone, Debug)]
pub struct FaultEnv {
    /// Environment fault rate FR (per-bit flip probability).
    pub base_rate: f32,
    pub profiles: Vec<DeviceFaultProfile>,
    pub drift: Vec<DriftComponent>,
}

impl FaultEnv {
    /// A static environment (no drift).
    pub fn constant(base_rate: f32, profiles: Vec<DeviceFaultProfile>) -> Self {
        FaultEnv { base_rate, profiles, drift: Vec::new() }
    }

    pub fn num_devices(&self) -> usize {
        self.profiles.len()
    }

    fn drift_mult(&self, device: usize, t_s: f64) -> f32 {
        self.drift.iter().map(|c| c.mult(device, t_s)).product()
    }

    /// Per-device weight fault rates at time t (clamped to [0,1]).
    pub fn dev_w_rates(&self, t_s: f64) -> Vec<f32> {
        self.profiles
            .iter()
            .enumerate()
            .map(|(d, p)| (self.base_rate * p.w_mult * self.drift_mult(d, t_s)).clamp(0.0, 1.0))
            .collect()
    }

    /// Per-device activation fault rates at time t (clamped to [0,1]).
    pub fn dev_a_rates(&self, t_s: f64) -> Vec<f32> {
        self.profiles
            .iter()
            .enumerate()
            .map(|(d, p)| (self.base_rate * p.a_mult * self.drift_mult(d, t_s)).clamp(0.0, 1.0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(drift: Vec<DriftComponent>) -> FaultEnv {
        FaultEnv {
            base_rate: 0.2,
            profiles: DeviceFaultProfile::default_two_device(),
            drift,
        }
    }

    #[test]
    fn constant_env() {
        let e = env(vec![]);
        let w = e.dev_w_rates(100.0);
        assert!((w[0] - 0.2).abs() < 1e-6);
        assert!((w[1] - 0.03).abs() < 1e-6);
    }

    #[test]
    fn step_attack_fires_at_time() {
        let e = env(vec![DriftComponent::step(0, 10.0, 2.0)]);
        assert!((e.dev_w_rates(9.9)[0] - 0.2).abs() < 1e-6);
        assert!((e.dev_w_rates(10.0)[0] - 0.4).abs() < 1e-6);
        // other device untouched
        assert!((e.dev_w_rates(10.0)[1] - 0.03).abs() < 1e-6);
    }

    #[test]
    fn rates_clamped_to_unit_interval() {
        let e = env(vec![DriftComponent::step(0, 0.0, 100.0)]);
        assert!(e.dev_w_rates(1.0)[0] <= 1.0);
    }

    #[test]
    fn sinusoid_oscillates() {
        let e = env(vec![DriftComponent::sinusoid(0, 4.0, 0.5)]);
        let up = e.dev_w_rates(1.0)[0]; // sin(π/2)=1
        let down = e.dev_w_rates(3.0)[0]; // sin(3π/2)=-1
        assert!(up > 0.28 && down < 0.12);
    }

    #[test]
    fn decay_returns_to_ambient() {
        let e = env(vec![DriftComponent::decay(0, 3.0, 1.0)]);
        assert!(e.dev_w_rates(0.0)[0] > 0.55);
        assert!((e.dev_w_rates(50.0)[0] - 0.2).abs() < 1e-3);
    }

    #[test]
    fn components_stack_multiplicatively() {
        // step×sinusoid on dev0 + an independent step on dev1
        let e = env(vec![
            DriftComponent::step(0, 10.0, 2.0),
            DriftComponent::sinusoid(0, 4.0, 0.5),
            DriftComponent::step(1, 5.0, 3.0),
        ]);
        // t=11: step active (×2), sin(2π·11/4)=sin(5.5π)=-1 (×0.5)
        let w = e.dev_w_rates(11.0);
        assert!((w[0] - 0.2 * 2.0 * 0.5).abs() < 1e-5, "dev0 stacked mult: {}", w[0]);
        assert!((w[1] - 0.03 * 3.0).abs() < 1e-6, "dev1 independent: {}", w[1]);
        // before either step fires, only the sinusoid acts on dev0
        let w0 = e.dev_w_rates(0.0);
        assert!((w0[0] - 0.2).abs() < 1e-6);
        assert!((w0[1] - 0.03).abs() < 1e-6);
    }

    #[test]
    fn empty_stack_is_identity() {
        let e = env(vec![]);
        for t in [0.0, 10.0, 1000.0] {
            assert_eq!(e.dev_w_rates(t), e.dev_w_rates(0.0));
        }
    }
}
