//! Time-varying fault environment for the online phase.
//!
//! Models the scenarios of the paper's threat model (§III-A): an ambient
//! soft-error baseline plus drifting or adversarial components (EM attack
//! ramp on one device, supply-noise oscillation, decay after mitigation).
//! The online coordinator samples the environment each monitoring tick;
//! a drift past the θ threshold is what triggers dynamic repartitioning.

use super::profile::DeviceFaultProfile;

/// How the environment fault rate evolves over time (t in seconds).
#[derive(Clone, Debug)]
pub enum DriftSchedule {
    /// Constant ambient rate.
    Constant,
    /// Step attack: rate multiplies by `factor` on `device` at t >= at_s.
    StepAttack { device: usize, at_s: f64, factor: f32 },
    /// Sinusoidal supply noise on `device`: rate * (1 + amp*sin(2πt/period)).
    Sinusoid { device: usize, period_s: f64, amp: f32 },
    /// Exponential decay back to ambient after an incident at t=0.
    Decay { device: usize, factor: f32, tau_s: f64 },
}

/// The complete fault environment: base rate, per-device profiles, drift.
#[derive(Clone, Debug)]
pub struct FaultEnv {
    /// Environment fault rate FR (per-bit flip probability).
    pub base_rate: f32,
    pub profiles: Vec<DeviceFaultProfile>,
    pub drift: DriftSchedule,
}

impl FaultEnv {
    pub fn constant(base_rate: f32, profiles: Vec<DeviceFaultProfile>) -> Self {
        FaultEnv { base_rate, profiles, drift: DriftSchedule::Constant }
    }

    pub fn num_devices(&self) -> usize {
        self.profiles.len()
    }

    fn drift_mult(&self, device: usize, t_s: f64) -> f32 {
        match &self.drift {
            DriftSchedule::Constant => 1.0,
            DriftSchedule::StepAttack { device: d, at_s, factor } => {
                if device == *d && t_s >= *at_s {
                    *factor
                } else {
                    1.0
                }
            }
            DriftSchedule::Sinusoid { device: d, period_s, amp } => {
                if device == *d {
                    1.0 + amp * (2.0 * std::f64::consts::PI * t_s / period_s).sin() as f32
                } else {
                    1.0
                }
            }
            DriftSchedule::Decay { device: d, factor, tau_s } => {
                if device == *d {
                    1.0 + (factor - 1.0) * (-t_s / tau_s).exp() as f32
                } else {
                    1.0
                }
            }
        }
    }

    /// Per-device weight fault rates at time t (clamped to [0,1]).
    pub fn dev_w_rates(&self, t_s: f64) -> Vec<f32> {
        self.profiles
            .iter()
            .enumerate()
            .map(|(d, p)| (self.base_rate * p.w_mult * self.drift_mult(d, t_s)).clamp(0.0, 1.0))
            .collect()
    }

    /// Per-device activation fault rates at time t (clamped to [0,1]).
    pub fn dev_a_rates(&self, t_s: f64) -> Vec<f32> {
        self.profiles
            .iter()
            .enumerate()
            .map(|(d, p)| (self.base_rate * p.a_mult * self.drift_mult(d, t_s)).clamp(0.0, 1.0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(drift: DriftSchedule) -> FaultEnv {
        FaultEnv {
            base_rate: 0.2,
            profiles: DeviceFaultProfile::default_two_device(),
            drift,
        }
    }

    #[test]
    fn constant_env() {
        let e = env(DriftSchedule::Constant);
        let w = e.dev_w_rates(100.0);
        assert!((w[0] - 0.2).abs() < 1e-6);
        assert!((w[1] - 0.03).abs() < 1e-6);
    }

    #[test]
    fn step_attack_fires_at_time() {
        let e = env(DriftSchedule::StepAttack { device: 0, at_s: 10.0, factor: 2.0 });
        assert!((e.dev_w_rates(9.9)[0] - 0.2).abs() < 1e-6);
        assert!((e.dev_w_rates(10.0)[0] - 0.4).abs() < 1e-6);
        // other device untouched
        assert!((e.dev_w_rates(10.0)[1] - 0.03).abs() < 1e-6);
    }

    #[test]
    fn rates_clamped_to_unit_interval() {
        let e = env(DriftSchedule::StepAttack { device: 0, at_s: 0.0, factor: 100.0 });
        assert!(e.dev_w_rates(1.0)[0] <= 1.0);
    }

    #[test]
    fn sinusoid_oscillates() {
        let e = env(DriftSchedule::Sinusoid { device: 0, period_s: 4.0, amp: 0.5 });
        let up = e.dev_w_rates(1.0)[0]; // sin(π/2)=1
        let down = e.dev_w_rates(3.0)[0]; // sin(3π/2)=-1
        assert!(up > 0.28 && down < 0.12);
    }

    #[test]
    fn decay_returns_to_ambient() {
        let e = env(DriftSchedule::Decay { device: 0, factor: 3.0, tau_s: 1.0 });
        assert!(e.dev_w_rates(0.0)[0] > 0.55);
        assert!((e.dev_w_rates(50.0)[0] - 0.2).abs() < 1e-3);
    }
}
