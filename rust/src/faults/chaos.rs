//! Deterministic chaos injection for the serving runtime.
//!
//! The fault environment (`faults::env`) perturbs the *model tensors*;
//! this module perturbs the *serving system itself*: the inference
//! worker thread, the job queue, and the links between partition
//! devices. Failures are planned per tick by a seeded, stateless
//! engine, so a chaos run is bitwise-reproducible for a fixed seed and
//! independent of pipeline lookahead or wall-clock timing.
//!
//! Components compose like `DriftComponent` stacks: each component is
//! an independent Bernoulli stream with its own (seed, tick, index)
//! PRNG, optionally windowed to a tick range. The engine is off by
//! default (`ChaosEngine::disabled()`), in which case every plan is a
//! no-op and the serving path is byte-identical to a chaos-free build.

use crate::util::prng::Rng;

/// Stable identity of one fired chaos component at one tick: the tick
/// in the high bits, the component index in the low byte. Pure in its
/// coordinates, so every consumer of the same injected fault — the
/// planner, the supervisor, the trace, the offline analyzer — derives
/// the same id without sharing state.
pub fn fault_id(tick: usize, component: usize) -> u64 {
    ((tick as u64) << 8) | (component as u64 & 0xFF)
}

/// The tick a [`fault_id`] was injected at.
pub fn fault_tick(id: u64) -> usize {
    (id >> 8) as usize
}

/// The component index a [`fault_id`] was injected by.
pub fn fault_component(id: u64) -> usize {
    (id & 0xFF) as usize
}

/// One injected fault occurrence, as reported by
/// [`ChaosEngine::events`] — the attribution ledger's source records.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// Stable id ([`fault_id`] of `(tick, component)`).
    pub id: u64,
    pub tick: usize,
    /// Index of the firing component in the engine's stack.
    pub component: usize,
    /// Fault class (`"crash"`, `"transient"`, `"drop"`, `"delay"`,
    /// `"corrupt"`).
    pub class: &'static str,
    /// Burst units (transient/drop), injected link delay in ms (delay),
    /// 0 otherwise.
    pub magnitude: f64,
}

/// One class of injectable serving failure.
#[derive(Clone, Debug, PartialEq)]
pub enum ChaosKind {
    /// Kill the inference worker thread without replying; the
    /// supervisor observes the closed channel and must respawn.
    WorkerCrash,
    /// The worker reports a transient (retryable) PJRT-style error for
    /// the next `burst` attempts of the affected job.
    TransientError { burst: u32 },
    /// The link eats the worker's reply: the next `burst` replies of
    /// the affected job are silently dropped, forcing recv timeouts.
    LinkDrop { burst: u32 },
    /// Inter-device link congestion: adds `ms` to the reported
    /// execution latency (feeds `Metrics::exec_summary`).
    LinkDelay { ms: f64 },
    /// Bit-flips on the reply path: predictions arrive deterministically
    /// scrambled (never equal to the clean prediction).
    ReplyCorrupt,
}

impl ChaosKind {
    /// The attribution class this kind rolls up under.
    pub fn class(&self) -> &'static str {
        match self {
            ChaosKind::WorkerCrash => "crash",
            ChaosKind::TransientError { .. } => "transient",
            ChaosKind::LinkDrop { .. } => "drop",
            ChaosKind::LinkDelay { .. } => "delay",
            ChaosKind::ReplyCorrupt => "corrupt",
        }
    }
}

/// A chaos stream: a failure kind fired with probability `rate` per
/// tick, optionally limited to the half-open tick window
/// `[from_tick, until_tick)` (`until_tick == 0` means unbounded).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosComponent {
    pub kind: ChaosKind,
    pub rate: f64,
    pub from_tick: usize,
    pub until_tick: usize,
}

impl ChaosComponent {
    fn new(kind: ChaosKind, rate: f64) -> ChaosComponent {
        ChaosComponent { kind, rate, from_tick: 0, until_tick: 0 }
    }

    pub fn crash(rate: f64) -> ChaosComponent {
        ChaosComponent::new(ChaosKind::WorkerCrash, rate)
    }

    pub fn transient(rate: f64, burst: u32) -> ChaosComponent {
        ChaosComponent::new(ChaosKind::TransientError { burst }, rate)
    }

    pub fn drop(rate: f64, burst: u32) -> ChaosComponent {
        ChaosComponent::new(ChaosKind::LinkDrop { burst }, rate)
    }

    pub fn delay(rate: f64, ms: f64) -> ChaosComponent {
        ChaosComponent::new(ChaosKind::LinkDelay { ms }, rate)
    }

    pub fn corrupt(rate: f64) -> ChaosComponent {
        ChaosComponent::new(ChaosKind::ReplyCorrupt, rate)
    }

    /// Restrict the component to ticks in `[from, until)`.
    pub fn window(mut self, from: usize, until: usize) -> ChaosComponent {
        self.from_tick = from;
        self.until_tick = until;
        self
    }

    fn armed(&self, tick: usize) -> bool {
        tick >= self.from_tick && (self.until_tick == 0 || tick < self.until_tick)
    }
}

/// The failures planned for one tick's inference job. Attached to the
/// job when it is submitted; the worker and supervisor act it out.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosPlan {
    pub crash: bool,
    pub transient_failures: u32,
    pub drop_replies: u32,
    pub delay_ms: f64,
    pub corrupt: bool,
    /// Attribution ledger: [`fault_id`]s parallel to the effect fields
    /// above, one per effect *unit* for the burst kinds. The supervisor
    /// pops a queue at the exact point it consumes the matching effect
    /// unit, so every retry / respawn / terminal failure names the
    /// injected fault that caused it.
    pub crash_faults: Vec<u64>,
    pub transient_faults: Vec<u64>,
    pub drop_faults: Vec<u64>,
    pub delay_faults: Vec<u64>,
    pub corrupt_faults: Vec<u64>,
}

impl ChaosPlan {
    pub fn is_noop(&self) -> bool {
        !self.crash
            && self.transient_failures == 0
            && self.drop_replies == 0
            && self.delay_ms == 0.0
            && !self.corrupt
    }
}

/// Seeded, stateless chaos planner. `plan(tick)` is a pure function of
/// (seed, components, tick): each (tick, component) pair gets an
/// independent PRNG stream, so plans never consume shared randomness
/// and reordering queries cannot change outcomes.
#[derive(Clone, Debug)]
pub struct ChaosEngine {
    seed: u64,
    components: Vec<ChaosComponent>,
}

impl ChaosEngine {
    pub fn new(seed: u64, components: Vec<ChaosComponent>) -> ChaosEngine {
        ChaosEngine { seed, components }
    }

    /// An engine that never injects anything.
    pub fn disabled() -> ChaosEngine {
        ChaosEngine { seed: 0, components: Vec::new() }
    }

    /// The default failure mix used by `--chaos`: rare crashes, small
    /// retryable transient/drop bursts (below the default retry budget,
    /// so they degrade latency rather than terminate runs), and
    /// moderate link congestion.
    pub fn default_stack() -> Vec<ChaosComponent> {
        vec![
            ChaosComponent::crash(0.02),
            ChaosComponent::transient(0.06, 1),
            ChaosComponent::drop(0.02, 1),
            ChaosComponent::delay(0.15, 25.0),
            ChaosComponent::corrupt(0.04),
        ]
    }

    pub fn is_enabled(&self) -> bool {
        !self.components.is_empty()
    }

    /// Does component `ci` fire at `tick`? Pure in (seed, tick, ci).
    fn fires(&self, tick: usize, ci: usize, comp: &ChaosComponent) -> bool {
        if !comp.armed(tick) {
            return false;
        }
        let stream = self
            .seed
            .wrapping_add((tick as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((ci as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03));
        Rng::new(stream).chance(comp.rate)
    }

    /// Plan the failures for `tick`'s job, ledger ids included. Pure:
    /// only allocates when a component actually fires.
    pub fn plan(&self, tick: usize) -> ChaosPlan {
        let mut plan = ChaosPlan::default();
        for (ci, comp) in self.components.iter().enumerate() {
            if !self.fires(tick, ci, comp) {
                continue;
            }
            let id = fault_id(tick, ci);
            match comp.kind {
                ChaosKind::WorkerCrash => {
                    plan.crash = true;
                    plan.crash_faults.push(id);
                }
                ChaosKind::TransientError { burst } => {
                    plan.transient_failures += burst;
                    plan.transient_faults.extend(std::iter::repeat(id).take(burst as usize));
                }
                ChaosKind::LinkDrop { burst } => {
                    plan.drop_replies += burst;
                    plan.drop_faults.extend(std::iter::repeat(id).take(burst as usize));
                }
                ChaosKind::LinkDelay { ms } => {
                    plan.delay_ms += ms;
                    plan.delay_faults.push(id);
                }
                ChaosKind::ReplyCorrupt => {
                    plan.corrupt = true;
                    plan.corrupt_faults.push(id);
                }
            }
        }
        plan
    }

    /// The ledger view of `tick`: one [`FaultEvent`] per fired
    /// component, in component order. Pure in (seed, components, tick) —
    /// the same firing decisions as [`ChaosEngine::plan`], so the
    /// coordinator can emit `chaos_inject` trace events without
    /// disturbing (or depending on) the submitted plans.
    pub fn events(&self, tick: usize) -> Vec<FaultEvent> {
        let mut out = Vec::new();
        for (ci, comp) in self.components.iter().enumerate() {
            if !self.fires(tick, ci, comp) {
                continue;
            }
            let magnitude = match comp.kind {
                ChaosKind::TransientError { burst } | ChaosKind::LinkDrop { burst } => {
                    burst as f64
                }
                ChaosKind::LinkDelay { ms } => ms,
                ChaosKind::WorkerCrash | ChaosKind::ReplyCorrupt => 0.0,
            };
            out.push(FaultEvent {
                id: fault_id(tick, ci),
                tick,
                component: ci,
                class: comp.kind.class(),
                magnitude,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_engine_is_noop_everywhere() {
        let eng = ChaosEngine::disabled();
        assert!(!eng.is_enabled());
        for tick in 0..256 {
            assert!(eng.plan(tick).is_noop());
        }
    }

    #[test]
    fn plans_are_deterministic_and_query_order_free() {
        let eng = ChaosEngine::new(99, ChaosEngine::default_stack());
        let forward: Vec<ChaosPlan> = (0..64).map(|t| eng.plan(t)).collect();
        let backward: Vec<ChaosPlan> = (0..64).rev().map(|t| eng.plan(t)).collect();
        let backward: Vec<ChaosPlan> = backward.into_iter().rev().collect();
        assert_eq!(forward, backward);
        let again: Vec<ChaosPlan> =
            (0..64).map(|t| ChaosEngine::new(99, ChaosEngine::default_stack()).plan(t)).collect();
        assert_eq!(forward, again);
    }

    #[test]
    fn rate_one_always_fires_rate_zero_never() {
        let eng = ChaosEngine::new(5, vec![ChaosComponent::crash(1.0), ChaosComponent::corrupt(0.0)]);
        for tick in 0..32 {
            let plan = eng.plan(tick);
            assert!(plan.crash, "tick {tick}");
            assert!(!plan.corrupt, "tick {tick}");
        }
    }

    #[test]
    fn window_limits_arming() {
        let eng = ChaosEngine::new(7, vec![ChaosComponent::transient(1.0, 2).window(5, 8)]);
        for tick in 0..16 {
            let plan = eng.plan(tick);
            if (5..8).contains(&tick) {
                assert_eq!(plan.transient_failures, 2, "tick {tick}");
            } else {
                assert!(plan.is_noop(), "tick {tick}");
            }
        }
    }

    #[test]
    fn components_compose_additively() {
        let eng = ChaosEngine::new(3, vec![
            ChaosComponent::delay(1.0, 10.0),
            ChaosComponent::delay(1.0, 15.0),
            ChaosComponent::transient(1.0, 1),
            ChaosComponent::transient(1.0, 2),
        ]);
        let plan = eng.plan(0);
        assert_eq!(plan.delay_ms, 25.0);
        assert_eq!(plan.transient_failures, 3);
    }

    #[test]
    fn ledger_ids_parallel_effect_units() {
        let eng = ChaosEngine::new(
            17,
            vec![
                ChaosComponent::crash(1.0),
                ChaosComponent::transient(1.0, 2),
                ChaosComponent::drop(1.0, 3),
                ChaosComponent::delay(1.0, 25.0),
                ChaosComponent::corrupt(1.0),
            ],
        );
        for tick in [0usize, 7, 300] {
            let plan = eng.plan(tick);
            assert_eq!(plan.crash_faults, vec![fault_id(tick, 0)]);
            assert_eq!(plan.transient_faults, vec![fault_id(tick, 1); 2]);
            assert_eq!(plan.transient_faults.len(), plan.transient_failures as usize);
            assert_eq!(plan.drop_faults, vec![fault_id(tick, 2); 3]);
            assert_eq!(plan.drop_faults.len(), plan.drop_replies as usize);
            assert_eq!(plan.delay_faults, vec![fault_id(tick, 3)]);
            assert_eq!(plan.corrupt_faults, vec![fault_id(tick, 4)]);
            for (ci, id) in [(0, plan.crash_faults[0]), (3, plan.delay_faults[0])] {
                assert_eq!(fault_tick(id), tick);
                assert_eq!(fault_component(id), ci);
            }
        }
    }

    #[test]
    fn events_agree_with_plans() {
        let eng = ChaosEngine::new(99, ChaosEngine::default_stack());
        for tick in 0..128 {
            let plan = eng.plan(tick);
            let events = eng.events(tick);
            let count = |class: &str| events.iter().filter(|e| e.class == class).count();
            assert_eq!(count("crash"), plan.crash_faults.len());
            assert_eq!(count("corrupt"), plan.corrupt_faults.len());
            assert_eq!(count("delay"), plan.delay_faults.len());
            // one event per fired component, burst units expanded in the plan
            for e in &events {
                assert_eq!(e.id, fault_id(e.tick, e.component));
                assert_eq!(e.tick, tick);
                match e.class {
                    "transient" => assert!(plan.transient_faults.contains(&e.id)),
                    "drop" => assert!(plan.drop_faults.contains(&e.id)),
                    "delay" => assert_eq!(e.magnitude, 25.0),
                    _ => {}
                }
            }
            assert_eq!(plan.is_noop(), events.is_empty());
        }
    }

    #[test]
    fn seeds_decorrelate_streams() {
        let a = ChaosEngine::new(1, vec![ChaosComponent::crash(0.5)]);
        let b = ChaosEngine::new(2, vec![ChaosComponent::crash(0.5)]);
        let pa: Vec<bool> = (0..64).map(|t| a.plan(t).crash).collect();
        let pb: Vec<bool> = (0..64).map(|t| b.plan(t).crash).collect();
        assert_ne!(pa, pb);
    }
}
