//! Per-device fault susceptibility profiles.
//!
//! The environment-level fault rate FR (the paper's 10–40%) is scaled per
//! device: the aggressively voltage-scaled edge part feels the full rate,
//! the better-shielded package part a fraction of it (DESIGN.md §7). This
//! is what couples the layer→device mapping to ΔAcc and makes the
//! three-objective optimization non-trivial.

/// Fault susceptibility of one device.
#[derive(Clone, Debug)]
pub struct DeviceFaultProfile {
    pub device: String,
    /// Multiplier on the environment weight-fault rate.
    pub w_mult: f32,
    /// Multiplier on the environment activation-fault rate.
    pub a_mult: f32,
}

impl DeviceFaultProfile {
    pub fn new(device: &str, w_mult: f32, a_mult: f32) -> Self {
        DeviceFaultProfile { device: device.into(), w_mult, a_mult }
    }

    /// Paper-default platform: Eyeriss fault-prone, SIMBA shielded.
    pub fn default_two_device() -> Vec<DeviceFaultProfile> {
        vec![
            DeviceFaultProfile::new("eyeriss", 1.0, 1.0),
            DeviceFaultProfile::new("simba", 0.15, 0.15),
        ]
    }

    /// Extended platform: + ECC-protected host core, fault-immune.
    pub fn default_three_device() -> Vec<DeviceFaultProfile> {
        let mut p = Self::default_two_device();
        p.push(DeviceFaultProfile::new("cpu", 0.0, 0.0));
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_platform_has_contrast() {
        let p = DeviceFaultProfile::default_two_device();
        assert_eq!(p.len(), 2);
        assert!(p[0].w_mult > p[1].w_mult * 3.0);
    }
}
