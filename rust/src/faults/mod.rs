//! Fault environment: per-device fault-rate profiles, drift/attack
//! schedules, and fault scenarios (paper §III).
//!
//! The environment produces, at any time `t`, a per-device *weight* and
//! *activation* fault rate. The partition evaluator turns these into the
//! per-unit rate vectors the compiled HLO consumes: unit `l` mapped to
//! device `d` experiences the rates of `d` (the paper's "fault domain
//! constraints" — faults restricted to layers mapped to a given
//! accelerator).

pub mod chaos;
mod env;
mod profile;
mod scenario;

pub use chaos::{
    fault_component, fault_id, fault_tick, ChaosComponent, ChaosEngine, ChaosKind, ChaosPlan,
    FaultEvent,
};
pub use env::{DriftComponent, DriftWave, FaultEnv};
pub use profile::DeviceFaultProfile;
pub use scenario::FaultScenario;

/// Per-unit fault-rate vectors fed to the compiled model.
#[derive(Clone, Debug, PartialEq)]
pub struct RateVectors {
    pub w_rates: Vec<f32>,
    pub a_rates: Vec<f32>,
}

impl RateVectors {
    pub fn zeros(num_units: usize) -> Self {
        RateVectors { w_rates: vec![0.0; num_units], a_rates: vec![0.0; num_units] }
    }

    /// Build per-unit vectors from a mapping and per-device rates,
    /// masked by the fault scenario.
    pub fn from_mapping(
        mapping: &[usize],
        dev_w_rates: &[f32],
        dev_a_rates: &[f32],
        scenario: FaultScenario,
    ) -> Self {
        let (wm, am) = scenario.masks();
        RateVectors {
            w_rates: mapping.iter().map(|&d| dev_w_rates[d] * wm).collect(),
            a_rates: mapping.iter().map(|&d| dev_a_rates[d] * am).collect(),
        }
    }

    /// Quantized cache key: rates rounded to the 1/256 contract
    /// granularity (the kernel cannot distinguish finer rates, so ΔAcc
    /// memoization on this key is exact — DESIGN.md §4.2).
    pub fn cache_key(&self) -> Vec<u16> {
        self.w_rates
            .iter()
            .chain(self.a_rates.iter())
            .map(|&r| (r * 256.0).round().clamp(0.0, 256.0) as u16)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_to_rates() {
        let rv = RateVectors::from_mapping(
            &[0, 1, 0],
            &[0.2, 0.02],
            &[0.1, 0.01],
            FaultScenario::InputWeight,
        );
        assert_eq!(rv.w_rates, vec![0.2, 0.02, 0.2]);
        assert_eq!(rv.a_rates, vec![0.1, 0.01, 0.1]);
    }

    #[test]
    fn scenario_masks_domains() {
        let w_only = RateVectors::from_mapping(
            &[0, 1],
            &[0.2, 0.2],
            &[0.1, 0.1],
            FaultScenario::WeightOnly,
        );
        assert_eq!(w_only.a_rates, vec![0.0, 0.0]);
        assert!(w_only.w_rates.iter().all(|&r| r > 0.0));
        let a_only = RateVectors::from_mapping(
            &[0, 1],
            &[0.2, 0.2],
            &[0.1, 0.1],
            FaultScenario::InputOnly,
        );
        assert_eq!(a_only.w_rates, vec![0.0, 0.0]);
    }

    #[test]
    fn cache_key_quantizes_to_contract_granularity() {
        let a = RateVectors { w_rates: vec![0.2], a_rates: vec![0.1] };
        let b = RateVectors { w_rates: vec![0.2001], a_rates: vec![0.1001] };
        assert_eq!(a.cache_key(), b.cache_key());
        let c = RateVectors { w_rates: vec![0.21], a_rates: vec![0.1] };
        assert_ne!(a.cache_key(), c.cache_key());
    }
}
