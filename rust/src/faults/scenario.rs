//! Fault scenarios of the paper's Table II: weight-only, input-only
//! (activations), and combined input+weight.

/// Which fault domain(s) are active (paper §III-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultScenario {
    /// Bit-flips in stored quantized weights only ("model faults").
    WeightOnly,
    /// Bit-flips in activations only ("data faults").
    InputOnly,
    /// Both domains simultaneously.
    InputWeight,
}

impl FaultScenario {
    /// (weight multiplier, activation multiplier).
    pub fn masks(self) -> (f32, f32) {
        match self {
            FaultScenario::WeightOnly => (1.0, 0.0),
            FaultScenario::InputOnly => (0.0, 1.0),
            FaultScenario::InputWeight => (1.0, 1.0),
        }
    }

    pub fn all() -> [FaultScenario; 3] {
        [FaultScenario::WeightOnly, FaultScenario::InputOnly, FaultScenario::InputWeight]
    }

    pub fn label(self) -> &'static str {
        match self {
            FaultScenario::WeightOnly => "weight-only",
            FaultScenario::InputOnly => "input-only",
            FaultScenario::InputWeight => "input+weight",
        }
    }

    pub fn parse(s: &str) -> Option<FaultScenario> {
        match s {
            "weight" | "weight-only" | "w" => Some(FaultScenario::WeightOnly),
            "input" | "input-only" | "a" => Some(FaultScenario::InputOnly),
            "both" | "input+weight" | "iw" => Some(FaultScenario::InputWeight),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_labels() {
        for s in FaultScenario::all() {
            assert_eq!(FaultScenario::parse(s.label()), Some(s));
        }
        assert_eq!(FaultScenario::parse("nope"), None);
    }
}
