//! Shared experiment cells for the paper-reproduction benches: run one
//! (model, strategy, scenario, FR) cell and report the Table-II metrics.

use anyhow::Result;

use crate::baselines::{CnnParted, FaultUnaware};
use crate::config::ExperimentConfig;
use crate::coordinator::OfflineRunner;
use crate::experiment::Experiment;
use crate::faults::FaultScenario;
use crate::nsga2::Nsga2Config;
use crate::partition::Mapping;

/// The three strategies of Fig. 3 / Fig. 4 / Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tool {
    CnnParted,
    FaultUnaware,
    AFarePart,
}

impl Tool {
    pub fn all() -> [Tool; 3] {
        [Tool::CnnParted, Tool::FaultUnaware, Tool::AFarePart]
    }
    pub fn label(self) -> &'static str {
        match self {
            Tool::CnnParted => "CNNParted",
            Tool::FaultUnaware => "Flt-unware",
            Tool::AFarePart => "AFarePart",
        }
    }
}

/// One cell of Table II: the deployed mapping and its measured metrics.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub tool: Tool,
    pub mapping: Mapping,
    /// Faulty top-1 accuracy (fraction).
    pub acc: f64,
    /// ΔAcc vs clean.
    pub dacc: f64,
    pub latency_ms: f64,
    pub energy_mj: f64,
}

/// Run one strategy under one scenario and score its deployed mapping.
///
/// Scoring always uses a fresh evaluator with the *same* key seed and
/// batch budget so all tools are measured under identical fault draws.
pub fn run_cell(
    exp: &Experiment,
    scenario: FaultScenario,
    nsga2: &Nsga2Config,
    tool: Tool,
) -> Result<CellResult> {
    let mapping = match tool {
        Tool::CnnParted => {
            let mut ev = exp.partition_evaluator(scenario);
            CnnParted::new(nsga2.clone()).partition(&mut ev)?
        }
        Tool::FaultUnaware => {
            let mut ev = exp.partition_evaluator(scenario);
            FaultUnaware::new(nsga2.clone()).partition(&mut ev)?
        }
        Tool::AFarePart => {
            let mut ev = exp.partition_evaluator(scenario);
            // Deployment policy of the paper's evaluation (§V-B): "the
            // system operates with the most robust partition P* selected
            // from the offline Pareto front" — pure min-ΔAcc selection
            // (infinite budget factors), latency tiebreak. The budgeted
            // policy is exercised by the offline CLI/examples instead.
            let runner = OfflineRunner {
                nsga2: nsga2.clone(),
                lat_budget: f64::INFINITY,
                energy_budget: f64::INFINITY,
            };
            runner.run(&mut ev, vec![], |_| {})?.deployed
        }
    };
    score_mapping(exp, scenario, tool, mapping)
}

/// Score an existing mapping under a scenario (shared fault draws).
pub fn score_mapping(
    exp: &Experiment,
    scenario: FaultScenario,
    tool: Tool,
    mapping: Mapping,
) -> Result<CellResult> {
    let mut scorer = exp.partition_evaluator(scenario);
    let acc = scorer.faulty_accuracy(&mapping)?;
    Ok(CellResult {
        tool,
        dacc: (exp.clean_acc - acc).max(0.0),
        acc,
        latency_ms: scorer.latency_ms(&mapping),
        energy_mj: scorer.energy_mj(&mapping),
        mapping,
    })
}

/// Standard bench budget: full-fidelity by default, shrunk under
/// AFARE_BENCH_FAST (set by CI / quick runs).
pub fn bench_budget(fast: bool) -> (ExperimentConfig, Nsga2Config) {
    let nsga2 = if fast {
        Nsga2Config { pop_size: 16, generations: 6, ..Default::default() }
    } else {
        Nsga2Config { pop_size: 24, generations: 12, ..Default::default() }
    };
    let cfg = ExperimentConfig {
        eval_limit: if fast { 64 } else { 128 },
        nsga2: nsga2.clone(),
        ..Default::default()
    };
    (cfg, nsga2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tool_labels() {
        assert_eq!(Tool::all().len(), 3);
        assert_eq!(Tool::AFarePart.label(), "AFarePart");
    }

    #[test]
    fn budgets_shrink_in_fast_mode() {
        let (cfg_fast, n_fast) = bench_budget(true);
        let (cfg_full, n_full) = bench_budget(false);
        assert!(n_fast.pop_size < n_full.pop_size);
        assert!(cfg_fast.eval_limit < cfg_full.eval_limit);
    }
}
