//! Shared experiment cells for the paper-reproduction benches: run one
//! (model, strategy, scenario, FR) cell and report the Table-II metrics —
//! plus synthetic (artifact-free) fixtures for the eval-engine perf bench
//! and the determinism/concurrency test suite.

use anyhow::Result;

use crate::baselines::{CnnParted, FaultUnaware};
use crate::config::ExperimentConfig;
use crate::coordinator::OfflineRunner;
use crate::dataset::EvalSet;
use crate::experiment::Experiment;
use crate::faults::{FaultScenario, RateVectors};
use crate::model::{Manifest, UnitCost};
use crate::nsga2::{Individual, Nsga2Config};
use crate::partition::{Mapping, SensitivityTable};
use crate::util::prng::Rng;

/// The three strategies of Fig. 3 / Fig. 4 / Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tool {
    CnnParted,
    FaultUnaware,
    AFarePart,
}

impl Tool {
    pub fn all() -> [Tool; 3] {
        [Tool::CnnParted, Tool::FaultUnaware, Tool::AFarePart]
    }
    pub fn label(self) -> &'static str {
        match self {
            Tool::CnnParted => "CNNParted",
            Tool::FaultUnaware => "Flt-unware",
            Tool::AFarePart => "AFarePart",
        }
    }
}

/// One cell of Table II: the deployed mapping and its measured metrics.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub tool: Tool,
    pub mapping: Mapping,
    /// Faulty top-1 accuracy (fraction).
    pub acc: f64,
    /// ΔAcc vs clean.
    pub dacc: f64,
    pub latency_ms: f64,
    pub energy_mj: f64,
    /// Fitness evaluations the strategy submitted to find the mapping
    /// (effort parity across tools; 0 when scoring a precomputed mapping).
    pub evaluations: usize,
}

/// Run one strategy under one scenario and score its deployed mapping.
///
/// Scoring always uses a fresh evaluator with the *same* key seed and
/// batch budget so all tools are measured under identical fault draws.
pub fn run_cell(
    exp: &Experiment,
    scenario: FaultScenario,
    nsga2: &Nsga2Config,
    tool: Tool,
) -> Result<CellResult> {
    let (mapping, evaluations) = match tool {
        Tool::CnnParted => {
            let mut ev = exp.partition_evaluator(scenario);
            CnnParted::new(nsga2.clone()).partition_counted(&mut ev)?
        }
        Tool::FaultUnaware => {
            let mut ev = exp.partition_evaluator(scenario);
            FaultUnaware::new(nsga2.clone()).partition_counted(&mut ev)?
        }
        Tool::AFarePart => {
            let mut ev = exp.partition_evaluator(scenario);
            // Deployment policy of the paper's evaluation (§V-B): "the
            // system operates with the most robust partition P* selected
            // from the offline Pareto front" — pure min-ΔAcc selection
            // (infinite budget factors), latency tiebreak. The budgeted
            // policy is exercised by the offline CLI/examples instead.
            let runner = OfflineRunner {
                nsga2: nsga2.clone(),
                lat_budget: f64::INFINITY,
                energy_budget: f64::INFINITY,
            };
            let out = runner.run(&mut ev, vec![], |_| {})?;
            (out.deployed, out.evaluations)
        }
    };
    let mut cell = score_mapping(exp, scenario, tool, mapping)?;
    cell.evaluations = evaluations;
    Ok(cell)
}

/// Score an existing mapping under a scenario (shared fault draws).
pub fn score_mapping(
    exp: &Experiment,
    scenario: FaultScenario,
    tool: Tool,
    mapping: Mapping,
) -> Result<CellResult> {
    let mut scorer = exp.partition_evaluator(scenario);
    let acc = scorer.faulty_accuracy(&mapping)?;
    Ok(CellResult {
        tool,
        dacc: (exp.clean_acc - acc).max(0.0),
        acc,
        latency_ms: scorer.latency_ms(&mapping),
        energy_mj: scorer.energy_mj(&mapping),
        mapping,
        evaluations: 0,
    })
}

/// Synthetic manifest for artifact-free benching and testing: `n` units
/// with varied MAC/weight mixes so mappings have real cost trade-offs.
pub fn synthetic_manifest(n: usize) -> Manifest {
    let units = (0..n)
        .map(|i| UnitCost {
            name: format!("u{i}"),
            kind: if i % 3 == 2 { "dense".into() } else { "conv".into() },
            macs: 800_000 * (i as u64 % 5 + 1),
            w_params: 15_000 * (i as u64 % 3 + 1),
            w_bytes: 15_000 * (i as u64 % 3 + 1),
            in_bytes: 4_096,
            out_bytes: 4_096,
            out_shape: vec![1],
        })
        .collect();
    Manifest {
        model: format!("synthetic-L{n}"),
        num_units: n,
        num_classes: 10,
        precision: 8,
        faulty_bits: 4,
        batch: 8,
        hlo_file: "x".into(),
        weights_file: "x".into(),
        clean_acc_f32: 0.95,
        clean_acc_quant: 0.9,
        weight_scale: 0.01,
        units,
        weight_tensors: vec![],
        act_scales: vec![0.01; n],
    }
}

/// Synthetic layer-sensitivity table matching [`synthetic_manifest`]:
/// early units are markedly more fault-sensitive, so robust mappings are
/// non-trivial.
pub fn synthetic_sensitivity(n: usize) -> SensitivityTable {
    SensitivityTable {
        rate_grid: vec![0.1, 0.2, 0.4],
        w_drop: (0..n)
            .map(|i| {
                let s = 0.3 / (1.0 + i as f64);
                vec![0.5 * s, s, 1.5 * s]
            })
            .collect(),
        a_drop: (0..n).map(|i| vec![0.02 / (1.0 + i as f64); 3]).collect(),
        clean_acc: 0.9,
    }
}

/// Parse `synthetic-L<n>` model names into their unit count; `None` for
/// real (artifact-backed) models.
pub fn synthetic_units(model: &str) -> Option<usize> {
    model.strip_prefix("synthetic-L").and_then(|s| s.parse().ok())
}

/// Ground-truth label for a synthetic sample: FNV-1a over the leading
/// pixel bits, so labels are a pure function of the image bytes and any
/// backend can recompute them.
pub fn synthetic_label(sample: &[f32], num_classes: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in sample.iter().take(16) {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % num_classes as u64) as usize
}

/// Artifact-free eval set: seeded uniform images with labels derived
/// from the image bytes via [`synthetic_label`] (so a zero-fault
/// synthetic inference can score 100% accuracy).
pub fn synthetic_eval_set(
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    num_classes: usize,
    seed: u64,
) -> EvalSet {
    let mut rng = Rng::new(seed);
    let sample_len = h * w * c;
    let images: Vec<f32> = (0..n * sample_len).map(|_| rng.f32()).collect();
    let labels: Vec<i32> = (0..n)
        .map(|i| synthetic_label(&images[i * sample_len..(i + 1) * sample_len], num_classes) as i32)
        .collect();
    EvalSet { n, h, w, c, images, labels }
}

/// Deterministic stand-in for the PJRT inference path: predicts each
/// sample's [`synthetic_label`], flipped to a wrong class with a
/// probability driven by the mean injected fault rate. Pure function of
/// (images, rates, key) — the chaos tests and `synthetic-L*` online
/// serving rely on that purity for bitwise-reproducible timelines.
pub fn synthetic_predictions(
    images: &[f32],
    sample_len: usize,
    num_classes: usize,
    rates: &RateVectors,
    key: [u32; 2],
) -> Vec<usize> {
    let n = images.len() / sample_len;
    let rate_sum: f32 = rates.w_rates.iter().chain(rates.a_rates.iter()).sum();
    let rate_n = (rates.w_rates.len() + rates.a_rates.len()).max(1);
    let p_err = ((rate_sum as f64 / rate_n as f64) * 1.5).min(1.0);
    let key64 = ((key[0] as u64) << 32) | key[1] as u64;
    (0..n)
        .map(|i| {
            let sample = &images[i * sample_len..(i + 1) * sample_len];
            let truth = synthetic_label(sample, num_classes);
            let mut rng = Rng::new(key64 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            if num_classes > 1 && rng.chance(p_err) {
                (truth + 1 + rng.below(num_classes - 1)) % num_classes
            } else {
                truth
            }
        })
        .collect()
}

/// Bitwise fingerprint of a Pareto front (genomes + exact objective
/// bits) — the comparison key of every determinism check (parallel vs
/// serial engine paths, thread-count sweeps).
pub fn front_fingerprint(front: &[Individual]) -> Vec<(Vec<usize>, Vec<u64>)> {
    front
        .iter()
        .map(|i| (i.genome.clone(), i.objectives.iter().map(|o| o.to_bits()).collect()))
        .collect()
}

/// Frozen reference copy of the pre-parallelization serial NSGA-II — the
/// bitwise oracle for the `selection_threads <= 1` legacy contract.
///
/// This module is a verbatim snapshot of the optimizer core as it stood
/// before the parallel selection pipeline landed (same operators, same
/// single config-seeded PRNG, same consumption order), minus telemetry.
/// `bench_perf`'s variation section and the `nsga2_parallel` integration
/// test replay golden seeds through both and require identical
/// [`front_fingerprint`]s, so any accidental behavior change to the
/// serial path in `crate::nsga2` fails loudly. **Do not "fix" or
/// refactor this copy** — drift from the live implementation is exactly
/// what it exists to detect. It predates the NaN guards, so feed it
/// finite objectives only (`partial_cmp().unwrap()` panics otherwise,
/// which was the old behavior).
pub mod legacy_nsga2 {
    use crate::nsga2::{Individual, Nsga2Config, Problem};
    use crate::util::prng::Rng;

    fn dominates(a: &[f64], b: &[f64]) -> bool {
        let mut strictly = false;
        for (x, y) in a.iter().zip(b) {
            if x > y {
                return false;
            }
            if x < y {
                strictly = true;
            }
        }
        strictly
    }

    fn fast_non_dominated_sort(objs: &[&[f64]]) -> Vec<Vec<usize>> {
        let n = objs.len();
        let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut domination_count = vec![0usize; n];
        let mut fronts: Vec<Vec<usize>> = vec![Vec::new()];
        for p in 0..n {
            for q in (p + 1)..n {
                if dominates(objs[p], objs[q]) {
                    dominated_by[p].push(q);
                    domination_count[q] += 1;
                } else if dominates(objs[q], objs[p]) {
                    dominated_by[q].push(p);
                    domination_count[p] += 1;
                }
            }
        }
        for p in 0..n {
            if domination_count[p] == 0 {
                fronts[0].push(p);
            }
        }
        let mut i = 0;
        while !fronts[i].is_empty() {
            let mut next = Vec::new();
            for &p in &fronts[i] {
                for &q in &dominated_by[p] {
                    domination_count[q] -= 1;
                    if domination_count[q] == 0 {
                        next.push(q);
                    }
                }
            }
            i += 1;
            fronts.push(next);
        }
        fronts.pop();
        fronts
    }

    fn crowding_distance(objs: &[&[f64]]) -> Vec<f64> {
        let n = objs.len();
        if n == 0 {
            return Vec::new();
        }
        if n <= 2 {
            return vec![f64::INFINITY; n];
        }
        let m = objs[0].len();
        let mut dist = vec![0.0f64; n];
        let mut idx: Vec<usize> = (0..n).collect();
        for k in 0..m {
            idx.sort_by(|&a, &b| objs[a][k].partial_cmp(&objs[b][k]).unwrap());
            let lo = objs[idx[0]][k];
            let hi = objs[idx[n - 1]][k];
            dist[idx[0]] = f64::INFINITY;
            dist[idx[n - 1]] = f64::INFINITY;
            let range = hi - lo;
            if range <= 0.0 {
                continue;
            }
            for w in 1..n - 1 {
                let prev = objs[idx[w - 1]][k];
                let next = objs[idx[w + 1]][k];
                if dist[idx[w]].is_finite() {
                    dist[idx[w]] += (next - prev) / range;
                }
            }
        }
        dist
    }

    fn rank_population(pop: &mut [Individual]) -> Vec<Vec<usize>> {
        let fronts = {
            let objs: Vec<&[f64]> = pop.iter().map(|i| i.objectives.as_slice()).collect();
            fast_non_dominated_sort(&objs)
        };
        for (rank, front) in fronts.iter().enumerate() {
            let crowd = {
                let front_objs: Vec<&[f64]> =
                    front.iter().map(|&i| pop[i].objectives.as_slice()).collect();
                crowding_distance(&front_objs)
            };
            for (k, &i) in front.iter().enumerate() {
                pop[i].rank = rank;
                pop[i].crowding = crowd[k];
            }
        }
        fronts
    }

    fn tournament<'a>(rng: &mut Rng, pop: &'a [Individual]) -> &'a Individual {
        let a = &pop[rng.below(pop.len())];
        let b = &pop[rng.below(pop.len())];
        if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
            a
        } else {
            b
        }
    }

    fn crossover(
        rng: &mut Rng,
        crossover_prob: f64,
        a: &[usize],
        b: &[usize],
    ) -> (Vec<usize>, Vec<usize>) {
        let n = a.len();
        if !rng.chance(crossover_prob) || n < 2 {
            return (a.to_vec(), b.to_vec());
        }
        if rng.chance(0.5) {
            let mut c = a.to_vec();
            let mut d = b.to_vec();
            for i in 0..n {
                if rng.chance(0.5) {
                    std::mem::swap(&mut c[i], &mut d[i]);
                }
            }
            (c, d)
        } else {
            let (mut i, mut j) = (rng.below(n), rng.below(n));
            if i > j {
                std::mem::swap(&mut i, &mut j);
            }
            let mut c = a.to_vec();
            let mut d = b.to_vec();
            for k in i..=j {
                std::mem::swap(&mut c[k], &mut d[k]);
            }
            (c, d)
        }
    }

    fn mutate(rng: &mut Rng, mutation_prob: f64, genome: &mut [usize], alphabet: usize) {
        for g in genome.iter_mut() {
            if rng.chance(mutation_prob) {
                *g = rng.below(alphabet);
            }
        }
    }

    fn produce_offspring(
        rng: &mut Rng,
        cfg: &Nsga2Config,
        pop: &[Individual],
        alphabet: usize,
    ) -> Vec<Vec<usize>> {
        let mut offspring_genomes = Vec::with_capacity(cfg.pop_size);
        while offspring_genomes.len() < cfg.pop_size {
            let pa = tournament(rng, pop);
            let pb = tournament(rng, pop);
            let (mut c, mut d) = crossover(rng, cfg.crossover_prob, &pa.genome, &pb.genome);
            mutate(rng, cfg.mutation_prob, &mut c, alphabet);
            mutate(rng, cfg.mutation_prob, &mut d, alphabet);
            offspring_genomes.push(c);
            if offspring_genomes.len() < cfg.pop_size {
                offspring_genomes.push(d);
            }
        }
        offspring_genomes
    }

    fn evaluate_all<P: Problem>(problem: &mut P, genomes: Vec<Vec<usize>>) -> Vec<Individual> {
        let objectives = problem.evaluate_batch(&genomes);
        genomes
            .into_iter()
            .zip(objectives)
            .map(|(genome, objectives)| Individual {
                genome,
                objectives,
                rank: usize::MAX,
                crowding: 0.0,
            })
            .collect()
    }

    /// The frozen pre-parallelization run loop: returns the final first
    /// front exactly as `Nsga2::run` did (and `selection_threads <= 1`
    /// still must).
    pub fn run<P: Problem>(cfg: &Nsga2Config, problem: &mut P) -> Vec<Individual> {
        let len = problem.genome_len();
        let alphabet = problem.alphabet();
        assert!(alphabet >= 1 && len >= 1);
        let mut rng = Rng::new(cfg.seed);

        let mut genomes: Vec<Vec<usize>> = problem
            .seeds()
            .into_iter()
            .filter(|g| g.len() == len && g.iter().all(|&x| x < alphabet))
            .take(cfg.pop_size)
            .collect();
        while genomes.len() < cfg.pop_size {
            genomes.push((0..len).map(|_| rng.below(alphabet)).collect());
        }
        let mut pop = evaluate_all(problem, genomes);
        rank_population(&mut pop);

        for _generation in 0..cfg.generations {
            let offspring_genomes = produce_offspring(&mut rng, cfg, &pop, alphabet);
            let offspring = evaluate_all(problem, offspring_genomes);
            pop.extend(offspring);
            let fronts = rank_population(&mut pop);
            let mut next: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
            for front in &fronts {
                if next.len() + front.len() <= cfg.pop_size {
                    for &i in front {
                        next.push(pop[i].clone());
                    }
                } else {
                    let mut rest: Vec<usize> = front.clone();
                    rest.sort_by(|&a, &b| {
                        pop[b]
                            .crowding
                            .partial_cmp(&pop[a].crowding)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    });
                    for &i in rest.iter().take(cfg.pop_size - next.len()) {
                        next.push(pop[i].clone());
                    }
                    break;
                }
            }
            pop = next;
            rank_population(&mut pop);
        }

        let mut front: Vec<Individual> = pop.into_iter().filter(|i| i.rank == 0).collect();
        front.sort_by(|a, b| a.genome.cmp(&b.genome));
        front.dedup_by(|a, b| a.genome == b.genome);
        front
    }
}

/// Standard bench budget: full-fidelity by default, shrunk under
/// AFARE_BENCH_FAST (set by CI / quick runs).
pub fn bench_budget(fast: bool) -> (ExperimentConfig, Nsga2Config) {
    let nsga2 = if fast {
        Nsga2Config { pop_size: 16, generations: 6, ..Default::default() }
    } else {
        Nsga2Config { pop_size: 24, generations: 12, ..Default::default() }
    };
    let cfg = ExperimentConfig {
        eval_limit: if fast { 64 } else { 128 },
        nsga2: nsga2.clone(),
        ..Default::default()
    };
    (cfg, nsga2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tool_labels() {
        assert_eq!(Tool::all().len(), 3);
        assert_eq!(Tool::AFarePart.label(), "AFarePart");
    }

    #[test]
    fn synthetic_fixtures_are_consistent() {
        let m = synthetic_manifest(10);
        let t = synthetic_sensitivity(10);
        assert_eq!(m.units.len(), 10);
        assert_eq!(t.w_drop.len(), 10);
        assert_eq!(t.most_sensitive_unit(), 0);
    }

    #[test]
    fn synthetic_model_names_parse() {
        assert_eq!(synthetic_units("synthetic-L12"), Some(12));
        assert_eq!(synthetic_units("synthetic-L7"), Some(7));
        assert_eq!(synthetic_units("alexnet"), None);
        assert_eq!(synthetic_units("synthetic-Lx"), None);
    }

    #[test]
    fn synthetic_eval_set_labels_match_predictions_at_zero_rate() {
        let eval = synthetic_eval_set(16, 4, 4, 3, 10, 42);
        assert_eq!(eval.images.len(), 16 * 4 * 4 * 3);
        let preds =
            synthetic_predictions(&eval.images, 4 * 4 * 3, 10, &RateVectors::zeros(6), [1, 2]);
        assert_eq!(preds.len(), 16);
        for (p, &l) in preds.iter().zip(&eval.labels) {
            assert_eq!(*p as i32, l, "zero-rate synthetic inference must be exact");
        }
    }

    #[test]
    fn synthetic_predictions_deterministic_and_fault_sensitive() {
        let eval = synthetic_eval_set(32, 4, 4, 3, 10, 7);
        let heavy = RateVectors { w_rates: vec![0.5; 6], a_rates: vec![0.5; 6] };
        let a = synthetic_predictions(&eval.images, 48, 10, &heavy, [9, 9]);
        let b = synthetic_predictions(&eval.images, 48, 10, &heavy, [9, 9]);
        assert_eq!(a, b, "same key must reproduce predictions");
        let clean = synthetic_predictions(&eval.images, 48, 10, &RateVectors::zeros(6), [9, 9]);
        let flipped = a.iter().zip(&clean).filter(|(x, y)| x != y).count();
        assert!(flipped > 0, "heavy faults must flip some predictions");
    }

    #[test]
    fn legacy_oracle_matches_current_serial_path() {
        // the `selection_threads <= 1` bitwise contract, checked for the
        // golden seeds the bench replays
        struct Toy;
        impl crate::nsga2::Problem for Toy {
            fn genome_len(&self) -> usize {
                8
            }
            fn alphabet(&self) -> usize {
                3
            }
            fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
                let sum = g.iter().sum::<usize>() as f64;
                let twos = g.iter().filter(|&&x| x == 2).count() as f64;
                vec![sum, 8.0 - twos]
            }
        }
        for seed in [7u64, 11, 23] {
            let cfg = Nsga2Config { pop_size: 12, generations: 6, seed, ..Default::default() };
            let current = crate::nsga2::Nsga2::new(cfg.clone()).run(&mut Toy, |_| {});
            let legacy = legacy_nsga2::run(&cfg, &mut Toy);
            assert_eq!(
                front_fingerprint(&current),
                front_fingerprint(&legacy),
                "serial path diverged from the frozen pre-PR oracle at seed {seed}"
            );
        }
    }

    #[test]
    fn budgets_shrink_in_fast_mode() {
        let (cfg_fast, n_fast) = bench_budget(true);
        let (cfg_full, n_full) = bench_budget(false);
        assert!(n_fast.pop_size < n_full.pop_size);
        assert!(cfg_fast.eval_limit < cfg_full.eval_limit);
    }
}
