//! Shared experiment cells for the paper-reproduction benches: run one
//! (model, strategy, scenario, FR) cell and report the Table-II metrics —
//! plus synthetic (artifact-free) fixtures for the eval-engine perf bench
//! and the determinism/concurrency test suite.

use anyhow::Result;

use crate::baselines::{CnnParted, FaultUnaware};
use crate::config::ExperimentConfig;
use crate::coordinator::OfflineRunner;
use crate::experiment::Experiment;
use crate::faults::FaultScenario;
use crate::model::{Manifest, UnitCost};
use crate::nsga2::{Individual, Nsga2Config};
use crate::partition::{Mapping, SensitivityTable};

/// The three strategies of Fig. 3 / Fig. 4 / Table II.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tool {
    CnnParted,
    FaultUnaware,
    AFarePart,
}

impl Tool {
    pub fn all() -> [Tool; 3] {
        [Tool::CnnParted, Tool::FaultUnaware, Tool::AFarePart]
    }
    pub fn label(self) -> &'static str {
        match self {
            Tool::CnnParted => "CNNParted",
            Tool::FaultUnaware => "Flt-unware",
            Tool::AFarePart => "AFarePart",
        }
    }
}

/// One cell of Table II: the deployed mapping and its measured metrics.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub tool: Tool,
    pub mapping: Mapping,
    /// Faulty top-1 accuracy (fraction).
    pub acc: f64,
    /// ΔAcc vs clean.
    pub dacc: f64,
    pub latency_ms: f64,
    pub energy_mj: f64,
    /// Fitness evaluations the strategy submitted to find the mapping
    /// (effort parity across tools; 0 when scoring a precomputed mapping).
    pub evaluations: usize,
}

/// Run one strategy under one scenario and score its deployed mapping.
///
/// Scoring always uses a fresh evaluator with the *same* key seed and
/// batch budget so all tools are measured under identical fault draws.
pub fn run_cell(
    exp: &Experiment,
    scenario: FaultScenario,
    nsga2: &Nsga2Config,
    tool: Tool,
) -> Result<CellResult> {
    let (mapping, evaluations) = match tool {
        Tool::CnnParted => {
            let mut ev = exp.partition_evaluator(scenario);
            CnnParted::new(nsga2.clone()).partition_counted(&mut ev)?
        }
        Tool::FaultUnaware => {
            let mut ev = exp.partition_evaluator(scenario);
            FaultUnaware::new(nsga2.clone()).partition_counted(&mut ev)?
        }
        Tool::AFarePart => {
            let mut ev = exp.partition_evaluator(scenario);
            // Deployment policy of the paper's evaluation (§V-B): "the
            // system operates with the most robust partition P* selected
            // from the offline Pareto front" — pure min-ΔAcc selection
            // (infinite budget factors), latency tiebreak. The budgeted
            // policy is exercised by the offline CLI/examples instead.
            let runner = OfflineRunner {
                nsga2: nsga2.clone(),
                lat_budget: f64::INFINITY,
                energy_budget: f64::INFINITY,
            };
            let out = runner.run(&mut ev, vec![], |_| {})?;
            (out.deployed, out.evaluations)
        }
    };
    let mut cell = score_mapping(exp, scenario, tool, mapping)?;
    cell.evaluations = evaluations;
    Ok(cell)
}

/// Score an existing mapping under a scenario (shared fault draws).
pub fn score_mapping(
    exp: &Experiment,
    scenario: FaultScenario,
    tool: Tool,
    mapping: Mapping,
) -> Result<CellResult> {
    let mut scorer = exp.partition_evaluator(scenario);
    let acc = scorer.faulty_accuracy(&mapping)?;
    Ok(CellResult {
        tool,
        dacc: (exp.clean_acc - acc).max(0.0),
        acc,
        latency_ms: scorer.latency_ms(&mapping),
        energy_mj: scorer.energy_mj(&mapping),
        mapping,
        evaluations: 0,
    })
}

/// Synthetic manifest for artifact-free benching and testing: `n` units
/// with varied MAC/weight mixes so mappings have real cost trade-offs.
pub fn synthetic_manifest(n: usize) -> Manifest {
    let units = (0..n)
        .map(|i| UnitCost {
            name: format!("u{i}"),
            kind: if i % 3 == 2 { "dense".into() } else { "conv".into() },
            macs: 800_000 * (i as u64 % 5 + 1),
            w_params: 15_000 * (i as u64 % 3 + 1),
            w_bytes: 15_000 * (i as u64 % 3 + 1),
            in_bytes: 4_096,
            out_bytes: 4_096,
            out_shape: vec![1],
        })
        .collect();
    Manifest {
        model: format!("synthetic-L{n}"),
        num_units: n,
        num_classes: 10,
        precision: 8,
        faulty_bits: 4,
        batch: 8,
        hlo_file: "x".into(),
        weights_file: "x".into(),
        clean_acc_f32: 0.95,
        clean_acc_quant: 0.9,
        weight_scale: 0.01,
        units,
        weight_tensors: vec![],
        act_scales: vec![0.01; n],
    }
}

/// Synthetic layer-sensitivity table matching [`synthetic_manifest`]:
/// early units are markedly more fault-sensitive, so robust mappings are
/// non-trivial.
pub fn synthetic_sensitivity(n: usize) -> SensitivityTable {
    SensitivityTable {
        rate_grid: vec![0.1, 0.2, 0.4],
        w_drop: (0..n)
            .map(|i| {
                let s = 0.3 / (1.0 + i as f64);
                vec![0.5 * s, s, 1.5 * s]
            })
            .collect(),
        a_drop: (0..n).map(|i| vec![0.02 / (1.0 + i as f64); 3]).collect(),
        clean_acc: 0.9,
    }
}

/// Bitwise fingerprint of a Pareto front (genomes + exact objective
/// bits) — the comparison key of every determinism check (parallel vs
/// serial engine paths, thread-count sweeps).
pub fn front_fingerprint(front: &[Individual]) -> Vec<(Vec<usize>, Vec<u64>)> {
    front
        .iter()
        .map(|i| (i.genome.clone(), i.objectives.iter().map(|o| o.to_bits()).collect()))
        .collect()
}

/// Standard bench budget: full-fidelity by default, shrunk under
/// AFARE_BENCH_FAST (set by CI / quick runs).
pub fn bench_budget(fast: bool) -> (ExperimentConfig, Nsga2Config) {
    let nsga2 = if fast {
        Nsga2Config { pop_size: 16, generations: 6, ..Default::default() }
    } else {
        Nsga2Config { pop_size: 24, generations: 12, ..Default::default() }
    };
    let cfg = ExperimentConfig {
        eval_limit: if fast { 64 } else { 128 },
        nsga2: nsga2.clone(),
        ..Default::default()
    };
    (cfg, nsga2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tool_labels() {
        assert_eq!(Tool::all().len(), 3);
        assert_eq!(Tool::AFarePart.label(), "AFarePart");
    }

    #[test]
    fn synthetic_fixtures_are_consistent() {
        let m = synthetic_manifest(10);
        let t = synthetic_sensitivity(10);
        assert_eq!(m.units.len(), 10);
        assert_eq!(t.w_drop.len(), 10);
        assert_eq!(t.most_sensitive_unit(), 0);
    }

    #[test]
    fn budgets_shrink_in_fast_mode() {
        let (cfg_fast, n_fast) = bench_budget(true);
        let (cfg_full, n_full) = bench_budget(false);
        assert!(n_fast.pop_size < n_full.pop_size);
        assert!(cfg_fast.eval_limit < cfg_full.eval_limit);
    }
}
