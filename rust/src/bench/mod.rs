//! Micro-benchmark harness (criterion is unavailable offline —
//! DESIGN.md §9): warmup + timed samples + summary statistics, plus a
//! stopwatch for one-shot phase timings. Used by the `rust/benches/*`
//! binaries, which `cargo bench` runs with `harness = false`.

pub mod suite;

use std::time::Instant;

use crate::util::fmt::Table;
use crate::util::stats::{summarize, Summary};

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, sample_iters: 10 }
    }
}

/// Time a closure: `warmup_iters` unrecorded runs, then `sample_iters`
/// timed runs. Returns per-iteration milliseconds.
pub fn bench_ms<F: FnMut()>(cfg: BenchConfig, mut f: F) -> Summary {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.sample_iters);
    for _ in 0..cfg.sample_iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize(&samples)
}

/// One-shot stopwatch (phases too expensive to repeat).
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Collects named timing rows and renders the standard bench table.
#[derive(Default)]
pub struct BenchReport {
    rows: Vec<(String, Summary)>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    pub fn add(&mut self, name: impl Into<String>, s: Summary) {
        self.rows.push((name.into(), s));
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&["benchmark", "mean ms", "p50 ms", "p95 ms", "min ms", "n"]);
        for (name, s) in &self.rows {
            t.row(vec![
                name.clone(),
                format!("{:.3}", s.mean),
                format!("{:.3}", s.p50),
                format!("{:.3}", s.p95),
                format!("{:.3}", s.min),
                s.n.to_string(),
            ]);
        }
        t.render()
    }
}

/// Standard bench preamble: prints the bench name and returns eval-budget
/// overrides from the environment (AFARE_BENCH_FAST shrinks budgets for CI).
pub fn bench_header(name: &str) -> bool {
    let fast = std::env::var("AFARE_BENCH_FAST").is_ok();
    println!("\n=== {name} {}===", if fast { "(fast mode) " } else { "" });
    fast
}

/// Write a machine-readable bench result next to the repo root (e.g.
/// `BENCH_eval_engine.json`) so later PRs can track perf trajectories.
/// Prints the destination; errors are reported, not fatal — a read-only
/// checkout shouldn't kill a bench run.
pub fn write_json_result(path: &str, value: &crate::util::json::Value) {
    let text = crate::util::json::to_string(value);
    match std::fs::write(path, &text) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let s = bench_ms(BenchConfig { warmup_iters: 1, sample_iters: 5 }, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
        assert!(s.min <= s.p95);
    }

    #[test]
    fn report_renders_rows() {
        let mut r = BenchReport::new();
        r.add("x", summarize(&[1.0, 2.0, 3.0]));
        let out = r.render();
        assert!(out.contains('x'));
        assert!(out.contains("2.000"));
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.ms() >= 1.0);
    }
}
