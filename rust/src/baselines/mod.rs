//! Comparator strategies of the paper's evaluation (§VI):
//!
//! * [`CnnParted`] — re-implementation of CNNParted's published strategy:
//!   fault-agnostic NSGA-II over {latency, energy} *including* link
//!   latency/energy, with aggressive perf/energy selection (min normalized
//!   latency+energy sum).
//! * [`FaultUnaware`] — the paper's own "fault-unaware base model": the
//!   same optimizer stack as AFarePart with the ΔAcc objective removed and
//!   no link costs, knee-point selection ("alternative partitioning
//!   strategies" — §VI-D explains why it sometimes lands on more resilient
//!   mappings than CNNParted despite being equally fault-agnostic).
//! * [`greedy_latency_mapping`] / [`random_search_mapping`] — sanity
//!   baselines used by the ablation bench.

mod cnnparted;
mod fault_unaware;
mod greedy;
mod random_search;

pub use cnnparted::CnnParted;
pub use fault_unaware::FaultUnaware;
pub use greedy::greedy_latency_mapping;
pub use random_search::random_search_mapping;
