//! CNNParted baseline (Kreß et al. 2023): fault-agnostic NSGA-II over
//! {latency, energy} with link costs modeled, selecting aggressively for
//! combined performance/efficiency.

use anyhow::Result;

use crate::coordinator::offline::optimize_partitions_counted;
use crate::nsga2::{Individual, Nsga2Config};
use crate::partition::{Mapping, PartitionEvaluator};

/// CNNParted-style partitioner.
pub struct CnnParted {
    pub nsga2: Nsga2Config,
}

impl Default for CnnParted {
    fn default() -> Self {
        CnnParted { nsga2: Nsga2Config::default() }
    }
}

impl CnnParted {
    pub fn new(nsga2: Nsga2Config) -> Self {
        CnnParted { nsga2 }
    }

    /// Aggressive perf/energy selection: min of normalized latency+energy.
    pub fn select(front: &[Individual]) -> Option<&Individual> {
        if front.is_empty() {
            return None;
        }
        let min_l = front.iter().map(|i| i.objectives[0]).fold(f64::INFINITY, f64::min);
        let max_l = front.iter().map(|i| i.objectives[0]).fold(f64::NEG_INFINITY, f64::max);
        let min_e = front.iter().map(|i| i.objectives[1]).fold(f64::INFINITY, f64::min);
        let max_e = front.iter().map(|i| i.objectives[1]).fold(f64::NEG_INFINITY, f64::max);
        let norm = |x: f64, lo: f64, hi: f64| if hi > lo { (x - lo) / (hi - lo) } else { 0.0 };
        front.iter().min_by(|a, b| {
            let sa = norm(a.objectives[0], min_l, max_l) + norm(a.objectives[1], min_e, max_e);
            let sb = norm(b.objectives[0], min_l, max_l) + norm(b.objectives[1], min_e, max_e);
            sa.partial_cmp(&sb).unwrap()
        })
    }

    /// Run the CNNParted flow; link costs are enabled for the duration of
    /// the optimization (CNNParted models them; AFarePart doesn't — §VI-E).
    /// Two-objective batches skip the ΔAcc engine entirely, so the
    /// baseline rides the same batched NSGA-II loop at zero fault cost.
    pub fn partition(&self, ev: &mut PartitionEvaluator) -> Result<Mapping> {
        Ok(self.partition_counted(ev)?.0)
    }

    /// [`CnnParted::partition`] plus the submitted evaluation count
    /// (effort-parity reporting — see `bench::suite::run_cell`).
    pub fn partition_counted(&self, ev: &mut PartitionEvaluator) -> Result<(Mapping, usize)> {
        let saved_link = ev.include_link_cost;
        ev.include_link_cost = true;
        let (front, evals) = optimize_partitions_counted(ev, &self.nsga2, false, vec![], |_| {});
        ev.include_link_cost = saved_link;
        let chosen = Self::select(&front).expect("empty CNNParted front");
        Ok((Mapping(chosen.genome.clone()), evals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(l: f64, e: f64) -> Individual {
        Individual { genome: vec![0], objectives: vec![l, e], rank: 0, crowding: 0.0 }
    }

    #[test]
    fn selects_aggressive_perf_energy() {
        let front = vec![ind(10.0, 9.0), ind(11.0, 5.0), ind(30.0, 4.9)];
        // normalized sums: a=0+1=1.0, b=0.05+~0.02=0.07, c=1+0=1.0
        let sel = CnnParted::select(&front).unwrap();
        assert_eq!(sel.objectives[0], 11.0);
    }

    #[test]
    fn empty_front_none() {
        assert!(CnnParted::select(&[]).is_none());
    }
}
