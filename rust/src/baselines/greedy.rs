//! Greedy per-unit baseline: each unit goes to its individually fastest
//! (or most efficient) device — no global view, no fault awareness.

use crate::partition::{Mapping, PartitionEvaluator};

/// Assign each unit to the device minimizing
/// `alpha * latency + (1-alpha) * energy` for that unit alone.
///
/// Without link costs (the common case) each candidate device for unit
/// `l` is scored via the evaluator's O(changed-genes) incremental update
/// ([`PartitionEvaluator::lat_en_delta`]) against a shared base mapping,
/// making the sweep O(L·D) instead of the former O(L²·D) full
/// re-evaluations; additivity of the cost model makes the delta exact.
/// With link costs enabled the incremental path is invalid (a gene change
/// perturbs boundary terms), so the sweep falls back to full evaluations
/// of single-gene variants — the pre-engine behavior.
pub fn greedy_latency_mapping(ev: &PartitionEvaluator, alpha: f64) -> Mapping {
    let n = ev.num_units();
    let d = ev.num_devices();
    let base = Mapping::all_on(0, n);
    let score = |(lat, en): (f64, f64)| alpha * lat + (1.0 - alpha) * en;
    let base_cost = ev.lat_en(&base);
    let mut genes = Vec::with_capacity(n);
    for l in 0..n {
        let mut best = 0;
        let mut best_cost = f64::INFINITY;
        for dev in 0..d {
            let cost = if ev.include_link_cost {
                let mut m = base.clone();
                m.0[l] = dev;
                score(ev.lat_en(&m))
            } else {
                score(ev.lat_en_delta(&base, base_cost, &[(l, dev)]))
            };
            if cost < best_cost {
                best_cost = cost;
                best = dev;
            }
        }
        genes.push(best);
    }
    Mapping(genes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultScenario;
    use crate::hw::Platform;
    use crate::model::{Manifest, UnitCost};
    use crate::partition::DaccMode;

    #[test]
    fn greedy_picks_per_unit_minimum() {
        let units = vec![
            UnitCost {
                name: "small".into(),
                kind: "conv".into(),
                macs: 10_000,
                w_params: 100,
                w_bytes: 100,
                in_bytes: 100,
                out_bytes: 100,
                out_shape: vec![1],
            },
            UnitCost {
                name: "bigfc".into(),
                kind: "dense".into(),
                macs: 80_000_000,
                w_params: 1_000_000,
                w_bytes: 1_000_000,
                in_bytes: 100,
                out_bytes: 10,
                out_shape: vec![10],
            },
        ];
        let m = Manifest {
            model: "t".into(),
            num_units: 2,
            num_classes: 10,
            precision: 8,
            faulty_bits: 4,
            batch: 4,
            hlo_file: "x".into(),
            weights_file: "x".into(),
            clean_acc_f32: 0.9,
            clean_acc_quant: 0.9,
            weight_scale: 0.01,
            units,
            weight_tensors: vec![],
            act_scales: vec![0.1, 0.1],
        };
        let p = Platform::default_two_device();
        let ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::WeightOnly,
            0.9,
            false,
            DaccMode::None,
        );
        let map = greedy_latency_mapping(&ev, 1.0);
        // tiny conv -> eyeriss (0), massive dense -> simba (1)
        assert_eq!(map.0, vec![0, 1]);
    }
}
