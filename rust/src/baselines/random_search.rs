//! Random-search baseline: same evaluation budget as NSGA-II, no
//! evolutionary structure. Used by the ablation bench to show the
//! optimizer earns its keep.

use anyhow::Result;

use crate::partition::{Mapping, PartitionEvaluator};
use crate::util::prng::Rng;

/// Sample `budget` random mappings; return the one minimizing
/// `w_lat*lat + w_en*energy + w_dacc*dacc` (a scalarization — random
/// search has no Pareto machinery).
///
/// All samples are drawn up front and scored through the batched
/// evaluation engine (`objectives_batch`): duplicate and rate-equivalent
/// samples — common at small D^L — are deduplicated against the ΔAcc
/// cache, and residual exact evaluations fan out across the evaluator's
/// worker threads. Sampling order (and thus the PRNG stream and the
/// selected mapping) is identical to the former one-at-a-time loop.
pub fn random_search_mapping(
    ev: &mut PartitionEvaluator,
    budget: usize,
    weights: (f64, f64, f64),
    seed: u64,
) -> Result<Mapping> {
    let mut rng = Rng::new(seed);
    let (n, d) = (ev.num_units(), ev.num_devices());
    let mappings: Vec<Mapping> =
        (0..budget).map(|_| Mapping::random(&mut rng, n, d)).collect();
    let objectives = ev.objectives_batch(&mappings, true)?;
    let mut best: Option<(f64, usize)> = None;
    for (i, objs) in objectives.iter().enumerate() {
        let score = weights.0 * objs[0] + weights.1 * objs[1] + weights.2 * objs[2];
        if best.map(|(s, _)| score < s).unwrap_or(true) {
            best = Some((score, i));
        }
    }
    let (_, i) = best.expect("budget > 0");
    Ok(mappings.into_iter().nth(i).expect("index in range"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultScenario;
    use crate::hw::Platform;
    use crate::model::{Manifest, UnitCost};
    use crate::partition::DaccMode;

    #[test]
    fn finds_low_latency_mapping_with_budget() {
        let units = (0..4)
            .map(|i| UnitCost {
                name: format!("u{i}"),
                kind: "conv".into(),
                macs: 1_000_000,
                w_params: 1_000,
                w_bytes: 1_000,
                in_bytes: 1_000,
                out_bytes: 1_000,
                out_shape: vec![1],
            })
            .collect();
        let m = Manifest {
            model: "t".into(),
            num_units: 4,
            num_classes: 10,
            precision: 8,
            faulty_bits: 4,
            batch: 4,
            hlo_file: "x".into(),
            weights_file: "x".into(),
            clean_acc_f32: 0.9,
            clean_acc_quant: 0.9,
            weight_scale: 0.01,
            units,
            weight_tensors: vec![],
            act_scales: vec![0.1; 4],
        };
        let p = Platform::default_two_device();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::WeightOnly,
            0.9,
            false,
            DaccMode::None,
        );
        let best = random_search_mapping(&mut ev, 64, (1.0, 0.0, 0.0), 3).unwrap();
        // with 2^4=16 mappings and budget 64, the optimum is found
        let lat_best = ev.latency_ms(&best);
        for bits in 0..16usize {
            let m = Mapping((0..4).map(|i| (bits >> i) & 1).collect());
            assert!(lat_best <= ev.latency_ms(&m) + 1e-12);
        }
    }
}
