//! Random-search baseline: same evaluation budget as NSGA-II, no
//! evolutionary structure. Used by the ablation bench to show the
//! optimizer earns its keep.

use anyhow::Result;

use crate::partition::{Mapping, PartitionEvaluator};
use crate::util::prng::Rng;

/// Sample `budget` random mappings; return the one minimizing
/// `w_lat*lat + w_en*energy + w_dacc*dacc` (a scalarization — random
/// search has no Pareto machinery).
pub fn random_search_mapping(
    ev: &mut PartitionEvaluator,
    budget: usize,
    weights: (f64, f64, f64),
    seed: u64,
) -> Result<Mapping> {
    let mut rng = Rng::new(seed);
    let (n, d) = (ev.num_units(), ev.num_devices());
    let mut best: Option<(f64, Mapping)> = None;
    for _ in 0..budget {
        let m = Mapping::random(&mut rng, n, d);
        let lat = ev.latency_ms(&m);
        let en = ev.energy_mj(&m);
        let da = ev.dacc(&m)?;
        let score = weights.0 * lat + weights.1 * en + weights.2 * da;
        if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
            best = Some((score, m));
        }
    }
    Ok(best.expect("budget > 0").1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultScenario;
    use crate::hw::Platform;
    use crate::model::{Manifest, UnitCost};
    use crate::partition::DaccMode;

    #[test]
    fn finds_low_latency_mapping_with_budget() {
        let units = (0..4)
            .map(|i| UnitCost {
                name: format!("u{i}"),
                kind: "conv".into(),
                macs: 1_000_000,
                w_params: 1_000,
                w_bytes: 1_000,
                in_bytes: 1_000,
                out_bytes: 1_000,
                out_shape: vec![1],
            })
            .collect();
        let m = Manifest {
            model: "t".into(),
            num_units: 4,
            num_classes: 10,
            precision: 8,
            faulty_bits: 4,
            batch: 4,
            hlo_file: "x".into(),
            weights_file: "x".into(),
            clean_acc_f32: 0.9,
            clean_acc_quant: 0.9,
            weight_scale: 0.01,
            units,
            weight_tensors: vec![],
            act_scales: vec![0.1; 4],
        };
        let p = Platform::default_two_device();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::WeightOnly,
            0.9,
            false,
            DaccMode::None,
        );
        let best = random_search_mapping(&mut ev, 64, (1.0, 0.0, 0.0), 3).unwrap();
        // with 2^4=16 mappings and budget 64, the optimum is found
        let lat_best = ev.latency_ms(&best);
        for bits in 0..16usize {
            let m = Mapping((0..4).map(|i| (bits >> i) & 1).collect());
            assert!(lat_best <= ev.latency_ms(&m) + 1e-12);
        }
    }
}
