//! The paper's fault-unaware base model (§VI-A): AFarePart's optimizer
//! with the ΔAcc objective removed. No link costs; knee-point selection.

use anyhow::Result;

use crate::coordinator::offline::optimize_partitions_counted;
use crate::nsga2::{Individual, Nsga2Config};
use crate::partition::{select_knee, Mapping, PartitionEvaluator};

/// Fault-unaware two-objective partitioner.
pub struct FaultUnaware {
    pub nsga2: Nsga2Config,
}

impl Default for FaultUnaware {
    fn default() -> Self {
        FaultUnaware { nsga2: Nsga2Config::default() }
    }
}

impl FaultUnaware {
    pub fn new(nsga2: Nsga2Config) -> Self {
        FaultUnaware { nsga2 }
    }

    /// Knee-point selection over the 2-objective front.
    pub fn select(front: &[Individual]) -> Option<&Individual> {
        select_knee(front)
    }

    pub fn partition(&self, ev: &mut PartitionEvaluator) -> Result<Mapping> {
        Ok(self.partition_counted(ev)?.0)
    }

    /// [`FaultUnaware::partition`] plus the submitted evaluation count
    /// (effort-parity reporting — see `bench::suite::run_cell`).
    pub fn partition_counted(&self, ev: &mut PartitionEvaluator) -> Result<(Mapping, usize)> {
        let saved_link = ev.include_link_cost;
        ev.include_link_cost = false;
        let (front, evals) = optimize_partitions_counted(ev, &self.nsga2, false, vec![], |_| {});
        ev.include_link_cost = saved_link;
        let chosen = Self::select(&front).expect("empty fault-unaware front");
        Ok((Mapping(chosen.genome.clone()), evals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knee_selection_balances() {
        let ind = |l: f64, e: f64| Individual {
            genome: vec![0],
            objectives: vec![l, e],
            rank: 0,
            crowding: 0.0,
        };
        let front = vec![ind(10.0, 100.0), ind(12.0, 20.0), ind(100.0, 10.0)];
        let sel = FaultUnaware::select(&front).unwrap();
        assert_eq!(sel.objectives[0], 12.0);
    }
}
