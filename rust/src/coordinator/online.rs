//! Online phase (paper Algorithm 1, lines 13–19): dynamic accuracy-aware
//! repartitioning.
//!
//! The system serves inference with the deployed partition P* while the
//! fault environment drifts. A rolling accuracy monitor (labeled canary
//! batches) compares observed accuracy against A_clean; when
//! `A_clean − A_rolling > θ` the coordinator re-invokes NSGA-II with the
//! *current* environment rates ("RunNSGAIIWithCurrentStats"), seeded with
//! the incumbent mapping, and swaps in the new P'.
//!
//! # Batched canary traffic
//!
//! Canary batches flow through the inference server as a *pipeline*: up
//! to `lookahead` future ticks are speculatively submitted ahead of the
//! tick being consumed, so client-side batch preparation overlaps the
//! server's PJRT execution instead of strictly alternating with it (the
//! ROADMAP's "batch the monitor's PJRT traffic through the same engine"
//! item — the serving analogue of the PR-1 batched ΔAcc engine, whose
//! worker budget also provides the default depth).
//!
//! Determinism: the timeline is bitwise identical at any lookahead.
//! Each tick's PRNG key is drawn exactly once, in tick order, and cached
//! (speculation *pre*-draws keys but never re-draws them); a tick's
//! rates depend only on its timestamp; and when a reconfiguration
//! changes the mapping, every speculative batch submitted under the old
//! mapping is discarded and resubmitted with the new mapping and the
//! *same* cached key. At `lookahead = 1` the loop degenerates to the
//! pre-pipelined serve-one-wait-one behaviour.

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;
use std::time::Instant;

use anyhow::{Context, Result};

use super::metrics::Metrics;
use super::offline::optimize_partitions_counted;
use super::server::{InferJob, InferReply, InferenceServer};
use crate::dataset::EvalSet;
use crate::faults::FaultEnv;
use crate::nsga2::Nsga2Config;
use crate::partition::{
    select_min_dacc_within_budget, CacheStats, Mapping, PartitionEvaluator,
};
use crate::util::prng::Rng;
use crate::util::stats::RollingMean;

/// Online-phase configuration.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Accuracy-drop threshold θ that triggers repartitioning (paper: 1%
    /// — we default to 5% for the drifting-attack demo; configurable).
    pub theta: f64,
    /// Rolling monitor window (batches).
    pub window: usize,
    /// Simulated seconds per served batch (drives the drift schedule).
    pub tick_seconds: f64,
    /// Number of batches to serve.
    pub ticks: usize,
    /// NSGA-II budget for re-optimization (smaller than offline).
    pub reopt: Nsga2Config,
    /// Budget factors for P' selection.
    pub lat_budget: f64,
    pub energy_budget: f64,
    /// Cooldown (ticks) after a reconfiguration before the next trigger.
    pub cooldown: usize,
    pub seed: u64,
    /// Canary pipeline depth: how many ticks may be in flight at the
    /// inference server at once. 1 = serve-one-wait-one (the legacy
    /// loop); results are bitwise identical at any depth.
    pub lookahead: usize,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            theta: 0.05,
            window: 8,
            tick_seconds: 1.0,
            ticks: 120,
            reopt: Nsga2Config { pop_size: 16, generations: 6, ..Default::default() },
            // Wider than the offline defaults: while a fault attack is in
            // progress, accuracy dominates the trade-off (the ablation
            // bench showed 1.6x budgets pin sensitive units to the
            // attacked device because robust mappings cost ~2-3x energy
            // on this platform).
            lat_budget: 2.5,
            energy_budget: 4.0,
            cooldown: 10,
            seed: 11,
            lookahead: 1,
        }
    }
}

/// One timeline sample of the serving run.
#[derive(Clone, Debug)]
pub struct TimelinePoint {
    pub tick: usize,
    pub sim_time_s: f64,
    /// Environment weight-fault rate on the most fault-prone device.
    pub env_rate_dev0: f32,
    pub batch_accuracy: f64,
    pub rolling_accuracy: f64,
    pub mapping: Mapping,
    pub reconfigured: bool,
}

/// Result of an online run.
#[derive(Debug)]
pub struct OnlineOutcome {
    pub timeline: Vec<TimelinePoint>,
    pub metrics: Metrics,
    pub final_mapping: Mapping,
    /// Cumulative ΔAcc-cache statistics across every environment epoch of
    /// the run (each reconfiguration rolls the cache to a new epoch; the
    /// lifetime counters keep the history the per-epoch view drops).
    pub cache_lifetime: CacheStats,
}

/// The online coordinator.
pub struct OnlineRunner<'a, 'b> {
    pub cfg: OnlineConfig,
    pub server: &'a InferenceServer,
    pub evaluator: &'b mut PartitionEvaluator<'a>,
    pub clean_acc: f64,
}

impl OnlineRunner<'_, '_> {
    /// Serve `cfg.ticks` labeled batches from `eval` under the drifting
    /// `env`, monitoring accuracy and repartitioning on θ violations.
    pub fn run(
        &mut self,
        eval: &EvalSet,
        env: &FaultEnv,
        initial: Mapping,
        mut on_tick: impl FnMut(&TimelinePoint),
    ) -> Result<OnlineOutcome> {
        let batch = self.server.batch;
        let sample_len = eval.h * eval.w * eval.c;
        let n_batches_avail = eval.n / batch;
        assert!(n_batches_avail > 0, "eval set smaller than a batch");
        let lookahead = self.cfg.lookahead.max(1);
        let tick_seconds = self.cfg.tick_seconds;

        let mut mapping = initial;
        let mut monitor = RollingMean::new(self.cfg.window);
        let mut metrics = Metrics::default();
        let mut timeline = Vec::with_capacity(self.cfg.ticks);
        let mut rng = Rng::new(self.cfg.seed);
        let mut cooldown = 0usize;

        // Per-tick PRNG keys, drawn lazily but exactly once each and in
        // strictly increasing tick order — speculation must consume the
        // PRNG in the same order as the serial loop.
        let mut keys: Vec<[u32; 2]> = Vec::with_capacity(self.cfg.ticks);
        // In-flight speculative canary batches, in tick order.
        let mut pending: VecDeque<(usize, Receiver<InferReply>)> = VecDeque::new();
        // Next tick not yet submitted to the server.
        let mut next_submit = 0usize;

        // Submit one canary batch for `tick` under `mapping`.
        let submit = |tick: usize,
                      mapping: &Mapping,
                      keys: &mut Vec<[u32; 2]>,
                      rng: &mut Rng,
                      server: &InferenceServer,
                      scenario: crate::faults::FaultScenario|
         -> Result<Receiver<InferReply>> {
            while keys.len() <= tick {
                keys.push([rng.next_u32(), rng.next_u32()]);
            }
            let t_s = tick as f64 * tick_seconds;
            let rates = crate::faults::RateVectors::from_mapping(
                &mapping.0,
                &env.dev_w_rates(t_s),
                &env.dev_a_rates(t_s),
                scenario,
            );
            let bi = tick % n_batches_avail;
            let images = eval.batch_images(bi * batch, batch).to_vec();
            debug_assert_eq!(images.len(), batch * sample_len);
            let (reply_tx, reply_rx) = std::sync::mpsc::channel();
            server.submit(InferJob {
                images,
                n_valid: batch,
                rates,
                key: keys[tick],
                reply: reply_tx,
            })?;
            Ok(reply_rx)
        };

        for tick in 0..self.cfg.ticks {
            // keep up to `lookahead` ticks in flight
            while next_submit < self.cfg.ticks && next_submit < tick + lookahead {
                let rx = submit(
                    next_submit,
                    &mapping,
                    &mut keys,
                    &mut rng,
                    self.server,
                    self.evaluator.scenario,
                )?;
                pending.push_back((next_submit, rx));
                next_submit += 1;
            }

            let t_s = tick as f64 * self.cfg.tick_seconds;
            let dev_w = env.dev_w_rates(t_s);
            let dev_a = env.dev_a_rates(t_s);

            let (served_tick, rx) = pending.pop_front().expect("pipeline starved");
            debug_assert_eq!(served_tick, tick);
            let reply = rx.recv().context("inference worker dropped reply")?;
            metrics.record_batch(batch, reply.exec_ms);

            let bi = tick % n_batches_avail;
            let labels = eval.batch_labels(bi * batch, batch);
            let hits = reply
                .preds
                .iter()
                .zip(labels)
                .filter(|(p, &l)| **p as i32 == l)
                .count();
            let acc = hits as f64 / batch as f64;
            monitor.push(acc);
            let rolling = monitor.mean().unwrap_or(acc);

            // θ trigger (Algorithm 1 line 16)
            let mut reconfigured = false;
            if cooldown > 0 {
                cooldown -= 1;
            } else if monitor.is_warm() && self.clean_acc - rolling > self.cfg.theta {
                let t0 = Instant::now();
                // RunNSGAIIWithCurrentStats: current environment rates,
                // seeded with the incumbent mapping. The rollover keeps
                // cumulative cache telemetry even though the per-epoch
                // view (correctly) starts from zero under the new rates.
                let rollover = self.evaluator.set_env_rates(dev_w.clone(), dev_a.clone());
                let (front, reopt_evals) = optimize_partitions_counted(
                    self.evaluator,
                    &self.cfg.reopt,
                    true,
                    vec![mapping.clone()],
                    |_| {},
                );
                if let Some(chosen) = select_min_dacc_within_budget(
                    &front,
                    self.cfg.lat_budget,
                    self.cfg.energy_budget,
                ) {
                    let new_mapping = Mapping(chosen.genome.clone());
                    reconfigured = new_mapping != mapping;
                    mapping = new_mapping;
                }
                metrics.record_reconfiguration(
                    reopt_evals,
                    t0.elapsed().as_secs_f64() * 1e3,
                );
                metrics.record_cache_epoch(rollover.ended_epoch);
                // reset the monitor so stale pre-reconfig samples don't
                // immediately re-trigger
                monitor = RollingMean::new(self.cfg.window);
                cooldown = self.cfg.cooldown;
                if reconfigured {
                    // speculative batches were computed under the old
                    // mapping: discard and resubmit from tick+1 with the
                    // new mapping and the *same* cached per-tick keys
                    metrics.speculative_discarded += pending.len();
                    pending.clear();
                    next_submit = tick + 1;
                }
            }

            let point = TimelinePoint {
                tick,
                sim_time_s: t_s,
                env_rate_dev0: dev_w[0],
                batch_accuracy: acc,
                rolling_accuracy: rolling,
                mapping: mapping.clone(),
                reconfigured,
            };
            on_tick(&point);
            timeline.push(point);
        }

        Ok(OnlineOutcome {
            timeline,
            metrics,
            final_mapping: mapping,
            cache_lifetime: self.evaluator.cache_lifetime_stats(),
        })
    }
}
