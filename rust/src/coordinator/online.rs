//! Online phase (paper Algorithm 1, lines 13–19): dynamic accuracy-aware
//! repartitioning.
//!
//! The system serves inference with the deployed partition P* while the
//! fault environment drifts. A rolling accuracy monitor (labeled canary
//! batches) compares observed accuracy against A_clean; when
//! `A_clean − A_rolling > θ` the coordinator re-invokes NSGA-II with the
//! *current* environment rates ("RunNSGAIIWithCurrentStats"), seeded with
//! the incumbent mapping, and swaps in the new P'.
//!
//! # Batched canary traffic
//!
//! Canary batches flow through the inference server as a *pipeline*: up
//! to `lookahead` future ticks are speculatively submitted ahead of the
//! tick being consumed, so client-side batch preparation overlaps the
//! server's PJRT execution instead of strictly alternating with it (the
//! ROADMAP's "batch the monitor's PJRT traffic through the same engine"
//! item — the serving analogue of the PR-1 batched ΔAcc engine, whose
//! worker budget also provides the default depth).
//!
//! Determinism: the timeline is bitwise identical at any lookahead.
//! Each tick's PRNG key is drawn exactly once, in tick order, and cached
//! (speculation *pre*-draws keys but never re-draws them); a tick's
//! rates depend only on its timestamp; and when a reconfiguration
//! changes the mapping, every speculative batch submitted under the old
//! mapping is discarded and resubmitted with the new mapping and the
//! *same* cached key. At `lookahead = 1` the loop degenerates to the
//! pre-pipelined serve-one-wait-one behaviour. Chaos plans are a pure
//! per-tick function of the chaos seed (never the loop's RNG), so
//! enabling `spec.chaos` does not disturb the key stream and disabling
//! it reproduces chaos-free timelines bit for bit.
//!
//! # Graceful degradation
//!
//! When the supervised server reports a *terminal* failure for a tick
//! (retries exhausted, respawn budget gone), the runner falls back to
//! the pre-computed *safe mapping* — all units on the healthiest device,
//! picked from the offline front by [`safe_fallback_mapping`] — instead
//! of aborting the run. The failed tick is recorded with
//! `batch_accuracy = 0` and `degraded = true`; serving continues under
//! the safe mapping (θ-triggers suppressed) until a health-probe
//! cooldown of `health_cooldown` ticks passes without another terminal
//! failure, at which point the pre-degradation mapping is restored and
//! the degraded interval is closed into `Metrics::degraded_intervals`.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::offline::optimize_partitions_counted;
use super::server::{InferError, InferJob, InferenceServer, SupervisorPolicy, Ticket};
use crate::dataset::EvalSet;
use crate::faults::{ChaosEngine, DeviceFaultProfile, FaultEnv};
use crate::nsga2::{Individual, Nsga2Config, HV_REFERENCE_MARGIN};
use crate::obs::Telemetry;
use crate::partition::{
    front_quality, select_min_dacc_within_budget, CacheStats, Mapping, PartitionEvaluator,
};
use crate::util::json::{num, s as jstr, Value};
use crate::util::prng::Rng;
use crate::util::stats::RollingMean;

/// Online-phase configuration.
#[derive(Clone, Debug)]
pub struct OnlineConfig {
    /// Accuracy-drop threshold θ that triggers repartitioning (paper: 1%
    /// — we default to 5% for the drifting-attack demo; configurable).
    pub theta: f64,
    /// Rolling monitor window (batches).
    pub window: usize,
    /// Simulated seconds per served batch (drives the drift schedule).
    pub tick_seconds: f64,
    /// Number of batches to serve.
    pub ticks: usize,
    /// NSGA-II budget for re-optimization (smaller than offline).
    pub reopt: Nsga2Config,
    /// Budget factors for P' selection.
    pub lat_budget: f64,
    pub energy_budget: f64,
    /// Cooldown (ticks) after a reconfiguration before the next trigger.
    pub cooldown: usize,
    pub seed: u64,
    /// Canary pipeline depth: how many ticks may be in flight at the
    /// inference server at once. 1 = serve-one-wait-one (the legacy
    /// loop); results are bitwise identical at any depth.
    pub lookahead: usize,
    /// Reply deadline per inference attempt (ms); 0 waits forever.
    pub recv_timeout_ms: u64,
    /// Retries per canary batch before its failure becomes terminal.
    pub max_retries: usize,
    /// Base retry backoff (ms), doubled per attempt.
    pub backoff_ms: u64,
    /// Ticks to keep serving on the safe mapping after a terminal
    /// failure before re-admitting the degraded configuration.
    pub health_cooldown: usize,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            theta: 0.05,
            window: 8,
            tick_seconds: 1.0,
            ticks: 120,
            reopt: Nsga2Config { pop_size: 16, generations: 6, ..Default::default() },
            // Wider than the offline defaults: while a fault attack is in
            // progress, accuracy dominates the trade-off (the ablation
            // bench showed 1.6x budgets pin sensitive units to the
            // attacked device because robust mappings cost ~2-3x energy
            // on this platform).
            lat_budget: 2.5,
            energy_budget: 4.0,
            cooldown: 10,
            seed: 11,
            lookahead: 1,
            recv_timeout_ms: 5_000,
            max_retries: 3,
            backoff_ms: 5,
            health_cooldown: 10,
        }
    }
}

impl OnlineConfig {
    /// The server supervision budgets implied by this config.
    pub fn supervisor_policy(&self) -> SupervisorPolicy {
        SupervisorPolicy {
            recv_timeout_ms: self.recv_timeout_ms,
            max_retries: self.max_retries,
            backoff_ms: self.backoff_ms,
            ..SupervisorPolicy::default()
        }
    }
}

/// One timeline sample of the serving run.
#[derive(Clone, Debug)]
pub struct TimelinePoint {
    pub tick: usize,
    pub sim_time_s: f64,
    /// Environment weight-fault rate on the most fault-prone device.
    pub env_rate_dev0: f32,
    pub batch_accuracy: f64,
    pub rolling_accuracy: f64,
    pub mapping: Mapping,
    pub reconfigured: bool,
    /// Tick served (or lost) under safe-mapping degradation.
    pub degraded: bool,
}

/// Result of an online run.
#[derive(Debug)]
pub struct OnlineOutcome {
    pub timeline: Vec<TimelinePoint>,
    pub metrics: Metrics,
    pub final_mapping: Mapping,
    /// Cumulative ΔAcc-cache statistics across every environment epoch of
    /// the run (each reconfiguration rolls the cache to a new epoch; the
    /// lifetime counters keep the history the per-epoch view drops).
    pub cache_lifetime: CacheStats,
}

/// Pick the degradation fallback: all units on the healthiest device
/// (lowest combined fault multipliers). Prefer an offline-front member
/// already of that shape (its objectives were vetted); otherwise
/// construct the mapping directly.
pub fn safe_fallback_mapping(
    front: &[Individual],
    profiles: &[DeviceFaultProfile],
    num_units: usize,
) -> Mapping {
    let best = profiles
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            (a.w_mult + a.a_mult)
                .partial_cmp(&(b.w_mult + b.a_mult))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, _)| i)
        .unwrap_or(0);
    if let Some(member) = front.iter().find(|ind| ind.genome.iter().all(|&g| g == best)) {
        return Mapping(member.genome.clone());
    }
    Mapping::all_on(best, num_units)
}

/// The online coordinator.
pub struct OnlineRunner<'a, 'b> {
    pub cfg: OnlineConfig,
    pub server: &'a InferenceServer,
    pub evaluator: &'b mut PartitionEvaluator<'a>,
    pub clean_acc: f64,
    /// Serving-failure injector (`ChaosEngine::disabled()` for none).
    pub chaos: ChaosEngine,
    /// Degradation fallback; `None` turns terminal inference failures
    /// into run errors (the pre-resilience behaviour).
    pub safe_mapping: Option<Mapping>,
    /// Observability handle ([`Telemetry::disabled`] for none). Ticks,
    /// reconfigurations, and degradation transitions emit spans/events
    /// from this (coordinating) thread only, in tick order.
    pub telemetry: Telemetry,
}

impl OnlineRunner<'_, '_> {
    /// Serve `cfg.ticks` labeled batches from `eval` under the drifting
    /// `env`, monitoring accuracy and repartitioning on θ violations.
    pub fn run(
        &mut self,
        eval: &EvalSet,
        env: &FaultEnv,
        initial: Mapping,
        mut on_tick: impl FnMut(&TimelinePoint),
    ) -> Result<OnlineOutcome> {
        let batch = self.server.batch;
        let sample_len = eval.h * eval.w * eval.c;
        let n_batches_avail = eval.n / batch;
        assert!(n_batches_avail > 0, "eval set smaller than a batch");
        let lookahead = self.cfg.lookahead.max(1);
        let tick_seconds = self.cfg.tick_seconds;
        let stats0 = self.server.stats();

        let telemetry = self.telemetry.clone();
        let mut mapping = initial;
        let mut monitor = RollingMean::new(self.cfg.window);
        let mut metrics = Metrics::with_telemetry(telemetry.clone());
        let mut timeline = Vec::with_capacity(self.cfg.ticks);
        let mut rng = Rng::new(self.cfg.seed);
        let mut cooldown = 0usize;

        // Degradation state: entry tick of the current outage, the
        // mapping to restore, and the first tick eligible for re-entry.
        let mut degraded_since: Option<usize> = None;
        let mut pre_degrade: Option<Mapping> = None;
        let mut degraded_until = 0usize;

        // Per-tick PRNG keys, drawn lazily but exactly once each and in
        // strictly increasing tick order — speculation must consume the
        // PRNG in the same order as the serial loop.
        let mut keys: Vec<[u32; 2]> = Vec::with_capacity(self.cfg.ticks);
        // In-flight speculative canary batches, in tick order.
        let mut pending: VecDeque<(usize, Ticket)> = VecDeque::new();
        // Next tick not yet submitted to the server.
        let mut next_submit = 0usize;

        // Submit one canary batch for `tick` under `mapping`.
        let submit = |tick: usize,
                      mapping: &Mapping,
                      keys: &mut Vec<[u32; 2]>,
                      rng: &mut Rng,
                      server: &InferenceServer,
                      scenario: crate::faults::FaultScenario,
                      chaos: &ChaosEngine|
         -> Result<Ticket> {
            while keys.len() <= tick {
                keys.push([rng.next_u32(), rng.next_u32()]);
            }
            let t_s = tick as f64 * tick_seconds;
            let rates = crate::faults::RateVectors::from_mapping(
                &mapping.0,
                &env.dev_w_rates(t_s),
                &env.dev_a_rates(t_s),
                scenario,
            );
            let bi = tick % n_batches_avail;
            let images = eval.batch_images(bi * batch, batch).to_vec();
            debug_assert_eq!(images.len(), batch * sample_len);
            server.submit(InferJob {
                images,
                n_valid: batch,
                rates,
                key: keys[tick],
                plan: chaos.plan(tick),
            })
        };

        for tick in 0..self.cfg.ticks {
            let mut tick_span = telemetry.span("online.tick");
            tick_span.note("tick", num(tick as f64));
            // Attribution ledger header: re-derive this tick's injected
            // faults (pure in (chaos seed, tick)) and emit them before
            // any supervision event that may blame them. Emitted here —
            // not at submit time — so the stream stays in strict tick
            // order at any lookahead.
            let mut injected_delay = 0.0;
            if telemetry.has_trace() && self.chaos.is_enabled() {
                for ev in self.chaos.events(tick) {
                    if ev.class == "delay" {
                        injected_delay += ev.magnitude;
                    }
                    telemetry.trace_event(
                        "chaos_inject",
                        Some("online.chaos"),
                        &[
                            ("class", jstr(ev.class)),
                            ("component", num(ev.component as f64)),
                            ("fault", num(ev.id as f64)),
                            ("magnitude", num(ev.magnitude)),
                            ("tick", num(ev.tick as f64)),
                        ],
                    );
                }
            }
            tick_span.note("injected_delay", num(injected_delay));
            // re-admit the pre-degradation mapping once the health probe
            // cooldown has passed without another terminal failure
            if let Some(start) = degraded_since {
                if tick >= degraded_until {
                    metrics.record_degraded_interval(start, degraded_until);
                    telemetry.trace_event(
                        "degrade_exit",
                        Some("online.degrade"),
                        &[
                            ("tick", num(tick as f64)),
                            ("start", num(start as f64)),
                            ("end", num(degraded_until as f64)),
                        ],
                    );
                    if let Some(prev) = pre_degrade.take() {
                        mapping = prev;
                    }
                    degraded_since = None;
                    monitor = RollingMean::new(self.cfg.window);
                    // in-flight batches were computed under the safe
                    // mapping: discard and resubmit under the restored
                    // one. Drain by *waiting* (not canceling): canceling
                    // would leave the stale wire jobs racing the worker,
                    // making the supervision counters timing-dependent.
                    metrics.record_speculative_discard(pending.len());
                    for (_, t) in pending.drain(..) {
                        let _ = self.server.wait(t);
                    }
                    next_submit = tick;
                }
            }

            // keep up to `lookahead` ticks in flight
            while next_submit < self.cfg.ticks && next_submit < tick + lookahead {
                let ticket = submit(
                    next_submit,
                    &mapping,
                    &mut keys,
                    &mut rng,
                    self.server,
                    self.evaluator.scenario,
                    &self.chaos,
                )?;
                pending.push_back((next_submit, ticket));
                next_submit += 1;
            }

            let t_s = tick as f64 * self.cfg.tick_seconds;
            let dev_w = env.dev_w_rates(t_s);
            let dev_a = env.dev_a_rates(t_s);

            let (served_tick, ticket) = pending.pop_front().ok_or_else(|| {
                anyhow::anyhow!(
                    "canary pipeline starved at tick {tick} \
                     (lookahead {lookahead}, next_submit {next_submit})"
                )
            })?;
            debug_assert_eq!(served_tick, tick);

            let point = match self.server.wait(ticket) {
                Ok(reply) => {
                    metrics.record_batch(batch, reply.exec_ms);

                    let bi = tick % n_batches_avail;
                    let labels = eval.batch_labels(bi * batch, batch);
                    let hits = reply
                        .preds
                        .iter()
                        .zip(labels)
                        .filter(|(p, &l)| **p as i32 == l)
                        .count();
                    let acc = hits as f64 / batch as f64;
                    monitor.push(acc);
                    let rolling = monitor.mean().unwrap_or(acc);
                    let degraded_now = degraded_since.is_some();
                    if degraded_now {
                        metrics.record_degraded_tick();
                    }

                    // θ trigger (Algorithm 1 line 16); suppressed while
                    // degraded — the safe mapping is not a candidate for
                    // re-optimization, it is a refuge
                    let mut reconfigured = false;
                    if cooldown > 0 {
                        cooldown -= 1;
                    } else if !degraded_now
                        && monitor.is_warm()
                        && self.clean_acc - rolling > self.cfg.theta
                    {
                        let t0 = Instant::now();
                        let mut reopt_span = telemetry.span("online.reconfig");
                        reopt_span.note("tick", num(tick as f64));
                        // RunNSGAIIWithCurrentStats: current environment
                        // rates, seeded with the incumbent mapping. The
                        // rollover keeps cumulative cache telemetry even
                        // though the per-epoch view (correctly) starts
                        // from zero under the new rates.
                        let rollover =
                            self.evaluator.set_env_rates(dev_w.clone(), dev_a.clone());
                        let (front, reopt_evals) = optimize_partitions_counted(
                            self.evaluator,
                            &self.cfg.reopt,
                            true,
                            vec![mapping.clone()],
                            |_| {},
                        );
                        if let Some(chosen) = select_min_dacc_within_budget(
                            &front,
                            self.cfg.lat_budget,
                            self.cfg.energy_budget,
                        ) {
                            let new_mapping = Mapping(chosen.genome.clone());
                            reconfigured = new_mapping != mapping;
                            mapping = new_mapping;
                        }
                        let fq = front_quality(&front, HV_REFERENCE_MARGIN);
                        reopt_span.note("evaluations", num(reopt_evals as f64));
                        reopt_span.note("changed", Value::Bool(reconfigured));
                        reopt_span.note("front_size", num(fq.size as f64));
                        reopt_span.note("front_hv", num(fq.hypervolume));
                        reopt_span.note("front_spread", num(fq.spread));
                        drop(reopt_span);
                        metrics.record_reconfiguration(
                            reopt_evals,
                            t0.elapsed().as_secs_f64() * 1e3,
                        );
                        metrics.record_cache_epoch(rollover.ended_epoch);
                        // reset the monitor so stale pre-reconfig samples
                        // don't immediately re-trigger
                        monitor = RollingMean::new(self.cfg.window);
                        cooldown = self.cfg.cooldown;
                        if reconfigured {
                            // speculative batches were computed under the
                            // old mapping: discard and resubmit from
                            // tick+1 with the new mapping and the *same*
                            // cached per-tick keys (drained by waiting,
                            // see the re-admission path)
                            metrics.record_speculative_discard(pending.len());
                            for (_, t) in pending.drain(..) {
                                let _ = self.server.wait(t);
                            }
                            next_submit = tick + 1;
                        }
                    }

                    TimelinePoint {
                        tick,
                        sim_time_s: t_s,
                        env_rate_dev0: dev_w[0],
                        batch_accuracy: acc,
                        rolling_accuracy: rolling,
                        mapping: mapping.clone(),
                        reconfigured,
                        degraded: degraded_now,
                    }
                }
                Err(err) => {
                    // terminal inference failure: degrade to the safe
                    // mapping instead of aborting (when configured)
                    if self.safe_mapping.is_none() {
                        return Err(anyhow::Error::from(err).context(format!(
                            "tick {tick}: inference failed terminally \
                             and no safe mapping is configured"
                        )));
                    }
                    let safe = self.safe_mapping.clone().expect("checked above");
                    metrics.record_degradation();
                    metrics.record_degraded_tick();
                    let reason = match &err {
                        InferError::Exhausted { .. } => "exhausted",
                        InferError::TimedOut { .. } => "timeout",
                        InferError::Crashed { .. } => "crashed",
                        InferError::Fatal { .. } => "fatal",
                        InferError::Transient { .. } => "transient",
                    };
                    if degraded_since.is_none() {
                        degraded_since = Some(tick);
                        pre_degrade = Some(mapping.clone());
                        monitor = RollingMean::new(self.cfg.window);
                        telemetry.trace_event(
                            "degrade_enter",
                            Some("online.degrade"),
                            &[("tick", num(tick as f64)), ("reason", jstr(reason))],
                        );
                    } else {
                        // a further terminal failure while already
                        // degraded extends the outage; ledger consumers
                        // see the extension explicitly
                        telemetry.trace_event(
                            "degrade_extend",
                            Some("online.degrade"),
                            &[("tick", num(tick as f64)), ("reason", jstr(reason))],
                        );
                    }
                    // every terminal failure (also while already
                    // degraded) restarts the health-probe cooldown
                    degraded_until = tick + 1 + self.cfg.health_cooldown;
                    mapping = safe;
                    // the failed tick's batch is lost; in-flight
                    // speculation was computed under the failed mapping
                    // (drained by waiting, see the re-admission path)
                    metrics.record_speculative_discard(pending.len());
                    for (_, t) in pending.drain(..) {
                        let _ = self.server.wait(t);
                    }
                    next_submit = tick + 1;

                    TimelinePoint {
                        tick,
                        sim_time_s: t_s,
                        env_rate_dev0: dev_w[0],
                        batch_accuracy: 0.0,
                        rolling_accuracy: monitor.mean().unwrap_or(0.0),
                        mapping: mapping.clone(),
                        reconfigured: false,
                        degraded: true,
                    }
                }
            };
            tick_span.note("reconfigured", Value::Bool(point.reconfigured));
            tick_span.note("degraded", Value::Bool(point.degraded));
            // per-tick accuracy delta vs. the clean baseline — the
            // ledger's "effect" side (both values are deterministic)
            tick_span.note("acc", num(point.batch_accuracy));
            tick_span.note("acc_drop", num(self.clean_acc - point.rolling_accuracy));
            on_tick(&point);
            timeline.push(point);
        }

        // close a still-open degraded interval at the run boundary
        if let Some(start) = degraded_since {
            metrics.record_degraded_interval(start, degraded_until.min(self.cfg.ticks));
        }

        // fold the supervision counters accumulated during this run
        let sd = self.server.stats().delta_since(&stats0);
        metrics.record_supervision(sd.respawns, sd.retries, sd.transient_errors, sd.timeouts);

        Ok(OnlineOutcome {
            timeline,
            metrics,
            final_mapping: mapping,
            cache_lifetime: self.evaluator.cache_lifetime_stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(genome: Vec<usize>) -> Individual {
        Individual { genome, objectives: vec![0.0; 3], rank: 0, crowding: 0.0 }
    }

    #[test]
    fn safe_fallback_prefers_front_member_on_healthiest_device() {
        let profiles = DeviceFaultProfile::default_two_device(); // simba (1) is safest
        let front = vec![ind(vec![0, 0, 0]), ind(vec![1, 1, 1]), ind(vec![0, 1, 0])];
        let safe = safe_fallback_mapping(&front, &profiles, 3);
        assert_eq!(safe, Mapping(vec![1, 1, 1]));
    }

    #[test]
    fn safe_fallback_constructs_mapping_when_front_lacks_one() {
        let profiles = DeviceFaultProfile::default_two_device();
        let front = vec![ind(vec![0, 1, 0, 1])];
        let safe = safe_fallback_mapping(&front, &profiles, 4);
        assert_eq!(safe, Mapping::all_on(1, 4));
    }

    #[test]
    fn supervisor_policy_mirrors_config() {
        let cfg = OnlineConfig { recv_timeout_ms: 250, max_retries: 7, backoff_ms: 2, ..Default::default() };
        let p = cfg.supervisor_policy();
        assert_eq!(p.recv_timeout_ms, 250);
        assert_eq!(p.max_retries, 7);
        assert_eq!(p.backoff_ms, 2);
        assert_eq!(p.max_respawns, SupervisorPolicy::default().max_respawns);
    }
}
