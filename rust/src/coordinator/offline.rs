//! Offline phase (paper Algorithm 1, lines 1–12): NSGA-II over the
//! layer→device mapping with {latency, energy, ΔAcc} objectives.

use anyhow::Result;

use crate::nsga2::{GenStats, Individual, Nsga2, Nsga2Config, Problem};
use crate::partition::{select_min_dacc_within_budget, Mapping, PartitionEvaluator};

/// NSGA-II problem adapter over the partition evaluator.
///
/// `three_obj = true` is AFarePart (latency, energy, ΔAcc); `false` is the
/// fault-unaware 2-objective formulation used by the baselines.
///
/// Fitness flows through the batched evaluation engine: NSGA-II submits a
/// whole generation at once and [`PartitionEvaluator::objectives_batch`]
/// deduplicates equivalent rate vectors, serves repeats from the sharded
/// ΔAcc cache, and fans residual exact evaluations across the evaluator's
/// worker threads — bitwise identical results to the serial path.
struct PartitionProblem<'a, 'b> {
    ev: &'b mut PartitionEvaluator<'a>,
    three_obj: bool,
    seeds: Vec<Vec<usize>>,
}

impl Problem for PartitionProblem<'_, '_> {
    fn genome_len(&self) -> usize {
        self.ev.num_units()
    }

    fn alphabet(&self) -> usize {
        self.ev.num_devices()
    }

    fn evaluate(&mut self, genome: &[usize]) -> Vec<f64> {
        let mapping = Mapping(genome.to_vec());
        if self.three_obj {
            // A PJRT failure here means the artifact stack is broken —
            // unrecoverable mid-optimization, so fail loudly.
            self.ev.objectives3(&mapping).expect("fault-injected accuracy evaluation failed")
        } else {
            self.ev.objectives2(&mapping)
        }
    }

    fn evaluate_batch(&mut self, genomes: &[Vec<usize>]) -> Vec<Vec<f64>> {
        let mappings: Vec<Mapping> = genomes.iter().map(|g| Mapping(g.clone())).collect();
        self.ev
            .objectives_batch(&mappings, self.three_obj)
            .expect("fault-injected accuracy evaluation failed")
    }

    fn seeds(&self) -> Vec<Vec<usize>> {
        self.seeds.clone()
    }
}

/// Run NSGA-II over partitions; returns the final Pareto front.
///
/// `seeds` inject known-good mappings (e.g. the currently deployed P* when
/// the online phase re-optimizes — "RunNSGAIIWithCurrentStats").
pub fn optimize_partitions(
    ev: &mut PartitionEvaluator,
    cfg: &Nsga2Config,
    three_obj: bool,
    seeds: Vec<Mapping>,
    on_gen: impl FnMut(&GenStats),
) -> Vec<Individual> {
    optimize_partitions_counted(ev, cfg, three_obj, seeds, on_gen).0
}

/// Like [`optimize_partitions`], also returning the number of fitness
/// evaluations actually submitted (the figure benches and the online
/// phase report this as re-optimization effort).
pub fn optimize_partitions_counted(
    ev: &mut PartitionEvaluator,
    cfg: &Nsga2Config,
    three_obj: bool,
    seeds: Vec<Mapping>,
    mut on_gen: impl FnMut(&GenStats),
) -> (Vec<Individual>, usize) {
    // the optimizer shares the evaluator's telemetry handle, so its
    // generation spans land in the same registry/trace as eval batches
    let telemetry = ev.telemetry().clone();
    let mut problem = PartitionProblem {
        ev,
        three_obj,
        seeds: seeds.into_iter().map(|m| m.0).collect(),
    };
    let mut opt = Nsga2::new(cfg.clone()).with_telemetry(telemetry);
    let front = opt.run(&mut problem, &mut on_gen);
    (front, opt.evaluations())
}

/// Result of the offline phase.
#[derive(Clone, Debug)]
pub struct OfflineOutcome {
    /// Final Pareto front (deduplicated genomes with objective vectors).
    pub front: Vec<Individual>,
    /// Deployed partition P* (selection policy: min ΔAcc within budget).
    pub deployed: Mapping,
    /// Objectives of the deployed partition [lat_ms, energy_mj, dacc].
    pub deployed_objectives: Vec<f64>,
    /// Total fitness evaluations performed.
    pub evaluations: usize,
    /// ΔAcc cache statistics (hits, misses, hit rate).
    pub cache: (usize, usize, f64),
}

/// Convenience driver owning the policy defaults of the paper's setup.
pub struct OfflineRunner {
    pub nsga2: Nsga2Config,
    /// Latency budget factor for P* selection (see partition::front).
    pub lat_budget: f64,
    /// Energy budget factor for P* selection.
    pub energy_budget: f64,
}

impl Default for OfflineRunner {
    fn default() -> Self {
        // Paper §VI-A: population 60, generations 60. Budget factors keep
        // the paper's "initial balance" (§V-B) without vetoing robustness:
        // on this platform the robust device costs ~2-3x energy for small
        // units, so tighter budgets (e.g. 1.6x) pin sensitive layers to
        // the fault-prone part and defeat the algorithm's purpose
        // (measured in bench_ablation A3's history).
        OfflineRunner { nsga2: Nsga2Config::default(), lat_budget: 2.0, energy_budget: 3.0 }
    }
}

impl OfflineRunner {
    /// Execute the offline phase (AFarePart: three objectives).
    pub fn run(
        &self,
        ev: &mut PartitionEvaluator,
        seeds: Vec<Mapping>,
        on_gen: impl FnMut(&GenStats),
    ) -> Result<OfflineOutcome> {
        let (front, evaluations) =
            optimize_partitions_counted(ev, &self.nsga2, true, seeds, on_gen);
        let chosen = select_min_dacc_within_budget(&front, self.lat_budget, self.energy_budget)
            .expect("NSGA-II returned an empty front");
        let deployed = Mapping(chosen.genome.clone());
        let deployed_objectives = chosen.objectives.clone();
        let cache = ev.cache_stats();
        Ok(OfflineOutcome { front, deployed, deployed_objectives, evaluations, cache })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultScenario;
    use crate::hw::Platform;
    use crate::model::{Manifest, UnitCost};
    use crate::partition::{DaccMode, SensitivityTable};

    fn manifest(n: usize) -> Manifest {
        let units = (0..n)
            .map(|i| UnitCost {
                name: format!("u{i}"),
                kind: if i == n - 1 { "dense".into() } else { "conv".into() },
                macs: 1_000_000 * (i as u64 + 1),
                w_params: 10_000,
                w_bytes: 10_000,
                in_bytes: 5_000,
                out_bytes: 5_000,
                out_shape: vec![1],
            })
            .collect();
        Manifest {
            model: "toy".into(),
            num_units: n,
            num_classes: 10,
            precision: 8,
            faulty_bits: 4,
            batch: 4,
            hlo_file: "x".into(),
            weights_file: "x".into(),
            clean_acc_f32: 0.95,
            clean_acc_quant: 0.9,
            weight_scale: 0.01,
            units,
            weight_tensors: vec![],
            act_scales: vec![0.01; n],
        }
    }

    fn sensitivity(n: usize) -> SensitivityTable {
        // unit 0 highly sensitive, decaying with index
        SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            w_drop: (0..n)
                .map(|i| {
                    let s = 0.3 / (1.0 + i as f64);
                    vec![s * 0.5, s, s * 1.5]
                })
                .collect(),
            a_drop: (0..n).map(|_| vec![0.01, 0.02, 0.04]).collect(),
            clean_acc: 0.9,
        }
    }

    #[test]
    fn offline_finds_front_and_robust_deployment() {
        let platform = Platform::default_two_device();
        let m = manifest(6);
        let table = sensitivity(6);
        let mut ev = PartitionEvaluator::new(
            &m,
            &platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        let runner = OfflineRunner {
            nsga2: Nsga2Config { pop_size: 24, generations: 15, ..Default::default() },
            ..Default::default()
        };
        let out = runner.run(&mut ev, vec![], |_| {}).unwrap();
        assert!(!out.front.is_empty());
        assert_eq!(out.deployed.len(), 6);
        // the chosen P* must beat the all-on-fault-prone-device mapping on ΔAcc
        let all0 = Mapping::all_on(0, 6);
        let d_all0 = ev.dacc(&all0).unwrap();
        assert!(
            out.deployed_objectives[2] <= d_all0,
            "deployed dacc {} vs all-on-0 {}",
            out.deployed_objectives[2],
            d_all0
        );
        // cache observed traffic
        let (h, mi, _) = out.cache;
        assert!(h + mi > 0);
        // evaluations report the true submitted count, not the front size
        assert_eq!(out.evaluations, 24 * (15 + 1));
        assert_eq!(h + mi, out.evaluations, "every 3-obj evaluation consults the ΔAcc cache");
    }

    #[test]
    fn seeded_mapping_survives() {
        let platform = Platform::default_two_device();
        let m = manifest(4);
        let table = sensitivity(4);
        let mut ev = PartitionEvaluator::new(
            &m,
            &platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        let seed = Mapping(vec![1, 1, 1, 1]);
        let front = optimize_partitions(
            &mut ev,
            &Nsga2Config { pop_size: 8, generations: 2, ..Default::default() },
            true,
            vec![seed],
            |_| {},
        );
        assert!(!front.is_empty());
    }

    #[test]
    fn two_objective_mode_ignores_faults() {
        let platform = Platform::default_two_device();
        let m = manifest(4);
        let mut ev = PartitionEvaluator::new(
            &m,
            &platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::None,
        );
        let front = optimize_partitions(
            &mut ev,
            &Nsga2Config { pop_size: 16, generations: 10, ..Default::default() },
            false,
            vec![],
            |_| {},
        );
        assert!(front.iter().all(|i| i.objectives.len() == 2));
    }
}
