//! Coordinator metrics: counters + latency samples exported by both phases.
//!
//! Every `record_*` mutation is mirrored into the run's
//! [`crate::obs::MetricRegistry`] (when telemetry is enabled), so the
//! Prometheus snapshot and the report fields can never disagree. The
//! public fields remain the source of truth for `OnlineReport` — their
//! values are byte-for-byte what they were before the registry existed.

use crate::obs::Telemetry;
use crate::partition::CacheStats;
use crate::util::stats::{summarize, Summary};

/// Accumulated metrics of a serving run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub batches_served: usize,
    pub samples_served: usize,
    pub reconfigurations: usize,
    pub reopt_evaluations: usize,
    /// Speculative canary batches discarded because a reconfiguration
    /// changed the mapping while they were in flight (pipelined serving
    /// only; always 0 at lookahead = 1).
    pub speculative_discarded: usize,
    /// ΔAcc-cache epochs closed by environment rollovers, with their
    /// summed traffic (the lifetime view the per-epoch counters lose).
    pub cache_epochs_closed: usize,
    pub closed_epoch_cache: CacheStats,
    /// Inference worker threads respawned by the supervisor.
    pub worker_respawns: usize,
    /// Non-terminal inference retries (transient errors + timeouts).
    pub retries: usize,
    /// Transient worker errors observed (retryable).
    pub transient_errors: usize,
    /// Reply deadlines that expired.
    pub timeouts: usize,
    /// Terminal inference failures that triggered safe-mapping fallback.
    pub degradations: usize,
    /// Ticks served (or skipped) while degraded to the safe mapping.
    pub degraded_ticks: usize,
    /// Half-open `[start, end)` tick intervals spent degraded
    /// (adjacent intervals are merged).
    pub degraded_intervals: Vec<(usize, usize)>,
    exec_ms: Vec<f64>,
    reopt_ms: Vec<f64>,
    telemetry: Telemetry,
}

impl Metrics {
    /// A metrics accumulator that mirrors every recording into the run's
    /// registry. `Metrics::default()` keeps telemetry disabled.
    pub fn with_telemetry(telemetry: Telemetry) -> Metrics {
        Metrics { telemetry, ..Metrics::default() }
    }

    pub fn record_batch(&mut self, n_valid: usize, exec_ms: f64) {
        self.batches_served += 1;
        self.samples_served += n_valid;
        self.exec_ms.push(exec_ms);
        self.telemetry.counter_add("serve_batches_total", 1);
        self.telemetry.counter_add("serve_samples_total", n_valid as u64);
        self.telemetry.observe_ms("serve_exec_ms", exec_ms);
    }

    pub fn record_reconfiguration(&mut self, evals: usize, wall_ms: f64) {
        self.reconfigurations += 1;
        self.reopt_evaluations += evals;
        self.reopt_ms.push(wall_ms);
        self.telemetry.counter_add("serve_reconfigurations_total", 1);
        self.telemetry.counter_add("serve_reopt_evaluations_total", evals as u64);
        self.telemetry.observe_ms("serve_reopt_ms", wall_ms);
    }

    /// Fold a closed cache epoch (see `PartitionEvaluator::set_env_rates`)
    /// into the run totals.
    pub fn record_cache_epoch(&mut self, epoch: CacheStats) {
        self.cache_epochs_closed += 1;
        self.closed_epoch_cache.hits += epoch.hits;
        self.closed_epoch_cache.misses += epoch.misses;
        self.telemetry.counter_add("serve_cache_epochs_closed_total", 1);
        self.telemetry.counter_add("serve_cache_epoch_hits_total", epoch.hits as u64);
        self.telemetry.counter_add("serve_cache_epoch_misses_total", epoch.misses as u64);
    }

    /// A terminal inference failure pushed the runner onto the safe
    /// mapping (or restarted its health-probe cooldown).
    pub fn record_degradation(&mut self) {
        self.degradations += 1;
        self.telemetry.counter_add("serve_degradations_total", 1);
    }

    /// One tick served (or lost) under safe-mapping degradation.
    pub fn record_degraded_tick(&mut self) {
        self.degraded_ticks += 1;
        self.telemetry.counter_add("serve_degraded_ticks_total", 1);
    }

    /// Speculative canary batches discarded by a mapping change.
    pub fn record_speculative_discard(&mut self, n: usize) {
        self.speculative_discarded += n;
        self.telemetry.counter_add("serve_speculative_discarded_total", n as u64);
    }

    /// Fold the supervision counters accumulated by the inference server
    /// over this run (a `ServerStats` delta). Deliberately NOT mirrored
    /// into the registry: `InferenceServer` bumps `server_*_total` live
    /// at the same points it mutates `ServerStats`, so mirroring the
    /// end-of-run delta here would double-count.
    pub fn record_supervision(
        &mut self,
        respawns: usize,
        retries: usize,
        transient_errors: usize,
        timeouts: usize,
    ) {
        self.worker_respawns += respawns;
        self.retries += retries;
        self.transient_errors += transient_errors;
        self.timeouts += timeouts;
    }

    /// Record a degraded interval `[start, end)`. Half-open: `end` is the
    /// first non-degraded tick. Adjacent (`last.end == start`) and
    /// overlapping (`last.end > start`) intervals merge into the previous
    /// span so re-entries during one outage read as one interval; empty
    /// intervals (`end <= start`) are ignored.
    pub fn record_degraded_interval(&mut self, start: usize, end: usize) {
        if end <= start {
            return;
        }
        if let Some(last) = self.degraded_intervals.last_mut() {
            if last.1 >= start {
                last.1 = last.1.max(end);
                return;
            }
        }
        self.degraded_intervals.push((start, end));
        self.telemetry.counter_add("serve_degraded_intervals_total", 1);
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        if self.exec_ms.is_empty() {
            None
        } else {
            Some(summarize(&self.exec_ms))
        }
    }

    pub fn reopt_summary(&self) -> Option<Summary> {
        if self.reopt_ms.is_empty() {
            None
        } else {
            Some(summarize(&self.reopt_ms))
        }
    }

    /// Served throughput in samples/second given total wall seconds.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.samples_served as f64 / wall_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::default();
        m.record_batch(64, 5.0);
        m.record_batch(32, 7.0);
        m.record_reconfiguration(120, 300.0);
        m.record_cache_epoch(CacheStats { hits: 30, misses: 10 });
        m.record_cache_epoch(CacheStats { hits: 5, misses: 5 });
        assert_eq!(m.batches_served, 2);
        assert_eq!(m.samples_served, 96);
        assert_eq!(m.reconfigurations, 1);
        assert_eq!(m.cache_epochs_closed, 2);
        assert_eq!(m.closed_epoch_cache, CacheStats { hits: 35, misses: 15 });
        let s = m.exec_summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((m.throughput(2.0) - 48.0).abs() < 1e-12);
    }

    #[test]
    fn degraded_intervals_merge_when_contiguous() {
        let mut m = Metrics::default();
        m.record_degraded_interval(5, 9);
        m.record_degraded_interval(9, 12);
        m.record_degraded_interval(20, 22);
        m.record_degraded_interval(3, 3); // empty: ignored
        assert_eq!(m.degraded_intervals, vec![(5, 12), (20, 22)]);
    }

    /// Half-open semantics: `[5, 6)` is exactly tick 5. An adjacent
    /// single-tick interval extends the previous span by one.
    #[test]
    fn degraded_intervals_single_tick() {
        let mut m = Metrics::default();
        m.record_degraded_interval(5, 6);
        assert_eq!(m.degraded_intervals, vec![(5, 6)]);
        m.record_degraded_interval(6, 7);
        assert_eq!(m.degraded_intervals, vec![(5, 7)]);
        m.record_degraded_interval(9, 10);
        assert_eq!(m.degraded_intervals, vec![(5, 7), (9, 10)]);
    }

    /// Overlapping re-entries (a terminal failure restarting the health
    /// cooldown inside a still-open outage) fold into one span; a
    /// contained interval must not shrink the previous end.
    #[test]
    fn degraded_intervals_merge_when_overlapping() {
        let mut m = Metrics::default();
        m.record_degraded_interval(5, 12);
        m.record_degraded_interval(10, 15); // overlaps the open span
        assert_eq!(m.degraded_intervals, vec![(5, 15)]);
        m.record_degraded_interval(6, 8); // fully contained: no-op
        assert_eq!(m.degraded_intervals, vec![(5, 15)]);
        m.record_degraded_interval(15, 16); // adjacent after merging
        assert_eq!(m.degraded_intervals, vec![(5, 16)]);
    }

    #[test]
    fn empty_summaries_none() {
        let m = Metrics::default();
        assert!(m.exec_summary().is_none());
        assert!(m.reopt_summary().is_none());
    }

    /// Every record_* mirrors into the registry; report fields and the
    /// exported counters can never disagree.
    #[test]
    fn telemetry_mirrors_recordings() {
        let t = Telemetry::enabled();
        let mut m = Metrics::with_telemetry(t.clone());
        m.record_batch(64, 5.0);
        m.record_batch(64, 6.0);
        m.record_reconfiguration(120, 300.0);
        m.record_cache_epoch(CacheStats { hits: 30, misses: 10 });
        m.record_degradation();
        m.record_degraded_tick();
        m.record_degraded_tick();
        m.record_speculative_discard(3);
        m.record_supervision(1, 4, 2, 1);
        m.record_degraded_interval(5, 9);
        assert_eq!(t.counter_get("serve_batches_total"), m.batches_served as u64);
        assert_eq!(t.counter_get("serve_samples_total"), m.samples_served as u64);
        assert_eq!(
            t.counter_get("serve_reconfigurations_total"),
            m.reconfigurations as u64
        );
        assert_eq!(
            t.counter_get("serve_reopt_evaluations_total"),
            m.reopt_evaluations as u64
        );
        assert_eq!(t.counter_get("serve_cache_epoch_hits_total"), 30);
        assert_eq!(t.counter_get("serve_degradations_total"), m.degradations as u64);
        assert_eq!(t.counter_get("serve_degraded_ticks_total"), m.degraded_ticks as u64);
        assert_eq!(
            t.counter_get("serve_speculative_discarded_total"),
            m.speculative_discarded as u64
        );
        // supervision deltas fold into the report fields but are NOT
        // re-mirrored: the server bumps server_*_total live
        assert_eq!(m.worker_respawns, 1);
        assert_eq!(t.counter_get("server_respawns_total"), 0);
        assert_eq!(
            t.counter_get("serve_degraded_intervals_total"),
            m.degraded_intervals.len() as u64
        );
        let snap = t.snapshot().unwrap();
        assert_eq!(snap.histograms.get("serve_exec_ms").unwrap().count, 2);
    }
}
