//! Coordinator metrics: counters + latency samples exported by both phases.

use crate::partition::CacheStats;
use crate::util::stats::{summarize, Summary};

/// Accumulated metrics of a serving run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub batches_served: usize,
    pub samples_served: usize,
    pub reconfigurations: usize,
    pub reopt_evaluations: usize,
    /// Speculative canary batches discarded because a reconfiguration
    /// changed the mapping while they were in flight (pipelined serving
    /// only; always 0 at lookahead = 1).
    pub speculative_discarded: usize,
    /// ΔAcc-cache epochs closed by environment rollovers, with their
    /// summed traffic (the lifetime view the per-epoch counters lose).
    pub cache_epochs_closed: usize,
    pub closed_epoch_cache: CacheStats,
    /// Inference worker threads respawned by the supervisor.
    pub worker_respawns: usize,
    /// Non-terminal inference retries (transient errors + timeouts).
    pub retries: usize,
    /// Transient worker errors observed (retryable).
    pub transient_errors: usize,
    /// Reply deadlines that expired.
    pub timeouts: usize,
    /// Terminal inference failures that triggered safe-mapping fallback.
    pub degradations: usize,
    /// Ticks served (or skipped) while degraded to the safe mapping.
    pub degraded_ticks: usize,
    /// Half-open `[start, end)` tick intervals spent degraded
    /// (adjacent intervals are merged).
    pub degraded_intervals: Vec<(usize, usize)>,
    exec_ms: Vec<f64>,
    reopt_ms: Vec<f64>,
}

impl Metrics {
    pub fn record_batch(&mut self, n_valid: usize, exec_ms: f64) {
        self.batches_served += 1;
        self.samples_served += n_valid;
        self.exec_ms.push(exec_ms);
    }

    pub fn record_reconfiguration(&mut self, evals: usize, wall_ms: f64) {
        self.reconfigurations += 1;
        self.reopt_evaluations += evals;
        self.reopt_ms.push(wall_ms);
    }

    /// Fold a closed cache epoch (see `PartitionEvaluator::set_env_rates`)
    /// into the run totals.
    pub fn record_cache_epoch(&mut self, epoch: CacheStats) {
        self.cache_epochs_closed += 1;
        self.closed_epoch_cache.hits += epoch.hits;
        self.closed_epoch_cache.misses += epoch.misses;
    }

    /// Record a degraded interval `[start, end)`; contiguous intervals
    /// are merged so re-entries during one outage read as one span.
    pub fn record_degraded_interval(&mut self, start: usize, end: usize) {
        if end <= start {
            return;
        }
        if let Some(last) = self.degraded_intervals.last_mut() {
            if last.1 == start {
                last.1 = end;
                return;
            }
        }
        self.degraded_intervals.push((start, end));
    }

    pub fn exec_summary(&self) -> Option<Summary> {
        if self.exec_ms.is_empty() {
            None
        } else {
            Some(summarize(&self.exec_ms))
        }
    }

    pub fn reopt_summary(&self) -> Option<Summary> {
        if self.reopt_ms.is_empty() {
            None
        } else {
            Some(summarize(&self.reopt_ms))
        }
    }

    /// Served throughput in samples/second given total wall seconds.
    pub fn throughput(&self, wall_s: f64) -> f64 {
        if wall_s > 0.0 {
            self.samples_served as f64 / wall_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::default();
        m.record_batch(64, 5.0);
        m.record_batch(32, 7.0);
        m.record_reconfiguration(120, 300.0);
        m.record_cache_epoch(CacheStats { hits: 30, misses: 10 });
        m.record_cache_epoch(CacheStats { hits: 5, misses: 5 });
        assert_eq!(m.batches_served, 2);
        assert_eq!(m.samples_served, 96);
        assert_eq!(m.reconfigurations, 1);
        assert_eq!(m.cache_epochs_closed, 2);
        assert_eq!(m.closed_epoch_cache, CacheStats { hits: 35, misses: 15 });
        let s = m.exec_summary().unwrap();
        assert_eq!(s.n, 2);
        assert!((m.throughput(2.0) - 48.0).abs() < 1e-12);
    }

    #[test]
    fn degraded_intervals_merge_when_contiguous() {
        let mut m = Metrics::default();
        m.record_degraded_interval(5, 9);
        m.record_degraded_interval(9, 12);
        m.record_degraded_interval(20, 22);
        m.record_degraded_interval(3, 3); // empty: ignored
        assert_eq!(m.degraded_intervals, vec![(5, 12), (20, 22)]);
    }

    #[test]
    fn empty_summaries_none() {
        let m = Metrics::default();
        assert!(m.exec_summary().is_none());
        assert!(m.reopt_summary().is_none());
    }
}
