//! Threaded inference server: the request-path event loop of the online
//! phase (tokio is unavailable offline — this is a hand-rolled
//! channel-based design, DESIGN.md §9).
//!
//! A dedicated worker thread owns the PJRT client and compiled executable
//! (PJRT handles are not Send-safe to share, so the executable never
//! leaves its thread); clients talk to it through an mpsc queue. Each job
//! carries the fault-rate vectors its batch experiences (decided by the
//! coordinator from the current mapping + environment) and a PRNG key.

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::faults::RateVectors;
use crate::model::Manifest;
use crate::runtime::Runtime;

/// One inference job: a full batch of images (server batch size).
pub struct InferJob {
    /// Row-major NHWC f32, exactly batch*h*w*c floats.
    pub images: Vec<f32>,
    /// Number of *real* samples in the batch (rest is padding).
    pub n_valid: usize,
    pub rates: RateVectors,
    pub key: [u32; 2],
    pub reply: Sender<InferReply>,
}

/// Result of one job.
#[derive(Clone, Debug)]
pub struct InferReply {
    /// Top-1 predictions for the valid samples.
    pub preds: Vec<usize>,
    /// Wall-clock execution time of the PJRT call (ms).
    pub exec_ms: f64,
}

enum Cmd {
    Infer(Box<InferJob>),
    Shutdown,
}

/// Handle to the serving thread.
pub struct InferenceServer {
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<Result<()>>>,
    pub batch: usize,
    pub num_units: usize,
    pub img_dims: (usize, usize, usize),
}

impl InferenceServer {
    /// Spawn the worker: it compiles `model` from `artifacts_dir` on its
    /// own thread and then serves jobs until shutdown.
    pub fn spawn(
        artifacts_dir: PathBuf,
        manifest: Manifest,
        img_dims: (usize, usize, usize),
    ) -> Result<InferenceServer> {
        let batch = manifest.batch;
        let num_units = manifest.num_units;
        let (tx, rx): (Sender<Cmd>, Receiver<Cmd>) = mpsc::channel();
        // readiness handshake so spawn() fails fast on compile errors
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dims = img_dims;
        let handle = std::thread::Builder::new()
            .name("afare-infer".into())
            .spawn(move || -> Result<()> {
                let rt = match Runtime::cpu() {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return Ok(());
                    }
                };
                let model = match rt.load_model(&artifacts_dir, manifest) {
                    Ok(m) => m,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return Ok(());
                    }
                };
                let _ = ready_tx.send(Ok(()));
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Shutdown => break,
                        Cmd::Infer(job) => {
                            let t0 = Instant::now();
                            let lit = model.image_literal(&job.images, dims.0, dims.1, dims.2)?;
                            let logits = model.run_batch(&lit, &job.rates, job.key)?;
                            let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                            let mut preds = model.argmax_predictions(&logits);
                            preds.truncate(job.n_valid);
                            // receiver may have gone away; that's fine
                            let _ = job.reply.send(InferReply { preds, exec_ms });
                        }
                    }
                }
                Ok(())
            })
            .context("spawning inference worker")?;
        ready_rx
            .recv()
            .context("inference worker died before ready")?
            .context("inference worker failed to initialize")?;
        Ok(InferenceServer { tx, handle: Some(handle), batch, num_units, img_dims })
    }

    /// Submit a job (non-blocking); reply arrives on the job's channel.
    pub fn submit(&self, job: InferJob) -> Result<()> {
        self.tx
            .send(Cmd::Infer(Box::new(job)))
            .map_err(|_| anyhow::anyhow!("inference worker gone"))
    }

    /// Convenience: synchronous round-trip for one batch.
    pub fn infer_blocking(
        &self,
        images: Vec<f32>,
        n_valid: usize,
        rates: RateVectors,
        key: [u32; 2],
    ) -> Result<InferReply> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(InferJob { images, n_valid, rates, key, reply: reply_tx })?;
        reply_rx.recv().context("inference worker dropped reply")
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Request batcher: accumulates single-sample requests into full batches,
/// padding the tail by repeating the last sample (padding predictions are
/// discarded via `n_valid`).
pub struct Batcher {
    batch: usize,
    sample_len: usize,
    buf: Vec<f32>,
    count: usize,
}

impl Batcher {
    pub fn new(batch: usize, sample_len: usize) -> Batcher {
        Batcher { batch, sample_len, buf: Vec::with_capacity(batch * sample_len), count: 0 }
    }

    /// Add one sample; returns a full (images, n_valid) batch when ready.
    pub fn push(&mut self, sample: &[f32]) -> Option<(Vec<f32>, usize)> {
        assert_eq!(sample.len(), self.sample_len, "sample length mismatch");
        self.buf.extend_from_slice(sample);
        self.count += 1;
        if self.count == self.batch {
            Some(self.take())
        } else {
            None
        }
    }

    /// Flush a partial batch (padded), if any samples are pending.
    pub fn flush(&mut self) -> Option<(Vec<f32>, usize)> {
        if self.count == 0 {
            return None;
        }
        let n_real = self.count;
        // pad by repeating the last sample
        let last = self.buf[self.buf.len() - self.sample_len..].to_vec();
        while self.count < self.batch {
            self.buf.extend_from_slice(&last);
            self.count += 1;
        }
        let (images, _) = self.take();
        Some((images, n_real))
    }

    pub fn pending(&self) -> usize {
        self.count
    }

    fn take(&mut self) -> (Vec<f32>, usize) {
        let n_valid = self.count.min(self.batch);
        let images = std::mem::take(&mut self.buf);
        self.count = 0;
        self.buf = Vec::with_capacity(self.batch * self.sample_len);
        (images, n_valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batcher_fills_and_emits() {
        let mut b = Batcher::new(3, 2);
        assert!(b.push(&[1.0, 2.0]).is_none());
        assert!(b.push(&[3.0, 4.0]).is_none());
        let (imgs, n) = b.push(&[5.0, 6.0]).unwrap();
        assert_eq!(n, 3);
        assert_eq!(imgs, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn batcher_flush_pads_with_last() {
        let mut b = Batcher::new(4, 1);
        b.push(&[1.0]);
        b.push(&[2.0]);
        let (imgs, n) = b.flush().unwrap();
        assert_eq!(n, 2);
        assert_eq!(imgs, vec![1.0, 2.0, 2.0, 2.0]);
        assert!(b.flush().is_none());
    }
}
