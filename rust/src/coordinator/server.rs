//! Supervised threaded inference server: the request-path event loop of
//! the online phase (tokio is unavailable offline — this is a
//! hand-rolled channel-based design, DESIGN.md §9).
//!
//! A dedicated worker thread owns the PJRT client and compiled
//! executable (PJRT handles are not Send-safe to share, so the
//! executable never leaves its thread); clients talk to it through an
//! mpsc queue. Each job carries the fault-rate vectors its batch
//! experiences (decided by the coordinator from the current mapping +
//! environment), a PRNG key, and a [`ChaosPlan`] of injected serving
//! failures (empty unless `spec.chaos` is enabled).
//!
//! # Supervision state machine
//!
//! The server keeps a ledger of every in-flight job (`pending`, keyed
//! by monotonically increasing [`Ticket`]s) plus per-job retry budgets,
//! and drives each wait through this loop:
//!
//! ```text
//!            submit ── record in pending ──> send to worker
//!                                                 │
//!    ┌─────────────────────── wait(ticket) <──────┘
//!    │
//!    ├─ Ok(reply)            -> remove from pending, return reply
//!    ├─ Err(Transient)       -> attempts += 1
//!    │       attempts > max_retries -> InferError::Exhausted
//!    │       else: exponential backoff (backoff_ms << attempt, capped),
//!    │             resubmit the job to the live worker
//!    ├─ recv timeout         -> attempts += 1
//!    │       attempts > max_retries -> InferError::TimedOut
//!    │       else: the worker is hung or the reply was lost on the
//!    │             link — respawn the worker, resubmit ALL pending
//!    ├─ channel disconnected -> the worker thread died (crash):
//!    │       respawn budget exhausted -> InferError::Crashed
//!    │       else: recompile on a fresh thread, resubmit ALL pending
//!    │             jobs in ticket order, keep waiting
//!    └─ Err(Fatal)           -> non-retryable backend error, returned
//! ```
//!
//! Respawn never joins the old worker thread (a wedged PJRT call cannot
//! be force-killed); the dead thread's queue is dropped and its
//! `JoinHandle` detached. On a crash respawn the earliest pending job
//! still flagged `crash` — the worker serves FIFO, so that is the one
//! that killed it — has its flag consumed before resubmission: each
//! planned crash kills exactly one worker no matter where in the
//! pipeline it is detected, which makes respawn/retry counters
//! deterministic. (Timeout respawns consume nothing: a pending crash
//! that has not yet fired will still kill the replacement.)
//! `shutdown()` joins the (live) worker and surfaces its `Result`,
//! which `Drop` can only log.
//!
//! The supervisor serializes callers through one mutex; the online
//! coordinator is single-threaded, so waits never contend.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::faults::{ChaosPlan, RateVectors};
use crate::model::Manifest;
use crate::obs::Telemetry;
use crate::runtime::Runtime;
use crate::util::json::{num, s as jstr, Value};
use crate::util::prng::Rng;

/// Pop the next ledger id from a fault-attribution queue (FIFO; `None`
/// when the effect was not chaos-injected).
fn pop_fault(queue: &mut Vec<u64>) -> Option<u64> {
    if queue.is_empty() {
        None
    } else {
        Some(queue.remove(0))
    }
}

/// Trace-field form of an optional fault id (`Null` = unattributed).
fn fault_field(fault: Option<u64>) -> Value {
    match fault {
        Some(id) => num(id as f64),
        None => Value::Null,
    }
}

/// One inference job: a full batch of images (server batch size).
pub struct InferJob {
    /// Row-major NHWC f32, exactly batch*h*w*c floats.
    pub images: Vec<f32>,
    /// Number of *real* samples in the batch (rest is padding).
    pub n_valid: usize,
    pub rates: RateVectors,
    pub key: [u32; 2],
    /// Injected serving failures for this job (default: none).
    pub plan: ChaosPlan,
}

/// Result of one job.
#[derive(Clone, Debug)]
pub struct InferReply {
    /// Top-1 predictions for the valid samples.
    pub preds: Vec<usize>,
    /// Wall-clock execution time of the inference call (ms), including
    /// any injected link delay.
    pub exec_ms: f64,
}

/// Typed inference failure: callers see the real cause instead of a
/// generic "worker dropped reply".
#[derive(Clone, Debug, PartialEq)]
pub enum InferError {
    /// Retryable backend error (the supervisor retries these itself;
    /// callers only see it via [`InferError::Exhausted`]).
    Transient { detail: String },
    /// The worker thread died and could not be (re)spawned.
    Crashed { detail: String },
    /// No reply within the recv deadline after exhausting retries.
    TimedOut { waited_ms: u64, attempts: usize },
    /// Transient failures persisted past the retry budget.
    Exhausted { attempts: usize, last: String },
    /// Non-retryable backend failure (bad literal, PJRT execute error).
    Fatal { detail: String },
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::Transient { detail } => {
                write!(f, "transient inference failure: {detail}")
            }
            InferError::Crashed { detail } => write!(f, "inference worker crashed: {detail}"),
            InferError::TimedOut { waited_ms, attempts } => {
                write!(f, "inference timed out after {attempts} attempts ({waited_ms} ms deadline)")
            }
            InferError::Exhausted { attempts, last } => {
                write!(f, "inference retries exhausted after {attempts} attempts: {last}")
            }
            InferError::Fatal { detail } => write!(f, "inference backend failure: {detail}"),
        }
    }
}

impl std::error::Error for InferError {}

/// Retry/respawn budgets of the supervisor (see module doc).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SupervisorPolicy {
    /// Reply deadline per attempt (ms); 0 waits forever.
    pub recv_timeout_ms: u64,
    /// Retries per job before a transient/timeout becomes terminal.
    pub max_retries: usize,
    /// Base backoff between retries (ms), doubled per attempt, capped
    /// at 1s.
    pub backoff_ms: u64,
    /// Worker respawns per server lifetime before giving up.
    pub max_respawns: usize,
}

impl Default for SupervisorPolicy {
    fn default() -> SupervisorPolicy {
        SupervisorPolicy { recv_timeout_ms: 5_000, max_retries: 3, backoff_ms: 5, max_respawns: 32 }
    }
}

/// Cumulative supervision counters (monotonic over the server's life).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServerStats {
    /// Worker threads (re)booted after the initial spawn.
    pub respawns: usize,
    /// Non-terminal retry attempts (transient or timeout).
    pub retries: usize,
    /// Transient errors reported by the worker.
    pub transient_errors: usize,
    /// Recv deadlines that expired.
    pub timeouts: usize,
    /// Worker threads observed dead (channel disconnect).
    pub crashes: usize,
}

impl ServerStats {
    /// Counters accumulated since an `earlier` snapshot.
    pub fn delta_since(&self, earlier: &ServerStats) -> ServerStats {
        ServerStats {
            respawns: self.respawns - earlier.respawns,
            retries: self.retries - earlier.retries,
            transient_errors: self.transient_errors - earlier.transient_errors,
            timeouts: self.timeouts - earlier.timeouts,
            crashes: self.crashes - earlier.crashes,
        }
    }
}

/// What the worker thread serves with. `Artifacts` compiles the real
/// PJRT executable; `Synthetic` uses the deterministic predictor from
/// `bench::suite` (no artifacts required) — the chaos tests and
/// `synthetic-L*` online runs are built on it.
#[derive(Clone)]
pub enum BackendSpec {
    Artifacts { artifacts_dir: PathBuf, manifest: Manifest },
    Synthetic { manifest: Manifest, exec_cost: Duration },
}

impl BackendSpec {
    fn manifest(&self) -> &Manifest {
        match self {
            BackendSpec::Artifacts { manifest, .. } => manifest,
            BackendSpec::Synthetic { manifest, .. } => manifest,
        }
    }
}

/// Opaque handle to an in-flight job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket(u64);

/// Wire form of a job: images shared (respawn resubmits without
/// cloning pixels), plus the per-attempt reply channel.
struct WireJob {
    images: Arc<Vec<f32>>,
    n_valid: usize,
    rates: RateVectors,
    key: [u32; 2],
    plan: ChaosPlan,
    reply: Sender<std::result::Result<InferReply, InferError>>,
}

enum Cmd {
    Infer(Box<WireJob>),
    Shutdown,
}

struct Worker {
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<Result<()>>>,
}

/// Supervisor-side record of an in-flight job.
struct JobRec {
    images: Arc<Vec<f32>>,
    n_valid: usize,
    rates: RateVectors,
    key: [u32; 2],
    /// Remaining injected failures; decremented as they are consumed so
    /// resubmissions don't replay already-delivered faults.
    plan: ChaosPlan,
    attempts: usize,
    rx: Receiver<std::result::Result<InferReply, InferError>>,
}

struct Inner {
    worker: Worker,
    pending: BTreeMap<u64, JobRec>,
    next_ticket: u64,
    stats: ServerStats,
    shut_down: bool,
    /// Mirrors every `stats` mutation into the run's registry and emits
    /// supervision trace events. Lives under the supervisor mutex, so
    /// events interleave with the coordinator's tick events in a
    /// deterministic order (failures are chaos-injected, never timed).
    telemetry: Telemetry,
}

/// Handle to the supervised serving thread.
pub struct InferenceServer {
    backend: BackendSpec,
    policy: SupervisorPolicy,
    inner: Mutex<Inner>,
    pub batch: usize,
    pub num_units: usize,
    pub img_dims: (usize, usize, usize),
}

impl InferenceServer {
    /// Spawn a PJRT-backed worker with the default supervision policy:
    /// it compiles `manifest` from `artifacts_dir` on its own thread
    /// and then serves jobs until shutdown.
    pub fn spawn(
        artifacts_dir: PathBuf,
        manifest: Manifest,
        img_dims: (usize, usize, usize),
    ) -> Result<InferenceServer> {
        InferenceServer::spawn_with(
            BackendSpec::Artifacts { artifacts_dir, manifest },
            img_dims,
            SupervisorPolicy::default(),
        )
    }

    /// Spawn with an explicit backend and supervision policy.
    pub fn spawn_with(
        backend: BackendSpec,
        img_dims: (usize, usize, usize),
        policy: SupervisorPolicy,
    ) -> Result<InferenceServer> {
        let batch = backend.manifest().batch;
        let num_units = backend.manifest().num_units;
        let worker = boot_worker(&backend, img_dims)?;
        Ok(InferenceServer {
            backend,
            policy,
            inner: Mutex::new(Inner {
                worker,
                pending: BTreeMap::new(),
                next_ticket: 0,
                stats: ServerStats::default(),
                shut_down: false,
                telemetry: Telemetry::disabled(),
            }),
            batch,
            num_units,
            img_dims,
        })
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Attach the run's telemetry handle. Supervision counters
    /// (`server_*_total`) and retry/respawn trace events are then
    /// emitted at the same points as [`ServerStats`] mutations.
    pub fn set_telemetry(&self, telemetry: Telemetry) {
        self.lock().telemetry = telemetry;
    }

    /// Submit a job (non-blocking); claim the reply with [`wait`].
    ///
    /// [`wait`]: InferenceServer::wait
    pub fn submit(&self, job: InferJob) -> Result<Ticket> {
        let mut inner = self.lock();
        if inner.shut_down {
            anyhow::bail!("inference server is shut down");
        }
        let ticket = Ticket(inner.next_ticket);
        inner.next_ticket += 1;
        let (reply_tx, reply_rx) = mpsc::channel();
        // record the job BEFORE sending: if the worker dies mid-send the
        // respawn path finds (and resubmits) it like any in-flight job
        inner.pending.insert(
            ticket.0,
            JobRec {
                images: Arc::new(job.images),
                n_valid: job.n_valid,
                rates: job.rates,
                key: job.key,
                plan: job.plan,
                attempts: 0,
                rx: reply_rx,
            },
        );
        let rec = &inner.pending[&ticket.0];
        let wire = Cmd::Infer(Box::new(WireJob {
            images: Arc::clone(&rec.images),
            n_valid: rec.n_valid,
            rates: rec.rates.clone(),
            key: rec.key,
            plan: rec.plan.clone(),
            reply: reply_tx,
        }));
        if inner.worker.tx.send(wire).is_err() {
            // the worker died between jobs (e.g. an injected crash from
            // an earlier batch): replace it and resubmit everything
            self.respawn_and_resubmit(&mut inner, "send to dead worker", true)?;
        }
        Ok(ticket)
    }

    /// Block until `ticket`'s job succeeds or fails terminally,
    /// retrying / respawning per the supervision policy (module doc).
    pub fn wait(&self, ticket: Ticket) -> std::result::Result<InferReply, InferError> {
        let mut inner = self.lock();
        loop {
            let outcome = {
                let rec = match inner.pending.get(&ticket.0) {
                    Some(rec) => rec,
                    None => {
                        return Err(InferError::Fatal {
                            detail: format!("unknown or canceled ticket {}", ticket.0),
                        })
                    }
                };
                if self.policy.recv_timeout_ms == 0 {
                    rec.rx.recv().map_err(|_| RecvTimeoutError::Disconnected)
                } else {
                    rec.rx.recv_timeout(Duration::from_millis(self.policy.recv_timeout_ms))
                }
            };
            match outcome {
                Ok(Ok(reply)) => {
                    inner.pending.remove(&ticket.0);
                    return Ok(reply);
                }
                Ok(Err(InferError::Transient { detail })) => {
                    inner.stats.transient_errors += 1;
                    inner.telemetry.counter_add("server_transient_errors_total", 1);
                    let max_retries = self.policy.max_retries;
                    let rec = inner.pending.get_mut(&ticket.0).expect("pending rec");
                    rec.attempts += 1;
                    // this transient burst unit is consumed; pop its
                    // ledger id at the same point so the blame matches
                    // the effect exactly
                    rec.plan.transient_failures = rec.plan.transient_failures.saturating_sub(1);
                    let fault = pop_fault(&mut rec.plan.transient_faults);
                    let attempts = rec.attempts;
                    if attempts > max_retries {
                        inner.pending.remove(&ticket.0);
                        inner.telemetry.trace_event(
                            "server_terminal",
                            Some("server.supervise"),
                            &[
                                ("ticket", num(ticket.0 as f64)),
                                ("attempts", num(attempts as f64)),
                                ("reason", jstr("exhausted")),
                                ("fault", fault_field(fault)),
                            ],
                        );
                        return Err(InferError::Exhausted { attempts, last: detail });
                    }
                    inner.stats.retries += 1;
                    inner.telemetry.counter_add("server_retries_total", 1);
                    inner.telemetry.trace_event(
                        "server_retry",
                        Some("server.supervise"),
                        &[
                            ("ticket", num(ticket.0 as f64)),
                            ("attempts", num(attempts as f64)),
                            ("reason", jstr("transient")),
                            ("fault", fault_field(fault)),
                        ],
                    );
                    let backoff = self
                        .policy
                        .backoff_ms
                        .saturating_mul(1u64 << ((attempts - 1).min(6) as u32))
                        .min(1_000);
                    if backoff > 0 {
                        std::thread::sleep(Duration::from_millis(backoff));
                    }
                    if self.resubmit_one(&mut inner, ticket.0).is_err() {
                        // worker died while we were backing off
                        if let Err(e) =
                            self.respawn_and_resubmit(&mut inner, "worker died during retry", true)
                        {
                            inner.telemetry.trace_event(
                                "server_terminal",
                                Some("server.supervise"),
                                &[
                                    ("ticket", num(ticket.0 as f64)),
                                    ("reason", jstr("respawn_failed")),
                                    ("fault", Value::Null),
                                ],
                            );
                            return Err(e);
                        }
                    }
                }
                Ok(Err(other)) => {
                    // Fatal (and any future non-retryable kind): surface as-is
                    inner.pending.remove(&ticket.0);
                    inner.telemetry.trace_event(
                        "server_terminal",
                        Some("server.supervise"),
                        &[
                            ("ticket", num(ticket.0 as f64)),
                            ("reason", jstr("fatal")),
                            ("fault", Value::Null),
                        ],
                    );
                    return Err(other);
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // worker thread died with the job in flight
                    if let Err(e) =
                        self.respawn_and_resubmit(&mut inner, "worker channel disconnected", true)
                    {
                        inner.telemetry.trace_event(
                            "server_terminal",
                            Some("server.supervise"),
                            &[
                                ("ticket", num(ticket.0 as f64)),
                                ("reason", jstr("respawn_failed")),
                                ("fault", Value::Null),
                            ],
                        );
                        return Err(e);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    inner.stats.timeouts += 1;
                    inner.telemetry.counter_add("server_timeouts_total", 1);
                    let max_retries = self.policy.max_retries;
                    let waited_ms = self.policy.recv_timeout_ms;
                    let rec = inner.pending.get_mut(&ticket.0).expect("pending rec");
                    rec.attempts += 1;
                    // an injected link drop ate this reply; consume it
                    // (and its ledger id, for the retry's blame field)
                    rec.plan.drop_replies = rec.plan.drop_replies.saturating_sub(1);
                    let fault = pop_fault(&mut rec.plan.drop_faults);
                    let attempts = rec.attempts;
                    if attempts > max_retries {
                        inner.pending.remove(&ticket.0);
                        inner.telemetry.trace_event(
                            "server_terminal",
                            Some("server.supervise"),
                            &[
                                ("ticket", num(ticket.0 as f64)),
                                ("attempts", num(attempts as f64)),
                                ("reason", jstr("timeout")),
                                ("fault", fault_field(fault)),
                            ],
                        );
                        return Err(InferError::TimedOut { waited_ms, attempts });
                    }
                    inner.stats.retries += 1;
                    inner.telemetry.counter_add("server_retries_total", 1);
                    inner.telemetry.trace_event(
                        "server_retry",
                        Some("server.supervise"),
                        &[
                            ("ticket", num(ticket.0 as f64)),
                            ("attempts", num(attempts as f64)),
                            ("reason", jstr("timeout")),
                            ("fault", fault_field(fault)),
                        ],
                    );
                    // a silent worker is indistinguishable from a hang:
                    // replace it and resubmit everything pending
                    if let Err(e) = self.respawn_and_resubmit(&mut inner, "recv timeout", false) {
                        inner.telemetry.trace_event(
                            "server_terminal",
                            Some("server.supervise"),
                            &[
                                ("ticket", num(ticket.0 as f64)),
                                ("reason", jstr("respawn_failed")),
                                ("fault", Value::Null),
                            ],
                        );
                        return Err(e);
                    }
                }
            }
        }
    }

    /// Forget an in-flight job; its eventual reply (if any) is dropped.
    pub fn cancel(&self, ticket: Ticket) {
        self.lock().pending.remove(&ticket.0);
    }

    /// Convenience: synchronous round-trip for one (chaos-free) batch.
    pub fn infer_blocking(
        &self,
        images: Vec<f32>,
        n_valid: usize,
        rates: RateVectors,
        key: [u32; 2],
    ) -> Result<InferReply> {
        self.infer_blocking_with(images, n_valid, rates, key, ChaosPlan::default())
    }

    /// Synchronous round-trip with an explicit chaos plan.
    pub fn infer_blocking_with(
        &self,
        images: Vec<f32>,
        n_valid: usize,
        rates: RateVectors,
        key: [u32; 2],
        plan: ChaosPlan,
    ) -> Result<InferReply> {
        let ticket = self.submit(InferJob { images, n_valid, rates, key, plan })?;
        Ok(self.wait(ticket)?)
    }

    /// Snapshot of the supervision counters.
    pub fn stats(&self) -> ServerStats {
        self.lock().stats
    }

    /// Stop the worker and surface its thread `Result` (Drop can only
    /// log failures; call this on clean shutdown paths).
    pub fn shutdown(&self) -> Result<()> {
        let mut inner = self.lock();
        if inner.shut_down {
            return Ok(());
        }
        inner.shut_down = true;
        let _ = inner.worker.tx.send(Cmd::Shutdown);
        if let Some(handle) = inner.worker.handle.take() {
            match handle.join() {
                Ok(result) => result.context("inference worker exited with error")?,
                Err(_) => anyhow::bail!("inference worker panicked"),
            }
        }
        Ok(())
    }

    /// Replace a dead (or presumed-hung) worker and resubmit every
    /// pending job in ticket order. The old thread is detached, never
    /// joined. `crashed` distinguishes observed death from timeouts.
    fn respawn_and_resubmit(
        &self,
        inner: &mut Inner,
        reason: &str,
        crashed: bool,
    ) -> std::result::Result<(), InferError> {
        let mut fault: Option<u64> = None;
        if crashed {
            inner.stats.crashes += 1;
            inner.telemetry.counter_add("server_crashes_total", 1);
            // the worker serves FIFO, so the job that killed it is the
            // earliest pending one still flagged `crash`; consume exactly
            // that flag (and its ledger id). Later crash-flagged jobs
            // keep theirs and will kill the replacement in turn — one
            // planned crash, one dead worker, at any pipeline depth.
            if let Some(rec) = inner.pending.values_mut().find(|r| r.plan.crash) {
                rec.plan.crash = false;
                fault = pop_fault(&mut rec.plan.crash_faults);
            }
        }
        inner.stats.respawns += 1;
        inner.telemetry.counter_add("server_respawns_total", 1);
        inner.telemetry.trace_event(
            "server_respawn",
            Some("server.supervise"),
            &[
                ("reason", jstr(reason)),
                ("crashed", Value::Bool(crashed)),
                ("pending", num(inner.pending.len() as f64)),
                ("fault", fault_field(fault)),
            ],
        );
        if inner.stats.respawns > self.policy.max_respawns {
            return Err(InferError::Crashed {
                detail: format!(
                    "respawn budget exhausted ({} respawns; last reason: {reason})",
                    inner.stats.respawns - 1
                ),
            });
        }
        let fresh = boot_worker(&self.backend, self.img_dims).map_err(|e| InferError::Crashed {
            detail: format!("respawn after {reason} failed: {e:#}"),
        })?;
        // dropping the old Worker closes its queue and detaches its handle
        inner.worker = fresh;
        let tickets: Vec<u64> = inner.pending.keys().copied().collect();
        for t in tickets {
            self.resubmit_one(inner, t).map_err(|_| InferError::Crashed {
                detail: "fresh inference worker died immediately".into(),
            })?;
        }
        Ok(())
    }

    /// Re-send one pending job on a fresh reply channel.
    fn resubmit_one(&self, inner: &mut Inner, ticket: u64) -> std::result::Result<(), ()> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let rec = inner.pending.get_mut(&ticket).expect("resubmit: ticket pending");
        rec.rx = reply_rx;
        let wire = Cmd::Infer(Box::new(WireJob {
            images: Arc::clone(&rec.images),
            n_valid: rec.n_valid,
            rates: rec.rates.clone(),
            key: rec.key,
            plan: rec.plan.clone(),
            reply: reply_tx,
        }));
        inner.worker.tx.send(wire).map_err(|_| ())
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let mut inner = self.lock();
        if inner.shut_down {
            return;
        }
        let _ = inner.worker.tx.send(Cmd::Shutdown);
        if let Some(handle) = inner.worker.handle.take() {
            match handle.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => eprintln!("inference worker exited with error: {e:#}"),
                Err(_) => eprintln!("inference worker panicked"),
            }
        }
    }
}

/// The model a worker thread serves with.
enum WorkerModel {
    Compiled(crate::runtime::CompiledModel),
    Synthetic { manifest: Manifest, exec_cost: Duration },
}

impl WorkerModel {
    fn num_classes(&self) -> usize {
        match self {
            WorkerModel::Compiled(m) => m.manifest.num_classes,
            WorkerModel::Synthetic { manifest, .. } => manifest.num_classes,
        }
    }

    fn predict(
        &self,
        images: &[f32],
        dims: (usize, usize, usize),
        rates: &RateVectors,
        key: [u32; 2],
    ) -> Result<Vec<usize>> {
        match self {
            WorkerModel::Compiled(m) => {
                let lit = m.image_literal(images, dims.0, dims.1, dims.2)?;
                let logits = m.run_batch(&lit, rates, key)?;
                Ok(m.argmax_predictions(&logits))
            }
            WorkerModel::Synthetic { manifest, exec_cost } => {
                if !exec_cost.is_zero() {
                    std::thread::sleep(*exec_cost);
                }
                let sample_len = dims.0 * dims.1 * dims.2;
                Ok(crate::bench::suite::synthetic_predictions(
                    images,
                    sample_len,
                    manifest.num_classes,
                    rates,
                    key,
                ))
            }
        }
    }
}

/// Boot one worker thread with a readiness handshake, so callers fail
/// fast (with the worker's own error) on compile problems.
fn boot_worker(backend: &BackendSpec, img_dims: (usize, usize, usize)) -> Result<Worker> {
    let (tx, rx): (Sender<Cmd>, Receiver<Cmd>) = mpsc::channel();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let backend = backend.clone();
    let handle = std::thread::Builder::new()
        .name("afare-infer".into())
        .spawn(move || worker_main(backend, img_dims, rx, ready_tx))
        .context("spawning inference worker")?;
    let mut worker = Worker { tx, handle: Some(handle) };
    let ready = ready_rx.recv();
    match ready {
        Ok(Ok(())) => Ok(worker),
        Ok(Err(e)) => {
            // surface the JoinHandle result alongside the init error
            if let Some(h) = worker.handle.take() {
                let _ = h.join();
            }
            Err(e.context("inference worker failed to initialize"))
        }
        Err(_) => {
            let detail = match worker.handle.take().map(|h| h.join()) {
                Some(Ok(Err(e))) => format!("worker error: {e:#}"),
                Some(Err(_)) => "worker panicked".into(),
                _ => "no error reported".into(),
            };
            Err(anyhow::anyhow!("inference worker died before ready ({detail})"))
        }
    }
}

fn worker_main(
    backend: BackendSpec,
    dims: (usize, usize, usize),
    rx: Receiver<Cmd>,
    ready_tx: Sender<Result<()>>,
) -> Result<()> {
    // artifacts mode keeps the PJRT client alive next to the executable
    let mut _rt_guard: Option<Runtime> = None;
    let model = match backend {
        BackendSpec::Artifacts { artifacts_dir, manifest } => {
            let rt = match Runtime::cpu() {
                Ok(rt) => rt,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return Ok(());
                }
            };
            let compiled = match rt.load_model(&artifacts_dir, manifest) {
                Ok(m) => m,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return Ok(());
                }
            };
            _rt_guard = Some(rt);
            WorkerModel::Compiled(compiled)
        }
        BackendSpec::Synthetic { manifest, exec_cost } => {
            WorkerModel::Synthetic { manifest, exec_cost }
        }
    };
    let _ = ready_tx.send(Ok(()));
    // Reply channels of injected link drops are parked here (not dropped):
    // the supervisor must observe a *timeout* — a closed channel would
    // read as a worker crash and the drop would never be consumed.
    let mut parked_drops: Vec<Sender<std::result::Result<InferReply, InferError>>> = Vec::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Shutdown => break,
            Cmd::Infer(job) => {
                if job.plan.crash {
                    // simulated device/worker crash: die without
                    // replying; the supervisor sees the closed channels
                    anyhow::bail!("chaos: injected worker crash");
                }
                if job.plan.transient_failures > 0 {
                    let _ = job.reply.send(Err(InferError::Transient {
                        detail: "chaos: injected transient PJRT error".into(),
                    }));
                    continue;
                }
                let t0 = Instant::now();
                match model.predict(&job.images, dims, &job.rates, job.key) {
                    Err(e) => {
                        let _ = job
                            .reply
                            .send(Err(InferError::Fatal { detail: format!("{e:#}") }));
                    }
                    Ok(mut preds) => {
                        preds.truncate(job.n_valid);
                        if job.plan.corrupt {
                            corrupt_predictions(&mut preds, model.num_classes(), job.key);
                        }
                        let exec_ms = t0.elapsed().as_secs_f64() * 1e3 + job.plan.delay_ms;
                        if job.plan.drop_replies > 0 {
                            // reply lost on the link; keep serving
                            parked_drops.push(job.reply);
                            continue;
                        }
                        // receiver may have gone away; that's fine
                        let _ = job.reply.send(Ok(InferReply { preds, exec_ms }));
                    }
                }
            }
        }
    }
    Ok(())
}

/// Deterministic reply corruption: every prediction is shifted to a
/// different class by a key-seeded stream (pure in (preds, key)).
fn corrupt_predictions(preds: &mut [usize], num_classes: usize, key: [u32; 2]) {
    if num_classes < 2 {
        return;
    }
    let key64 = ((key[0] as u64) << 32) | key[1] as u64;
    let mut rng = Rng::new(key64 ^ 0xC0A2_55ED_5EED_F00D);
    for p in preds.iter_mut() {
        *p = (*p + 1 + rng.below(num_classes - 1)) % num_classes;
    }
}

/// Request batcher: accumulates single-sample requests into full batches,
/// padding the tail by repeating the last sample (padding predictions are
/// discarded via `n_valid`).
pub struct Batcher {
    batch: usize,
    sample_len: usize,
    buf: Vec<f32>,
    count: usize,
}

impl Batcher {
    pub fn new(batch: usize, sample_len: usize) -> Batcher {
        Batcher { batch, sample_len, buf: Vec::with_capacity(batch * sample_len), count: 0 }
    }

    /// Add one sample; returns a full (images, n_valid) batch when ready.
    pub fn push(&mut self, sample: &[f32]) -> Option<(Vec<f32>, usize)> {
        assert_eq!(sample.len(), self.sample_len, "sample length mismatch");
        self.buf.extend_from_slice(sample);
        self.count += 1;
        if self.count == self.batch {
            Some(self.take())
        } else {
            None
        }
    }

    /// Flush a partial batch (padded), if any samples are pending.
    pub fn flush(&mut self) -> Option<(Vec<f32>, usize)> {
        if self.count == 0 {
            return None;
        }
        let n_real = self.count;
        // pad by repeating the last sample
        let last = self.buf[self.buf.len() - self.sample_len..].to_vec();
        while self.count < self.batch {
            self.buf.extend_from_slice(&last);
            self.count += 1;
        }
        let (images, _) = self.take();
        Some((images, n_real))
    }

    pub fn pending(&self) -> usize {
        self.count
    }

    fn take(&mut self) -> (Vec<f32>, usize) {
        let n_valid = self.count.min(self.batch);
        let images = std::mem::take(&mut self.buf);
        self.count = 0;
        self.buf = Vec::with_capacity(self.batch * self.sample_len);
        (images, n_valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batcher_fills_and_emits() {
        let mut b = Batcher::new(3, 2);
        assert!(b.push(&[1.0, 2.0]).is_none());
        assert!(b.push(&[3.0, 4.0]).is_none());
        let (imgs, n) = b.push(&[5.0, 6.0]).unwrap();
        assert_eq!(n, 3);
        assert_eq!(imgs, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn batcher_flush_pads_with_last() {
        let mut b = Batcher::new(4, 1);
        b.push(&[1.0]);
        b.push(&[2.0]);
        let (imgs, n) = b.flush().unwrap();
        assert_eq!(n, 2);
        assert_eq!(imgs, vec![1.0, 2.0, 2.0, 2.0]);
        assert!(b.flush().is_none());
    }

    #[test]
    fn infer_error_displays_cause() {
        let e = InferError::TimedOut { waited_ms: 250, attempts: 4 };
        assert!(e.to_string().contains("250 ms"));
        let e = InferError::Exhausted { attempts: 4, last: "boom".into() };
        assert!(e.to_string().contains("boom"));
    }

    #[test]
    fn stats_delta_is_componentwise() {
        let a = ServerStats { respawns: 1, retries: 2, transient_errors: 3, timeouts: 0, crashes: 1 };
        let b = ServerStats { respawns: 4, retries: 6, transient_errors: 3, timeouts: 2, crashes: 2 };
        let d = b.delta_since(&a);
        assert_eq!(d, ServerStats { respawns: 3, retries: 4, transient_errors: 0, timeouts: 2, crashes: 1 });
    }

    #[test]
    fn corruption_is_deterministic_and_always_wrong() {
        let orig = vec![0usize, 3, 9, 5];
        let mut a = orig.clone();
        let mut b = orig.clone();
        corrupt_predictions(&mut a, 10, [7, 8]);
        corrupt_predictions(&mut b, 10, [7, 8]);
        assert_eq!(a, b);
        for (x, y) in a.iter().zip(&orig) {
            assert_ne!(x, y, "corrupted prediction equals the original");
            assert!(*x < 10);
        }
    }
}
