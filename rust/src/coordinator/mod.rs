//! The L3 coordinator: paper Algorithm 1.
//!
//! * [`offline`] — multi-objective partitioning (lines 1–12): NSGA-II over
//!   {latency, energy, ΔAcc} with fault injection inside each fitness
//!   evaluation; returns the Pareto front and the deployed P*.
//! * [`online`] — dynamic accuracy-aware repartitioning (lines 13–19): a
//!   threaded serving loop executing the compiled model, a rolling
//!   accuracy monitor, and θ-triggered re-optimization with current
//!   runtime statistics.
//! * [`server`] — the request/batching event loop used by `online`.
//! * [`metrics`] — counters and timelines exported by both phases.

pub mod metrics;
pub mod offline;
pub mod online;
pub mod server;

pub use offline::{
    optimize_partitions, optimize_partitions_counted, OfflineOutcome, OfflineRunner,
};
pub use online::{
    safe_fallback_mapping, OnlineConfig, OnlineOutcome, OnlineRunner, TimelinePoint,
};
pub use server::{
    BackendSpec, InferError, InferJob, InferReply, InferenceServer, ServerStats,
    SupervisorPolicy, Ticket,
};
