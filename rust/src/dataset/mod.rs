//! Evaluation dataset loader (AFED blob) and batch iteration.
//!
//! Layout (little-endian), produced by python/compile/aot.py:
//!   magic "AFED" | u32 version=1 | u32 n | u32 h | u32 w | u32 c
//!   f32 images[n*h*w*c] | i32 labels[n]

use std::path::Path;

use anyhow::{bail, Context, Result};

/// The held-out evaluation set used for accuracy measurement.
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Row-major [n, h, w, c].
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl EvalSet {
    pub fn load(path: &Path) -> Result<EvalSet> {
        let buf =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        EvalSet::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<EvalSet> {
        if buf.len() < 24 || &buf[..4] != b"AFED" {
            bail!("not an AFED eval blob");
        }
        let rd = |i: usize| u32::from_le_bytes(buf[i..i + 4].try_into().unwrap()) as usize;
        let version = rd(4);
        if version != 1 {
            bail!("unsupported AFED version {version}");
        }
        let (n, h, w, c) = (rd(8), rd(12), rd(16), rd(20));
        let img_bytes = n * h * w * c * 4;
        let lbl_bytes = n * 4;
        if buf.len() != 24 + img_bytes + lbl_bytes {
            bail!(
                "AFED size mismatch: have {}, want {}",
                buf.len(),
                24 + img_bytes + lbl_bytes
            );
        }
        let mut images = vec![0f32; n * h * w * c];
        for (i, ch) in buf[24..24 + img_bytes].chunks_exact(4).enumerate() {
            images[i] = f32::from_le_bytes(ch.try_into().unwrap());
        }
        let mut labels = vec![0i32; n];
        for (i, ch) in buf[24 + img_bytes..].chunks_exact(4).enumerate() {
            labels[i] = i32::from_le_bytes(ch.try_into().unwrap());
        }
        Ok(EvalSet { n, h, w, c, images, labels })
    }

    /// Image slice of sample `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.h * self.w * self.c;
        &self.images[i * sz..(i + 1) * sz]
    }

    /// Contiguous batch [start, start+len) of images (row-major).
    pub fn batch_images(&self, start: usize, len: usize) -> &[f32] {
        let sz = self.h * self.w * self.c;
        &self.images[start * sz..(start + len) * sz]
    }

    pub fn batch_labels(&self, start: usize, len: usize) -> &[i32] {
        &self.labels[start..start + len]
    }

    /// Number of full batches of size `b` available from the first `limit`
    /// samples (limit=0 means the whole set).
    pub fn full_batches(&self, b: usize, limit: usize) -> usize {
        let n = if limit == 0 { self.n } else { self.n.min(limit) };
        n / b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(n: usize, h: usize, w: usize, c: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"AFED");
        for v in [1u32, n as u32, h as u32, w as u32, c as u32] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        for i in 0..(n * h * w * c) {
            b.extend_from_slice(&(i as f32).to_le_bytes());
        }
        for i in 0..n {
            b.extend_from_slice(&((i % 10) as i32).to_le_bytes());
        }
        b
    }

    #[test]
    fn parse_and_slice() {
        let ev = EvalSet::parse(&blob(6, 2, 2, 3)).unwrap();
        assert_eq!((ev.n, ev.h, ev.w, ev.c), (6, 2, 2, 3));
        assert_eq!(ev.image(1)[0], 12.0);
        assert_eq!(ev.batch_labels(2, 3), &[2, 3, 4]);
        assert_eq!(ev.batch_images(1, 2).len(), 24);
        assert_eq!(ev.full_batches(2, 0), 3);
        assert_eq!(ev.full_batches(4, 5), 1);
    }

    #[test]
    fn rejects_bad_magic_and_size() {
        let mut b = blob(2, 2, 2, 3);
        b[1] = b'X';
        assert!(EvalSet::parse(&b).is_err());
        let b2 = blob(2, 2, 2, 3);
        assert!(EvalSet::parse(&b2[..b2.len() - 1]).is_err());
    }
}
