//! Minimal-but-complete JSON parser and writer (serde is unavailable
//! offline — DESIGN.md §9). Used for the artifact manifests produced by
//! python/compile/aot.py, experiment configs, and bench result dumps.
//!
//! Supports the full JSON grammar: nested objects/arrays, all escape
//! sequences including `\uXXXX` (with surrogate pairs), scientific-notation
//! numbers. Numbers are held as f64 — fine for manifests (scales, byte
//! counts < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj["a"]["b"][...]` chain helper.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        keys.iter().try_fold(self, |v, k| v.get(k))
    }
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError {
            msg: msg.into(),
            offset: self.i,
        })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(format!("expected literal {s}"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return self.err("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| ParseError { msg: "bad utf8 in \\u".into(), offset: self.i })?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| ParseError { msg: "bad hex in \\u".into(), offset: self.i })?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return self.err("lone high surrogate");
                                }
                            } else {
                                hi
                            };
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            continue; // hex4 advanced i already
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| ParseError {
                            msg: "invalid utf8".into(),
                            offset: start,
                        })?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError { msg: format!("bad number {s:?}"), offset: start })
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape_into(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// Serialize a value to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

/// Convenience constructors for building documents programmatically.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

pub fn arr<I: IntoIterator<Item = Value>>(xs: I) -> Value {
    Value::Arr(xs.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Value::Num(3.25));
        assert_eq!(parse("-1e3").unwrap(), Value::Num(-1000.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a":}"#).is_err());
        assert!(parse("tru").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v","n":null},"ok":true,"s":"a\"b"}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(to_string(&Value::Num(5.0)), "5");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" \n\t{ \"a\" :\n1 , \"b\" : [ ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
    }
}
