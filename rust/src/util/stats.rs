//! Descriptive statistics for bench results and the online accuracy monitor.

/// Summary of a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Compute a summary; panics on an empty sample.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p95: percentile_sorted(&sorted, 95.0),
        p99: percentile_sorted(&sorted, 99.0),
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Rolling window mean used by the online accuracy monitor.
#[derive(Clone, Debug)]
pub struct RollingMean {
    window: usize,
    buf: Vec<f64>,
    next: usize,
    filled: bool,
}

impl RollingMean {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        RollingMean { window, buf: Vec::with_capacity(window), next: 0, filled: false }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.window {
            self.buf.push(x);
            if self.buf.len() == self.window {
                self.filled = true;
            }
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.window;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True once the window has seen `window` samples.
    pub fn is_warm(&self) -> bool {
        self.filled
    }

    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = vec![0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 95.0) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = summarize(&[2.0; 10]);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn rolling_mean_window() {
        let mut r = RollingMean::new(3);
        assert!(r.mean().is_none());
        r.push(1.0);
        r.push(2.0);
        assert!(!r.is_warm());
        assert!((r.mean().unwrap() - 1.5).abs() < 1e-12);
        r.push(3.0);
        assert!(r.is_warm());
        r.push(10.0); // evicts 1.0
        assert!((r.mean().unwrap() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn rolling_mean_evicts_in_order() {
        let mut r = RollingMean::new(2);
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert!((r.mean().unwrap() - 3.5).abs() < 1e-12);
    }
}
