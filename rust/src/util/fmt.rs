//! Plain-text table renderer for bench output and CLI reports
//! (criterion being unavailable, the bench harness prints these tables).

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (bench tables).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format a fraction as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines equal width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(out.contains("longer"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formats() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.277), "27.7%");
    }
}
