//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ stream.
//!
//! The `rand` crate is unavailable offline (DESIGN.md §9); this is the
//! project-wide randomness source for the NSGA-II operators, the fault
//! environment simulator and the property-test helpers. All experiment
//! entry points take explicit seeds so every run is reproducible.

/// SplitMix64: used to expand a single `u64` seed into a xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — fast, high-quality, 2^256-period generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (SplitMix64-expanded, never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-thread / per-run splits).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Counter-derived stream: an independent generator addressed purely
    /// by `(seed, stream, counter)`, touching no live generator state.
    ///
    /// This is the primitive behind the parallel NSGA-II variation path:
    /// offspring pair `counter` of generation `stream` draws from
    /// `Rng::fork(cfg.seed, generation, pair)`, so the offspring are a
    /// pure function of those coordinates — bitwise identical no matter
    /// how pairs are scheduled across threads. Each coordinate passes
    /// through its own SplitMix64 round, so adjacent counters (the
    /// common case) land on fully decorrelated xoshiro states.
    pub fn fork(seed: u64, stream: u64, counter: u64) -> Rng {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let mut sm = a ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let b = splitmix64(&mut sm);
        let mut sm = b ^ counter.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire's rejection-free-ish method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the modulo bias below 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n), in random order.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(13);
        let ks = r.choose_k(20, 10);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(ks.iter().all(|&i| i < 20));
    }

    #[test]
    fn fork_is_deterministic_and_decorrelated() {
        let draws = |seed, s, c| {
            let mut r = Rng::fork(seed, s, c);
            (0..8).map(|_| r.next_u64()).collect::<Vec<_>>()
        };
        // pure function of the coordinates
        assert_eq!(draws(7, 3, 11), draws(7, 3, 11));
        // every coordinate matters
        assert_ne!(draws(7, 3, 11), draws(8, 3, 11));
        assert_ne!(draws(7, 3, 11), draws(7, 4, 11));
        assert_ne!(draws(7, 3, 11), draws(7, 3, 12));
        // adjacent counters (parallel variation's hot pattern) diverge
        let mut seen = std::collections::HashSet::new();
        for c in 0..64 {
            assert!(seen.insert(draws(7, 1, c)), "fork stream collision at counter {c}");
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut a = Rng::new(21);
        let mut b = a.split();
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
