//! Rust mirror of the paper's Algorithm 2 (probabilistic LSB bit-flip).
//!
//! Bit-exact with the L1 Pallas kernel and ref.py: bit i of an element
//! flips iff the i-th 8-bit slice of its uint32 random draw is below
//! round(rate * 256). The cross-language contract is pinned by
//! rust/tests/data/bitflip_golden.json (generated from ref.py and asserted
//! on both sides).
//!
//! Used by the L3 simulation-only paths (fault environment tests, the
//! surrogate sanity checks); the production inference path injects faults
//! inside the compiled HLO.

/// Threshold an FR in [0,1] to the shared 1/256-granularity contract.
#[inline]
pub fn rate_threshold(rate: f32) -> u32 {
    (rate * 256.0).round().max(0.0).min(256.0) as u32
}

/// Flip mask for one element given its random draw.
#[inline]
pub fn flip_mask(rnd: u32, thr: u32, bits: u32) -> i32 {
    let mut mask = 0i32;
    for i in 0..bits {
        let slice = (rnd >> (8 * i)) & 0xFF;
        if slice < thr {
            mask |= 1 << i;
        }
    }
    mask
}

/// Apply Algorithm 2 to a quantized tensor (int32 lanes).
pub fn bitflip(q: &[i32], rnd: &[u32], rate: f32, bits: u32) -> Vec<i32> {
    assert_eq!(q.len(), rnd.len());
    let thr = rate_threshold(rate);
    q.iter()
        .zip(rnd)
        .map(|(&x, &r)| x ^ flip_mask(r, thr, bits))
        .collect()
}

/// Expected fraction of *elements* altered at per-bit rate `rate`:
/// 1 - (1 - p)^bits with p quantized to the contract granularity.
pub fn expected_element_flip_fraction(rate: f32, bits: u32) -> f64 {
    let p = rate_threshold(rate) as f64 / 256.0;
    1.0 - (1.0 - p).powi(bits as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn zero_rate_never_flips() {
        let q = vec![1, -5, 127, -128];
        let rnd = vec![0u32; 4]; // slices of 0 would flip at any thr > 0
        assert_eq!(bitflip(&q, &rnd, 0.0, 4), q);
    }

    #[test]
    fn rate_one_flips_all_lsbs() {
        let q = vec![0, -1, 100, -37];
        let rnd = vec![0xFFFF_FFFFu32; 4];
        let out = bitflip(&q, &rnd, 1.0, 4);
        assert_eq!(out, q.iter().map(|x| x ^ 0xF).collect::<Vec<_>>());
    }

    #[test]
    fn flips_limited_to_lsb_window() {
        let mut rng = Rng::new(1);
        let q: Vec<i32> = (0..4096).map(|_| rng.range(0, 255) as i32 - 128).collect();
        let rnd: Vec<u32> = (0..4096).map(|_| rng.next_u32()).collect();
        for bits in 1..=4u32 {
            let out = bitflip(&q, &rnd, 1.0, bits);
            for (a, b) in q.iter().zip(&out) {
                assert_eq!((a ^ b) & !((1 << bits) - 1), 0);
            }
        }
    }

    #[test]
    fn empirical_rate_matches_threshold() {
        let mut rng = Rng::new(2);
        let n = 200_000;
        let q = vec![0i32; n];
        let rnd: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let out = bitflip(&q, &rnd, 0.2, 4);
        let expect = rate_threshold(0.2) as f64 / 256.0;
        for bit in 0..4 {
            let freq =
                out.iter().filter(|&&x| (x >> bit) & 1 == 1).count() as f64 / n as f64;
            assert!((freq - expect).abs() < 0.005, "bit {bit}: {freq} vs {expect}");
        }
    }

    #[test]
    fn expected_fraction_formula() {
        assert!((expected_element_flip_fraction(0.0, 4) - 0.0).abs() < 1e-12);
        assert!((expected_element_flip_fraction(1.0, 4) - 1.0).abs() < 1e-12);
        let p: f64 = 51.0 / 256.0; // rate 0.2
        let want = 1.0 - (1.0 - p).powi(4);
        assert!((expected_element_flip_fraction(0.2, 4) - want).abs() < 1e-12);
    }

    #[test]
    fn threshold_rounding() {
        assert_eq!(rate_threshold(0.0), 0);
        assert_eq!(rate_threshold(0.2), 51);
        assert_eq!(rate_threshold(1.0), 256);
        assert_eq!(rate_threshold(-0.5), 0);
        assert_eq!(rate_threshold(2.0), 256);
    }
}
