//! Foundation utilities built in-tree (offline environment — DESIGN.md §9):
//! PRNG, JSON, statistics, bit-flip mirror, table formatting.

pub mod bits;
pub mod fmt;
pub mod json;
pub mod prng;
pub mod stats;
