//! Flat experiment configuration: the runtime view the harness, benches
//! and examples consume directly.
//!
//! This struct is plain data. Loading it from JSON, environment
//! variables and CLI flags — and the precedence between those layers
//! (CLI > env > file > defaults) — lives in exactly one place:
//! [`crate::spec::ExperimentSpec::resolve`]. Construct an
//! `ExperimentConfig` either literally (`..Default::default()`, as the
//! benches do) or via [`crate::spec::ExperimentSpec::to_config`].

use std::path::PathBuf;

use crate::faults::FaultScenario;
use crate::nsga2::Nsga2Config;

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifacts directory (HLO, weights, manifests, eval data).
    pub artifacts_dir: PathBuf,
    /// Model name (must appear in artifacts/index.json).
    pub model: String,
    /// Environment fault rate FR (paper: 0.10–0.40; default 0.20).
    pub fault_rate: f32,
    /// Fault scenario (Table II columns).
    pub scenario: FaultScenario,
    /// NSGA-II settings (paper §VI-A: pop 60, gens 60). Carries the
    /// `selection_threads` knob for the selection/variation pipeline
    /// (1 = legacy bitwise serial path; >= 2 = seed-deterministic
    /// parallel path) — plumbed from the spec layer via `to_nsga2`.
    pub nsga2: Nsga2Config,
    /// Accuracy-drop threshold θ for the online phase.
    pub theta: f64,
    /// Eval-set sample budget for exact ΔAcc evaluation (0 = all).
    pub eval_limit: usize,
    /// Eval batches per exact ΔAcc evaluation (0 = all prepared).
    pub dacc_batches: usize,
    /// Use the sensitivity surrogate instead of exact injection.
    pub surrogate: bool,
    /// Worker threads for batched ΔAcc evaluation (0 = auto-detect from
    /// the machine; 1 = serial). Results are identical at any setting.
    pub eval_threads: usize,
    /// Include link latency/energy in the objectives (CNNParted mode).
    pub link_cost: bool,
    /// Budget factors for P* selection.
    pub lat_budget: f64,
    pub energy_budget: f64,
    /// Master seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            artifacts_dir: crate::runtime::ArtifactIndex::default_dir(),
            model: "alexnet".into(),
            fault_rate: 0.20,
            scenario: FaultScenario::InputWeight,
            nsga2: Nsga2Config::default(),
            theta: 0.05,
            eval_limit: 256,
            dacc_batches: 0,
            surrogate: false,
            eval_threads: 0,
            link_cost: false,
            lat_budget: 2.0,
            energy_budget: 3.0,
            seed: 7,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_the_paper_setup() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.model, "alexnet");
        assert!((cfg.fault_rate - 0.2).abs() < 1e-6);
        assert_eq!(cfg.scenario, FaultScenario::InputWeight);
        assert_eq!((cfg.nsga2.pop_size, cfg.nsga2.generations), (60, 60));
        assert_eq!(cfg.seed, cfg.nsga2.seed);
    }

    #[test]
    fn spec_is_the_loader() {
        // the JSON / env / CLI layering lives in crate::spec; the flat
        // config it lowers to must agree with these defaults
        let spec_cfg = crate::spec::ExperimentSpec::default().to_config();
        let cfg = ExperimentConfig::default();
        assert_eq!(spec_cfg.model, cfg.model);
        assert_eq!(spec_cfg.eval_limit, cfg.eval_limit);
        assert_eq!(spec_cfg.nsga2.pop_size, cfg.nsga2.pop_size);
        assert_eq!(spec_cfg.seed, cfg.seed);
    }
}
