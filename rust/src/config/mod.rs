//! Experiment configuration: typed struct with JSON-file loading, CLI and
//! environment overrides (precedence: CLI > env > file > defaults).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::faults::FaultScenario;
use crate::nsga2::Nsga2Config;
use crate::util::json::{self, Value};

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifacts directory (HLO, weights, manifests, eval data).
    pub artifacts_dir: PathBuf,
    /// Model name (must appear in artifacts/index.json).
    pub model: String,
    /// Environment fault rate FR (paper: 0.10–0.40; default 0.20).
    pub fault_rate: f32,
    /// Fault scenario (Table II columns).
    pub scenario: FaultScenario,
    /// NSGA-II settings (paper §VI-A: pop 60, gens 60).
    pub nsga2: Nsga2Config,
    /// Accuracy-drop threshold θ for the online phase.
    pub theta: f64,
    /// Eval-set sample budget for exact ΔAcc evaluation (0 = all).
    pub eval_limit: usize,
    /// Eval batches per exact ΔAcc evaluation (0 = all prepared).
    pub dacc_batches: usize,
    /// Use the sensitivity surrogate instead of exact injection.
    pub surrogate: bool,
    /// Worker threads for batched ΔAcc evaluation (0 = auto-detect from
    /// the machine; 1 = serial). Results are identical at any setting.
    pub eval_threads: usize,
    /// Include link latency/energy in the objectives (CNNParted mode).
    pub link_cost: bool,
    /// Budget factors for P* selection.
    pub lat_budget: f64,
    pub energy_budget: f64,
    /// Master seed.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            artifacts_dir: crate::runtime::ArtifactIndex::default_dir(),
            model: "alexnet".into(),
            fault_rate: 0.20,
            scenario: FaultScenario::InputWeight,
            nsga2: Nsga2Config::default(),
            theta: 0.05,
            eval_limit: 256,
            dacc_batches: 0,
            surrogate: false,
            eval_threads: 0,
            link_cost: false,
            lat_budget: 2.0,
            energy_budget: 3.0,
            seed: 7,
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON config file (all keys optional).
    pub fn from_file(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = json::parse(&text).context("config: invalid json")?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    fn apply_json(&mut self, v: &Value) -> Result<()> {
        if let Some(s) = v.get("artifacts_dir").and_then(Value::as_str) {
            self.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = v.get("model").and_then(Value::as_str) {
            self.model = s.to_string();
        }
        if let Some(x) = v.get("fault_rate").and_then(Value::as_f64) {
            self.fault_rate = x as f32;
        }
        if let Some(s) = v.get("scenario").and_then(Value::as_str) {
            self.scenario = FaultScenario::parse(s)
                .with_context(|| format!("config: bad scenario {s:?}"))?;
        }
        if let Some(x) = v.get("pop_size").and_then(Value::as_usize) {
            self.nsga2.pop_size = x;
        }
        if let Some(x) = v.get("generations").and_then(Value::as_usize) {
            self.nsga2.generations = x;
        }
        if let Some(x) = v.get("mutation_prob").and_then(Value::as_f64) {
            self.nsga2.mutation_prob = x;
        }
        if let Some(x) = v.get("crossover_prob").and_then(Value::as_f64) {
            self.nsga2.crossover_prob = x;
        }
        if let Some(x) = v.get("theta").and_then(Value::as_f64) {
            self.theta = x;
        }
        if let Some(x) = v.get("eval_limit").and_then(Value::as_usize) {
            self.eval_limit = x;
        }
        if let Some(x) = v.get("dacc_batches").and_then(Value::as_usize) {
            self.dacc_batches = x;
        }
        if let Some(b) = v.get("surrogate").and_then(Value::as_bool) {
            self.surrogate = b;
        }
        if let Some(x) = v.get("eval_threads").and_then(Value::as_usize) {
            self.eval_threads = x;
        }
        if let Some(b) = v.get("link_cost").and_then(Value::as_bool) {
            self.link_cost = b;
        }
        if let Some(x) = v.get("lat_budget").and_then(Value::as_f64) {
            self.lat_budget = x;
        }
        if let Some(x) = v.get("energy_budget").and_then(Value::as_f64) {
            self.energy_budget = x;
        }
        if let Some(x) = v.get("seed").and_then(Value::as_u64) {
            self.seed = x;
            self.nsga2.seed = x;
        }
        Ok(())
    }

    /// Apply environment overrides (AFARE_POP, AFARE_GENS, AFARE_EVAL_LIMIT)
    /// — used to shrink bench budgets without touching code.
    pub fn apply_env(&mut self) {
        let getenv = |k: &str| std::env::var(k).ok();
        if let Some(v) = getenv("AFARE_POP").and_then(|v| v.parse().ok()) {
            self.nsga2.pop_size = v;
        }
        if let Some(v) = getenv("AFARE_GENS").and_then(|v| v.parse().ok()) {
            self.nsga2.generations = v;
        }
        if let Some(v) = getenv("AFARE_EVAL_LIMIT").and_then(|v| v.parse().ok()) {
            self.eval_limit = v;
        }
        if let Some(v) = getenv("AFARE_EVAL_THREADS").and_then(|v| v.parse().ok()) {
            self.eval_threads = v;
        }
    }

    /// Apply CLI overrides.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(p) = args.get("config") {
            let file_cfg = ExperimentConfig::from_file(Path::new(p))?;
            *self = file_cfg;
        }
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(a) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(a);
        }
        self.fault_rate = args.get_f32("fault-rate", self.fault_rate);
        if let Some(s) = args.get("scenario") {
            self.scenario =
                FaultScenario::parse(s).with_context(|| format!("bad --scenario {s:?}"))?;
        }
        self.nsga2.pop_size = args.get_usize("pop", self.nsga2.pop_size);
        self.nsga2.generations = args.get_usize("gens", self.nsga2.generations);
        self.theta = args.get_f64("theta", self.theta);
        self.eval_limit = args.get_usize("eval-limit", self.eval_limit);
        self.dacc_batches = args.get_usize("dacc-batches", self.dacc_batches);
        self.eval_threads = args.get_usize("eval-threads", self.eval_threads);
        if args.has_flag("surrogate") {
            self.surrogate = true;
        }
        if args.has_flag("link-cost") {
            self.link_cost = true;
        }
        let seed = args.get_u64("seed", self.seed);
        self.seed = seed;
        self.nsga2.seed = seed;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_overrides_defaults() {
        let mut cfg = ExperimentConfig::default();
        let v = json::parse(
            r#"{"model": "resnet18", "fault_rate": 0.3, "scenario": "weight-only",
                "pop_size": 24, "generations": 12, "surrogate": true, "seed": 99,
                "eval_threads": 4}"#,
        )
        .unwrap();
        cfg.apply_json(&v).unwrap();
        assert_eq!(cfg.model, "resnet18");
        assert!((cfg.fault_rate - 0.3).abs() < 1e-6);
        assert_eq!(cfg.scenario, FaultScenario::WeightOnly);
        assert_eq!(cfg.nsga2.pop_size, 24);
        assert!(cfg.surrogate);
        assert_eq!(cfg.nsga2.seed, 99);
        assert_eq!(cfg.eval_threads, 4);
    }

    #[test]
    fn bad_scenario_rejected() {
        let mut cfg = ExperimentConfig::default();
        let v = json::parse(r#"{"scenario": "bogus"}"#).unwrap();
        assert!(cfg.apply_json(&v).is_err());
    }

    #[test]
    fn cli_overrides() {
        let raw: Vec<String> = ["offline", "--model", "squeezenet", "--pop", "10", "--surrogate"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["surrogate", "link-cost"]);
        let mut cfg = ExperimentConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.model, "squeezenet");
        assert_eq!(cfg.nsga2.pop_size, 10);
        assert!(cfg.surrogate);
    }
}
