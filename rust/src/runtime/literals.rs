//! Typed literal constructors over the xla crate's untyped-byte API.

use anyhow::{Context, Result};
use xla::{ElementType, Literal};

fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    // SAFETY: plain-old-data scalars (f32/i32/u32), little-endian host.
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
    }
}

/// f32 literal with the given dims.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes_of(data))
        .context("creating f32 literal")
}

/// i32 literal with the given dims.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes_of(data))
        .context("creating i32 literal")
}

/// u32 literal with the given dims.
pub fn literal_u32(data: &[u32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    Literal::create_from_shape_and_untyped_data(ElementType::U32, dims, bytes_of(data))
        .context("creating u32 literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn i32_roundtrip() {
        let l = literal_i32(&[-1, 2, -3], &[3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![-1, 2, -3]);
    }

    #[test]
    fn u32_roundtrip() {
        let l = literal_u32(&[7, 0xFFFF_FFFF], &[2]).unwrap();
        assert_eq!(l.to_vec::<u32>().unwrap(), vec![7, 0xFFFF_FFFF]);
    }
}
