//! Batched top-1 accuracy under fault injection — the paper's
//! EvaluateAccuracy(M, P, F) primitive (Algorithm 1, lines 5–6).
//!
//! Image literals are materialized once per batch and cached; the
//! per-evaluation cost is just the rate-vector/key literals and the PJRT
//! execution itself.

use anyhow::Result;

use super::client::CompiledModel;
use crate::dataset::EvalSet;
use crate::faults::RateVectors;

/// Accuracy evaluator bound to a compiled model and an eval subset.
pub struct AccuracyEvaluator {
    image_batches: Vec<xla::Literal>,
    label_batches: Vec<Vec<i32>>,
    batch: usize,
    pub num_batches: usize,
}

impl AccuracyEvaluator {
    /// Prepare literals for the first `limit` samples (0 = all), in full
    /// batches of the model's export batch size.
    pub fn new(model: &CompiledModel, eval: &EvalSet, limit: usize) -> Result<AccuracyEvaluator> {
        let b = model.batch();
        let num_batches = eval.full_batches(b, limit);
        let mut image_batches = Vec::with_capacity(num_batches);
        let mut label_batches = Vec::with_capacity(num_batches);
        for i in 0..num_batches {
            let imgs = eval.batch_images(i * b, b);
            image_batches.push(model.image_literal(imgs, eval.h, eval.w, eval.c)?);
            label_batches.push(eval.batch_labels(i * b, b).to_vec());
        }
        Ok(AccuracyEvaluator { image_batches, label_batches, batch: b, num_batches })
    }

    /// Number of samples covered by `n_batches` (0 = all prepared).
    pub fn samples(&self, n_batches: usize) -> usize {
        let nb = if n_batches == 0 { self.num_batches } else { n_batches.min(self.num_batches) };
        nb * self.batch
    }

    /// Top-1 accuracy under the given per-unit fault rates.
    ///
    /// `key_seed` decorrelates fault draws across calls; each batch uses
    /// key (key_seed, batch_index).
    pub fn accuracy(
        &self,
        model: &CompiledModel,
        rates: &RateVectors,
        key_seed: u32,
        n_batches: usize,
    ) -> Result<f64> {
        let nb = if n_batches == 0 { self.num_batches } else { n_batches.min(self.num_batches) };
        assert!(nb > 0, "no eval batches prepared");
        let mut hits = 0usize;
        let mut total = 0usize;
        for i in 0..nb {
            let logits = model.run_batch(&self.image_batches[i], rates, [key_seed, i as u32])?;
            let preds = model.argmax_predictions(&logits);
            for (p, &l) in preds.iter().zip(&self.label_batches[i]) {
                hits += (*p as i32 == l) as usize;
                total += 1;
            }
        }
        Ok(hits as f64 / total as f64)
    }

    /// Clean (zero-rate) accuracy — A_clean of ΔAcc.
    pub fn clean_accuracy(&self, model: &CompiledModel, n_batches: usize) -> Result<f64> {
        self.accuracy(model, &RateVectors::zeros(model.num_units()), 0, n_batches)
    }
}
