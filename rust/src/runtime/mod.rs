//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! request path. Python is never involved here (DESIGN.md dataflow).
//!
//! * `client` — PJRT CPU client wrapper + compiled-model handle.
//! * `literals` — byte-level literal construction helpers.
//! * `artifacts` — artifact directory discovery (index.json).
//! * `evaluator` — batched top-1 accuracy under fault-rate vectors, the
//!   EvaluateAccuracy(M, P, F) primitive of the paper's Algorithm 1.

mod artifacts;
mod client;
mod evaluator;
mod literals;

pub use artifacts::ArtifactIndex;
pub use client::{CompiledModel, Runtime};
pub use evaluator::AccuracyEvaluator;
pub use literals::{literal_f32, literal_i32, literal_u32};
