//! Artifact directory discovery: `artifacts/index.json` written by aot.py.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// Parsed `index.json`: which models exist and the global export config.
#[derive(Clone, Debug)]
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub models: Vec<String>,
    pub eval_data: String,
    pub batch: usize,
    pub precision: u32,
    pub faulty_bits: u32,
    pub n_eval: usize,
}

impl ArtifactIndex {
    /// Load from an artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactIndex> {
        let text = std::fs::read_to_string(dir.join("index.json"))
            .with_context(|| format!("reading {}/index.json — run `make artifacts`", dir.display()))?;
        let v = json::parse(&text).context("index.json: invalid json")?;
        let models = v
            .get("models")
            .and_then(Value::as_arr)
            .context("index.json: missing models")?
            .iter()
            .filter_map(|m| m.as_str().map(str::to_string))
            .collect();
        Ok(ArtifactIndex {
            dir: dir.to_path_buf(),
            models,
            eval_data: v
                .get("eval_data")
                .and_then(Value::as_str)
                .unwrap_or("eval_data.bin")
                .to_string(),
            batch: v.get("batch").and_then(Value::as_usize).unwrap_or(64),
            precision: v.get("precision").and_then(Value::as_u64).unwrap_or(8) as u32,
            faulty_bits: v.get("faulty_bits").and_then(Value::as_u64).unwrap_or(4) as u32,
            n_eval: v.get("n_eval").and_then(Value::as_usize).unwrap_or(512),
        })
    }

    /// Default artifacts dir: $AFARE_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("AFARE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest_path(&self, model: &str) -> PathBuf {
        self.dir.join(format!("{model}_manifest.json"))
    }

    pub fn eval_data_path(&self) -> PathBuf {
        self.dir.join(&self.eval_data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_index_from_tempdir() {
        let dir = std::env::temp_dir().join(format!("afare_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("index.json"),
            r#"{"models": ["a", "b"], "eval_data": "e.bin", "batch": 32,
                "precision": 8, "faulty_bits": 4, "n_eval": 128}"#,
        )
        .unwrap();
        let idx = ArtifactIndex::load(&dir).unwrap();
        assert_eq!(idx.models, vec!["a", "b"]);
        assert_eq!(idx.batch, 32);
        assert!(idx.manifest_path("a").ends_with("a_manifest.json"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_is_informative() {
        let err = ArtifactIndex::load(Path::new("/nonexistent_xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
