//! PJRT CPU client wrapper and the compiled-model handle.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per model.
//!
//! Hot-path design: weight literals are materialized ONCE at load time and
//! reused across every execution (the NSGA-II loop runs thousands of
//! evaluations against the same weights); per-call work is limited to the
//! images (cached per batch by the evaluator), the two L-length rate
//! vectors and the PRNG key.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::literals::{literal_f32, literal_i32, literal_u32};
use crate::faults::RateVectors;
use crate::model::{load_weights, Manifest};

/// Shared PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile a model's HLO artifact and bind its weights.
    pub fn load_model(&self, artifacts_dir: &Path, manifest: Manifest) -> Result<CompiledModel> {
        let hlo_path = artifacts_dir.join(&manifest.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", manifest.model))?;

        let tensors = load_weights(&artifacts_dir.join(&manifest.weights_file))?;
        if tensors.len() != manifest.weight_tensors.len() {
            bail!(
                "{}: weights.bin has {} tensors, manifest lists {}",
                manifest.model,
                tensors.len(),
                manifest.weight_tensors.len()
            );
        }
        let mut weight_literals = Vec::with_capacity(tensors.len());
        for (t, wt) in tensors.iter().zip(&manifest.weight_tensors) {
            if t.shape != wt.shape {
                bail!(
                    "{}: weight tensor {}/{} shape mismatch: blob {:?} vs manifest {:?}",
                    manifest.model,
                    wt.unit,
                    wt.prefix,
                    t.shape,
                    wt.shape
                );
            }
            weight_literals.push(literal_i32(&t.data, &t.shape)?);
        }
        Ok(CompiledModel { exe, manifest, weight_literals })
    }
}

/// A compiled model ready for execution: executable + bound weights.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    weight_literals: Vec<xla::Literal>,
}

impl CompiledModel {
    pub fn batch(&self) -> usize {
        self.manifest.batch
    }

    pub fn num_units(&self) -> usize {
        self.manifest.num_units
    }

    /// Build the image literal for a batch (row-major NHWC f32).
    pub fn image_literal(&self, images: &[f32], h: usize, w: usize, c: usize) -> Result<xla::Literal> {
        let b = self.manifest.batch;
        if images.len() != b * h * w * c {
            bail!(
                "{}: batch size mismatch: got {} floats, want {}x{}x{}x{}",
                self.manifest.model,
                images.len(),
                b,
                h,
                w,
                c
            );
        }
        literal_f32(images, &[b, h, w, c])
    }

    /// Execute one batch: returns logits [batch * num_classes].
    ///
    /// `key` is the PRNG key for the in-graph fault injection; use a fresh
    /// key per batch for independent fault draws.
    pub fn run_batch(
        &self,
        images: &xla::Literal,
        rates: &RateVectors,
        key: [u32; 2],
    ) -> Result<Vec<f32>> {
        let l = self.manifest.num_units;
        if rates.w_rates.len() != l || rates.a_rates.len() != l {
            bail!("{}: rate vector length != {}", self.manifest.model, l);
        }
        // parameter order: images, wq..., w_rates, a_rates, key
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(4 + self.weight_literals.len());
        let w_rates = literal_f32(&rates.w_rates, &[l])?;
        let a_rates = literal_f32(&rates.a_rates, &[l])?;
        let key_lit = literal_u32(&key, &[2])?;
        args.push(images);
        for w in &self.weight_literals {
            args.push(w);
        }
        args.push(&w_rates);
        args.push(&a_rates);
        args.push(&key_lit);

        let result = self
            .exe
            .execute(&args)
            .with_context(|| format!("executing {}", self.manifest.model))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result")?
            .to_tuple1()
            .context("unwrapping 1-tuple output")?;
        out.to_vec::<f32>().context("reading logits")
    }

    /// Top-1 predictions from a logits buffer.
    pub fn argmax_predictions(&self, logits: &[f32]) -> Vec<usize> {
        let k = self.manifest.num_classes;
        logits
            .chunks_exact(k)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {


    #[test]
    fn argmax_rows() {
        // fabricate a CompiledModel-free check of the helper via a tiny shim
        let logits = [0.1f32, 0.9, 0.0, 2.0, -1.0, 1.0];
        // emulate num_classes = 3
        let preds: Vec<usize> = logits
            .chunks_exact(3)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect();
        assert_eq!(preds, vec![1, 0]);
    }
}
