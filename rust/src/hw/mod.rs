//! Analytical accelerator cost models — the Timeloop/Accelergy stand-in
//! (DESIGN.md §1): dataflow-aware per-unit latency/energy estimation for
//! Eyeriss-style row-stationary edge accelerators and SIMBA-style
//! multi-chip-module packages, plus the inter-device link model.
//!
//! The partitioner consumes only per-(unit, device) scalars, so what
//! matters is the *structure* these models give the search space: Eyeriss
//! is energy-lean and competent on small convolutions, SIMBA wins big
//! GEMM-heavy layers but pays a fixed chiplet/NoP toll per layer, and the
//! link makes scattered mappings expensive.

mod accel;
mod cpu;
mod eyeriss;
mod link;
mod simba;

pub use accel::{Accelerator, DeviceSpec};
pub use cpu::HostCpu;
pub use eyeriss::Eyeriss;
pub use link::Link;
pub use simba::Simba;

use crate::model::UnitCost;

/// The modeled platform: a set of devices and the link between them.
pub struct Platform {
    pub devices: Vec<Box<dyn Accelerator + Send + Sync>>,
    pub link: Link,
}

impl Platform {
    /// The paper's default two-device platform (Eyeriss + SIMBA).
    pub fn default_two_device() -> Platform {
        Platform {
            devices: vec![Box::new(Eyeriss::default()), Box::new(Simba::default())],
            link: Link::default(),
        }
    }

    /// Extended three-device platform (paper §I: FPGAs, CPUs, NPUs on one
    /// SoC): Eyeriss + SIMBA + an ECC-protected host core that is slow but
    /// fault-immune (its fault multiplier is zero — see
    /// DeviceFaultProfile::default_three_device).
    pub fn default_three_device() -> Platform {
        Platform {
            devices: vec![
                Box::new(Eyeriss::default()),
                Box::new(Simba::default()),
                Box::new(HostCpu::default()),
            ],
            link: Link::default(),
        }
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Per-unit latency on each device (ms), precomputed for the evaluator.
    pub fn latency_table(&self, units: &[UnitCost]) -> Vec<Vec<f64>> {
        units
            .iter()
            .map(|u| self.devices.iter().map(|d| d.latency_ms(u)).collect())
            .collect()
    }

    /// Per-unit energy on each device (mJ), precomputed for the evaluator.
    pub fn energy_table(&self, units: &[UnitCost]) -> Vec<Vec<f64>> {
        units
            .iter()
            .map(|u| self.devices.iter().map(|d| d.energy_mj(u)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(kind: &str, macs: u64, w: u64, inb: u64, outb: u64) -> UnitCost {
        UnitCost {
            name: "u".into(),
            kind: kind.into(),
            macs,
            w_params: w,
            w_bytes: w,
            in_bytes: inb,
            out_bytes: outb,
            out_shape: vec![1],
        }
    }

    #[test]
    fn platform_tables_shape() {
        let p = Platform::default_two_device();
        let units = vec![
            unit("conv", 2_500_000, 2_400, 12_288, 32_768),
            unit("dense", 262_144, 262_144, 1_024, 256),
        ];
        let lat = p.latency_table(&units);
        let en = p.energy_table(&units);
        assert_eq!(lat.len(), 2);
        assert_eq!(lat[0].len(), 2);
        assert!(lat.iter().flatten().all(|&x| x > 0.0));
        assert!(en.iter().flatten().all(|&x| x > 0.0));
    }

    #[test]
    fn eyeriss_beats_simba_on_small_conv_energy() {
        // The structural property the paper's trade-off needs.
        let e = Eyeriss::default();
        let s = Simba::default();
        let small = unit("conv", 500_000, 1_000, 8_192, 8_192);
        assert!(e.energy_mj(&small) < s.energy_mj(&small));
    }

    #[test]
    fn simba_beats_eyeriss_on_big_dense_latency() {
        let e = Eyeriss::default();
        let s = Simba::default();
        let big = unit("dense", 50_000_000, 1_000_000, 4_096, 4_096);
        assert!(s.latency_ms(&big) < e.latency_ms(&big));
    }
}
