//! Host-CPU fallback accelerator model (third device).
//!
//! The paper's introduction targets SoCs mixing "FPGAs, CPUs, and NPUs";
//! this models the applications-class core with ECC-protected caches that
//! such SoCs keep as a fallback: one-to-two orders of magnitude slower
//! than the accelerators on MAC-heavy layers and energy-hungry per MAC,
//! but *fault-immune* (ECC + mature voltage margins — its
//! DeviceFaultProfile multiplier is 0). It stretches the Pareto front:
//! mapping a tiny, highly fault-sensitive unit to the CPU buys resilience
//! at almost no latency cost, which the D=3 experiments exercise.

use super::accel::{Accelerator, DeviceSpec};
use crate::model::UnitCost;

/// ECC-protected host core (e.g. Cortex-A with NEON).
#[derive(Clone, Debug)]
pub struct HostCpu {
    spec: DeviceSpec,
}

impl Default for HostCpu {
    fn default() -> Self {
        HostCpu {
            spec: DeviceSpec {
                name: "cpu",
                macs_per_cycle: 8.0, // 128-bit SIMD int8 dot, pessimistic
                clock_mhz: 1200.0,
                dram_gbps: 6.4,
                layer_overhead_us: 5.0, // no reconfiguration, just a call
                e_mac_pj: 15.0,         // general-purpose pipeline overhead
                e_onchip_pj_byte: 4.0,
                e_dram_pj_byte: 120.0,
                static_mw: 120.0,
                util_conv: 0.55,
                util_dense: 0.70,
                onchip_traffic_per_mac: 3.0,
            },
        }
    }
}

impl Accelerator for HostCpu {
    fn name(&self) -> &str {
        self.spec.name
    }
    fn latency_ms(&self, unit: &UnitCost) -> f64 {
        self.spec.latency_ms(unit)
    }
    fn energy_mj(&self, unit: &UnitCost) -> f64 {
        self.spec.energy_mj(unit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{Eyeriss, Simba};

    #[test]
    fn cpu_much_slower_on_big_convs() {
        let big = UnitCost {
            name: "c".into(),
            kind: "conv".into(),
            macs: 13_000_000,
            w_params: 50_000,
            w_bytes: 50_000,
            in_bytes: 8_192,
            out_bytes: 16_384,
            out_shape: vec![16, 16, 64],
        };
        let cpu = HostCpu::default();
        let eye = Eyeriss::default();
        let simba = Simba::default();
        assert!(cpu.latency_ms(&big) > 3.0 * eye.latency_ms(&big));
        assert!(cpu.latency_ms(&big) > 3.0 * simba.latency_ms(&big));
    }

    #[test]
    fn cpu_competitive_on_tiny_units() {
        let tiny = UnitCost {
            name: "fc3".into(),
            kind: "dense".into(),
            macs: 1_280,
            w_params: 1_280,
            w_bytes: 1_280,
            in_bytes: 128,
            out_bytes: 10,
            out_shape: vec![10],
        };
        let cpu = HostCpu::default();
        let simba = Simba::default();
        // the NoP toll makes SIMBA worse than the plain core here
        assert!(cpu.latency_ms(&tiny) < simba.latency_ms(&tiny));
    }
}
