//! Eyeriss(-v2)-style edge accelerator model.
//!
//! Row-stationary dataflow on a 12x14 PE array: excellent convolution
//! reuse (low on-chip traffic per MAC, high conv utilization), weak on
//! fully-connected layers (little reuse to exploit), modest clock and
//! DRAM bandwidth, very low energy per event — the "edge, aggressively
//! voltage-scaled" device of DESIGN.md §7. Constants follow the published
//! Eyeriss energy hierarchy (RF : GLB : DRAM ≈ 1 : 6 : 200 per access,
//! INT8/16 MAC well under a pJ).

use super::accel::{Accelerator, DeviceSpec};
use crate::model::UnitCost;

/// Eyeriss-mini analytical model.
#[derive(Clone, Debug)]
pub struct Eyeriss {
    spec: DeviceSpec,
}

impl Default for Eyeriss {
    fn default() -> Self {
        Eyeriss {
            spec: DeviceSpec {
                name: "eyeriss",
                macs_per_cycle: 168.0, // 12x14 PE array
                clock_mhz: 200.0,      // aggressively voltage-scaled edge part
                dram_gbps: 1.6,
                layer_overhead_us: 20.0,
                e_mac_pj: 0.4,
                e_onchip_pj_byte: 0.8, // row-stationary: mostly RF traffic
                e_dram_pj_byte: 120.0,
                static_mw: 30.0,
                util_conv: 0.80, // RS dataflow maps convs well
                util_dense: 0.25, // ... and FC poorly
                onchip_traffic_per_mac: 1.2, // high reuse -> little traffic
            },
        }
    }
}

impl Accelerator for Eyeriss {
    fn name(&self) -> &str {
        self.spec.name
    }
    fn latency_ms(&self, unit: &UnitCost) -> f64 {
        self.spec.latency_ms(unit)
    }
    fn energy_mj(&self, unit: &UnitCost) -> f64 {
        self.spec.energy_mj(unit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sane_magnitudes_for_mini_alexnet_conv() {
        // conv2 of alexnet-mini: ~13.1M MACs, 51KB weights, 8/16KB acts
        let u = UnitCost {
            name: "conv2".into(),
            kind: "conv".into(),
            macs: 13_107_200,
            w_params: 51_200,
            w_bytes: 51_200,
            in_bytes: 8_192,
            out_bytes: 16_384,
            out_shape: vec![16, 16, 64],
        };
        let e = Eyeriss::default();
        let lat = e.latency_ms(&u);
        let en = e.energy_mj(&u);
        // ~1ms compute, well under 1 mJ
        assert!(lat > 0.3 && lat < 10.0, "lat={lat}");
        assert!(en > 0.001 && en < 1.0, "en={en}");
    }
}
