//! Inter-device link model: latency + energy of moving a unit's input
//! activation between accelerators at a partition boundary.
//!
//! The paper notes AFarePart *excludes* link cost while CNNParted includes
//! it (§VI-E); both code paths exist and the evaluator takes a flag —
//! ablation A3 measures the difference.

/// Point-to-point interconnect between two accelerators.
#[derive(Clone, Debug)]
pub struct Link {
    /// Sustained bandwidth in GB/s.
    pub bandwidth_gbps: f64,
    /// Fixed per-transfer setup latency in µs.
    pub setup_us: f64,
    /// Energy per byte in pJ.
    pub e_pj_byte: f64,
}

impl Default for Link {
    fn default() -> Self {
        // PCB-level chip-to-chip interconnect.
        Link { bandwidth_gbps: 2.0, setup_us: 25.0, e_pj_byte: 40.0 }
    }
}

impl Link {
    /// Transfer latency in ms for `bytes` of activation.
    pub fn latency_ms(&self, bytes: u64) -> f64 {
        (self.setup_us * 1e-6 + bytes as f64 / (self.bandwidth_gbps * 1e9)) * 1e3
    }

    /// Transfer energy in mJ.
    pub fn energy_mj(&self, bytes: u64) -> f64 {
        bytes as f64 * self.e_pj_byte * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_has_setup_floor() {
        let l = Link::default();
        assert!(l.latency_ms(0) >= 0.025 - 1e-9);
        assert!(l.latency_ms(1_000_000) > l.latency_ms(1_000));
    }

    #[test]
    fn energy_linear_in_bytes() {
        let l = Link::default();
        let e1 = l.energy_mj(1000);
        let e2 = l.energy_mj(2000);
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
    }
}
