//! The `Accelerator` trait and the shared roofline helper.

use crate::model::UnitCost;

/// One modeled hardware accelerator.
pub trait Accelerator {
    fn name(&self) -> &str;
    /// Per-sample latency of running `unit` on this device, in ms.
    fn latency_ms(&self, unit: &UnitCost) -> f64;
    /// Per-sample energy of running `unit` on this device, in mJ.
    fn energy_mj(&self, unit: &UnitCost) -> f64;
}

/// Common analytical parameters of a MAC-array accelerator.
///
/// Latency is a roofline: max(compute time, memory time) + fixed per-layer
/// overhead. Energy is Accelergy-style per-event accounting: MAC energy +
/// on-chip traffic (operand fetch through the reuse hierarchy) + DRAM.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Peak MACs per cycle (PE array width).
    pub macs_per_cycle: f64,
    /// Core clock in MHz.
    pub clock_mhz: f64,
    /// DRAM bandwidth in GB/s.
    pub dram_gbps: f64,
    /// Fixed per-layer dispatch/configuration overhead in µs.
    pub layer_overhead_us: f64,
    /// Energy per MAC in pJ.
    pub e_mac_pj: f64,
    /// Energy per byte moved on-chip (RF/GLB average) in pJ.
    pub e_onchip_pj_byte: f64,
    /// Energy per byte moved from/to DRAM in pJ.
    pub e_dram_pj_byte: f64,
    /// Static/leakage power in mW (charged against layer latency).
    pub static_mw: f64,
    /// Dataflow utilization per layer kind: (conv-like, dense-like).
    pub util_conv: f64,
    pub util_dense: f64,
    /// On-chip reuse factor: on-chip bytes moved per MAC operand pair.
    pub onchip_traffic_per_mac: f64,
}

impl DeviceSpec {
    fn util_for(&self, kind: &str) -> f64 {
        match kind {
            "dense" | "gap_dense" => self.util_dense,
            _ => self.util_conv, // conv / fire / block / conv_gap
        }
    }

    /// Roofline latency in ms.
    pub fn latency_ms(&self, unit: &UnitCost) -> f64 {
        let peak = self.macs_per_cycle * self.util_for(&unit.kind) * self.clock_mhz * 1e6;
        let t_compute = unit.macs as f64 / peak; // seconds
        let dram_bytes = (unit.w_bytes + unit.in_bytes + unit.out_bytes) as f64;
        let t_mem = dram_bytes / (self.dram_gbps * 1e9);
        (t_compute.max(t_mem) + self.layer_overhead_us * 1e-6) * 1e3
    }

    /// Per-event energy in mJ.
    pub fn energy_mj(&self, unit: &UnitCost) -> f64 {
        let e_mac = unit.macs as f64 * self.e_mac_pj;
        let onchip_bytes = unit.macs as f64 * self.onchip_traffic_per_mac;
        let e_onchip = onchip_bytes * self.e_onchip_pj_byte;
        let dram_bytes = (unit.w_bytes + unit.in_bytes + unit.out_bytes) as f64;
        let e_dram = dram_bytes * self.e_dram_pj_byte;
        let e_static = self.static_mw * 1e-3 * (self.latency_ms(unit) * 1e-3) * 1e12; // pJ
        (e_mac + e_onchip + e_dram + e_static) * 1e-9 // pJ -> mJ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> DeviceSpec {
        DeviceSpec {
            name: "toy",
            macs_per_cycle: 100.0,
            clock_mhz: 100.0,
            dram_gbps: 1.0,
            layer_overhead_us: 10.0,
            e_mac_pj: 1.0,
            e_onchip_pj_byte: 1.0,
            e_dram_pj_byte: 100.0,
            static_mw: 10.0,
            util_conv: 0.8,
            util_dense: 0.4,
            onchip_traffic_per_mac: 2.0,
        }
    }

    fn unit(kind: &str, macs: u64, bytes: u64) -> UnitCost {
        UnitCost {
            name: "u".into(),
            kind: kind.into(),
            macs,
            w_params: bytes,
            w_bytes: bytes,
            in_bytes: bytes,
            out_bytes: bytes,
            out_shape: vec![1],
        }
    }

    #[test]
    fn latency_scales_with_macs() {
        let s = spec();
        let a = s.latency_ms(&unit("conv", 1_000_000, 10));
        let b = s.latency_ms(&unit("conv", 10_000_000, 10));
        assert!(b > a * 5.0);
    }

    #[test]
    fn dense_utilization_penalty() {
        let s = spec();
        let c = s.latency_ms(&unit("conv", 5_000_000, 10));
        let d = s.latency_ms(&unit("dense", 5_000_000, 10));
        assert!(d > c);
    }

    #[test]
    fn memory_bound_layers_hit_bandwidth_roof() {
        let s = spec();
        // tiny compute, huge weights: latency ~ bytes / bw
        let u = unit("conv", 1_000, 3_000_000);
        let t = s.latency_ms(&u);
        let t_mem_ms = 9_000_000.0 / 1e9 * 1e3;
        assert!((t - t_mem_ms - 0.01).abs() < 0.5);
    }

    #[test]
    fn energy_positive_and_dram_dominated_for_fat_layers() {
        let s = spec();
        let lean = s.energy_mj(&unit("conv", 1_000_000, 100));
        let fat = s.energy_mj(&unit("conv", 1_000_000, 1_000_000));
        assert!(fat > lean * 2.0);
    }
}
