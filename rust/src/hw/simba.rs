//! SIMBA-style multi-chip-module accelerator model.
//!
//! A package of PE chiplets connected by a network-on-package: far higher
//! peak throughput and DRAM bandwidth than the edge part, good utilization
//! on GEMM-heavy layers, but (a) every layer pays a network-on-package
//! dispatch toll, (b) per-event energies are higher (inter-chiplet hops),
//! (c) static power is substantial. The "reliable but costly" device of
//! the paper's trade-off — its fault multiplier lives in
//! faults::DeviceFaultProfile, not here.

use super::accel::{Accelerator, DeviceSpec};
use crate::model::UnitCost;

/// SIMBA-lite analytical model.
#[derive(Clone, Debug)]
pub struct Simba {
    spec: DeviceSpec,
}

impl Default for Simba {
    fn default() -> Self {
        Simba {
            spec: DeviceSpec {
                name: "simba",
                macs_per_cycle: 1024.0, // chiplet array
                clock_mhz: 400.0,
                dram_gbps: 12.8,
                layer_overhead_us: 150.0, // NoP configuration toll per layer
                e_mac_pj: 0.6,
                e_onchip_pj_byte: 2.5, // NoC + NoP hops
                e_dram_pj_byte: 160.0,
                static_mw: 250.0,
                util_conv: 0.45, // small spatial convs under-fill chiplets
                util_dense: 0.70, // GEMMs map well
                onchip_traffic_per_mac: 2.0,
            },
        }
    }
}

impl Accelerator for Simba {
    fn name(&self) -> &str {
        self.spec.name
    }
    fn latency_ms(&self, unit: &UnitCost) -> f64 {
        self.spec.latency_ms(unit)
    }
    fn energy_mj(&self, unit: &UnitCost) -> f64 {
        self.spec.energy_mj(unit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::Eyeriss;

    #[test]
    fn fixed_toll_hurts_tiny_layers() {
        let tiny = UnitCost {
            name: "t".into(),
            kind: "conv".into(),
            macs: 10_000,
            w_params: 100,
            w_bytes: 100,
            in_bytes: 100,
            out_bytes: 100,
            out_shape: vec![1],
        };
        let s = Simba::default();
        let e = Eyeriss::default();
        // on a tiny layer the edge part is both faster and cheaper
        assert!(e.latency_ms(&tiny) < s.latency_ms(&tiny));
        assert!(e.energy_mj(&tiny) < s.energy_mj(&tiny));
    }
}
