//! Fast non-dominated sorting (Deb et al. 2002, §III-A).

/// True iff `a` Pareto-dominates `b` (all objectives <=, at least one <).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Partition a population (objective vectors) into non-dominated fronts.
/// Returns index lists; front 0 is the Pareto set. O(M·N²).
pub fn fast_non_dominated_sort(objs: &[&[f64]]) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // S_p
    let mut domination_count = vec![0usize; n]; // n_p
    let mut fronts: Vec<Vec<usize>> = vec![Vec::new()];

    for p in 0..n {
        for q in (p + 1)..n {
            if dominates(objs[p], objs[q]) {
                dominated_by[p].push(q);
                domination_count[q] += 1;
            } else if dominates(objs[q], objs[p]) {
                dominated_by[q].push(p);
                domination_count[p] += 1;
            }
        }
    }
    for p in 0..n {
        if domination_count[p] == 0 {
            fronts[0].push(p);
        }
    }

    let mut i = 0;
    while !fronts[i].is_empty() {
        let mut next = Vec::new();
        for &p in &fronts[i] {
            for &q in &dominated_by[p] {
                domination_count[q] -= 1;
                if domination_count[q] == 0 {
                    next.push(q);
                }
            }
        }
        i += 1;
        fronts.push(next);
    }
    fronts.pop(); // drop trailing empty front
    fronts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominates_basic() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: no strict
    }

    #[test]
    fn sorts_into_layers() {
        // points: A(0,0) dominates everything; B(1,2)/C(2,1) mutually
        // non-dominated; D(3,3) dominated by all.
        let pts: Vec<&[f64]> = vec![&[0.0, 0.0], &[1.0, 2.0], &[2.0, 1.0], &[3.0, 3.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 3);
        assert_eq!(fronts[0], vec![0]);
        assert_eq!(fronts[1], vec![1, 2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn all_non_dominated_single_front() {
        let pts: Vec<&[f64]> = vec![&[0.0, 3.0], &[1.0, 2.0], &[2.0, 1.0], &[3.0, 0.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 4);
    }

    #[test]
    fn duplicates_share_front() {
        let pts: Vec<&[f64]> = vec![&[1.0, 1.0], &[1.0, 1.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 2);
    }

    #[test]
    fn empty_population() {
        let pts: Vec<&[f64]> = vec![];
        assert!(fast_non_dominated_sort(&pts).is_empty());
    }

    #[test]
    fn three_objectives() {
        let pts: Vec<&[f64]> =
            vec![&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0], &[2.0, 2.0, 2.0], &[3.0, 3.0, 3.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts[0].len(), 3);
        assert_eq!(fronts[1], vec![3]);
    }
}
