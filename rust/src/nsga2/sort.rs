//! Fast non-dominated sorting (Deb et al. 2002, §III-A).

/// True iff `a` Pareto-dominates `b` (all objectives <=, at least one <).
///
/// Objective vectors must be finite: a NaN component compares false both
/// ways, so a NaN vector is never dominated and would silently pollute
/// front 0. [`crate::nsga2::Nsga2`] rejects non-finite vectors at the
/// evaluation boundary with a contextual error; this assert is the
/// debug-build backstop for callers going through the raw sort API.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(
        a.iter().chain(b).all(|x| x.is_finite()),
        "dominates: non-finite objective vector (a={a:?}, b={b:?})"
    );
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Partition a population (objective vectors) into non-dominated fronts.
/// Returns index lists; front 0 is the Pareto set. O(M·N²).
pub fn fast_non_dominated_sort(objs: &[&[f64]]) -> Vec<Vec<usize>> {
    fast_non_dominated_sort_threads(objs, 1)
}

/// [`fast_non_dominated_sort`] with the O(M·N²) domination matrix built
/// across `threads` scoped worker threads, rows chunked contiguously.
///
/// Exactly the same fronts in exactly the same index order as the serial
/// path at any thread count: row `p` scans every `q != p` in ascending
/// order, which reproduces the pairwise loop's `S_p` push order (all
/// dominated `q < p` ascending, then all dominated `q > p` ascending)
/// and its domination counts, so the front peeling below is untouched
/// by the fan-out. Thread count is a pure performance knob.
pub fn fast_non_dominated_sort_threads(objs: &[&[f64]], threads: usize) -> Vec<Vec<usize>> {
    let n = objs.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // S_p
    let mut domination_count = vec![0usize; n]; // n_p
    let mut fronts: Vec<Vec<usize>> = vec![Vec::new()];

    let threads = threads.clamp(1, n.max(1));
    if threads == 1 || n < threads * 8 {
        // serial pairwise loop: one dominates() call per unordered pair
        for p in 0..n {
            for q in (p + 1)..n {
                if dominates(objs[p], objs[q]) {
                    dominated_by[p].push(q);
                    domination_count[q] += 1;
                } else if dominates(objs[q], objs[p]) {
                    dominated_by[q].push(p);
                    domination_count[p] += 1;
                }
            }
        }
    } else {
        // row-chunked: each worker owns a contiguous band of rows and
        // writes only its own S_p / n_p slots (disjoint chunks)
        let chunk = n.div_ceil(threads);
        std::thread::scope(|scope| {
            for (ci, (dom_rows, cnt_rows)) in dominated_by
                .chunks_mut(chunk)
                .zip(domination_count.chunks_mut(chunk))
                .enumerate()
            {
                let base = ci * chunk;
                scope.spawn(move || {
                    for (r, (dom, cnt)) in
                        dom_rows.iter_mut().zip(cnt_rows.iter_mut()).enumerate()
                    {
                        let p = base + r;
                        for (q, obj_q) in objs.iter().enumerate() {
                            if q == p {
                                continue;
                            }
                            if dominates(objs[p], obj_q) {
                                dom.push(q);
                            } else if dominates(obj_q, objs[p]) {
                                *cnt += 1;
                            }
                        }
                    }
                });
            }
        });
    }
    for p in 0..n {
        if domination_count[p] == 0 {
            fronts[0].push(p);
        }
    }

    let mut i = 0;
    while !fronts[i].is_empty() {
        let mut next = Vec::new();
        for &p in &fronts[i] {
            for &q in &dominated_by[p] {
                domination_count[q] -= 1;
                if domination_count[q] == 0 {
                    next.push(q);
                }
            }
        }
        i += 1;
        fronts.push(next);
    }
    fronts.pop(); // drop trailing empty front
    fronts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominates_basic() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: no strict
    }

    #[test]
    fn sorts_into_layers() {
        // points: A(0,0) dominates everything; B(1,2)/C(2,1) mutually
        // non-dominated; D(3,3) dominated by all.
        let pts: Vec<&[f64]> = vec![&[0.0, 0.0], &[1.0, 2.0], &[2.0, 1.0], &[3.0, 3.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 3);
        assert_eq!(fronts[0], vec![0]);
        assert_eq!(fronts[1], vec![1, 2]);
        assert_eq!(fronts[2], vec![3]);
    }

    #[test]
    fn all_non_dominated_single_front() {
        let pts: Vec<&[f64]> = vec![&[0.0, 3.0], &[1.0, 2.0], &[2.0, 1.0], &[3.0, 0.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 4);
    }

    #[test]
    fn duplicates_share_front() {
        let pts: Vec<&[f64]> = vec![&[1.0, 1.0], &[1.0, 1.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 2);
    }

    #[test]
    fn empty_population() {
        let pts: Vec<&[f64]> = vec![];
        assert!(fast_non_dominated_sort(&pts).is_empty());
    }

    #[test]
    fn parallel_matches_serial_on_random_populations() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0xD0D0);
        for n in [1usize, 7, 64, 257] {
            let objs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..3).map(|_| (rng.below(12) as f64) * 0.5).collect())
                .collect();
            let views: Vec<&[f64]> = objs.iter().map(|o| o.as_slice()).collect();
            let serial = fast_non_dominated_sort(&views);
            for t in [2usize, 3, 4, 7] {
                assert_eq!(
                    fast_non_dominated_sort_threads(&views, t),
                    serial,
                    "fronts diverge from serial at n={n} threads={t}"
                );
            }
        }
    }

    #[test]
    fn three_objectives() {
        let pts: Vec<&[f64]> =
            vec![&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0], &[2.0, 2.0, 2.0], &[3.0, 3.0, 3.0]];
        let fronts = fast_non_dominated_sort(&pts);
        assert_eq!(fronts[0].len(), 3);
        assert_eq!(fronts[1], vec![3]);
    }
}
