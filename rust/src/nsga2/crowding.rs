//! Crowding distance (Deb et al. 2002, §III-B): diversity preservation
//! within a front; boundary solutions get +∞ so extremes always survive.

/// Total-order comparator, NaN sorting last. For finite values this is
/// exactly `partial_cmp` (stable sort keeps `-0.0`/`0.0` ties in index
/// order, like the old `.unwrap()` comparator did), but a NaN objective
/// no longer aborts the run — it orders after every real value,
/// consistent with the `unwrap_or(Equal)` truncation sort in
/// `nsga2/mod.rs`, and the NaN-range guard below keeps it out of every
/// finite member's distance.
fn nan_last(a: f64, b: f64) -> std::cmp::Ordering {
    match a.partial_cmp(&b) {
        Some(o) => o,
        None => a.is_nan().cmp(&b.is_nan()),
    }
}

/// Crowding distance of each member of one front (same index order).
pub fn crowding_distance(objs: &[&[f64]]) -> Vec<f64> {
    let n = objs.len();
    if n == 0 {
        return Vec::new();
    }
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    let m = objs[0].len();
    let mut dist = vec![0.0f64; n];
    let mut idx: Vec<usize> = (0..n).collect();
    for k in 0..m {
        idx.sort_by(|&a, &b| nan_last(objs[a][k], objs[b][k]));
        let lo = objs[idx[0]][k];
        let hi = objs[idx[n - 1]][k];
        dist[idx[0]] = f64::INFINITY;
        dist[idx[n - 1]] = f64::INFINITY;
        let range = hi - lo;
        if range.is_nan() || range <= 0.0 {
            continue; // degenerate (or NaN-poisoned) objective: contributes nothing
        }
        for w in 1..n - 1 {
            let prev = objs[idx[w - 1]][k];
            let next = objs[idx[w + 1]][k];
            if dist[idx[w]].is_finite() {
                dist[idx[w]] += (next - prev) / range;
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundaries_are_infinite() {
        let pts: Vec<&[f64]> = vec![&[0.0, 3.0], &[1.0, 2.0], &[2.0, 1.0], &[3.0, 0.0]];
        let d = crowding_distance(&pts);
        assert!(d[0].is_infinite());
        assert!(d[3].is_infinite());
        assert!(d[1].is_finite() && d[1] > 0.0);
    }

    #[test]
    fn sparser_point_has_larger_distance() {
        // middle points: one crowded pair, one isolated
        let pts: Vec<&[f64]> =
            vec![&[0.0, 10.0], &[1.0, 8.9], &[1.2, 8.7], &[5.0, 2.0], &[10.0, 0.0]];
        let d = crowding_distance(&pts);
        assert!(d[3] > d[1], "isolated {} vs crowded {}", d[3], d[2]);
    }

    #[test]
    fn small_fronts_all_infinite() {
        let pts: Vec<&[f64]> = vec![&[1.0, 2.0]];
        assert!(crowding_distance(&pts)[0].is_infinite());
        let two: Vec<&[f64]> = vec![&[1.0, 2.0], &[2.0, 1.0]];
        assert!(crowding_distance(&two).iter().all(|d| d.is_infinite()));
    }

    #[test]
    fn degenerate_objective_no_nan() {
        let pts: Vec<&[f64]> = vec![&[1.0, 5.0], &[1.0, 3.0], &[1.0, 1.0]];
        let d = crowding_distance(&pts);
        assert!(d.iter().all(|x| !x.is_nan()));
    }

    #[test]
    fn nan_objective_does_not_panic_and_sorts_last() {
        // regression: the old `partial_cmp().unwrap()` comparator aborted
        // the whole run on the first NaN objective
        let pts: Vec<&[f64]> =
            vec![&[0.0, 3.0], &[f64::NAN, 2.0], &[2.0, 1.0], &[3.0, 0.0], &[1.0, 2.5]];
        let d = crowding_distance(&pts);
        assert_eq!(d.len(), 5);
        // the NaN-poisoned objective contributes nothing, so every finite
        // member's distance stays NaN-free
        assert!(d.iter().all(|x| !x.is_nan()), "{d:?}");
        // objective 0's range is NaN -> skipped; objective 1 still ranks
        // its own boundaries infinite
        assert!(d[3].is_infinite());
    }

    #[test]
    fn nan_last_is_a_total_order() {
        use std::cmp::Ordering::*;
        assert_eq!(nan_last(1.0, 2.0), Less);
        assert_eq!(nan_last(2.0, 1.0), Greater);
        assert_eq!(nan_last(1.0, 1.0), Equal);
        assert_eq!(nan_last(f64::NAN, 1.0), Greater);
        assert_eq!(nan_last(1.0, f64::NAN), Less);
        assert_eq!(nan_last(f64::NAN, f64::NAN), Equal);
        assert_eq!(nan_last(f64::NEG_INFINITY, f64::INFINITY), Less);
    }

    #[test]
    fn empty_front() {
        let pts: Vec<&[f64]> = vec![];
        assert!(crowding_distance(&pts).is_empty());
    }
}
