//! NSGA-II multi-objective evolutionary optimizer (Deb et al. 2002),
//! implemented from scratch for integer genomes with a fixed per-gene
//! alphabet — the layer→device mapping P : {1..L} → {0..D-1} of the paper
//! (§IV), but generic enough to drive the fault-unaware baselines too.
//!
//! Components: fast non-dominated sorting, crowding distance, binary
//! tournament on (rank, crowding), uniform + two-point crossover,
//! per-gene reset mutation, elitist (μ+λ) environmental selection.
//!
//! # Evaluation engine
//!
//! Fitness evaluation is *batched*: the optimizer collects each
//! generation's offspring genomes first (variation consumes the PRNG in
//! exactly the legacy order) and then hands the whole generation to
//! [`Problem::evaluate_batch`] in one call. The default implementation
//! falls back to a serial [`Problem::evaluate`] loop, so simple problems
//! are unaffected; expensive problems (fault-injected accuracy — see
//! `partition::PartitionEvaluator::objectives_batch`) override it to
//! deduplicate equivalent genomes and fan residual work across threads.
//!
//! Determinism contract: the optimizer's PRNG is only consumed by
//! variation and never crosses into evaluation, and batch results are
//! consumed in submission order — so for a fixed seed the population
//! trajectory (and final front) is bitwise identical whether a problem
//! evaluates serially or in parallel.
//!
//! # Parallel selection pipeline
//!
//! [`Nsga2Config::selection_threads`] parallelizes the optimizer's own
//! hot loops — the O(M·N²) domination matrix, per-front crowding, and
//! offspring variation — with two distinct determinism contracts:
//!
//! * `selection_threads <= 1` (default): the **legacy bitwise contract**.
//!   Variation consumes the single config-seeded PRNG in the historical
//!   order; trajectories are bit-for-bit what every release to date
//!   produced (frozen as a reference oracle in
//!   `bench::suite::legacy_nsga2`).
//! * `selection_threads >= 2`: the **self-deterministic parallel
//!   contract**. Each offspring pair draws from its own counter-derived
//!   stream ([`crate::util::prng::Rng::fork`]`(seed, generation, pair)`),
//!   so the trajectory is a pure function of the seed — bitwise identical
//!   across repeats and across *any* thread count ≥ 2, but (by design) a
//!   different sequence than the legacy serial path.
//!
//! Sorting and crowding fan-outs are result-identical to serial at any
//! thread count (row chunking preserves `S_p` order; fronts are
//! independent), so they run under the same knob without affecting
//! either contract.

mod crowding;
mod hypervolume;
mod sort;

pub use crowding::crowding_distance;
pub use hypervolume::{front_hypervolume, front_spread, hypervolume};
pub use sort::{dominates, fast_non_dominated_sort, fast_non_dominated_sort_threads};

use crate::obs::Telemetry;
use crate::util::json::num;
use crate::util::prng::Rng;

/// One candidate solution with its evaluated objective vector (minimized).
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Vec<usize>,
    pub objectives: Vec<f64>,
    pub rank: usize,
    pub crowding: f64,
}

/// Optimizer configuration (paper §VI-A: population 60, generations 60).
#[derive(Clone, Debug)]
pub struct Nsga2Config {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub seed: u64,
    /// Worker threads for the selection pipeline (domination matrix,
    /// per-front crowding, offspring variation). `0`/`1` = the legacy
    /// bitwise-exact serial PRNG path; `>= 2` selects the
    /// self-deterministic parallel variation algorithm, whose results
    /// depend only on the seed — never on the actual thread count (see
    /// module docs). Sorting/crowding results are serial-identical at
    /// any value.
    pub selection_threads: usize,
    /// Reference point for per-generation hypervolume convergence
    /// analytics (spec: `telemetry.hv_reference`). `None` freezes a
    /// reference from the worst initial-population objectives (×
    /// [`HV_REFERENCE_MARGIN`]) so generations stay comparable within a
    /// run; a spec-declared point additionally makes curves comparable
    /// *across* runs. Only consulted when telemetry is enabled.
    pub hv_reference: Option<Vec<f64>>,
}

/// Margin applied to the worst initial objectives when freezing an
/// implicit hypervolume reference point (no `hv_reference` declared).
pub const HV_REFERENCE_MARGIN: f64 = 1.1;

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config {
            pop_size: 60,
            generations: 60,
            crossover_prob: 0.9,
            mutation_prob: 0.08,
            seed: 7,
            selection_threads: 1,
            hv_reference: None,
        }
    }
}

/// The optimization problem: genome shape + objective evaluation.
pub trait Problem {
    /// Number of genes (L, the number of partitionable units).
    fn genome_len(&self) -> usize;
    /// Per-gene alphabet size (D, the number of devices).
    fn alphabet(&self) -> usize;
    /// Evaluate a genome to an objective vector (all minimized).
    fn evaluate(&mut self, genome: &[usize]) -> Vec<f64>;
    /// Evaluate a whole generation at once. The returned vectors must be
    /// in submission order, one per genome. The default delegates to
    /// [`Problem::evaluate`] serially; override for batched backends
    /// (dedup, caching, thread fan-out). Implementations must stay pure
    /// per genome: the same genome maps to the same objectives regardless
    /// of batch composition, or determinism across batch shapes is lost.
    fn evaluate_batch(&mut self, genomes: &[Vec<usize>]) -> Vec<Vec<f64>> {
        genomes.iter().map(|g| self.evaluate(g)).collect()
    }
    /// Optional: seed individuals injected into the initial population.
    fn seeds(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }
}

/// Per-generation statistics handed to the progress callback.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub generation: usize,
    pub front_size: usize,
    pub best_per_objective: Vec<f64>,
    pub evaluations: usize,
}

/// The optimizer.
pub struct Nsga2 {
    cfg: Nsga2Config,
    rng: Rng,
    evaluations: usize,
    /// Variation rounds produced so far — the `stream` coordinate of the
    /// parallel path's counter-derived PRNG forks, so every generation
    /// (and every standalone `produce_offspring` call) gets fresh
    /// per-pair streams.
    variation_epoch: u64,
    telemetry: Telemetry,
}

impl Nsga2 {
    pub fn new(cfg: Nsga2Config) -> Self {
        let rng = Rng::new(cfg.seed);
        Nsga2 { cfg, rng, evaluations: 0, variation_epoch: 0, telemetry: Telemetry::disabled() }
    }

    /// Attach the run's telemetry handle (builder form). Each generation
    /// then emits an `opt.generation` span from the optimizer thread.
    pub fn with_telemetry(mut self, telemetry: Telemetry) -> Self {
        self.telemetry = telemetry;
        self
    }

    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    fn random_genome(&mut self, len: usize, alphabet: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.below(alphabet)).collect()
    }

    /// Evaluate one generation's worth of genomes as a single batch.
    fn evaluate_all<P: Problem>(
        &mut self,
        problem: &mut P,
        genomes: Vec<Vec<usize>>,
    ) -> Vec<Individual> {
        self.evaluations += genomes.len();
        let objectives = problem.evaluate_batch(&genomes);
        assert_eq!(
            objectives.len(),
            genomes.len(),
            "evaluate_batch must return one objective vector per genome"
        );
        // NaN/∞ boundary check: a non-finite objective compares false both
        // ways in `dominates`, is never dominated, and would silently
        // pollute front 0 — fail loudly here, naming the offender.
        for (genome, objs) in genomes.iter().zip(&objectives) {
            assert!(
                objs.iter().all(|x| x.is_finite()),
                "problem produced a non-finite objective vector {objs:?} \
                 for genome {genome:?}; NaN/infinite objectives corrupt \
                 Pareto ranking (never dominated -> land in front 0)"
            );
        }
        genomes
            .into_iter()
            .zip(objectives)
            .map(|(genome, objectives)| Individual {
                genome,
                objectives,
                rank: usize::MAX,
                crowding: 0.0,
            })
            .collect()
    }

    /// Assign ranks + crowding in place; returns the fronts (index lists).
    /// Serial entry point — identical to `rank_population_threads(pop, 1)`.
    pub fn rank_population(pop: &mut [Individual]) -> Vec<Vec<usize>> {
        Self::rank_population_threads(pop, 1)
    }

    /// [`Nsga2::rank_population`] with the domination matrix row-chunked
    /// and per-front crowding distances computed across `threads` scoped
    /// workers. Fronts are independent of each other, and the parallel
    /// sort is order-identical to serial, so the assigned ranks/crowding
    /// are the same at any thread count.
    pub fn rank_population_threads(pop: &mut [Individual], threads: usize) -> Vec<Vec<usize>> {
        let fronts = {
            let objs: Vec<&[f64]> = pop.iter().map(|i| i.objectives.as_slice()).collect();
            fast_non_dominated_sort_threads(&objs, threads)
        };
        let crowds: Vec<Vec<f64>> = if threads >= 2 && fronts.len() >= 2 {
            let pop_view: &[Individual] = pop;
            let front_crowd = |front: &[usize]| {
                let front_objs: Vec<&[f64]> =
                    front.iter().map(|&i| pop_view[i].objectives.as_slice()).collect();
                crowding_distance(&front_objs)
            };
            let mut crowds: Vec<Vec<f64>> = vec![Vec::new(); fronts.len()];
            let chunk = fronts.len().div_ceil(threads);
            std::thread::scope(|scope| {
                let front_crowd = &front_crowd;
                for (out_chunk, front_chunk) in
                    crowds.chunks_mut(chunk).zip(fronts.chunks(chunk))
                {
                    scope.spawn(move || {
                        for (out, front) in out_chunk.iter_mut().zip(front_chunk) {
                            *out = front_crowd(front);
                        }
                    });
                }
            });
            crowds
        } else {
            fronts
                .iter()
                .map(|front| {
                    let front_objs: Vec<&[f64]> =
                        front.iter().map(|&i| pop[i].objectives.as_slice()).collect();
                    crowding_distance(&front_objs)
                })
                .collect()
        };
        for (rank, (front, crowd)) in fronts.iter().zip(&crowds).enumerate() {
            for (k, &i) in front.iter().enumerate() {
                pop[i].rank = rank;
                pop[i].crowding = crowd[k];
            }
        }
        fronts
    }

    /// Binary tournament: lower rank wins; ties broken by larger crowding.
    /// Static so the forked parallel path can run it on a per-pair RNG;
    /// the draw order is exactly the historical method's.
    fn tournament_with<'a>(rng: &mut Rng, pop: &'a [Individual]) -> &'a Individual {
        let a = &pop[rng.below(pop.len())];
        let b = &pop[rng.below(pop.len())];
        if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
            a
        } else {
            b
        }
    }

    fn crossover_with(
        rng: &mut Rng,
        crossover_prob: f64,
        a: &[usize],
        b: &[usize],
    ) -> (Vec<usize>, Vec<usize>) {
        let n = a.len();
        if !rng.chance(crossover_prob) || n < 2 {
            return (a.to_vec(), b.to_vec());
        }
        if rng.chance(0.5) {
            // uniform
            let mut c = a.to_vec();
            let mut d = b.to_vec();
            for i in 0..n {
                if rng.chance(0.5) {
                    std::mem::swap(&mut c[i], &mut d[i]);
                }
            }
            (c, d)
        } else {
            // two-point
            let (mut i, mut j) = (rng.below(n), rng.below(n));
            if i > j {
                std::mem::swap(&mut i, &mut j);
            }
            let mut c = a.to_vec();
            let mut d = b.to_vec();
            for k in i..=j {
                std::mem::swap(&mut c[k], &mut d[k]);
            }
            (c, d)
        }
    }

    fn mutate_with(rng: &mut Rng, mutation_prob: f64, genome: &mut [usize], alphabet: usize) {
        for g in genome.iter_mut() {
            if rng.chance(mutation_prob) {
                *g = rng.below(alphabet);
            }
        }
    }

    /// One full round of variation: tournament-select parents from
    /// `pop` (which must already be ranked) and produce `pop_size`
    /// offspring genomes via crossover + per-gene mutation. It is `pub`
    /// so `bench_perf` can profile variation throughput in isolation
    /// (`BENCH_variation.json`).
    ///
    /// Dispatches on [`Nsga2Config::selection_threads`]: `<= 1` keeps
    /// the legacy serial loop, whose PRNG consumption order is identical
    /// to the inline loop `run` used historically (behavior-preserving);
    /// `>= 2` uses per-pair counter-derived streams — bitwise identical
    /// for a given seed at any thread count, but a different (equally
    /// valid) sequence than the serial path.
    pub fn produce_offspring(&mut self, pop: &[Individual], alphabet: usize) -> Vec<Vec<usize>> {
        let epoch = self.variation_epoch;
        self.variation_epoch += 1;
        if self.cfg.selection_threads <= 1 {
            let mut offspring_genomes = Vec::with_capacity(self.cfg.pop_size);
            while offspring_genomes.len() < self.cfg.pop_size {
                let pa = Self::tournament_with(&mut self.rng, pop);
                let pb = Self::tournament_with(&mut self.rng, pop);
                let (mut c, mut d) = Self::crossover_with(
                    &mut self.rng,
                    self.cfg.crossover_prob,
                    &pa.genome,
                    &pb.genome,
                );
                Self::mutate_with(&mut self.rng, self.cfg.mutation_prob, &mut c, alphabet);
                Self::mutate_with(&mut self.rng, self.cfg.mutation_prob, &mut d, alphabet);
                offspring_genomes.push(c);
                if offspring_genomes.len() < self.cfg.pop_size {
                    offspring_genomes.push(d);
                }
            }
            offspring_genomes
        } else {
            self.produce_offspring_forked(pop, alphabet, epoch)
        }
    }

    /// Parallel variation: offspring pair `p` draws every random decision
    /// from `Rng::fork(seed, epoch, p)`, so the generation is a pure
    /// function of `(seed, epoch)` — the thread count only changes how
    /// pairs are scheduled, never what they produce. Slots are
    /// pre-allocated and handed out as disjoint `&mut` chunks of whole
    /// pairs, so workers never contend.
    fn produce_offspring_forked(
        &self,
        pop: &[Individual],
        alphabet: usize,
        epoch: u64,
    ) -> Vec<Vec<usize>> {
        let pop_size = self.cfg.pop_size;
        let pairs = pop_size.div_ceil(2);
        let mut slots: Vec<Vec<usize>> = vec![Vec::new(); pop_size];
        if pairs == 0 {
            return slots;
        }
        let threads = self.cfg.selection_threads.clamp(1, pairs);

        let run_pair = |pair_idx: usize, out: &mut [Vec<usize>]| {
            let mut rng = Rng::fork(self.cfg.seed, epoch, pair_idx as u64);
            let pa = Self::tournament_with(&mut rng, pop);
            let pb = Self::tournament_with(&mut rng, pop);
            let (mut c, mut d) = Self::crossover_with(
                &mut rng,
                self.cfg.crossover_prob,
                &pa.genome,
                &pb.genome,
            );
            Self::mutate_with(&mut rng, self.cfg.mutation_prob, &mut c, alphabet);
            Self::mutate_with(&mut rng, self.cfg.mutation_prob, &mut d, alphabet);
            out[0] = c;
            if out.len() > 1 {
                out[1] = d; // odd pop_size: the last pair's second child is dropped
            }
        };

        let pair_chunk = pairs.div_ceil(threads);
        std::thread::scope(|scope| {
            let run_pair = &run_pair;
            for (ci, slot_chunk) in slots.chunks_mut(2 * pair_chunk).enumerate() {
                let base_pair = ci * pair_chunk;
                scope.spawn(move || {
                    for (k, out) in slot_chunk.chunks_mut(2).enumerate() {
                        run_pair(base_pair + k, out);
                    }
                });
            }
        });
        slots
    }

    /// Run the full loop; returns the final first front (Pareto set).
    pub fn run<P: Problem>(
        &mut self,
        problem: &mut P,
        mut on_generation: impl FnMut(&GenStats),
    ) -> Vec<Individual> {
        let len = problem.genome_len();
        let alphabet = problem.alphabet();
        assert!(alphabet >= 1 && len >= 1);
        // clone the (refcounted) handle: spans borrow the telemetry,
        // and `self` is mutably borrowed throughout the loop
        let telemetry = self.telemetry.clone();
        let mut run_span = telemetry.span("opt.run");
        run_span.note("pop_size", num(self.cfg.pop_size as f64));
        run_span.note("generations", num(self.cfg.generations as f64));
        let sel_threads = self.cfg.selection_threads.max(1);
        telemetry.gauge_set("opt_selection_threads", sel_threads as f64);

        // initial population: seeds first, then random fill
        let mut genomes: Vec<Vec<usize>> = problem
            .seeds()
            .into_iter()
            .filter(|g| g.len() == len && g.iter().all(|&x| x < alphabet))
            .take(self.cfg.pop_size)
            .collect();
        while genomes.len() < self.cfg.pop_size {
            genomes.push(self.random_genome(len, alphabet));
        }
        let mut pop = self.evaluate_all(problem, genomes);
        Self::rank_population_threads(&mut pop, sel_threads);

        // Convergence analytics (telemetry-gated so disabled runs skip
        // the O(front²) hypervolume work entirely): the reference point
        // is fixed once — spec-declared, or frozen from the worst
        // initial objectives — so per-generation hypervolumes are
        // comparable. Computed here, on the coordinating thread, from
        // deterministic objective values only.
        let hv_reference: Option<Vec<f64>> = if telemetry.is_enabled() {
            Some(self.cfg.hv_reference.clone().unwrap_or_else(|| {
                let nobj = pop[0].objectives.len();
                (0..nobj)
                    .map(|k| {
                        pop.iter()
                            .map(|i| i.objectives[k])
                            .fold(f64::NEG_INFINITY, f64::max)
                            * HV_REFERENCE_MARGIN
                            + 1e-9
                    })
                    .collect()
            }))
        } else {
            None
        };
        let mut prev_hv: Option<f64> = None;
        let mut stall = 0usize;

        for generation in 0..self.cfg.generations {
            let mut gen_span = telemetry.span("opt.generation");
            gen_span.note("generation", num(generation as f64));
            // variation first: collect the full offspring generation so it
            // can be evaluated as one batch. Parents are borrowed from the
            // population (cloned exactly once, inside crossover).
            let offspring_genomes = {
                let mut var_span = telemetry.span("opt.variation");
                var_span.note("generation", num(generation as f64));
                var_span.note("threads", num(sel_threads as f64));
                self.produce_offspring(&pop, alphabet)
            };
            let offspring = self.evaluate_all(problem, offspring_genomes);

            // elitist environmental selection over parents + offspring
            pop.extend(offspring);
            let fronts = {
                let mut sort_span = telemetry.span("opt.sort");
                sort_span.note("generation", num(generation as f64));
                sort_span.note("pool", num(pop.len() as f64));
                Self::rank_population_threads(&mut pop, sel_threads)
            };
            let mut next: Vec<Individual> = Vec::with_capacity(self.cfg.pop_size);
            for front in &fronts {
                if next.len() + front.len() <= self.cfg.pop_size {
                    for &i in front {
                        next.push(pop[i].clone());
                    }
                } else {
                    // fill by descending crowding distance
                    let mut rest: Vec<usize> = front.clone();
                    rest.sort_by(|&a, &b| {
                        pop[b]
                            .crowding
                            .partial_cmp(&pop[a].crowding)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    });
                    for &i in rest.iter().take(self.cfg.pop_size - next.len()) {
                        next.push(pop[i].clone());
                    }
                    break;
                }
            }
            pop = next;
            Self::rank_population_threads(&mut pop, sel_threads);

            let nobj = pop[0].objectives.len();
            let best: Vec<f64> = (0..nobj)
                .map(|k| {
                    pop.iter().map(|i| i.objectives[k]).fold(f64::INFINITY, f64::min)
                })
                .collect();
            let front_size = pop.iter().filter(|i| i.rank == 0).count();
            gen_span.note("front_size", num(front_size as f64));
            gen_span.note("evaluations", num(self.evaluations as f64));
            telemetry.counter_add("opt_generations_total", 1);
            if let Some(reference) = &hv_reference {
                let front_objs: Vec<Vec<f64>> = pop
                    .iter()
                    .filter(|i| i.rank == 0)
                    .map(|i| i.objectives.clone())
                    .collect();
                let hv = hypervolume(&front_objs, reference);
                let spread = front_spread(&front_objs);
                // epsilon-progress: hypervolume gained this generation;
                // the stall counter tracks consecutive non-improving
                // generations (the analyzer's convergence curve input)
                let progress = hv - prev_hv.unwrap_or(0.0);
                if prev_hv.is_some() && progress <= 1e-12 {
                    stall += 1;
                } else {
                    stall = 0;
                }
                prev_hv = Some(hv);
                telemetry.gauge_set("opt_hypervolume", hv);
                telemetry.gauge_set("opt_front_spread", spread);
                telemetry.gauge_set("opt_hv_stall_generations", stall as f64);
                telemetry.trace_event(
                    "convergence",
                    Some("opt.convergence"),
                    &[
                        ("generation", num(generation as f64)),
                        ("hypervolume", num(hv)),
                        ("spread", num(spread)),
                        ("progress", num(progress)),
                        ("stall", num(stall as f64)),
                        ("front_size", num(front_size as f64)),
                    ],
                );
            }
            on_generation(&GenStats {
                generation,
                front_size,
                best_per_objective: best,
                evaluations: self.evaluations,
            });
        }
        run_span.note("evaluations", num(self.evaluations as f64));

        let mut front: Vec<Individual> =
            pop.into_iter().filter(|i| i.rank == 0).collect();
        // dedup identical genomes for a clean returned front
        front.sort_by(|a, b| a.genome.cmp(&b.genome));
        front.dedup_by(|a, b| a.genome == b.genome);
        front
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `selection_threads` for the generic behavior tests below, so CI
    /// can force both the legacy serial path and the forked parallel
    /// path through the whole suite (`AFARE_SELECTION_THREADS=1|4` in
    /// `scripts/check.sh`).
    fn env_sel_threads() -> usize {
        std::env::var("AFARE_SELECTION_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1)
    }

    /// Two-objective toy: minimize (#ones, #zeros). Every genome is
    /// Pareto-optimal on the count trade-off; extremes must be found.
    struct OnesZeros {
        len: usize,
    }

    impl Problem for OnesZeros {
        fn genome_len(&self) -> usize {
            self.len
        }
        fn alphabet(&self) -> usize {
            2
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            let ones = g.iter().filter(|&&x| x == 1).count() as f64;
            vec![ones, self.len as f64 - ones]
        }
    }

    #[test]
    fn finds_extremes_of_tradeoff() {
        let mut p = OnesZeros { len: 12 };
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 40,
            generations: 30,
            selection_threads: env_sel_threads(),
            ..Default::default()
        });
        let front = opt.run(&mut p, |_| {});
        let ones: Vec<f64> = front.iter().map(|i| i.objectives[0]).collect();
        assert!(ones.iter().any(|&o| o == 0.0), "all-zeros not found");
        assert!(ones.iter().any(|&o| o == 12.0), "all-ones not found");
        // front covers a range of trade-offs
        assert!(front.len() >= 8, "front too small: {}", front.len());
    }

    /// Single-objective sanity: NSGA-II degenerates to elitist GA.
    struct SumMin;
    impl Problem for SumMin {
        fn genome_len(&self) -> usize {
            16
        }
        fn alphabet(&self) -> usize {
            4
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            vec![g.iter().sum::<usize>() as f64]
        }
    }

    #[test]
    fn minimizes_single_objective() {
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 30,
            generations: 40,
            selection_threads: env_sel_threads(),
            ..Default::default()
        });
        let front = opt.run(&mut SumMin, |_| {});
        assert!(front.iter().any(|i| i.objectives[0] == 0.0));
    }

    #[test]
    fn produce_offspring_is_well_formed_and_seeded() {
        // ranked parent pool of all-zero / all-one genomes
        let mk_pop = || {
            let mut pop: Vec<Individual> = (0..8)
                .map(|i| Individual {
                    genome: vec![usize::from(i % 2 == 0); 6],
                    objectives: vec![i as f64],
                    rank: 0,
                    crowding: 0.0,
                })
                .collect();
            Nsga2::rank_population(&mut pop);
            pop
        };
        let gen = |seed| {
            let mut opt = Nsga2::new(Nsga2Config { pop_size: 10, seed, ..Default::default() });
            opt.produce_offspring(&mk_pop(), 2)
        };
        let kids = gen(3);
        assert_eq!(kids.len(), 10);
        assert!(kids.iter().all(|g| g.len() == 6 && g.iter().all(|&x| x < 2)));
        // deterministic in the config seed
        assert_eq!(gen(3), gen(3));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut opt = Nsga2::new(Nsga2Config {
                pop_size: 20,
                generations: 10,
                seed,
                selection_threads: env_sel_threads(),
                ..Default::default()
            });
            opt.run(&mut OnesZeros { len: 8 }, |_| {})
                .iter()
                .map(|i| i.genome.clone())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    /// The `selection_threads >= 2` contract: the trajectory is a pure
    /// function of the seed — identical across repeats AND across any
    /// thread count in the parallel regime.
    #[test]
    fn forked_path_is_thread_count_invariant() {
        let run = |threads: usize, seed: u64| {
            let mut opt = Nsga2::new(Nsga2Config {
                pop_size: 24,
                generations: 8,
                seed,
                selection_threads: threads,
                ..Default::default()
            });
            let front = opt.run(&mut OnesZeros { len: 10 }, |_| {});
            crate::bench::suite::front_fingerprint(&front)
        };
        let two = run(2, 11);
        assert_eq!(two, run(2, 11), "forked path not repeatable");
        assert_eq!(two, run(3, 11), "forked path depends on thread count (3)");
        assert_eq!(two, run(8, 11), "forked path depends on thread count (8)");
        assert_ne!(two, run(2, 12), "forked path ignores the seed");
    }

    /// Odd `pop_size`: both paths must produce exactly `pop_size`
    /// well-formed offspring (the last pair's second child is dropped).
    #[test]
    fn odd_pop_size_offspring_both_paths() {
        let mut pop: Vec<Individual> = (0..7)
            .map(|i| Individual {
                genome: vec![i % 3; 5],
                objectives: vec![i as f64, 7.0 - i as f64],
                rank: usize::MAX,
                crowding: 0.0,
            })
            .collect();
        Nsga2::rank_population(&mut pop);
        for threads in [1usize, 2, 4] {
            let mut opt = Nsga2::new(Nsga2Config {
                pop_size: 7,
                seed: 9,
                selection_threads: threads,
                ..Default::default()
            });
            let kids = opt.produce_offspring(&pop, 3);
            assert_eq!(kids.len(), 7, "threads={threads}");
            assert!(
                kids.iter().all(|g| g.len() == 5 && g.iter().all(|&x| x < 3)),
                "malformed offspring at threads={threads}"
            );
        }
    }

    /// Successive variation rounds at `selection_threads >= 2` use fresh
    /// per-pair streams (the epoch counter), so generations differ.
    #[test]
    fn forked_epochs_reseed_between_rounds() {
        let mut pop: Vec<Individual> = (0..10)
            .map(|i| Individual {
                genome: (0..6).map(|k| (i + k) % 4).collect(),
                objectives: vec![i as f64, 10.0 - i as f64],
                rank: usize::MAX,
                crowding: 0.0,
            })
            .collect();
        Nsga2::rank_population(&mut pop);
        let mut opt =
            Nsga2::new(Nsga2Config { pop_size: 10, seed: 5, selection_threads: 2, ..Default::default() });
        let first = opt.produce_offspring(&pop, 4);
        let second = opt.produce_offspring(&pop, 4);
        assert_ne!(first, second, "variation epochs reuse the same streams");
    }

    /// Regression: a problem emitting NaN objectives must fail loudly at
    /// the evaluation boundary (naming the genome), not silently park the
    /// NaN vector in front 0.
    #[test]
    fn nan_objectives_are_rejected_with_context() {
        struct Poisoned;
        impl Problem for Poisoned {
            fn genome_len(&self) -> usize {
                4
            }
            fn alphabet(&self) -> usize {
                2
            }
            fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
                if g.iter().sum::<usize>() == 0 {
                    vec![f64::NAN, 1.0] // all-zeros genome poisons the run
                } else {
                    vec![g.iter().sum::<usize>() as f64, 1.0]
                }
            }
        }
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // keep the expected panic quiet
        let result = std::panic::catch_unwind(|| {
            let mut opt = Nsga2::new(Nsga2Config {
                pop_size: 16,
                generations: 4,
                ..Default::default()
            });
            opt.run(&mut Poisoned, |_| {});
        });
        std::panic::set_hook(prev);
        let err = result.expect_err("NaN objective vector must abort evaluation");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("non-finite objective"),
            "panic message lacks context: {msg:?}"
        );
        assert!(msg.contains("genome"), "panic message does not name the genome: {msg:?}");
    }

    /// rank_population_threads assigns the same ranks/crowding as the
    /// serial path at every thread count.
    #[test]
    fn threaded_ranking_matches_serial() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0xBEEF);
        let mk = |rng: &mut Rng| -> Vec<Individual> {
            (0..65)
                .map(|_| Individual {
                    genome: vec![0; 4],
                    objectives: (0..3).map(|_| (rng.below(9) as f64) * 0.25).collect(),
                    rank: usize::MAX,
                    crowding: 0.0,
                })
                .collect()
        };
        let base = mk(&mut rng);
        let mut serial = base.clone();
        let serial_fronts = Nsga2::rank_population(&mut serial);
        for threads in [2usize, 3, 4] {
            let mut par = base.clone();
            let fronts = Nsga2::rank_population_threads(&mut par, threads);
            assert_eq!(fronts, serial_fronts, "fronts diverge at threads={threads}");
            for (s, p) in serial.iter().zip(&par) {
                assert_eq!(s.rank, p.rank);
                assert!(
                    s.crowding == p.crowding
                        || (s.crowding.is_infinite() && p.crowding.is_infinite()),
                    "crowding diverges at threads={threads}: {} vs {}",
                    s.crowding,
                    p.crowding
                );
            }
        }
    }

    #[test]
    fn seeds_are_injected() {
        struct Seeded;
        impl Problem for Seeded {
            fn genome_len(&self) -> usize {
                6
            }
            fn alphabet(&self) -> usize {
                2
            }
            fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
                // strongly reward the seeded genome so it must survive
                let target = [1, 0, 1, 0, 1, 0];
                let d = g.iter().zip(&target).filter(|(a, b)| a != b).count();
                vec![d as f64]
            }
            fn seeds(&self) -> Vec<Vec<usize>> {
                vec![vec![1, 0, 1, 0, 1, 0]]
            }
        }
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 10,
            generations: 1,
            ..Default::default()
        });
        let front = opt.run(&mut Seeded, |_| {});
        assert!(front.iter().any(|i| i.objectives[0] == 0.0));
    }

    /// The optimizer submits whole generations to evaluate_batch, and an
    /// overriding problem produces the same run as the serial default.
    #[test]
    fn batch_evaluation_receives_whole_generations() {
        struct Batched {
            inner: OnesZeros,
            batch_sizes: Vec<usize>,
        }
        impl Problem for Batched {
            fn genome_len(&self) -> usize {
                self.inner.genome_len()
            }
            fn alphabet(&self) -> usize {
                self.inner.alphabet()
            }
            fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
                self.inner.evaluate(g)
            }
            fn evaluate_batch(&mut self, genomes: &[Vec<usize>]) -> Vec<Vec<f64>> {
                self.batch_sizes.push(genomes.len());
                genomes.iter().map(|g| self.inner.evaluate(g)).collect()
            }
        }
        let cfg = Nsga2Config { pop_size: 10, generations: 3, ..Default::default() };
        let mut batched = Batched { inner: OnesZeros { len: 8 }, batch_sizes: vec![] };
        let front_batched = Nsga2::new(cfg.clone()).run(&mut batched, |_| {});
        // initial population + one batch per generation, all full-size
        assert_eq!(batched.batch_sizes, vec![10; 4]);
        // identical trajectory to the serial default implementation
        let front_serial = Nsga2::new(cfg).run(&mut OnesZeros { len: 8 }, |_| {});
        let key = crate::bench::suite::front_fingerprint;
        assert_eq!(key(&front_batched), key(&front_serial));
    }

    #[test]
    fn callback_reports_progress() {
        let mut gens = Vec::new();
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 10,
            generations: 5,
            ..Default::default()
        });
        opt.run(&mut OnesZeros { len: 8 }, |s| gens.push(s.generation));
        assert_eq!(gens, vec![0, 1, 2, 3, 4]);
        assert_eq!(opt.evaluations(), 10 + 5 * 10);
    }
}
