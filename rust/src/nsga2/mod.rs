//! NSGA-II multi-objective evolutionary optimizer (Deb et al. 2002),
//! implemented from scratch for integer genomes with a fixed per-gene
//! alphabet — the layer→device mapping P : {1..L} → {0..D-1} of the paper
//! (§IV), but generic enough to drive the fault-unaware baselines too.
//!
//! Components: fast non-dominated sorting, crowding distance, binary
//! tournament on (rank, crowding), uniform + two-point crossover,
//! per-gene reset mutation, elitist (μ+λ) environmental selection.
//!
//! # Evaluation engine
//!
//! Fitness evaluation is *batched*: the optimizer collects each
//! generation's offspring genomes first (variation consumes the PRNG in
//! exactly the legacy order) and then hands the whole generation to
//! [`Problem::evaluate_batch`] in one call. The default implementation
//! falls back to a serial [`Problem::evaluate`] loop, so simple problems
//! are unaffected; expensive problems (fault-injected accuracy — see
//! `partition::PartitionEvaluator::objectives_batch`) override it to
//! deduplicate equivalent genomes and fan residual work across threads.
//!
//! Determinism contract: the optimizer's PRNG is only consumed by
//! variation and never crosses into evaluation, and batch results are
//! consumed in submission order — so for a fixed seed the population
//! trajectory (and final front) is bitwise identical whether a problem
//! evaluates serially or in parallel.

mod crowding;
mod hypervolume;
mod sort;

pub use crowding::crowding_distance;
pub use hypervolume::{front_hypervolume, hypervolume};
pub use sort::{dominates, fast_non_dominated_sort};

use crate::obs::Telemetry;
use crate::util::json::num;
use crate::util::prng::Rng;

/// One candidate solution with its evaluated objective vector (minimized).
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Vec<usize>,
    pub objectives: Vec<f64>,
    pub rank: usize,
    pub crowding: f64,
}

/// Optimizer configuration (paper §VI-A: population 60, generations 60).
#[derive(Clone, Debug)]
pub struct Nsga2Config {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    pub seed: u64,
}

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config {
            pop_size: 60,
            generations: 60,
            crossover_prob: 0.9,
            mutation_prob: 0.08,
            seed: 7,
        }
    }
}

/// The optimization problem: genome shape + objective evaluation.
pub trait Problem {
    /// Number of genes (L, the number of partitionable units).
    fn genome_len(&self) -> usize;
    /// Per-gene alphabet size (D, the number of devices).
    fn alphabet(&self) -> usize;
    /// Evaluate a genome to an objective vector (all minimized).
    fn evaluate(&mut self, genome: &[usize]) -> Vec<f64>;
    /// Evaluate a whole generation at once. The returned vectors must be
    /// in submission order, one per genome. The default delegates to
    /// [`Problem::evaluate`] serially; override for batched backends
    /// (dedup, caching, thread fan-out). Implementations must stay pure
    /// per genome: the same genome maps to the same objectives regardless
    /// of batch composition, or determinism across batch shapes is lost.
    fn evaluate_batch(&mut self, genomes: &[Vec<usize>]) -> Vec<Vec<f64>> {
        genomes.iter().map(|g| self.evaluate(g)).collect()
    }
    /// Optional: seed individuals injected into the initial population.
    fn seeds(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }
}

/// Per-generation statistics handed to the progress callback.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub generation: usize,
    pub front_size: usize,
    pub best_per_objective: Vec<f64>,
    pub evaluations: usize,
}

/// The optimizer.
pub struct Nsga2 {
    cfg: Nsga2Config,
    rng: Rng,
    evaluations: usize,
    telemetry: Telemetry,
}

impl Nsga2 {
    pub fn new(cfg: Nsga2Config) -> Self {
        let rng = Rng::new(cfg.seed);
        Nsga2 { cfg, rng, evaluations: 0, telemetry: Telemetry::disabled() }
    }

    /// Attach the run's telemetry handle (builder form). Each generation
    /// then emits an `opt.generation` span from the optimizer thread.
    pub fn with_telemetry(mut self, telemetry: Telemetry) -> Self {
        self.telemetry = telemetry;
        self
    }

    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    fn random_genome(&mut self, len: usize, alphabet: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.below(alphabet)).collect()
    }

    /// Evaluate one generation's worth of genomes as a single batch.
    fn evaluate_all<P: Problem>(
        &mut self,
        problem: &mut P,
        genomes: Vec<Vec<usize>>,
    ) -> Vec<Individual> {
        self.evaluations += genomes.len();
        let objectives = problem.evaluate_batch(&genomes);
        assert_eq!(
            objectives.len(),
            genomes.len(),
            "evaluate_batch must return one objective vector per genome"
        );
        genomes
            .into_iter()
            .zip(objectives)
            .map(|(genome, objectives)| Individual {
                genome,
                objectives,
                rank: usize::MAX,
                crowding: 0.0,
            })
            .collect()
    }

    /// Assign ranks + crowding in place; returns the fronts (index lists).
    fn rank_population(pop: &mut [Individual]) -> Vec<Vec<usize>> {
        let fronts = {
            let objs: Vec<&[f64]> = pop.iter().map(|i| i.objectives.as_slice()).collect();
            fast_non_dominated_sort(&objs)
        };
        for (rank, front) in fronts.iter().enumerate() {
            let crowd = {
                let front_objs: Vec<&[f64]> =
                    front.iter().map(|&i| pop[i].objectives.as_slice()).collect();
                crowding_distance(&front_objs)
            };
            for (k, &i) in front.iter().enumerate() {
                pop[i].rank = rank;
                pop[i].crowding = crowd[k];
            }
        }
        fronts
    }

    /// Binary tournament: lower rank wins; ties broken by larger crowding.
    fn tournament<'a>(&mut self, pop: &'a [Individual]) -> &'a Individual {
        let a = &pop[self.rng.below(pop.len())];
        let b = &pop[self.rng.below(pop.len())];
        if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
            a
        } else {
            b
        }
    }

    fn crossover(&mut self, a: &[usize], b: &[usize]) -> (Vec<usize>, Vec<usize>) {
        let n = a.len();
        if !self.rng.chance(self.cfg.crossover_prob) || n < 2 {
            return (a.to_vec(), b.to_vec());
        }
        if self.rng.chance(0.5) {
            // uniform
            let mut c = a.to_vec();
            let mut d = b.to_vec();
            for i in 0..n {
                if self.rng.chance(0.5) {
                    std::mem::swap(&mut c[i], &mut d[i]);
                }
            }
            (c, d)
        } else {
            // two-point
            let (mut i, mut j) = (self.rng.below(n), self.rng.below(n));
            if i > j {
                std::mem::swap(&mut i, &mut j);
            }
            let mut c = a.to_vec();
            let mut d = b.to_vec();
            for k in i..=j {
                std::mem::swap(&mut c[k], &mut d[k]);
            }
            (c, d)
        }
    }

    fn mutate(&mut self, genome: &mut [usize], alphabet: usize) {
        for g in genome.iter_mut() {
            if self.rng.chance(self.cfg.mutation_prob) {
                *g = self.rng.below(alphabet);
            }
        }
    }

    /// One full round of variation: tournament-select parents from
    /// `pop` (which must already be ranked) and produce `pop_size`
    /// offspring genomes via two-point crossover + per-gene mutation.
    /// PRNG consumption order is identical to the inline loop `run`
    /// used historically, so extracting it is behavior-preserving; it
    /// is `pub` so `bench_perf` can profile variation throughput in
    /// isolation (`BENCH_variation.json`).
    pub fn produce_offspring(&mut self, pop: &[Individual], alphabet: usize) -> Vec<Vec<usize>> {
        let mut offspring_genomes = Vec::with_capacity(self.cfg.pop_size);
        while offspring_genomes.len() < self.cfg.pop_size {
            let pa = self.tournament(pop);
            let pb = self.tournament(pop);
            let (mut c, mut d) = self.crossover(&pa.genome, &pb.genome);
            self.mutate(&mut c, alphabet);
            self.mutate(&mut d, alphabet);
            offspring_genomes.push(c);
            if offspring_genomes.len() < self.cfg.pop_size {
                offspring_genomes.push(d);
            }
        }
        offspring_genomes
    }

    /// Run the full loop; returns the final first front (Pareto set).
    pub fn run<P: Problem>(
        &mut self,
        problem: &mut P,
        mut on_generation: impl FnMut(&GenStats),
    ) -> Vec<Individual> {
        let len = problem.genome_len();
        let alphabet = problem.alphabet();
        assert!(alphabet >= 1 && len >= 1);
        // clone the (refcounted) handle: spans borrow the telemetry,
        // and `self` is mutably borrowed throughout the loop
        let telemetry = self.telemetry.clone();
        let mut run_span = telemetry.span("opt.run");
        run_span.note("pop_size", num(self.cfg.pop_size as f64));
        run_span.note("generations", num(self.cfg.generations as f64));

        // initial population: seeds first, then random fill
        let mut genomes: Vec<Vec<usize>> = problem
            .seeds()
            .into_iter()
            .filter(|g| g.len() == len && g.iter().all(|&x| x < alphabet))
            .take(self.cfg.pop_size)
            .collect();
        while genomes.len() < self.cfg.pop_size {
            genomes.push(self.random_genome(len, alphabet));
        }
        let mut pop = self.evaluate_all(problem, genomes);
        Self::rank_population(&mut pop);

        for generation in 0..self.cfg.generations {
            let mut gen_span = telemetry.span("opt.generation");
            gen_span.note("generation", num(generation as f64));
            // variation first: collect the full offspring generation so it
            // can be evaluated as one batch. Parents are borrowed from the
            // population (cloned exactly once, inside crossover).
            let offspring_genomes = self.produce_offspring(&pop, alphabet);
            let offspring = self.evaluate_all(problem, offspring_genomes);

            // elitist environmental selection over parents + offspring
            pop.extend(offspring);
            let fronts = Self::rank_population(&mut pop);
            let mut next: Vec<Individual> = Vec::with_capacity(self.cfg.pop_size);
            for front in &fronts {
                if next.len() + front.len() <= self.cfg.pop_size {
                    for &i in front {
                        next.push(pop[i].clone());
                    }
                } else {
                    // fill by descending crowding distance
                    let mut rest: Vec<usize> = front.clone();
                    rest.sort_by(|&a, &b| {
                        pop[b]
                            .crowding
                            .partial_cmp(&pop[a].crowding)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    });
                    for &i in rest.iter().take(self.cfg.pop_size - next.len()) {
                        next.push(pop[i].clone());
                    }
                    break;
                }
            }
            pop = next;
            Self::rank_population(&mut pop);

            let nobj = pop[0].objectives.len();
            let best: Vec<f64> = (0..nobj)
                .map(|k| {
                    pop.iter().map(|i| i.objectives[k]).fold(f64::INFINITY, f64::min)
                })
                .collect();
            let front_size = pop.iter().filter(|i| i.rank == 0).count();
            gen_span.note("front_size", num(front_size as f64));
            gen_span.note("evaluations", num(self.evaluations as f64));
            telemetry.counter_add("opt_generations_total", 1);
            on_generation(&GenStats {
                generation,
                front_size,
                best_per_objective: best,
                evaluations: self.evaluations,
            });
        }
        run_span.note("evaluations", num(self.evaluations as f64));

        let mut front: Vec<Individual> =
            pop.into_iter().filter(|i| i.rank == 0).collect();
        // dedup identical genomes for a clean returned front
        front.sort_by(|a, b| a.genome.cmp(&b.genome));
        front.dedup_by(|a, b| a.genome == b.genome);
        front
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-objective toy: minimize (#ones, #zeros). Every genome is
    /// Pareto-optimal on the count trade-off; extremes must be found.
    struct OnesZeros {
        len: usize,
    }

    impl Problem for OnesZeros {
        fn genome_len(&self) -> usize {
            self.len
        }
        fn alphabet(&self) -> usize {
            2
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            let ones = g.iter().filter(|&&x| x == 1).count() as f64;
            vec![ones, self.len as f64 - ones]
        }
    }

    #[test]
    fn finds_extremes_of_tradeoff() {
        let mut p = OnesZeros { len: 12 };
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 40,
            generations: 30,
            ..Default::default()
        });
        let front = opt.run(&mut p, |_| {});
        let ones: Vec<f64> = front.iter().map(|i| i.objectives[0]).collect();
        assert!(ones.iter().any(|&o| o == 0.0), "all-zeros not found");
        assert!(ones.iter().any(|&o| o == 12.0), "all-ones not found");
        // front covers a range of trade-offs
        assert!(front.len() >= 8, "front too small: {}", front.len());
    }

    /// Single-objective sanity: NSGA-II degenerates to elitist GA.
    struct SumMin;
    impl Problem for SumMin {
        fn genome_len(&self) -> usize {
            16
        }
        fn alphabet(&self) -> usize {
            4
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            vec![g.iter().sum::<usize>() as f64]
        }
    }

    #[test]
    fn minimizes_single_objective() {
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 30,
            generations: 40,
            ..Default::default()
        });
        let front = opt.run(&mut SumMin, |_| {});
        assert!(front.iter().any(|i| i.objectives[0] == 0.0));
    }

    #[test]
    fn produce_offspring_is_well_formed_and_seeded() {
        // ranked parent pool of all-zero / all-one genomes
        let mk_pop = || {
            let mut pop: Vec<Individual> = (0..8)
                .map(|i| Individual {
                    genome: vec![usize::from(i % 2 == 0); 6],
                    objectives: vec![i as f64],
                    rank: 0,
                    crowding: 0.0,
                })
                .collect();
            Nsga2::rank_population(&mut pop);
            pop
        };
        let gen = |seed| {
            let mut opt = Nsga2::new(Nsga2Config { pop_size: 10, seed, ..Default::default() });
            opt.produce_offspring(&mk_pop(), 2)
        };
        let kids = gen(3);
        assert_eq!(kids.len(), 10);
        assert!(kids.iter().all(|g| g.len() == 6 && g.iter().all(|&x| x < 2)));
        // deterministic in the config seed
        assert_eq!(gen(3), gen(3));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut opt = Nsga2::new(Nsga2Config {
                pop_size: 20,
                generations: 10,
                seed,
                ..Default::default()
            });
            opt.run(&mut OnesZeros { len: 8 }, |_| {})
                .iter()
                .map(|i| i.genome.clone())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn seeds_are_injected() {
        struct Seeded;
        impl Problem for Seeded {
            fn genome_len(&self) -> usize {
                6
            }
            fn alphabet(&self) -> usize {
                2
            }
            fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
                // strongly reward the seeded genome so it must survive
                let target = [1, 0, 1, 0, 1, 0];
                let d = g.iter().zip(&target).filter(|(a, b)| a != b).count();
                vec![d as f64]
            }
            fn seeds(&self) -> Vec<Vec<usize>> {
                vec![vec![1, 0, 1, 0, 1, 0]]
            }
        }
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 10,
            generations: 1,
            ..Default::default()
        });
        let front = opt.run(&mut Seeded, |_| {});
        assert!(front.iter().any(|i| i.objectives[0] == 0.0));
    }

    /// The optimizer submits whole generations to evaluate_batch, and an
    /// overriding problem produces the same run as the serial default.
    #[test]
    fn batch_evaluation_receives_whole_generations() {
        struct Batched {
            inner: OnesZeros,
            batch_sizes: Vec<usize>,
        }
        impl Problem for Batched {
            fn genome_len(&self) -> usize {
                self.inner.genome_len()
            }
            fn alphabet(&self) -> usize {
                self.inner.alphabet()
            }
            fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
                self.inner.evaluate(g)
            }
            fn evaluate_batch(&mut self, genomes: &[Vec<usize>]) -> Vec<Vec<f64>> {
                self.batch_sizes.push(genomes.len());
                genomes.iter().map(|g| self.inner.evaluate(g)).collect()
            }
        }
        let cfg = Nsga2Config { pop_size: 10, generations: 3, ..Default::default() };
        let mut batched = Batched { inner: OnesZeros { len: 8 }, batch_sizes: vec![] };
        let front_batched = Nsga2::new(cfg.clone()).run(&mut batched, |_| {});
        // initial population + one batch per generation, all full-size
        assert_eq!(batched.batch_sizes, vec![10; 4]);
        // identical trajectory to the serial default implementation
        let front_serial = Nsga2::new(cfg).run(&mut OnesZeros { len: 8 }, |_| {});
        let key = crate::bench::suite::front_fingerprint;
        assert_eq!(key(&front_batched), key(&front_serial));
    }

    #[test]
    fn callback_reports_progress() {
        let mut gens = Vec::new();
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size: 10,
            generations: 5,
            ..Default::default()
        });
        opt.run(&mut OnesZeros { len: 8 }, |s| gens.push(s.generation));
        assert_eq!(gens, vec![0, 1, 2, 3, 4]);
        assert_eq!(opt.evaluations(), 10 + 5 * 10);
    }
}
