//! Hypervolume indicator (S-metric): the volume of objective space
//! dominated by a front, bounded by a reference point. The standard
//! front-quality measure for comparing multi-objective optimizers
//! (used by bench_ablation A4 to compare NSGA-II against random search
//! beyond single-point scalarization).
//!
//! Implementation: WFG-style recursive slicing — exact, fine for the 2-3
//! objective fronts and <100-point sets this project produces.

/// Hypervolume of `front` (minimization) w.r.t. `reference`.
///
/// Points not strictly dominating the reference contribute nothing.
/// Complexity is fine for small fronts (exponential in objectives,
/// ~quadratic in points for m <= 3).
pub fn hypervolume(front: &[Vec<f64>], reference: &[f64]) -> f64 {
    let m = reference.len();
    // keep only points that dominate the reference box
    let pts: Vec<Vec<f64>> = front
        .iter()
        .filter(|p| p.len() == m && p.iter().zip(reference).all(|(a, r)| a < r))
        .cloned()
        .collect();
    hv_rec(&pts, reference)
}

fn hv_rec(pts: &[Vec<f64>], reference: &[f64]) -> f64 {
    let m = reference.len();
    if pts.is_empty() {
        return 0.0;
    }
    if m == 1 {
        let best = pts.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min);
        return (reference[0] - best).max(0.0);
    }
    // sort by the last objective ascending; sweep slices
    let mut sorted = pts.to_vec();
    sorted.sort_by(|a, b| a[m - 1].partial_cmp(&b[m - 1]).unwrap());
    let mut volume = 0.0;
    for i in 0..sorted.len() {
        let z_lo = sorted[i][m - 1];
        let z_hi = if i + 1 < sorted.len() { sorted[i + 1][m - 1] } else { reference[m - 1] };
        let depth = (z_hi - z_lo).max(0.0);
        if depth <= 0.0 {
            continue;
        }
        // points active in this slice: those with last objective <= z_lo
        let slice: Vec<Vec<f64>> = sorted[..=i]
            .iter()
            .map(|p| p[..m - 1].to_vec())
            .collect();
        let slice_refs = &reference[..m - 1];
        volume += depth * hv_rec(&nondominated(&slice), slice_refs);
    }
    volume
}

/// Filter to the non-dominated subset (minimization).
fn nondominated(pts: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut keep = Vec::new();
    'outer: for (i, p) in pts.iter().enumerate() {
        for (j, q) in pts.iter().enumerate() {
            if i != j && super::dominates(q, p) {
                continue 'outer;
            }
        }
        keep.push(p.clone());
    }
    keep
}

/// Spread of a front: the diagonal of its objective-space bounding box
/// (`sqrt(Σ_k (max_k − min_k)²)`). 0 for empty or single-point fronts.
/// A cheap, deterministic measure of how much of the trade-off surface
/// the front covers — convergence analytics pair it with hypervolume to
/// distinguish "converged to one corner" from "covers the front".
pub fn front_spread(front: &[Vec<f64>]) -> f64 {
    if front.len() < 2 {
        return 0.0;
    }
    let m = front[0].len();
    (0..m)
        .map(|k| {
            let lo = front.iter().map(|p| p[k]).fold(f64::INFINITY, f64::min);
            let hi = front.iter().map(|p| p[k]).fold(f64::NEG_INFINITY, f64::max);
            let ext = hi - lo;
            ext * ext
        })
        .sum::<f64>()
        .sqrt()
}

/// Normalized hypervolume of a set of Individuals against a reference
/// derived from the worst observed value per objective (times a margin).
pub fn front_hypervolume(front: &[crate::nsga2::Individual], margin: f64) -> f64 {
    if front.is_empty() {
        return 0.0;
    }
    let m = front[0].objectives.len();
    let reference: Vec<f64> = (0..m)
        .map(|k| {
            front
                .iter()
                .map(|i| i.objectives[k])
                .fold(f64::NEG_INFINITY, f64::max)
                * margin
                + 1e-9
        })
        .collect();
    let pts: Vec<Vec<f64>> = front.iter().map(|i| i.objectives.clone()).collect();
    hypervolume(&pts, &reference)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_box() {
        // point (1,1), ref (3,4): dominated box is 2x3 = 6
        assert!((hypervolume(&[vec![1.0, 1.0]], &[3.0, 4.0]) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn two_disjoint_staircase() {
        // (1,3) and (3,1) with ref (4,4): union = 3*1 + 1*3 - overlap 1*1 = 5
        let hv = hypervolume(&[vec![1.0, 3.0], vec![3.0, 1.0]], &[4.0, 4.0]);
        assert!((hv - 5.0).abs() < 1e-9, "{hv}");
    }

    #[test]
    fn dominated_point_adds_nothing() {
        let base = hypervolume(&[vec![1.0, 1.0]], &[4.0, 4.0]);
        let with_dup = hypervolume(&[vec![1.0, 1.0], vec![2.0, 2.0]], &[4.0, 4.0]);
        assert!((base - with_dup).abs() < 1e-9);
    }

    #[test]
    fn point_outside_reference_ignored() {
        assert_eq!(hypervolume(&[vec![5.0, 5.0]], &[4.0, 4.0]), 0.0);
    }

    #[test]
    fn three_objectives_unit_cube() {
        // point at origin with ref (1,1,1): volume 1
        let hv = hypervolume(&[vec![0.0, 0.0, 0.0]], &[1.0, 1.0, 1.0]);
        assert!((hv - 1.0).abs() < 1e-9);
        // two points carving an L-shape
        let hv2 = hypervolume(
            &[vec![0.0, 0.5, 0.0], vec![0.5, 0.0, 0.0]],
            &[1.0, 1.0, 1.0],
        );
        // union = 0.5 + 0.5 - 0.25 = 0.75
        assert!((hv2 - 0.75).abs() < 1e-9, "{hv2}");
    }

    #[test]
    fn monotone_in_front_quality() {
        // a strictly better front has strictly larger hypervolume
        let worse = hypervolume(&[vec![2.0, 2.0]], &[4.0, 4.0]);
        let better = hypervolume(&[vec![1.0, 1.0]], &[4.0, 4.0]);
        assert!(better > worse);
    }

    #[test]
    fn empty_front_zero() {
        assert_eq!(hypervolume(&[], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn spread_is_bounding_box_diagonal() {
        assert_eq!(front_spread(&[]), 0.0);
        assert_eq!(front_spread(&[vec![1.0, 2.0]]), 0.0);
        let s = front_spread(&[vec![0.0, 0.0], vec![3.0, 4.0], vec![1.0, 1.0]]);
        assert!((s - 5.0).abs() < 1e-12, "{s}");
    }
}
