//! The batched, parallel, deduplicating ΔAcc evaluation engine.
//!
//! NSGA-II hands each generation's offspring to the partition evaluator as
//! one batch ([`crate::nsga2::Problem::evaluate_batch`]). This module owns
//! the ΔAcc half of that pipeline:
//!
//! 1. map every request to its quantized rate-vector cache key,
//! 2. answer known keys from the sharded [`DaccCache`] and deduplicate
//!    repeats *within* the batch (equivalent mappings dominate NSGA-II
//!    traffic; a batch-dedup repeat is a cache hit that merely arrived
//!    early),
//! 3. fan the residual unique misses out across a scoped `std::thread`
//!    pool, each worker driving its own copy of the ΔAcc backend handle,
//! 4. write results back in submission order.
//!
//! Determinism: every backend is a pure function of the rate vectors (the
//! exact mode keys its fault draws by `(key_seed, batch_index)`, never by
//! wall clock or thread id), so the batch results are bitwise identical
//! for any thread count, including the serial path. No PRNG state ever
//! crosses a thread boundary.

use std::time::Duration;

use anyhow::Result;

use super::cache::DaccCache;
use super::sensitivity::SensitivityTable;
use crate::faults::RateVectors;
use crate::runtime::{AccuracyEvaluator, CompiledModel};

/// Engine knobs carried by the partition evaluator.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker threads for miss evaluation. 1 = serial (the default for
    /// library users; the experiment harness resolves `eval_threads = 0`
    /// to [`EngineConfig::auto`]).
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threads: 1 }
    }
}

impl EngineConfig {
    pub fn with_threads(threads: usize) -> EngineConfig {
        EngineConfig { threads: threads.max(1) }
    }

    /// One worker per available core, capped: exact-mode misses are
    /// millisecond-scale PJRT calls, so a handful of workers saturates.
    pub fn auto() -> EngineConfig {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        EngineConfig { threads: cores.min(8) }
    }
}

/// A per-worker handle on the ΔAcc backend. `Copy`: each scoped worker
/// takes its own copy, so no backend state is shared mutably — the exact
/// mode's compiled model and prepared eval batches are read-only.
#[derive(Clone, Copy)]
pub(crate) enum DaccBackend<'a> {
    /// The paper's method: run the compiled fault-injected forward.
    Exact {
        model: &'a CompiledModel,
        eval: &'a AccuracyEvaluator,
        key_seed: u32,
        n_batches: usize,
    },
    /// Compose the measured layer-sensitivity table (cheap; online phase).
    Surrogate { table: &'a SensitivityTable },
    /// Bench/test stand-in for `Exact`: surrogate-valued accuracy plus a
    /// simulated per-evaluation cost emulating the blocking PJRT call.
    Synthetic { table: &'a SensitivityTable, cost: Duration },
    /// ΔAcc not evaluated (fault-unaware baselines): clean accuracy.
    Clean { acc: f64 },
}

impl DaccBackend<'_> {
    /// Evaluate faulty accuracy for one rate vector. Pure in `rates`.
    pub(crate) fn eval(&self, rates: &RateVectors) -> Result<f64> {
        match self {
            DaccBackend::Exact { model, eval, key_seed, n_batches } => {
                eval.accuracy(model, rates, *key_seed, *n_batches)
            }
            DaccBackend::Surrogate { table } => Ok(table.faulty_accuracy(rates)),
            DaccBackend::Synthetic { table, cost } => {
                if !cost.is_zero() {
                    std::thread::sleep(*cost);
                }
                Ok(table.faulty_accuracy(rates))
            }
            DaccBackend::Clean { acc } => Ok(*acc),
        }
    }

    /// Smallest miss count worth a thread fan-out. Surrogate lookups are
    /// sub-microsecond — spawning threads for them would *cost* latency
    /// (the ≤5%-regression budget of the surrogate path), so only very
    /// large surrogate batches parallelize.
    fn min_parallel_misses(&self) -> usize {
        match self {
            DaccBackend::Exact { .. } | DaccBackend::Synthetic { .. } => 2,
            DaccBackend::Surrogate { .. } => 256,
            DaccBackend::Clean { .. } => usize::MAX,
        }
    }
}

/// A second-level, context-keyed view of a [`DaccCache`] shared across
/// campaign cells of the same model. `ctx` folds every rate-independent
/// backend parameter (exact seed/batch budget, sensitivity-table
/// fingerprint, clean-accuracy floor) so cells only exchange values they
/// would have computed identically.
#[derive(Clone, Copy)]
pub(crate) struct SharedCache<'a> {
    pub cache: &'a DaccCache,
    pub ctx: u64,
}

/// Result of one batched ΔAcc evaluation.
pub(crate) struct BatchOutcome {
    /// Faulty accuracy per request, in submission order.
    pub accs: Vec<f64>,
    /// Unique keys this evaluator's private cache did not hold. This is
    /// the *deterministic* miss count — it does not depend on what other
    /// cells have already published to a shared cache.
    pub unique_misses: usize,
    /// Unique keys actually sent to the backend (`unique_misses` minus
    /// the shared-cache answers). Schedule-dependent under sharing.
    pub backend_evals: usize,
    /// Unique misses answered by the shared cross-cell cache.
    pub shared_hits: usize,
}

/// Evaluate faulty accuracy for a batch of rate vectors: cache lookup,
/// in-batch dedup, shared-cache (L2) probe, parallel miss fan-out,
/// order-preserving write-back.
///
/// Statistics semantics (see ISSUE satellite): a request answered by the
/// private cache is a hit; the *first* request for an uncached key is a
/// miss; any further request for that same key inside the batch is a
/// dedup hit and counts as a hit. The optional `shared` cache answers
/// private misses without a backend call, but never changes the private
/// hit/miss attribution — per-cell stats stay deterministic at any
/// campaign schedule; only `backend_evals`/`shared_hits` (and the shared
/// cache's own lifetime counters) reflect cross-cell reuse.
pub(crate) fn faulty_accuracy_batch(
    backend: DaccBackend<'_>,
    cache: &DaccCache,
    shared: Option<SharedCache<'_>>,
    cfg: EngineConfig,
    rates: &[RateVectors],
) -> Result<BatchOutcome> {
    let n = rates.len();
    let mut accs: Vec<Option<f64>> = vec![None; n];
    // request index -> slot in the miss list (for requests not answered
    // directly from the cache)
    let mut assign: Vec<usize> = Vec::new();
    let mut assign_idx: Vec<usize> = Vec::new();
    // first-occurrence bookkeeping for uncached keys
    let mut first_seen: std::collections::HashMap<Vec<u16>, usize> =
        std::collections::HashMap::new();
    let mut miss_keys: Vec<Vec<u16>> = Vec::new();
    let mut miss_rates: Vec<&RateVectors> = Vec::new();
    let mut cache_hits = 0usize;
    let mut dedup_hits = 0usize;

    for (i, r) in rates.iter().enumerate() {
        let key = r.cache_key();
        if let Some(v) = cache.probe(&key) {
            accs[i] = Some(v);
            cache_hits += 1;
        } else if let Some(&slot) = first_seen.get(&key) {
            assign_idx.push(i);
            assign.push(slot);
            dedup_hits += 1;
        } else {
            let slot = miss_keys.len();
            first_seen.insert(key.clone(), slot);
            miss_keys.push(key);
            miss_rates.push(r);
            assign_idx.push(i);
            assign.push(slot);
        }
    }
    // one atomic attribution for the whole batch: concurrent stats
    // readers (telemetry snapshots) see this batch all-or-nothing
    cache.record_batch(cache_hits + dedup_hits, miss_keys.len());

    // second-level probe: private misses another cell already evaluated
    // (same context) need no backend call
    let m = miss_rates.len();
    let mut miss_vals = vec![0.0f64; m];
    let mut residual: Vec<usize> = Vec::with_capacity(m);
    let mut shared_hits = 0usize;
    if let Some(sh) = shared {
        for (slot, key) in miss_keys.iter().enumerate() {
            match sh.cache.probe_ctx(sh.ctx, key) {
                Some(v) => {
                    miss_vals[slot] = v;
                    shared_hits += 1;
                }
                None => residual.push(slot),
            }
        }
    } else {
        residual.extend(0..m);
    }

    // evaluate the residual misses — parallel when it pays for itself
    let e = residual.len();
    let res_rates: Vec<&RateVectors> = residual.iter().map(|&slot| miss_rates[slot]).collect();
    let mut res_vals = vec![0.0f64; e];
    let workers = cfg.threads.min(e).max(1);
    if workers <= 1 || e < backend.min_parallel_misses() {
        for (v, &r) in res_vals.iter_mut().zip(&res_rates) {
            *v = backend.eval(r)?;
        }
    } else {
        let chunk = (e + workers - 1) / workers;
        let mut worker_results: Vec<Result<()>> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for (vals, rs) in res_vals.chunks_mut(chunk).zip(res_rates.chunks(chunk)) {
                handles.push(s.spawn(move || -> Result<()> {
                    for (v, &r) in vals.iter_mut().zip(rs) {
                        *v = backend.eval(r)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                worker_results.push(h.join().expect("ΔAcc eval worker panicked"));
            }
        });
        for r in worker_results {
            r?;
        }
    }
    for (&slot, &v) in residual.iter().zip(&res_vals) {
        miss_vals[slot] = v;
    }

    // The shared cache's own counters are the per-model lifetime truth:
    // exactly one attribution per private miss, so aggregating it never
    // double-counts lookups the way summing per-cell lifetimes would.
    if let Some(sh) = shared {
        sh.cache.record_batch(shared_hits, e);
    }

    // publish to the caches, then resolve the deferred requests in
    // submission order. The private cache learns every miss value; the
    // shared cache learns only what the backend just computed (its L2
    // hits are already present).
    let mut evaluated = residual.iter().copied().peekable();
    for (slot, (key, &v)) in miss_keys.into_iter().zip(&miss_vals).enumerate() {
        if let Some(sh) = shared {
            if evaluated.peek() == Some(&slot) {
                evaluated.next();
                sh.cache.put_key_ctx(sh.ctx, key.clone(), v);
            }
        }
        cache.put_key(key, v);
    }
    for (&i, &slot) in assign_idx.iter().zip(&assign) {
        accs[i] = Some(miss_vals[slot]);
    }

    Ok(BatchOutcome {
        accs: accs.into_iter().map(|v| v.expect("unresolved batch slot")).collect(),
        unique_misses: m,
        backend_evals: e,
        shared_hits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::cache::CacheStats;

    fn table() -> SensitivityTable {
        SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            w_drop: vec![vec![0.05, 0.1, 0.2], vec![0.01, 0.02, 0.04]],
            a_drop: vec![vec![0.0; 3], vec![0.0; 3]],
            clean_acc: 0.9,
        }
    }

    fn rv(a: f32, b: f32) -> RateVectors {
        RateVectors { w_rates: vec![a, b], a_rates: vec![0.0, 0.0] }
    }

    #[test]
    fn dedup_counts_and_order() {
        let t = table();
        let cache = DaccCache::new();
        let reqs = vec![rv(0.2, 0.0), rv(0.2, 0.0), rv(0.4, 0.0), rv(0.2, 0.0)];
        let out = faulty_accuracy_batch(
            DaccBackend::Surrogate { table: &t },
            &cache,
            None,
            EngineConfig::default(),
            &reqs,
        )
        .unwrap();
        assert_eq!(out.unique_misses, 2);
        // duplicates resolve to the representative's value
        assert_eq!(out.accs[0], out.accs[1]);
        assert_eq!(out.accs[0], out.accs[3]);
        assert_ne!(out.accs[0], out.accs[2]);
        // 2 unique misses; the 2 in-batch repeats count as hits
        assert_eq!((cache.hits(), cache.misses()), (2, 2));

        // a second batch over the same keys is all cache hits
        let out2 = faulty_accuracy_batch(
            DaccBackend::Surrogate { table: &t },
            &cache,
            None,
            EngineConfig::default(),
            &reqs,
        )
        .unwrap();
        assert_eq!(out2.unique_misses, 0);
        assert_eq!(out2.accs, out.accs);
        assert_eq!((cache.hits(), cache.misses()), (6, 2));
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let t = table();
        let reqs: Vec<RateVectors> =
            (0..40).map(|i| rv((i % 17) as f32 / 20.0, (i % 5) as f32 / 10.0)).collect();
        let serial = faulty_accuracy_batch(
            DaccBackend::Synthetic { table: &t, cost: Duration::ZERO },
            &DaccCache::new(),
            None,
            EngineConfig::with_threads(1),
            &reqs,
        )
        .unwrap();
        let parallel = faulty_accuracy_batch(
            DaccBackend::Synthetic { table: &t, cost: Duration::ZERO },
            &DaccCache::new(),
            None,
            EngineConfig::with_threads(4),
            &reqs,
        )
        .unwrap();
        assert_eq!(serial.accs, parallel.accs);
        assert_eq!(serial.unique_misses, parallel.unique_misses);
    }

    #[test]
    fn shared_cache_answers_other_cells_misses() {
        let t = table();
        let shared = DaccCache::new();
        let reqs = vec![rv(0.2, 0.0), rv(0.4, 0.0), rv(0.2, 0.0)];

        // Cell A: cold private cache, cold shared cache — every unique
        // key goes to the backend and is published to both levels.
        let cell_a = DaccCache::new();
        let a = faulty_accuracy_batch(
            DaccBackend::Surrogate { table: &t },
            &cell_a,
            Some(SharedCache { cache: &shared, ctx: 42 }),
            EngineConfig::default(),
            &reqs,
        )
        .unwrap();
        assert_eq!((a.unique_misses, a.backend_evals, a.shared_hits), (2, 2, 0));
        assert_eq!(shared.len(), 2);

        // Cell B: cold private cache, warm shared cache — same private
        // miss attribution (deterministic), zero backend calls.
        let cell_b = DaccCache::new();
        let b = faulty_accuracy_batch(
            DaccBackend::Surrogate { table: &t },
            &cell_b,
            Some(SharedCache { cache: &shared, ctx: 42 }),
            EngineConfig::default(),
            &reqs,
        )
        .unwrap();
        assert_eq!(b.accs, a.accs);
        assert_eq!((b.unique_misses, b.backend_evals, b.shared_hits), (2, 0, 2));
        // private per-cell stats are identical for A and B: 1 dedup hit,
        // 2 misses each, regardless of what the shared cache answered
        assert_eq!((cell_a.hits(), cell_a.misses()), (1, 2));
        assert_eq!((cell_b.hits(), cell_b.misses()), (1, 2));
        // the shared cache's own counters see each private miss once:
        // A's 2 evaluations then B's 2 L2 hits
        assert_eq!(shared.lifetime_stats(), CacheStats { hits: 2, misses: 2 });

        // a different context shares nothing
        let cell_c = DaccCache::new();
        let c = faulty_accuracy_batch(
            DaccBackend::Surrogate { table: &t },
            &cell_c,
            Some(SharedCache { cache: &shared, ctx: 7 }),
            EngineConfig::default(),
            &reqs,
        )
        .unwrap();
        assert_eq!((c.unique_misses, c.backend_evals, c.shared_hits), (2, 2, 0));
    }

    #[test]
    fn clean_backend_returns_clean_acc() {
        let cache = DaccCache::new();
        let out = faulty_accuracy_batch(
            DaccBackend::Clean { acc: 0.77 },
            &cache,
            None,
            EngineConfig::default(),
            &[rv(0.1, 0.2), rv(0.3, 0.4)],
        )
        .unwrap();
        assert_eq!(out.accs, vec![0.77, 0.77]);
        assert_eq!(out.unique_misses, 2);
    }
}
