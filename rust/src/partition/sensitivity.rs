//! Layer-wise fault-sensitivity surrogate (DESIGN.md §4.1, ablation A1).
//!
//! The paper's "layer-wise fault sweeping" (§V-C) measured once up front:
//! for each unit l and each rate r on a grid, run the compiled model with
//! faults in unit l only and record the accuracy drop. A candidate
//! mapping's ΔAcc is then *estimated* by composing per-unit survival
//! fractions:
//!
//!   ΔAcc(P) ≈ A_clean · (1 − Π_l (1 − d_l(r_l)))
//!   with d_l(r) = ΔAcc_l(r) / A_clean, linearly interpolated on the grid.
//!
//! This is the cheap mode the online phase can afford; the exact mode
//! (paper's Algorithm 1) runs the real fault-injected forward per
//! candidate. bench_ablation quantifies the fidelity gap.

use anyhow::Result;

use crate::faults::RateVectors;
use crate::runtime::{AccuracyEvaluator, CompiledModel};

/// Per-unit, per-rate measured accuracy drops.
#[derive(Clone, Debug)]
pub struct SensitivityTable {
    pub rate_grid: Vec<f32>,
    /// [unit][grid] accuracy drop when only that unit's WEIGHTS are faulted.
    pub w_drop: Vec<Vec<f64>>,
    /// [unit][grid] accuracy drop when only that unit's ACTIVATIONS are faulted.
    pub a_drop: Vec<Vec<f64>>,
    pub clean_acc: f64,
}

impl SensitivityTable {
    /// Measure the table with the real compiled model (one-time cost:
    /// 2 · L · |grid| fault-injected accuracy evaluations).
    pub fn measure(
        model: &CompiledModel,
        eval: &AccuracyEvaluator,
        rate_grid: &[f32],
        n_batches: usize,
        key_seed: u32,
    ) -> Result<SensitivityTable> {
        let l = model.num_units();
        let clean_acc = eval.clean_accuracy(model, n_batches)?;
        let mut w_drop = vec![vec![0.0; rate_grid.len()]; l];
        let mut a_drop = vec![vec![0.0; rate_grid.len()]; l];
        for unit in 0..l {
            for (gi, &r) in rate_grid.iter().enumerate() {
                let mut rv = RateVectors::zeros(l);
                rv.w_rates[unit] = r;
                let acc = eval.accuracy(model, &rv, key_seed, n_batches)?;
                w_drop[unit][gi] = (clean_acc - acc).max(0.0);

                let mut rv = RateVectors::zeros(l);
                rv.a_rates[unit] = r;
                let acc = eval.accuracy(model, &rv, key_seed, n_batches)?;
                a_drop[unit][gi] = (clean_acc - acc).max(0.0);
            }
        }
        Ok(SensitivityTable {
            rate_grid: rate_grid.to_vec(),
            w_drop,
            a_drop,
            clean_acc,
        })
    }

    /// Linear interpolation of a drop curve at rate r (clamped to grid).
    fn interp(grid: &[f32], drops: &[f64], r: f32) -> f64 {
        if r <= 0.0 {
            return 0.0;
        }
        if r <= grid[0] {
            // linear from (0, 0) to the first grid point
            return drops[0] * (r / grid[0]) as f64;
        }
        for w in grid.windows(2).zip(drops.windows(2)) {
            let (g, d) = w;
            if r <= g[1] {
                let t = ((r - g[0]) / (g[1] - g[0])) as f64;
                return d[0] * (1.0 - t) + d[1] * t;
            }
        }
        *drops.last().unwrap()
    }

    /// Estimated faulty accuracy A_clean − ΔAcĉ, clamped at 0 — the
    /// surrogate's answer to the exact mode's `AccuracyEvaluator::accuracy`.
    /// Pure in `rates` and allocation-free, so the batched evaluation
    /// engine calls it concurrently from its worker threads (the table is
    /// immutable shared data).
    pub fn faulty_accuracy(&self, rates: &RateVectors) -> f64 {
        (self.clean_acc - self.estimate_dacc(rates)).max(0.0)
    }

    /// Estimated ΔAcc for full per-unit rate vectors.
    pub fn estimate_dacc(&self, rates: &RateVectors) -> f64 {
        if self.clean_acc <= 0.0 {
            return 0.0;
        }
        let mut survival = 1.0f64;
        for unit in 0..rates.w_rates.len() {
            let dw = Self::interp(&self.rate_grid, &self.w_drop[unit], rates.w_rates[unit]);
            let da = Self::interp(&self.rate_grid, &self.a_drop[unit], rates.a_rates[unit]);
            survival *= (1.0 - (dw / self.clean_acc).clamp(0.0, 1.0))
                * (1.0 - (da / self.clean_acc).clamp(0.0, 1.0));
        }
        self.clean_acc * (1.0 - survival)
    }

    /// Most weight-fault-sensitive unit at the top grid rate (diagnostics).
    pub fn most_sensitive_unit(&self) -> usize {
        let gi = self.rate_grid.len() - 1;
        (0..self.w_drop.len())
            .max_by(|&a, &b| {
                (self.w_drop[a][gi] + self.a_drop[a][gi])
                    .partial_cmp(&(self.w_drop[b][gi] + self.a_drop[b][gi]))
                    .unwrap()
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SensitivityTable {
        SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            w_drop: vec![vec![0.05, 0.10, 0.20], vec![0.0, 0.01, 0.02]],
            a_drop: vec![vec![0.10, 0.20, 0.40], vec![0.01, 0.02, 0.04]],
            clean_acc: 0.9,
        }
    }

    #[test]
    fn zero_rates_zero_drop() {
        let t = table();
        assert_eq!(t.estimate_dacc(&RateVectors::zeros(2)), 0.0);
    }

    #[test]
    fn interpolates_between_grid_points() {
        let t = table();
        let rv = RateVectors { w_rates: vec![0.15, 0.0], a_rates: vec![0.0, 0.0] };
        let est = t.estimate_dacc(&rv);
        assert!(est > 0.05 && est < 0.10, "est={est}");
    }

    #[test]
    fn extrapolation_clamps_to_last() {
        let t = table();
        let rv = RateVectors { w_rates: vec![0.9, 0.0], a_rates: vec![0.0, 0.0] };
        assert!((t.estimate_dacc(&rv) - 0.20).abs() < 1e-9);
    }

    #[test]
    fn composition_le_clean_and_monotone() {
        let t = table();
        let one = RateVectors { w_rates: vec![0.4, 0.0], a_rates: vec![0.0, 0.0] };
        let both = RateVectors { w_rates: vec![0.4, 0.4], a_rates: vec![0.4, 0.4] };
        let d1 = t.estimate_dacc(&one);
        let d2 = t.estimate_dacc(&both);
        assert!(d2 >= d1);
        assert!(d2 <= t.clean_acc + 1e-9);
    }

    #[test]
    fn most_sensitive_unit_is_unit0() {
        assert_eq!(table().most_sensitive_unit(), 0);
    }

    #[test]
    fn below_first_grid_point_scales_linearly() {
        let t = table();
        let rv = RateVectors { w_rates: vec![0.05, 0.0], a_rates: vec![0.0, 0.0] };
        let est = t.estimate_dacc(&rv);
        assert!((est - 0.025).abs() < 1e-6, "est={est}");
    }
}
