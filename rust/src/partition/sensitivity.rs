//! Layer-wise fault-sensitivity surrogate (DESIGN.md §4.1, ablation A1).
//!
//! The paper's "layer-wise fault sweeping" (§V-C) measured once up front:
//! for each unit l and each rate r on a grid, run the compiled model with
//! faults in unit l only and record the accuracy drop. A candidate
//! mapping's ΔAcc is then *estimated* by composing per-unit survival
//! fractions:
//!
//!   ΔAcc(P) ≈ A_clean · (1 − Π_l (1 − d_l(r_l)))
//!   with d_l(r) = ΔAcc_l(r) / A_clean, linearly interpolated on the grid.
//!
//! This is the cheap mode the online phase can afford; the exact mode
//! (paper's Algorithm 1) runs the real fault-injected forward per
//! candidate. bench_ablation quantifies the fidelity gap.

use std::time::Instant;

use anyhow::Result;

use crate::faults::RateVectors;
use crate::obs::Telemetry;
use crate::runtime::{AccuracyEvaluator, CompiledModel};
use crate::util::json::{num, s as jstr};

/// One measurement cell of the layer sweep: which unit, which grid
/// point, and whether its weights or its activations are faulted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SweepCell {
    pub unit: usize,
    pub grid_index: usize,
    /// `true`: fault this unit's weights; `false`: its activations.
    pub weights: bool,
}

/// Deterministic cell order: unit-major, then grid point, weights
/// before activations — exactly the order of the historical serial
/// double loop, so parallel results land in identical slots.
pub(crate) fn sweep_cells(num_units: usize, grid_len: usize) -> Vec<SweepCell> {
    let mut cells = Vec::with_capacity(num_units * grid_len * 2);
    for unit in 0..num_units {
        for grid_index in 0..grid_len {
            for weights in [true, false] {
                cells.push(SweepCell { unit, grid_index, weights });
            }
        }
    }
    cells
}

/// Evaluate every cell with up to `threads` scoped workers, writing
/// `(value, wall_ms)` into pre-sized cell-order slots — the same
/// chunked fan-out as the batch engine, so the value vector is bitwise
/// identical at any thread count as long as `f` is pure per cell.
pub(crate) fn measure_cells<F>(
    cells: &[SweepCell],
    threads: usize,
    f: F,
) -> Result<Vec<(f64, f64)>>
where
    F: Fn(SweepCell) -> Result<f64> + Sync,
{
    let m = cells.len();
    let mut out = vec![(0.0f64, 0.0f64); m];
    let workers = threads.min(m).max(1);
    if workers <= 1 {
        for (slot, &cell) in out.iter_mut().zip(cells) {
            let t0 = Instant::now();
            *slot = (f(cell)?, t0.elapsed().as_secs_f64() * 1e3);
        }
        return Ok(out);
    }
    let chunk = (m + workers - 1) / workers;
    let f = &f;
    let mut worker_results: Vec<Result<()>> = Vec::with_capacity(workers);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for (slots, cs) in out.chunks_mut(chunk).zip(cells.chunks(chunk)) {
            handles.push(s.spawn(move || -> Result<()> {
                for (slot, &cell) in slots.iter_mut().zip(cs) {
                    let t0 = Instant::now();
                    *slot = (f(cell)?, t0.elapsed().as_secs_f64() * 1e3);
                }
                Ok(())
            }));
        }
        for h in handles {
            worker_results.push(h.join().expect("sensitivity sweep worker panicked"));
        }
    });
    for r in worker_results {
        r?;
    }
    Ok(out)
}

/// Per-unit, per-rate measured accuracy drops.
#[derive(Clone, Debug)]
pub struct SensitivityTable {
    pub rate_grid: Vec<f32>,
    /// [unit][grid] accuracy drop when only that unit's WEIGHTS are faulted.
    pub w_drop: Vec<Vec<f64>>,
    /// [unit][grid] accuracy drop when only that unit's ACTIVATIONS are faulted.
    pub a_drop: Vec<Vec<f64>>,
    pub clean_acc: f64,
}

impl SensitivityTable {
    /// Measure the table with the real compiled model (one-time cost:
    /// 2 · L · |grid| fault-injected accuracy evaluations), serially.
    pub fn measure(
        model: &CompiledModel,
        eval: &AccuracyEvaluator,
        rate_grid: &[f32],
        n_batches: usize,
        key_seed: u32,
    ) -> Result<SensitivityTable> {
        Self::measure_with(model, eval, rate_grid, n_batches, key_seed, 1, &Telemetry::disabled())
    }

    /// [`measure`](SensitivityTable::measure) with the sweep's
    /// 2 · L · |grid| cells fanned out across `threads` scoped workers
    /// (each cell is an independent fault-injected accuracy run — the
    /// evaluator is pure in the rate vectors, so the table is bitwise
    /// identical at any thread count). Emits one span per (layer, rate)
    /// cell: wall time into the `span_sensitivity_cell_ms` histogram,
    /// and — from this coordinating thread, in cell order, never from
    /// workers — one trace event carrying the cell's logical coordinates.
    pub fn measure_with(
        model: &CompiledModel,
        eval: &AccuracyEvaluator,
        rate_grid: &[f32],
        n_batches: usize,
        key_seed: u32,
        threads: usize,
        telemetry: &Telemetry,
    ) -> Result<SensitivityTable> {
        let mut sweep_span = telemetry.span("sensitivity.measure");
        let l = model.num_units();
        sweep_span.note("units", num(l as f64));
        sweep_span.note("grid_points", num(rate_grid.len() as f64));
        let clean_acc = eval.clean_accuracy(model, n_batches)?;
        let cells = sweep_cells(l, rate_grid.len());
        let results = measure_cells(&cells, threads, |cell| {
            let mut rv = RateVectors::zeros(l);
            let r = rate_grid[cell.grid_index];
            if cell.weights {
                rv.w_rates[cell.unit] = r;
            } else {
                rv.a_rates[cell.unit] = r;
            }
            eval.accuracy(model, &rv, key_seed, n_batches)
        })?;
        let mut w_drop = vec![vec![0.0; rate_grid.len()]; l];
        let mut a_drop = vec![vec![0.0; rate_grid.len()]; l];
        for (cell, &(acc, ms)) in cells.iter().zip(&results) {
            let drop = (clean_acc - acc).max(0.0);
            if cell.weights {
                w_drop[cell.unit][cell.grid_index] = drop;
            } else {
                a_drop[cell.unit][cell.grid_index] = drop;
            }
            telemetry.observe_ms("span_sensitivity_cell_ms", ms);
            telemetry.trace_event(
                "span",
                Some("sensitivity.cell"),
                &[
                    ("unit", num(cell.unit as f64)),
                    ("grid_index", num(cell.grid_index as f64)),
                    ("fault", jstr(if cell.weights { "weights" } else { "activations" })),
                ],
            );
        }
        telemetry.counter_add("sensitivity_cells_total", cells.len() as u64);
        Ok(SensitivityTable { rate_grid: rate_grid.to_vec(), w_drop, a_drop, clean_acc })
    }

    /// Linear interpolation of a drop curve at rate r (clamped to grid).
    fn interp(grid: &[f32], drops: &[f64], r: f32) -> f64 {
        if r <= 0.0 {
            return 0.0;
        }
        if r <= grid[0] {
            // linear from (0, 0) to the first grid point
            return drops[0] * (r / grid[0]) as f64;
        }
        for w in grid.windows(2).zip(drops.windows(2)) {
            let (g, d) = w;
            if r <= g[1] {
                let t = ((r - g[0]) / (g[1] - g[0])) as f64;
                return d[0] * (1.0 - t) + d[1] * t;
            }
        }
        *drops.last().unwrap()
    }

    /// Estimated faulty accuracy A_clean − ΔAcĉ, clamped at 0 — the
    /// surrogate's answer to the exact mode's `AccuracyEvaluator::accuracy`.
    /// Pure in `rates` and allocation-free, so the batched evaluation
    /// engine calls it concurrently from its worker threads (the table is
    /// immutable shared data).
    pub fn faulty_accuracy(&self, rates: &RateVectors) -> f64 {
        (self.clean_acc - self.estimate_dacc(rates)).max(0.0)
    }

    /// Estimated ΔAcc for full per-unit rate vectors.
    pub fn estimate_dacc(&self, rates: &RateVectors) -> f64 {
        if self.clean_acc <= 0.0 {
            return 0.0;
        }
        let mut survival = 1.0f64;
        for unit in 0..rates.w_rates.len() {
            let dw = Self::interp(&self.rate_grid, &self.w_drop[unit], rates.w_rates[unit]);
            let da = Self::interp(&self.rate_grid, &self.a_drop[unit], rates.a_rates[unit]);
            survival *= (1.0 - (dw / self.clean_acc).clamp(0.0, 1.0))
                * (1.0 - (da / self.clean_acc).clamp(0.0, 1.0));
        }
        self.clean_acc * (1.0 - survival)
    }

    /// Most weight-fault-sensitive unit at the top grid rate (diagnostics).
    pub fn most_sensitive_unit(&self) -> usize {
        let gi = self.rate_grid.len() - 1;
        (0..self.w_drop.len())
            .max_by(|&a, &b| {
                (self.w_drop[a][gi] + self.a_drop[a][gi])
                    .partial_cmp(&(self.w_drop[b][gi] + self.a_drop[b][gi]))
                    .unwrap()
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SensitivityTable {
        SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            w_drop: vec![vec![0.05, 0.10, 0.20], vec![0.0, 0.01, 0.02]],
            a_drop: vec![vec![0.10, 0.20, 0.40], vec![0.01, 0.02, 0.04]],
            clean_acc: 0.9,
        }
    }

    #[test]
    fn zero_rates_zero_drop() {
        let t = table();
        assert_eq!(t.estimate_dacc(&RateVectors::zeros(2)), 0.0);
    }

    #[test]
    fn interpolates_between_grid_points() {
        let t = table();
        let rv = RateVectors { w_rates: vec![0.15, 0.0], a_rates: vec![0.0, 0.0] };
        let est = t.estimate_dacc(&rv);
        assert!(est > 0.05 && est < 0.10, "est={est}");
    }

    #[test]
    fn extrapolation_clamps_to_last() {
        let t = table();
        let rv = RateVectors { w_rates: vec![0.9, 0.0], a_rates: vec![0.0, 0.0] };
        assert!((t.estimate_dacc(&rv) - 0.20).abs() < 1e-9);
    }

    #[test]
    fn composition_le_clean_and_monotone() {
        let t = table();
        let one = RateVectors { w_rates: vec![0.4, 0.0], a_rates: vec![0.0, 0.0] };
        let both = RateVectors { w_rates: vec![0.4, 0.4], a_rates: vec![0.4, 0.4] };
        let d1 = t.estimate_dacc(&one);
        let d2 = t.estimate_dacc(&both);
        assert!(d2 >= d1);
        assert!(d2 <= t.clean_acc + 1e-9);
    }

    #[test]
    fn most_sensitive_unit_is_unit0() {
        assert_eq!(table().most_sensitive_unit(), 0);
    }

    #[test]
    fn sweep_cells_match_the_historical_serial_order() {
        let cells = sweep_cells(2, 2);
        assert_eq!(cells.len(), 8);
        assert_eq!(cells[0], SweepCell { unit: 0, grid_index: 0, weights: true });
        assert_eq!(cells[1], SweepCell { unit: 0, grid_index: 0, weights: false });
        assert_eq!(cells[2], SweepCell { unit: 0, grid_index: 1, weights: true });
        assert_eq!(cells[7], SweepCell { unit: 1, grid_index: 1, weights: false });
    }

    #[test]
    fn parallel_cell_sweep_matches_serial() {
        // pure per-cell function standing in for the fault-injected
        // accuracy run; parallel values must land in identical slots
        let cells = sweep_cells(5, 4);
        let f = |c: SweepCell| -> Result<f64> {
            Ok(c.unit as f64 * 100.0 + c.grid_index as f64 * 10.0 + c.weights as u8 as f64)
        };
        let serial: Vec<f64> =
            measure_cells(&cells, 1, f).unwrap().into_iter().map(|(v, _)| v).collect();
        for threads in [2, 4, 16] {
            let par: Vec<f64> =
                measure_cells(&cells, threads, f).unwrap().into_iter().map(|(v, _)| v).collect();
            assert_eq!(par, serial, "thread count {threads} permuted the sweep");
        }
    }

    #[test]
    fn cell_sweep_propagates_worker_errors() {
        let cells = sweep_cells(4, 4);
        let err = measure_cells(&cells, 4, |c: SweepCell| {
            if c.unit == 2 && c.grid_index == 3 {
                anyhow::bail!("injected failure")
            }
            Ok(0.0)
        })
        .unwrap_err();
        assert!(format!("{err}").contains("injected failure"));
    }

    #[test]
    fn below_first_grid_point_scales_linearly() {
        let t = table();
        let rv = RateVectors { w_rates: vec![0.05, 0.0], a_rates: vec![0.0, 0.0] };
        let est = t.estimate_dacc(&rv);
        assert!((est - 0.025).abs() < 1e-6, "est={est}");
    }
}
