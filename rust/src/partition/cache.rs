//! ΔAcc memoization (DESIGN.md §4.2, ablation A2).
//!
//! ΔAcc(P) depends on P only through the per-unit rate vectors, and the
//! bit-flip kernel quantizes rates to 1/256 granularity — so caching on
//! the quantized rate-vector key is *exact*, not approximate. NSGA-II
//! revisits equivalent mappings constantly (D^L is small at L ≈ 6–10,
//! D = 2), so hit rates above 90% are typical after the first generations.

use std::collections::HashMap;

use crate::faults::RateVectors;

/// Exact memo cache for fault-injected accuracy.
#[derive(Debug, Default)]
pub struct DaccCache {
    map: HashMap<Vec<u16>, f64>,
    hits: usize,
    misses: usize,
}

impl DaccCache {
    pub fn new() -> DaccCache {
        DaccCache::default()
    }

    pub fn get(&mut self, rates: &RateVectors) -> Option<f64> {
        match self.map.get(&rates.cache_key()) {
            Some(&v) => {
                self.hits += 1;
                Some(v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, rates: &RateVectors, acc: f64) {
        self.map.insert(rates.cache_key(), acc);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rv(w: f32, a: f32) -> RateVectors {
        RateVectors { w_rates: vec![w, w], a_rates: vec![a, a] }
    }

    #[test]
    fn hit_after_put() {
        let mut c = DaccCache::new();
        assert_eq!(c.get(&rv(0.2, 0.1)), None);
        c.put(&rv(0.2, 0.1), 0.85);
        assert_eq!(c.get(&rv(0.2, 0.1)), Some(0.85));
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sub_granularity_rates_collide_exactly() {
        let mut c = DaccCache::new();
        c.put(&rv(0.2, 0.1), 0.9);
        // 0.2001 quantizes to the same kernel threshold -> same accuracy
        assert_eq!(c.get(&rv(0.2001, 0.1)), Some(0.9));
    }

    #[test]
    fn distinct_rates_miss() {
        let mut c = DaccCache::new();
        c.put(&rv(0.2, 0.1), 0.9);
        assert_eq!(c.get(&rv(0.25, 0.1)), None);
    }

    #[test]
    fn clear_resets() {
        let mut c = DaccCache::new();
        c.put(&rv(0.2, 0.1), 0.9);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.hits(), 0);
    }
}
