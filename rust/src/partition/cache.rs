//! ΔAcc memoization (DESIGN.md §4.2, ablation A2) — sharded, lock-striped,
//! thread-safe.
//!
//! ΔAcc(P) depends on P only through the per-unit rate vectors, and the
//! bit-flip kernel quantizes rates to 1/256 granularity — so caching on
//! the quantized rate-vector key is *exact*, not approximate. NSGA-II
//! revisits equivalent mappings constantly (D^L is small at L ≈ 6–10,
//! D = 2), so hit rates above 90% are typical after the first generations.
//!
//! The store is striped across N mutex-guarded shards keyed by a hash of
//! the rate vector, and every operation takes `&self`: the batched
//! evaluation engine ([`crate::partition::engine`]) probes and fills the
//! cache from its scoped worker threads without serializing on one lock,
//! and the evaluator no longer needs `&mut` for cache access.
//!
//! Statistics come in two scopes. *Epoch* counters describe the current
//! fault environment and reset on [`DaccCache::clear`] (the online phase
//! clears on every environment change because stale ΔAcc values are
//! wrong under new rates). *Lifetime* counters accumulate across epochs
//! so long-running serving loops can report cumulative cache efficiency
//! instead of silently zeroing history — see [`CacheRollover`].
//!
//! When one cache is shared across campaign cells (PR 5), entries carry
//! an extra **context** dimension: ΔAcc depends on the backend's
//! non-rate configuration too (exact-eval seed and batch budget, the
//! identity of a sensitivity table, the clean-accuracy floor), so cells
//! that agree on rates but differ in backend context must not exchange
//! values. Callers fold everything rate-independent into a `u64` context
//! tag ([`probe_ctx`](DaccCache::probe_ctx) /
//! [`put_key_ctx`](DaccCache::put_key_ctx)); the ctx-less methods keep
//! their old meaning as context 0. Stat scopes split along the same
//! line: a per-cell private cache owns the deterministic *epoch*
//! numbers, while the shared per-model cache accumulates *lifetime*
//! totals exactly once per lookup — summing per-cell lifetimes would
//! double-count the shared history (see
//! `shared_cache_lifetime_counts_once` in the evaluator tests).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::faults::RateVectors;

/// Shard count: enough stripes that 4–16 eval workers rarely collide,
/// cheap enough that `len()`/`clear()` stay trivial.
const NUM_SHARDS: usize = 16;

/// A point-in-time snapshot of cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
}

impl CacheStats {
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// What [`crate::partition::PartitionEvaluator::set_env_rates`] reports
/// when it rolls the cache over to a new fault environment: the epoch
/// that just ended, and the lifetime totals including it.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheRollover {
    /// Counters of the epoch that was just closed.
    pub ended_epoch: CacheStats,
    /// Cumulative counters across all epochs so far (including the one
    /// that just ended).
    pub lifetime: CacheStats,
    /// Distinct entries dropped by the rollover.
    pub entries_dropped: usize,
}

/// Pack (hits, misses) into one u64 — hits in the high 32 bits, misses
/// in the low 32 — so a full batch attribution is ONE `fetch_add` and a
/// snapshot is ONE `load`: readers can never observe hits from one
/// instant paired with misses from another (the torn-read bug the
/// separate `AtomicUsize` pair had). 32 bits per scope bounds each
/// counter at ~4.2e9 per epoch/lifetime — orders of magnitude beyond
/// any run this system performs.
fn pack(hits: usize, misses: usize) -> u64 {
    debug_assert!(hits < (1 << 32) && misses < (1 << 32), "cache counter overflow");
    ((hits as u64) << 32) | (misses as u64)
}

fn unpack(word: u64) -> CacheStats {
    CacheStats { hits: (word >> 32) as usize, misses: (word & 0xFFFF_FFFF) as usize }
}

/// One stripe of the store: context tag → (quantized rate key → ΔAcc
/// accuracy). Nesting keeps the hot probe path allocation-free — a
/// composite `(u64, Vec<u16>)` key would force an owned tuple per
/// lookup, while the inner map still borrows `&[u16]`.
type Shard = HashMap<u64, HashMap<Vec<u16>, f64>>;

/// Exact memo cache for fault-injected accuracy. Thread-safe: all
/// operations take `&self`.
#[derive(Debug)]
pub struct DaccCache {
    shards: Vec<Mutex<Shard>>,
    /// Epoch (hits, misses), packed; reset by `clear`.
    epoch: AtomicU64,
    /// Lifetime (hits, misses), packed; never reset.
    lifetime: AtomicU64,
}

impl Default for DaccCache {
    fn default() -> Self {
        DaccCache::new()
    }
}

impl DaccCache {
    pub fn new() -> DaccCache {
        DaccCache {
            shards: (0..NUM_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            epoch: AtomicU64::new(0),
            lifetime: AtomicU64::new(0),
        }
    }

    fn shard(&self, ctx: u64, key: &[u16]) -> &Mutex<Shard> {
        // DefaultHasher::new() is deterministic (fixed keys), unlike a
        // HashMap's per-instance RandomState — shard choice is stable
        // across runs, though nothing observable depends on it.
        let mut h = DefaultHasher::new();
        ctx.hash(&mut h);
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Raw lookup by quantized key with **no** statistics side effects.
    /// The batch engine uses this so it can attribute hits/misses itself
    /// (a batch-deduplicated request is a hit even though the store
    /// doesn't hold the value yet). Context 0 — the single-evaluator
    /// keyspace.
    pub fn probe(&self, key: &[u16]) -> Option<f64> {
        self.probe_ctx(0, key)
    }

    /// Raw lookup in an explicit context keyspace; no statistics side
    /// effects. Entries from different contexts never alias even when
    /// their rate keys are identical.
    pub fn probe_ctx(&self, ctx: u64, key: &[u16]) -> Option<f64> {
        let shard = self.shard(ctx, key).lock().unwrap();
        shard.get(&ctx).and_then(|m| m.get(key)).copied()
    }

    /// Counted lookup: records a hit or a miss (both scopes).
    pub fn get(&self, rates: &RateVectors) -> Option<f64> {
        let key = rates.cache_key();
        match self.probe(&key) {
            Some(v) => {
                self.record_hits(1);
                Some(v)
            }
            None => {
                self.record_misses(1);
                None
            }
        }
    }

    pub fn put(&self, rates: &RateVectors, acc: f64) {
        self.put_key(rates.cache_key(), acc);
    }

    /// Insert into context 0 — the single-evaluator keyspace.
    pub fn put_key(&self, key: Vec<u16>, acc: f64) {
        self.put_key_ctx(0, key, acc);
    }

    /// Insert into an explicit context keyspace.
    pub fn put_key_ctx(&self, ctx: u64, key: Vec<u16>, acc: f64) {
        self.shard(ctx, &key).lock().unwrap().entry(ctx).or_default().insert(key, acc);
    }

    /// Attribute a whole batch's lookups in one atomic step per scope:
    /// a concurrent [`stats`](DaccCache::stats) /
    /// [`lifetime_stats`](DaccCache::lifetime_stats) reader observes
    /// this batch either fully or not at all, so mid-batch snapshots
    /// (the telemetry registry samples them) always satisfy
    /// `hits + misses == lookups` over completed batches.
    pub fn record_batch(&self, hits: usize, misses: usize) {
        let delta = pack(hits, misses);
        self.epoch.fetch_add(delta, Ordering::Relaxed);
        self.lifetime.fetch_add(delta, Ordering::Relaxed);
    }

    /// Attribute `n` hits (used for batch-dedup hits and engine lookups).
    pub fn record_hits(&self, n: usize) {
        self.record_batch(n, 0);
    }

    /// Attribute `n` misses (engine: unique keys that must be evaluated).
    pub fn record_misses(&self, n: usize) {
        self.record_batch(0, n);
    }

    /// Distinct entries across every context keyspace.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().values().map(HashMap::len).sum::<usize>())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().values().all(HashMap::is_empty))
    }

    /// Epoch hits (since the last clear).
    pub fn hits(&self) -> usize {
        self.stats().hits
    }

    /// Epoch misses (since the last clear).
    pub fn misses(&self) -> usize {
        self.stats().misses
    }

    pub fn hit_rate(&self) -> f64 {
        self.stats().hit_rate()
    }

    /// Epoch counters (reset on [`clear`](DaccCache::clear)). One
    /// atomic load: hits and misses are from the same instant.
    pub fn stats(&self) -> CacheStats {
        unpack(self.epoch.load(Ordering::Relaxed))
    }

    /// Cumulative counters across every epoch of this cache's life.
    /// One atomic load, same consistency as [`stats`](DaccCache::stats).
    pub fn lifetime_stats(&self) -> CacheStats {
        unpack(self.lifetime.load(Ordering::Relaxed))
    }

    /// Drop all entries and close the current stats epoch. Lifetime
    /// counters are preserved; the returned rollover reports both
    /// scopes. The epoch is closed with one atomic `swap`, so exactly
    /// the counts read are the counts reset even if workers race the
    /// rollover.
    pub fn clear(&self) -> CacheRollover {
        let ended_epoch = unpack(self.epoch.swap(0, Ordering::Relaxed));
        let lifetime = self.lifetime_stats();
        let mut entries_dropped = 0;
        for shard in &self.shards {
            let mut map = shard.lock().unwrap();
            entries_dropped += map.values().map(HashMap::len).sum::<usize>();
            map.clear();
        }
        CacheRollover { ended_epoch, lifetime, entries_dropped }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rv(w: f32, a: f32) -> RateVectors {
        RateVectors { w_rates: vec![w, w], a_rates: vec![a, a] }
    }

    #[test]
    fn hit_after_put() {
        let c = DaccCache::new();
        assert_eq!(c.get(&rv(0.2, 0.1)), None);
        c.put(&rv(0.2, 0.1), 0.85);
        assert_eq!(c.get(&rv(0.2, 0.1)), Some(0.85));
        assert_eq!((c.hits(), c.misses()), (1, 1));
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sub_granularity_rates_collide_exactly() {
        let c = DaccCache::new();
        c.put(&rv(0.2, 0.1), 0.9);
        // 0.2001 quantizes to the same kernel threshold -> same accuracy
        assert_eq!(c.get(&rv(0.2001, 0.1)), Some(0.9));
    }

    #[test]
    fn distinct_rates_miss() {
        let c = DaccCache::new();
        c.put(&rv(0.2, 0.1), 0.9);
        assert_eq!(c.get(&rv(0.25, 0.1)), None);
    }

    #[test]
    fn clear_resets_epoch_but_keeps_lifetime() {
        let c = DaccCache::new();
        assert_eq!(c.get(&rv(0.2, 0.1)), None); // miss
        c.put(&rv(0.2, 0.1), 0.9);
        assert_eq!(c.get(&rv(0.2, 0.1)), Some(0.9)); // hit
        let rollover = c.clear();
        assert!(c.is_empty());
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 0);
        assert_eq!(rollover.ended_epoch, CacheStats { hits: 1, misses: 1 });
        assert_eq!(rollover.entries_dropped, 1);
        // lifetime survives the rollover and keeps accumulating
        assert_eq!(c.lifetime_stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(c.get(&rv(0.3, 0.1)), None);
        assert_eq!(c.lifetime_stats(), CacheStats { hits: 1, misses: 2 });
        assert_eq!(c.stats(), CacheStats { hits: 0, misses: 1 });
    }

    #[test]
    fn probe_has_no_stat_side_effects() {
        let c = DaccCache::new();
        c.put(&rv(0.2, 0.1), 0.9);
        assert_eq!(c.probe(&rv(0.2, 0.1).cache_key()), Some(0.9));
        assert_eq!(c.probe(&rv(0.4, 0.1).cache_key()), None);
        assert_eq!(c.stats(), CacheStats::default());
    }

    #[test]
    fn contexts_are_isolated_keyspaces() {
        let c = DaccCache::new();
        let key = rv(0.2, 0.1).cache_key();
        c.put_key_ctx(7, key.clone(), 0.91);
        c.put_key_ctx(9, key.clone(), 0.33);
        // Same rate key, three different answers depending on context.
        assert_eq!(c.probe_ctx(7, &key), Some(0.91));
        assert_eq!(c.probe_ctx(9, &key), Some(0.33));
        assert_eq!(c.probe_ctx(8, &key), None);
        // The ctx-less API is exactly context 0.
        assert_eq!(c.probe(&key), None);
        c.put_key(key.clone(), 0.5);
        assert_eq!(c.probe_ctx(0, &key), Some(0.5));
        assert_eq!(c.len(), 3);
        let rollover = c.clear();
        assert_eq!(rollover.entries_dropped, 3);
        assert!(c.is_empty());
        assert_eq!(c.probe_ctx(7, &key), None);
    }

    #[test]
    fn len_spans_shards() {
        let c = DaccCache::new();
        for i in 0..100 {
            let r = i as f32 / 100.0;
            c.put(&rv(r, 0.5), r as f64);
        }
        assert_eq!(c.len(), 100);
    }

    #[test]
    fn batch_attribution_is_snapshot_atomic() {
        // Regression: hits/misses used to be two separate atomics, so a
        // mid-batch snapshot could pair hits from one instant with
        // misses from another. With packed single-word counters, every
        // snapshot must see whole (2 hits : 1 miss) batches.
        let c = DaccCache::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..5_000 {
                        c.record_batch(2, 1);
                    }
                });
            }
            let c = &c;
            s.spawn(move || {
                for _ in 0..20_000 {
                    for stats in [c.stats(), c.lifetime_stats()] {
                        assert_eq!(
                            stats.hits,
                            2 * stats.misses,
                            "torn read: {stats:?} is not a whole number of batches"
                        );
                        assert_eq!(stats.lookups(), stats.hits + stats.misses);
                    }
                }
            });
        });
        assert_eq!(c.stats(), CacheStats { hits: 40_000, misses: 20_000 });
        assert_eq!(c.lifetime_stats(), c.stats());
    }

    #[test]
    fn shared_across_threads() {
        let c = DaccCache::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for i in 0..50 {
                        let r = ((t * 50 + i) % 64) as f32 / 64.0;
                        c.put(&rv(r, 0.25), r as f64);
                    }
                });
            }
        });
        assert_eq!(c.len(), 64);
    }
}
