//! The three-objective partition evaluator (paper Eq. 2):
//! minimize [Latency(P), Energy(P), ΔAcc(P)].
//!
//! Latency/energy come from the analytical hardware models (per-unit
//! tables precomputed once); ΔAcc comes from the compiled fault-injected
//! model (exact mode, Algorithm 1) or the sensitivity surrogate, with
//! exact memoization on quantized rate vectors in between.

use anyhow::Result;

use super::cache::DaccCache;
use super::genome::Mapping;
use super::sensitivity::SensitivityTable;
use crate::faults::{FaultScenario, RateVectors};
use crate::hw::Platform;
use crate::model::Manifest;
use crate::runtime::{AccuracyEvaluator, CompiledModel};

/// How ΔAcc(P) is obtained.
pub enum DaccMode<'a> {
    /// Run the compiled fault-injected forward (the paper's method).
    Exact { model: &'a CompiledModel, eval: &'a AccuracyEvaluator, key_seed: u32, n_batches: usize },
    /// Compose the measured layer-sensitivity table (cheap; online phase).
    Surrogate(&'a SensitivityTable),
    /// ΔAcc not evaluated (2-objective fault-unaware baselines).
    None,
}

/// Evaluation-effort counters (reported by benches / EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalCounters {
    pub exact_evals: usize,
    pub surrogate_evals: usize,
}

/// Bound evaluator for one (model, platform, fault-environment) triple.
pub struct PartitionEvaluator<'a> {
    lat_table: Vec<Vec<f64>>, // [unit][device] ms
    en_table: Vec<Vec<f64>>,  // [unit][device] mJ
    in_bytes: Vec<u64>,       // per-unit input activation bytes
    platform: &'a Platform,
    /// Per-device fault rates (weights / activations) of the environment.
    pub dev_w_rates: Vec<f32>,
    pub dev_a_rates: Vec<f32>,
    pub scenario: FaultScenario,
    pub clean_acc: f64,
    /// CNNParted models link costs; AFarePart excludes them (§VI-E).
    pub include_link_cost: bool,
    dacc: DaccMode<'a>,
    cache: DaccCache,
    pub counters: EvalCounters,
}

impl<'a> PartitionEvaluator<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        manifest: &Manifest,
        platform: &'a Platform,
        dev_w_rates: Vec<f32>,
        dev_a_rates: Vec<f32>,
        scenario: FaultScenario,
        clean_acc: f64,
        include_link_cost: bool,
        dacc: DaccMode<'a>,
    ) -> Self {
        assert_eq!(dev_w_rates.len(), platform.num_devices());
        PartitionEvaluator {
            lat_table: platform.latency_table(&manifest.units),
            en_table: platform.energy_table(&manifest.units),
            in_bytes: manifest.units.iter().map(|u| u.in_bytes).collect(),
            platform,
            dev_w_rates,
            dev_a_rates,
            scenario,
            clean_acc,
            include_link_cost,
            dacc,
            cache: DaccCache::new(),
            counters: EvalCounters::default(),
        }
    }

    pub fn num_units(&self) -> usize {
        self.lat_table.len()
    }

    pub fn num_devices(&self) -> usize {
        self.platform.num_devices()
    }

    /// Update the environment rates (online phase re-optimization) and
    /// drop the now-stale ΔAcc cache.
    pub fn set_env_rates(&mut self, dev_w: Vec<f32>, dev_a: Vec<f32>) {
        self.dev_w_rates = dev_w;
        self.dev_a_rates = dev_a;
        self.cache.clear();
    }

    pub fn cache_stats(&self) -> (usize, usize, f64) {
        (self.cache.hits(), self.cache.misses(), self.cache.hit_rate())
    }

    /// Per-unit rate vectors induced by a mapping under this environment.
    pub fn rates_for(&self, mapping: &Mapping) -> RateVectors {
        RateVectors::from_mapping(&mapping.0, &self.dev_w_rates, &self.dev_a_rates, self.scenario)
    }

    /// End-to-end latency in ms (sequential layer execution, as in the
    /// paper's per-sample inference latency).
    pub fn latency_ms(&self, mapping: &Mapping) -> f64 {
        let mut total = 0.0;
        for (l, &d) in mapping.0.iter().enumerate() {
            total += self.lat_table[l][d];
        }
        if self.include_link_cost {
            for w in 0..mapping.0.len().saturating_sub(1) {
                if mapping.0[w] != mapping.0[w + 1] {
                    total += self.platform.link.latency_ms(self.in_bytes[w + 1]);
                }
            }
        }
        total
    }

    /// End-to-end energy in mJ.
    pub fn energy_mj(&self, mapping: &Mapping) -> f64 {
        let mut total = 0.0;
        for (l, &d) in mapping.0.iter().enumerate() {
            total += self.en_table[l][d];
        }
        if self.include_link_cost {
            for w in 0..mapping.0.len().saturating_sub(1) {
                if mapping.0[w] != mapping.0[w + 1] {
                    total += self.platform.link.energy_mj(self.in_bytes[w + 1]);
                }
            }
        }
        total
    }

    /// Fault-injected accuracy A_faulty(P) (memoized).
    pub fn faulty_accuracy(&mut self, mapping: &Mapping) -> Result<f64> {
        let rates = self.rates_for(mapping);
        if let Some(acc) = self.cache.get(&rates) {
            return Ok(acc);
        }
        let acc = match &self.dacc {
            DaccMode::Exact { model, eval, key_seed, n_batches } => {
                self.counters.exact_evals += 1;
                eval.accuracy(model, &rates, *key_seed, *n_batches)?
            }
            DaccMode::Surrogate(table) => {
                self.counters.surrogate_evals += 1;
                (table.clean_acc - table.estimate_dacc(&rates)).max(0.0)
            }
            DaccMode::None => self.clean_acc,
        };
        self.cache.put(&rates, acc);
        Ok(acc)
    }

    /// ΔAcc(P) = A_clean − A_faulty(P) (paper Eq. 1), clamped at 0.
    pub fn dacc(&mut self, mapping: &Mapping) -> Result<f64> {
        Ok((self.clean_acc - self.faulty_accuracy(mapping)?).max(0.0))
    }

    /// Three-objective vector (AFarePart).
    pub fn objectives3(&mut self, mapping: &Mapping) -> Result<Vec<f64>> {
        Ok(vec![self.latency_ms(mapping), self.energy_mj(mapping), self.dacc(mapping)?])
    }

    /// Two-objective vector (fault-unaware baselines).
    pub fn objectives2(&self, mapping: &Mapping) -> Vec<f64> {
        vec![self.latency_ms(mapping), self.energy_mj(mapping)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::UnitCost;

    fn manifest2() -> Manifest {
        let mk = |name: &str, kind: &str, macs: u64, w: u64, i: u64, o: u64| UnitCost {
            name: name.into(),
            kind: kind.into(),
            macs,
            w_params: w,
            w_bytes: w,
            in_bytes: i,
            out_bytes: o,
            out_shape: vec![1],
        };
        Manifest {
            model: "toy".into(),
            num_units: 3,
            num_classes: 10,
            precision: 8,
            faulty_bits: 4,
            batch: 4,
            hlo_file: "x".into(),
            weights_file: "x".into(),
            clean_acc_f32: 0.95,
            clean_acc_quant: 0.9,
            weight_scale: 0.0078,
            units: vec![
                mk("c1", "conv", 2_000_000, 2_000, 3_072, 8_192),
                mk("c2", "conv", 8_000_000, 50_000, 8_192, 4_096),
                mk("fc", "dense", 300_000, 300_000, 4_096, 10),
            ],
            weight_tensors: vec![],
            act_scales: vec![0.01, 0.01, 0.01],
        }
    }

    fn eval<'a>(platform: &'a Platform, link: bool) -> PartitionEvaluator<'a> {
        PartitionEvaluator::new(
            &manifest2(),
            platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            link,
            DaccMode::None,
        )
    }

    #[test]
    fn latency_additive_over_units() {
        let p = Platform::default_two_device();
        let ev = eval(&p, false);
        let m0 = Mapping::all_on(0, 3);
        let lat = ev.latency_ms(&m0);
        let per_unit: f64 = (0..3).map(|l| ev.lat_table[l][0]).sum();
        assert!((lat - per_unit).abs() < 1e-12);
    }

    #[test]
    fn link_cost_only_at_boundaries() {
        let p = Platform::default_two_device();
        let ev_nolink = eval(&p, false);
        let ev_link = eval(&p, true);
        let same = Mapping(vec![0, 0, 0]);
        assert_eq!(ev_nolink.latency_ms(&same), ev_link.latency_ms(&same));
        let split = Mapping(vec![0, 1, 1]);
        assert!(ev_link.latency_ms(&split) > ev_nolink.latency_ms(&split));
        assert!(ev_link.energy_mj(&split) > ev_nolink.energy_mj(&split));
    }

    #[test]
    fn rates_follow_mapping() {
        let p = Platform::default_two_device();
        let ev = eval(&p, false);
        let rv = ev.rates_for(&Mapping(vec![0, 1, 0]));
        assert_eq!(rv.w_rates, vec![0.2, 0.03, 0.2]);
    }

    #[test]
    fn dacc_none_mode_returns_zero_drop() {
        let p = Platform::default_two_device();
        let mut ev = eval(&p, false);
        assert_eq!(ev.dacc(&Mapping(vec![0, 0, 0])).unwrap(), 0.0);
    }

    #[test]
    fn surrogate_mode_prefers_shielded_device_for_sensitive_unit() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            // unit 0 is very weight-sensitive; others not at all
            w_drop: vec![vec![0.1, 0.3, 0.5], vec![0.0; 3], vec![0.0; 3]],
            a_drop: vec![vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::WeightOnly,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        let risky = ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        let safe = ev.dacc(&Mapping(vec![1, 0, 0])).unwrap();
        assert!(safe < risky, "safe={safe} risky={risky}");
    }

    #[test]
    fn cache_hits_on_equivalent_mappings() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.2],
            w_drop: vec![vec![0.1], vec![0.1], vec![0.1]],
            a_drop: vec![vec![0.1], vec![0.1], vec![0.1]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.2], // identical devices -> all mappings equivalent
            vec![0.2, 0.2],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        ev.dacc(&Mapping(vec![1, 1, 1])).unwrap(); // same rates -> cache hit
        let (hits, misses, _) = ev.cache_stats();
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(ev.counters.surrogate_evals, 1);
    }

    #[test]
    fn set_env_rates_invalidates_cache() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.2, 0.4],
            w_drop: vec![vec![0.1, 0.3], vec![0.1, 0.3], vec![0.1, 0.3]],
            a_drop: vec![vec![0.1, 0.3], vec![0.1, 0.3], vec![0.1, 0.3]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        let d1 = ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        ev.set_env_rates(vec![0.4, 0.03], vec![0.4, 0.03]);
        let d2 = ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        assert!(d2 > d1);
    }
}
