//! The three-objective partition evaluator (paper Eq. 2):
//! minimize [Latency(P), Energy(P), ΔAcc(P)].
//!
//! Latency/energy come from the analytical hardware models; ΔAcc comes
//! from the compiled fault-injected model (exact mode, Algorithm 1) or the
//! sensitivity surrogate, with exact memoization on quantized rate vectors
//! in between.
//!
//! # Evaluation engine
//!
//! The evaluator is the backend of the batched evaluation engine
//! introduced for NSGA-II throughput (see [`crate::partition::engine`] and
//! the module docs of [`crate::nsga2`]):
//!
//! * **Latency/energy fast path** — per-device *prefix sums* over the
//!   unit cost tables are precomputed once; a mapping's cost is then the
//!   sum of one prefix difference per contiguous device run (O(runs)
//!   float work instead of O(L)). [`PartitionEvaluator::lat_en_delta`]
//!   additionally exposes a true O(changed-genes) incremental update for
//!   single-gene searches (the greedy baseline uses it).
//! * **Batched ΔAcc** — [`PartitionEvaluator::objectives_batch`] maps the
//!   whole batch to quantized rate keys, dedupes within the batch,
//!   answers known keys from the sharded lock-striped [`DaccCache`], and
//!   fans residual misses across scoped worker threads
//!   ([`PartitionEvaluator::with_parallelism`]). Results are bitwise
//!   identical for any thread count: every ΔAcc backend is a pure
//!   function of the rate vectors.

use std::sync::Arc;

use anyhow::Result;

use super::cache::{CacheRollover, CacheStats, DaccCache};
use super::engine::{self, DaccBackend, EngineConfig, SharedCache};
use super::genome::Mapping;
use super::sensitivity::SensitivityTable;
use crate::faults::{FaultScenario, RateVectors};
use crate::hw::Platform;
use crate::model::Manifest;
use crate::obs::Telemetry;
use crate::runtime::{AccuracyEvaluator, CompiledModel};
use crate::util::json::num;

/// How ΔAcc(P) is obtained.
pub enum DaccMode<'a> {
    /// Run the compiled fault-injected forward (the paper's method).
    Exact { model: &'a CompiledModel, eval: &'a AccuracyEvaluator, key_seed: u32, n_batches: usize },
    /// Compose the measured layer-sensitivity table (cheap; online phase).
    Surrogate(&'a SensitivityTable),
    /// Bench/test stand-in for `Exact`: surrogate-valued accuracy plus a
    /// simulated per-evaluation runtime cost that emulates the blocking
    /// PJRT call. Used by bench_perf's eval-engine section and the
    /// determinism/concurrency tests — no artifacts required.
    SyntheticExact { table: &'a SensitivityTable, cost: std::time::Duration },
    /// ΔAcc not evaluated (2-objective fault-unaware baselines).
    None,
}

/// Evaluation-effort counters (reported by benches / EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalCounters {
    /// Exact-mode (or synthetic-exact) backend evaluations actually
    /// performed. Without a shared cache this equals the unique private
    /// misses; with one it excludes cross-cell hits.
    pub exact_evals: usize,
    /// Surrogate backend evaluations actually performed.
    pub surrogate_evals: usize,
    /// Batched evaluation calls served by the engine.
    pub batch_calls: usize,
    /// Total genomes submitted through the batched path.
    pub batch_genomes: usize,
    /// Private misses answered by a cross-cell shared cache instead of
    /// the backend (0 unless [`PartitionEvaluator::with_shared_cache`]).
    pub shared_hits: usize,
}

/// Bound evaluator for one (model, platform, fault-environment) triple.
pub struct PartitionEvaluator<'a> {
    lat_table: Vec<Vec<f64>>, // [unit][device] ms
    en_table: Vec<Vec<f64>>,  // [unit][device] mJ
    // Device-conditional prefix sums: *_prefix[d][l] = Σ_{i<l} table[i][d]
    // (length L+1 per device). A contiguous run [i, j) on device d costs
    // prefix[d][j] − prefix[d][i].
    lat_prefix: Vec<Vec<f64>>,
    en_prefix: Vec<Vec<f64>>,
    in_bytes: Vec<u64>, // per-unit input activation bytes
    platform: &'a Platform,
    /// Per-device fault rates (weights / activations) of the environment.
    pub dev_w_rates: Vec<f32>,
    pub dev_a_rates: Vec<f32>,
    pub scenario: FaultScenario,
    pub clean_acc: f64,
    /// CNNParted models link costs; AFarePart excludes them (§VI-E).
    pub include_link_cost: bool,
    dacc: DaccMode<'a>,
    cache: DaccCache,
    /// Optional cross-cell L2: `(per-model shared cache, context tag)`.
    /// The tag folds every rate-independent backend parameter so cells
    /// only exchange values they would have computed identically.
    shared: Option<(Arc<DaccCache>, u64)>,
    engine: EngineConfig,
    pub counters: EvalCounters,
    /// Observability handle (disabled by default; see [`crate::obs`]).
    telemetry: Telemetry,
}

impl<'a> PartitionEvaluator<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        manifest: &Manifest,
        platform: &'a Platform,
        dev_w_rates: Vec<f32>,
        dev_a_rates: Vec<f32>,
        scenario: FaultScenario,
        clean_acc: f64,
        include_link_cost: bool,
        dacc: DaccMode<'a>,
    ) -> Self {
        assert_eq!(dev_w_rates.len(), platform.num_devices());
        let lat_table = platform.latency_table(&manifest.units);
        let en_table = platform.energy_table(&manifest.units);
        let (lat_prefix, en_prefix) = (prefix_sums(&lat_table), prefix_sums(&en_table));
        PartitionEvaluator {
            lat_table,
            en_table,
            lat_prefix,
            en_prefix,
            in_bytes: manifest.units.iter().map(|u| u.in_bytes).collect(),
            platform,
            dev_w_rates,
            dev_a_rates,
            scenario,
            clean_acc,
            include_link_cost,
            dacc,
            cache: DaccCache::new(),
            shared: None,
            engine: EngineConfig::default(),
            counters: EvalCounters::default(),
            telemetry: Telemetry::disabled(),
        }
    }

    /// Set the engine's worker-thread budget (builder form).
    pub fn with_parallelism(mut self, threads: usize) -> Self {
        self.set_parallelism(threads);
        self
    }

    /// Attach a cross-cell shared ΔAcc cache (builder form). The
    /// campaign scheduler hands every cell of one model the same
    /// `Arc<DaccCache>`; this evaluator derives its context tag from the
    /// ΔAcc backend's rate-independent parameters, so only cells that
    /// would compute identical values exchange entries. The private
    /// cache and its deterministic epoch statistics are unaffected —
    /// shared answers surface only in [`EvalCounters::shared_hits`] and
    /// the shared cache's own lifetime counters.
    pub fn with_shared_cache(mut self, shared: Arc<DaccCache>) -> Self {
        self.set_shared_cache(shared);
        self
    }

    /// See [`PartitionEvaluator::with_shared_cache`].
    pub fn set_shared_cache(&mut self, shared: Arc<DaccCache>) {
        let ctx = self.shared_ctx();
        self.shared = Some((shared, ctx));
    }

    /// Fold the ΔAcc backend's rate-independent configuration into a
    /// context tag for the shared cache keyspace. Two evaluators receive
    /// the same tag exactly when `backend().eval(rates)` is the same
    /// pure function for both — fault rates, scenarios, and drifts do
    /// NOT enter the tag (they only shape which rate vectors get
    /// requested), which is precisely what lets a rates × scenarios grid
    /// share one warm keyspace per model.
    fn shared_ctx(&self) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        fn hash_table(t: &SensitivityTable, h: &mut DefaultHasher) {
            for v in &t.rate_grid {
                v.to_bits().hash(h);
            }
            for row in t.w_drop.iter().chain(&t.a_drop) {
                row.len().hash(h);
                for v in row {
                    v.to_bits().hash(h);
                }
            }
            t.clean_acc.to_bits().hash(h);
        }
        let mut h = DefaultHasher::new();
        match &self.dacc {
            DaccMode::Exact { model, eval, key_seed, n_batches } => {
                // In-process identity of the compiled model + eval set
                // (pointer equality is the guard: one Experiment per
                // model in a campaign), plus the fault-draw seed and the
                // eval budget, which change the measured accuracy.
                0u8.hash(&mut h);
                (*model as *const CompiledModel as usize).hash(&mut h);
                (*eval as *const AccuracyEvaluator as usize).hash(&mut h);
                key_seed.hash(&mut h);
                n_batches.hash(&mut h);
            }
            DaccMode::Surrogate(table) => {
                // Content fingerprint, not identity: per-cell synthetic
                // fixtures rebuild equal tables that must still share.
                1u8.hash(&mut h);
                hash_table(table, &mut h);
            }
            DaccMode::SyntheticExact { table, cost } => {
                2u8.hash(&mut h);
                hash_table(table, &mut h);
                cost.hash(&mut h);
            }
            DaccMode::None => {
                3u8.hash(&mut h);
                self.clean_acc.to_bits().hash(&mut h);
            }
        }
        h.finish()
    }

    /// The engine-facing view of the shared cache, if attached.
    fn shared_view(&self) -> Option<SharedCache<'_>> {
        self.shared.as_ref().map(|(cache, ctx)| SharedCache { cache, ctx: *ctx })
    }

    /// Attach the run's telemetry handle (builder form).
    pub fn with_telemetry(mut self, telemetry: Telemetry) -> Self {
        self.set_telemetry(telemetry);
        self
    }

    /// Attach the run's telemetry handle; batched evaluations then emit
    /// `eval.batch` spans and publish atomically-read cache gauges.
    pub fn set_telemetry(&mut self, telemetry: Telemetry) {
        self.telemetry = telemetry;
    }

    /// The attached telemetry handle (disabled unless set). The offline
    /// driver clones this into the optimizer so generation spans share
    /// the evaluator's registry/trace.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    pub fn set_parallelism(&mut self, threads: usize) {
        self.engine = EngineConfig::with_threads(threads);
    }

    pub fn parallelism(&self) -> usize {
        self.engine.threads
    }

    pub fn num_units(&self) -> usize {
        self.lat_table.len()
    }

    pub fn num_devices(&self) -> usize {
        self.platform.num_devices()
    }

    /// Update the environment rates (online phase re-optimization) and
    /// roll the now-stale ΔAcc cache over to a new epoch. The returned
    /// rollover carries both the ended epoch's stats and the cumulative
    /// lifetime stats, so callers can report history instead of losing it.
    pub fn set_env_rates(&mut self, dev_w: Vec<f32>, dev_a: Vec<f32>) -> CacheRollover {
        self.dev_w_rates = dev_w;
        self.dev_a_rates = dev_a;
        self.cache.clear()
    }

    /// Current-epoch cache statistics as (hits, misses, hit_rate).
    pub fn cache_stats(&self) -> (usize, usize, f64) {
        let s = self.cache.stats();
        (s.hits, s.misses, s.hit_rate())
    }

    /// Cumulative cache statistics across all environment epochs.
    pub fn cache_lifetime_stats(&self) -> CacheStats {
        self.cache.lifetime_stats()
    }

    /// Per-unit rate vectors induced by a mapping under this environment.
    pub fn rates_for(&self, mapping: &Mapping) -> RateVectors {
        RateVectors::from_mapping(&mapping.0, &self.dev_w_rates, &self.dev_a_rates, self.scenario)
    }

    /// End-to-end (latency ms, energy mJ) in one pass: prefix-difference
    /// per contiguous device run, plus link costs at run boundaries when
    /// modeled.
    pub fn lat_en(&self, mapping: &Mapping) -> (f64, f64) {
        let genes = &mapping.0;
        let (mut lat, mut en) = (0.0, 0.0);
        let mut start = 0;
        for l in 1..=genes.len() {
            if l == genes.len() || genes[l] != genes[start] {
                let d = genes[start];
                lat += self.lat_prefix[d][l] - self.lat_prefix[d][start];
                en += self.en_prefix[d][l] - self.en_prefix[d][start];
                if l < genes.len() {
                    if self.include_link_cost {
                        lat += self.platform.link.latency_ms(self.in_bytes[l]);
                        en += self.platform.link.energy_mj(self.in_bytes[l]);
                    }
                    start = l;
                }
            }
        }
        (lat, en)
    }

    /// End-to-end latency in ms (sequential layer execution, as in the
    /// paper's per-sample inference latency).
    pub fn latency_ms(&self, mapping: &Mapping) -> f64 {
        self.lat_en(mapping).0
    }

    /// End-to-end energy in mJ.
    pub fn energy_mj(&self, mapping: &Mapping) -> f64 {
        self.lat_en(mapping).1
    }

    /// Incremental cost update: the (latency, energy) of `base` after
    /// re-assigning the listed `(unit, device)` genes — O(changed genes),
    /// not O(L). Only valid without link costs (a gene change perturbs
    /// link boundaries non-locally); asserts that invariant.
    ///
    /// Note the floating-point sums differ from [`Self::lat_en`] in the
    /// last ulps (different association order), so the batched NSGA-II
    /// path deliberately does *not* chain deltas — bitwise determinism
    /// against the serial path outranks the constant-factor win there.
    /// Single-gene searches (greedy baseline, local refinement) are the
    /// intended users.
    pub fn lat_en_delta(
        &self,
        base: &Mapping,
        base_cost: (f64, f64),
        changes: &[(usize, usize)],
    ) -> (f64, f64) {
        assert!(
            !self.include_link_cost,
            "lat_en_delta: incremental updates are unavailable with link costs"
        );
        let (mut lat, mut en) = base_cost;
        for &(unit, dev) in changes {
            let old = base.0[unit];
            lat += self.lat_table[unit][dev] - self.lat_table[unit][old];
            en += self.en_table[unit][dev] - self.en_table[unit][old];
        }
        (lat, en)
    }

    /// The per-worker ΔAcc backend handle for the current mode.
    fn backend(&self) -> DaccBackend<'a> {
        match &self.dacc {
            DaccMode::Exact { model, eval, key_seed, n_batches } => DaccBackend::Exact {
                model: *model,
                eval: *eval,
                key_seed: *key_seed,
                n_batches: *n_batches,
            },
            DaccMode::Surrogate(table) => DaccBackend::Surrogate { table: *table },
            DaccMode::SyntheticExact { table, cost } => {
                DaccBackend::Synthetic { table: *table, cost: *cost }
            }
            DaccMode::None => DaccBackend::Clean { acc: self.clean_acc },
        }
    }

    /// Book unique backend evaluations against the right counter.
    fn note_backend_evals(&mut self, n: usize) {
        match &self.dacc {
            DaccMode::Exact { .. } | DaccMode::SyntheticExact { .. } => {
                self.counters.exact_evals += n
            }
            DaccMode::Surrogate(_) => self.counters.surrogate_evals += n,
            DaccMode::None => {}
        }
    }

    /// Fault-injected accuracy A_faulty(P) (memoized; consults the
    /// cross-cell shared cache, when attached, before the backend).
    pub fn faulty_accuracy(&mut self, mapping: &Mapping) -> Result<f64> {
        let rates = self.rates_for(mapping);
        if let Some(acc) = self.cache.get(&rates) {
            return Ok(acc);
        }
        let key = rates.cache_key();
        if let Some((shared, ctx)) = &self.shared {
            if let Some(acc) = shared.probe_ctx(*ctx, &key) {
                shared.record_hits(1);
                self.counters.shared_hits += 1;
                self.cache.put_key(key, acc);
                return Ok(acc);
            }
        }
        let acc = self.backend().eval(&rates)?;
        self.note_backend_evals(1);
        if let Some((shared, ctx)) = &self.shared {
            shared.record_misses(1);
            shared.put_key_ctx(*ctx, key.clone(), acc);
        }
        self.cache.put_key(key, acc);
        Ok(acc)
    }

    /// ΔAcc(P) = A_clean − A_faulty(P) (paper Eq. 1), clamped at 0.
    pub fn dacc(&mut self, mapping: &Mapping) -> Result<f64> {
        Ok((self.clean_acc - self.faulty_accuracy(mapping)?).max(0.0))
    }

    /// Three-objective vector (AFarePart).
    pub fn objectives3(&mut self, mapping: &Mapping) -> Result<Vec<f64>> {
        let (lat, en) = self.lat_en(mapping);
        Ok(vec![lat, en, self.dacc(mapping)?])
    }

    /// Two-objective vector (fault-unaware baselines).
    pub fn objectives2(&self, mapping: &Mapping) -> Vec<f64> {
        let (lat, en) = self.lat_en(mapping);
        vec![lat, en]
    }

    /// Batched objective evaluation — the engine entry point NSGA-II
    /// drives once per generation. Deduplicates equivalent rate vectors
    /// within the batch, serves known keys from the sharded cache, and
    /// evaluates residual misses on the engine's worker threads. Results
    /// are returned in submission order and are bitwise identical to
    /// evaluating each mapping serially via [`Self::objectives3`] /
    /// [`Self::objectives2`].
    pub fn objectives_batch(
        &mut self,
        mappings: &[Mapping],
        three_obj: bool,
    ) -> Result<Vec<Vec<f64>>> {
        // clone the (refcounted) handle so the span's borrow doesn't
        // pin `self` for the whole batch
        let telemetry = self.telemetry.clone();
        let mut span = telemetry.span("eval.batch");
        self.counters.batch_calls += 1;
        self.counters.batch_genomes += mappings.len();
        span.note("batch", num(self.counters.batch_calls as f64));
        span.note("genomes", num(mappings.len() as f64));
        telemetry.counter_add("eval_batch_calls_total", 1);
        telemetry.counter_add("eval_batch_genomes_total", mappings.len() as u64);
        let costs: Vec<(f64, f64)> = mappings.iter().map(|m| self.lat_en(m)).collect();
        if !three_obj {
            span.note("unique_misses", num(0.0));
            return Ok(costs.into_iter().map(|(l, e)| vec![l, e]).collect());
        }
        let rates: Vec<RateVectors> = mappings.iter().map(|m| self.rates_for(m)).collect();
        let outcome = engine::faulty_accuracy_batch(
            self.backend(),
            &self.cache,
            self.shared_view(),
            self.engine,
            &rates,
        )?;
        self.note_backend_evals(outcome.backend_evals);
        self.counters.shared_hits += outcome.shared_hits;
        // span notes stay schedule-invariant (trace determinism): the
        // private miss count, never the shared-cache outcome
        span.note("unique_misses", num(outcome.unique_misses as f64));
        span.note("cache_answered", num((mappings.len() - outcome.unique_misses) as f64));
        telemetry.counter_add("eval_backend_evals_total", outcome.backend_evals as u64);
        self.publish_cache_gauges(&telemetry);
        Ok(costs
            .into_iter()
            .zip(outcome.accs)
            .map(|((lat, en), acc)| vec![lat, en, (self.clean_acc - acc).max(0.0)])
            .collect())
    }

    /// Publish cache statistics into the registry. Each scope is ONE
    /// packed-atomic load ([`DaccCache::stats`]), so the exported
    /// (hits, misses) pair is always internally consistent — even if
    /// engine workers are mid-batch on another evaluator when a
    /// campaign snapshot is taken.
    fn publish_cache_gauges(&self, telemetry: &Telemetry) {
        if !telemetry.is_enabled() {
            return;
        }
        let epoch = self.cache.stats();
        let life = self.cache.lifetime_stats();
        telemetry.gauge_set("dacc_cache_epoch_hits", epoch.hits as f64);
        telemetry.gauge_set("dacc_cache_epoch_misses", epoch.misses as f64);
        telemetry.gauge_set("dacc_cache_lifetime_hits", life.hits as f64);
        telemetry.gauge_set("dacc_cache_lifetime_misses", life.misses as f64);
        telemetry.gauge_set("dacc_cache_entries", self.cache.len() as f64);
    }
}

/// Per-device prefix sums of a [unit][device] table: out[d][l] = Σ_{i<l}
/// table[i][d], with out[d].len() == L + 1.
fn prefix_sums(table: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let devices = table.first().map(|row| row.len()).unwrap_or(0);
    let mut out = vec![vec![0.0; table.len() + 1]; devices];
    for (l, row) in table.iter().enumerate() {
        for (d, &v) in row.iter().enumerate() {
            out[d][l + 1] = out[d][l] + v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::UnitCost;

    fn manifest2() -> Manifest {
        let mk = |name: &str, kind: &str, macs: u64, w: u64, i: u64, o: u64| UnitCost {
            name: name.into(),
            kind: kind.into(),
            macs,
            w_params: w,
            w_bytes: w,
            in_bytes: i,
            out_bytes: o,
            out_shape: vec![1],
        };
        Manifest {
            model: "toy".into(),
            num_units: 3,
            num_classes: 10,
            precision: 8,
            faulty_bits: 4,
            batch: 4,
            hlo_file: "x".into(),
            weights_file: "x".into(),
            clean_acc_f32: 0.95,
            clean_acc_quant: 0.9,
            weight_scale: 0.0078,
            units: vec![
                mk("c1", "conv", 2_000_000, 2_000, 3_072, 8_192),
                mk("c2", "conv", 8_000_000, 50_000, 8_192, 4_096),
                mk("fc", "dense", 300_000, 300_000, 4_096, 10),
            ],
            weight_tensors: vec![],
            act_scales: vec![0.01, 0.01, 0.01],
        }
    }

    fn eval<'a>(platform: &'a Platform, link: bool) -> PartitionEvaluator<'a> {
        PartitionEvaluator::new(
            &manifest2(),
            platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            link,
            DaccMode::None,
        )
    }

    #[test]
    fn latency_additive_over_units() {
        let p = Platform::default_two_device();
        let ev = eval(&p, false);
        let m0 = Mapping::all_on(0, 3);
        let lat = ev.latency_ms(&m0);
        let per_unit: f64 = (0..3).map(|l| ev.lat_table[l][0]).sum();
        assert!((lat - per_unit).abs() < 1e-12);
    }

    #[test]
    fn prefix_fast_path_matches_per_gene_sum() {
        let p = Platform::default_two_device();
        let ev = eval(&p, false);
        for bits in 0..8usize {
            let m = Mapping((0..3).map(|i| (bits >> i) & 1).collect());
            let want_lat: f64 = (0..3).map(|l| ev.lat_table[l][m.0[l]]).sum();
            let want_en: f64 = (0..3).map(|l| ev.en_table[l][m.0[l]]).sum();
            let (lat, en) = ev.lat_en(&m);
            assert!((lat - want_lat).abs() < 1e-9, "{m:?}: {lat} vs {want_lat}");
            assert!((en - want_en).abs() < 1e-9, "{m:?}: {en} vs {want_en}");
        }
    }

    #[test]
    fn delta_update_matches_full_evaluation() {
        let p = Platform::default_two_device();
        let ev = eval(&p, false);
        let base = Mapping(vec![0, 0, 0]);
        let base_cost = ev.lat_en(&base);
        let (dlat, den) = ev.lat_en_delta(&base, base_cost, &[(1, 1)]);
        let full = ev.lat_en(&Mapping(vec![0, 1, 0]));
        assert!((dlat - full.0).abs() < 1e-9);
        assert!((den - full.1).abs() < 1e-9);
    }

    #[test]
    fn link_cost_only_at_boundaries() {
        let p = Platform::default_two_device();
        let ev_nolink = eval(&p, false);
        let ev_link = eval(&p, true);
        let same = Mapping(vec![0, 0, 0]);
        assert_eq!(ev_nolink.latency_ms(&same), ev_link.latency_ms(&same));
        let split = Mapping(vec![0, 1, 1]);
        assert!(ev_link.latency_ms(&split) > ev_nolink.latency_ms(&split));
        assert!(ev_link.energy_mj(&split) > ev_nolink.energy_mj(&split));
    }

    #[test]
    fn rates_follow_mapping() {
        let p = Platform::default_two_device();
        let ev = eval(&p, false);
        let rv = ev.rates_for(&Mapping(vec![0, 1, 0]));
        assert_eq!(rv.w_rates, vec![0.2, 0.03, 0.2]);
    }

    #[test]
    fn dacc_none_mode_returns_zero_drop() {
        let p = Platform::default_two_device();
        let mut ev = eval(&p, false);
        assert_eq!(ev.dacc(&Mapping(vec![0, 0, 0])).unwrap(), 0.0);
    }

    #[test]
    fn surrogate_mode_prefers_shielded_device_for_sensitive_unit() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            // unit 0 is very weight-sensitive; others not at all
            w_drop: vec![vec![0.1, 0.3, 0.5], vec![0.0; 3], vec![0.0; 3]],
            a_drop: vec![vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::WeightOnly,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        let risky = ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        let safe = ev.dacc(&Mapping(vec![1, 0, 0])).unwrap();
        assert!(safe < risky, "safe={safe} risky={risky}");
    }

    #[test]
    fn cache_hits_on_equivalent_mappings() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.2],
            w_drop: vec![vec![0.1], vec![0.1], vec![0.1]],
            a_drop: vec![vec![0.1], vec![0.1], vec![0.1]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.2], // identical devices -> all mappings equivalent
            vec![0.2, 0.2],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        ev.dacc(&Mapping(vec![1, 1, 1])).unwrap(); // same rates -> cache hit
        let (hits, misses, _) = ev.cache_stats();
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(ev.counters.surrogate_evals, 1);
    }

    #[test]
    fn shared_cache_lifetime_counts_once() {
        // Regression (ISSUE 8 satellite): when one cache outlives a
        // single optimization run by being shared across cells, lifetime
        // accounting must live in the shared cache itself — summing the
        // per-cell lifetime stats would count the shared history once
        // per cell. Each private miss lands in the shared counters
        // exactly once (as a hit or a miss), never twice.
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.2],
            w_drop: vec![vec![0.1], vec![0.2], vec![0.3]],
            a_drop: vec![vec![0.0], vec![0.0], vec![0.0]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let shared = Arc::new(DaccCache::new());
        let mk = |rates: Vec<f32>| {
            PartitionEvaluator::new(
                &m,
                &p,
                rates.clone(),
                rates,
                FaultScenario::WeightOnly,
                0.9,
                false,
                DaccMode::Surrogate(&table),
            )
            .with_shared_cache(Arc::clone(&shared))
        };

        // Cell A computes one point; cell B (same backend context, a
        // different fault rate that happens to induce the same rate
        // vector for this mapping) reuses it without a backend call.
        let mut a = mk(vec![0.2, 0.2]);
        let va = a.faulty_accuracy(&Mapping(vec![0, 0, 0])).unwrap();
        assert_eq!(a.counters.surrogate_evals, 1);
        assert_eq!(a.counters.shared_hits, 0);

        let mut b = mk(vec![0.2, 0.05]);
        let vb = b.faulty_accuracy(&Mapping(vec![0, 0, 0])).unwrap();
        assert_eq!(va, vb);
        assert_eq!(b.counters.surrogate_evals, 0, "shared cache must answer B's miss");
        assert_eq!(b.counters.shared_hits, 1);

        // Private (per-cell) stats are deterministic and identical: one
        // miss each, regardless of who computed the value.
        assert_eq!(a.cache_stats().1, 1);
        assert_eq!(b.cache_stats().1, 1);
        // The shared cache saw each private miss exactly once: A's
        // backend evaluation (miss) then B's reuse (hit). Lookups = 2 —
        // NOT the 4 that double-counting per-cell lifetimes would give.
        let life = shared.lifetime_stats();
        assert_eq!(life, CacheStats { hits: 1, misses: 1 });
        assert_eq!(life.lookups(), 2);
        assert_eq!(shared.len(), 1);

        // The batched path shares through the same keyspace: a third
        // cold cell resolves the equivalent mapping batch with zero
        // backend evaluations.
        let mut c = mk(vec![0.2, 0.2]);
        c.objectives_batch(&[Mapping(vec![0, 0, 0]), Mapping(vec![1, 1, 1])], true).unwrap();
        assert_eq!(c.counters.surrogate_evals, 0);
        assert!(c.counters.shared_hits >= 1);
    }

    #[test]
    fn batch_matches_serial_objectives() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.1, 0.2, 0.4],
            w_drop: vec![vec![0.05, 0.1, 0.2], vec![0.01, 0.02, 0.04], vec![0.0; 3]],
            a_drop: vec![vec![0.01; 3], vec![0.01; 3], vec![0.01; 3]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mk = || {
            PartitionEvaluator::new(
                &m,
                &p,
                vec![0.2, 0.03],
                vec![0.2, 0.03],
                FaultScenario::InputWeight,
                0.9,
                false,
                DaccMode::Surrogate(&table),
            )
        };
        let mappings: Vec<Mapping> =
            (0..8usize).map(|b| Mapping((0..3).map(|i| (b >> i) & 1).collect())).collect();
        let mut batch_ev = mk();
        let batch = batch_ev.objectives_batch(&mappings, true).unwrap();
        let mut serial_ev = mk();
        for (m, got) in mappings.iter().zip(&batch) {
            let want = serial_ev.objectives3(m).unwrap();
            assert_eq!(got, &want, "batch diverges from serial for {m:?}");
        }
    }

    #[test]
    fn batch_two_objective_skips_dacc() {
        let p = Platform::default_two_device();
        let mut ev = eval(&p, false);
        let mappings = vec![Mapping(vec![0, 1, 0]), Mapping(vec![1, 1, 1])];
        let objs = ev.objectives_batch(&mappings, false).unwrap();
        assert_eq!(objs.len(), 2);
        assert_eq!(objs[0], ev.objectives2(&mappings[0]));
        let (h, mi, _) = ev.cache_stats();
        assert_eq!((h, mi), (0, 0), "2-objective batches must not touch the ΔAcc cache");
    }

    #[test]
    fn set_env_rates_invalidates_cache() {
        let p = Platform::default_two_device();
        let table = SensitivityTable {
            rate_grid: vec![0.2, 0.4],
            w_drop: vec![vec![0.1, 0.3], vec![0.1, 0.3], vec![0.1, 0.3]],
            a_drop: vec![vec![0.1, 0.3], vec![0.1, 0.3], vec![0.1, 0.3]],
            clean_acc: 0.9,
        };
        let m = manifest2();
        let mut ev = PartitionEvaluator::new(
            &m,
            &p,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            0.9,
            false,
            DaccMode::Surrogate(&table),
        );
        let d1 = ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        let rollover = ev.set_env_rates(vec![0.4, 0.03], vec![0.4, 0.03]);
        assert_eq!(rollover.ended_epoch.misses, 1);
        assert_eq!(rollover.lifetime.misses, 1);
        assert_eq!(rollover.entries_dropped, 1);
        let d2 = ev.dacc(&Mapping(vec![0, 0, 0])).unwrap();
        assert!(d2 > d1);
        // the new epoch starts clean; lifetime keeps accumulating
        assert_eq!(ev.cache_stats().1, 1);
        assert_eq!(ev.cache_lifetime_stats().misses, 2);
    }
}
