//! Partitioning layer: the genome, the three-objective evaluator
//! (latency, energy, ΔAcc — paper Eq. 2), the batched parallel evaluation
//! engine with its sharded ΔAcc memo cache, the layer-sensitivity
//! surrogate, and Pareto-front selection policies.

mod cache;
pub(crate) mod engine;
mod evaluator;
mod front;
mod genome;
mod sensitivity;

pub use cache::{CacheRollover, CacheStats, DaccCache};
pub use engine::EngineConfig;
pub use evaluator::{DaccMode, EvalCounters, PartitionEvaluator};
pub use front::{
    front_quality, select_knee, select_min_dacc, select_min_dacc_within_budget, FrontQuality,
};
pub use genome::Mapping;
pub use sensitivity::SensitivityTable;
