//! The partition genome: the paper's mapping P : {1..L} → {0..D-1}.

use crate::util::prng::Rng;

/// A layer→device mapping (one gene per partitionable unit).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Mapping(pub Vec<usize>);

impl Mapping {
    pub fn all_on(device: usize, len: usize) -> Mapping {
        Mapping(vec![device; len])
    }

    pub fn random(rng: &mut Rng, len: usize, devices: usize) -> Mapping {
        Mapping((0..len).map(|_| rng.below(devices)).collect())
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Number of device boundaries (consecutive units on different devices)
    /// — each one is a link transfer in the CNNParted cost model.
    pub fn boundaries(&self) -> usize {
        self.0.windows(2).filter(|w| w[0] != w[1]).count()
    }

    /// Units mapped to `device`.
    pub fn units_on(&self, device: usize) -> Vec<usize> {
        self.0
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == device)
            .map(|(i, _)| i)
            .collect()
    }

    /// Compact display, e.g. "01100" for 5 units on 2 devices.
    pub fn display(&self) -> String {
        self.0.iter().map(|d| std::char::from_digit(*d as u32 % 36, 36).unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundaries_counted() {
        assert_eq!(Mapping(vec![0, 0, 1, 1, 0]).boundaries(), 2);
        assert_eq!(Mapping(vec![0, 0, 0]).boundaries(), 0);
        assert_eq!(Mapping(vec![0, 1, 0, 1]).boundaries(), 3);
    }

    #[test]
    fn units_on_device() {
        let m = Mapping(vec![0, 1, 0, 1]);
        assert_eq!(m.units_on(0), vec![0, 2]);
        assert_eq!(m.units_on(1), vec![1, 3]);
    }

    #[test]
    fn random_in_alphabet() {
        let mut rng = Rng::new(1);
        let m = Mapping::random(&mut rng, 100, 3);
        assert!(m.0.iter().all(|&d| d < 3));
        // uses all devices with overwhelming probability
        for d in 0..3 {
            assert!(!m.units_on(d).is_empty());
        }
    }

    #[test]
    fn display_compact() {
        assert_eq!(Mapping(vec![0, 1, 1, 0]).display(), "0110");
    }
}
