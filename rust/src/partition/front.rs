//! Pareto-front selection policies: how the coordinator picks the deployed
//! partition P* from the offline front (paper §V-B: "the most robust
//! partition ... ensuring an initial balance").

use crate::nsga2::{front_hypervolume, front_spread, Individual};

/// Deterministic quality summary of a Pareto front: normalized
/// hypervolume plus bounding-box spread. Both are pure functions of the
/// front's objectives, so they are safe to note on trace spans (the
/// online runner stamps them on `online.reconfig`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrontQuality {
    pub size: usize,
    pub hypervolume: f64,
    pub spread: f64,
}

/// Measure `front` with the worst-point reference derived at `margin`
/// (see [`front_hypervolume`]).
pub fn front_quality(front: &[Individual], margin: f64) -> FrontQuality {
    let pts: Vec<Vec<f64>> = front.iter().map(|i| i.objectives.clone()).collect();
    FrontQuality {
        size: front.len(),
        hypervolume: front_hypervolume(front, margin),
        spread: front_spread(&pts),
    }
}

/// The most fault-resilient solution: minimum ΔAcc (objective index 2),
/// ties broken by latency.
pub fn select_min_dacc(front: &[Individual]) -> Option<&Individual> {
    front.iter().min_by(|a, b| {
        (a.objectives[2], a.objectives[0])
            .partial_cmp(&(b.objectives[2], b.objectives[0]))
            .unwrap()
    })
}

/// Minimum ΔAcc among solutions within latency/energy budget factors of
/// the front's best latency/energy (the paper's "keeping latency and
/// energy within acceptable limits", §V-B).
pub fn select_min_dacc_within_budget(
    front: &[Individual],
    lat_budget: f64,
    energy_budget: f64,
) -> Option<&Individual> {
    let best_lat = front.iter().map(|i| i.objectives[0]).fold(f64::INFINITY, f64::min);
    let best_en = front.iter().map(|i| i.objectives[1]).fold(f64::INFINITY, f64::min);
    let eligible: Vec<&Individual> = front
        .iter()
        .filter(|i| {
            i.objectives[0] <= best_lat * lat_budget && i.objectives[1] <= best_en * energy_budget
        })
        .collect();
    let pool: Vec<&Individual> =
        if eligible.is_empty() { front.iter().collect() } else { eligible };
    pool.into_iter().min_by(|a, b| {
        (a.objectives[2], a.objectives[0])
            .partial_cmp(&(b.objectives[2], b.objectives[0]))
            .unwrap()
    })
}

/// Knee point: minimum Euclidean distance to the ideal point after
/// per-objective min-max normalization.
pub fn select_knee(front: &[Individual]) -> Option<&Individual> {
    if front.is_empty() {
        return None;
    }
    let nobj = front[0].objectives.len();
    let mut lo = vec![f64::INFINITY; nobj];
    let mut hi = vec![f64::NEG_INFINITY; nobj];
    for i in front {
        for k in 0..nobj {
            lo[k] = lo[k].min(i.objectives[k]);
            hi[k] = hi[k].max(i.objectives[k]);
        }
    }
    front.iter().min_by(|a, b| {
        let dist = |ind: &Individual| -> f64 {
            (0..nobj)
                .map(|k| {
                    let range = hi[k] - lo[k];
                    if range <= 0.0 {
                        0.0
                    } else {
                        let t = (ind.objectives[k] - lo[k]) / range;
                        t * t
                    }
                })
                .sum()
        };
        dist(a).partial_cmp(&dist(b)).unwrap()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64]) -> Individual {
        Individual { genome: vec![0], objectives: objs.to_vec(), rank: 0, crowding: 0.0 }
    }

    fn front() -> Vec<Individual> {
        vec![
            ind(&[10.0, 5.0, 0.30]), // fast, cheap, fragile
            ind(&[12.0, 6.0, 0.10]), // balanced
            ind(&[20.0, 9.0, 0.02]), // slow, robust
        ]
    }

    #[test]
    fn min_dacc_picks_most_robust() {
        let f = front();
        assert_eq!(select_min_dacc(&f).unwrap().objectives[2], 0.02);
    }

    #[test]
    fn budget_constrains_selection() {
        let f = front();
        // within 1.3x latency and energy of best: excludes the slow one
        let sel = select_min_dacc_within_budget(&f, 1.3, 1.3).unwrap();
        assert_eq!(sel.objectives[2], 0.10);
        // generous budget: picks the most robust
        let sel = select_min_dacc_within_budget(&f, 10.0, 10.0).unwrap();
        assert_eq!(sel.objectives[2], 0.02);
    }

    #[test]
    fn budget_falls_back_when_infeasible() {
        let f = front();
        let sel = select_min_dacc_within_budget(&f, 0.5, 0.5).unwrap();
        // nothing fits an impossible budget; falls back to the full front
        assert_eq!(sel.objectives[2], 0.02);
    }

    #[test]
    fn knee_prefers_balanced() {
        let f = front();
        assert_eq!(select_knee(&f).unwrap().objectives[0], 12.0);
    }

    #[test]
    fn empty_front_is_none() {
        assert!(select_min_dacc(&[]).is_none());
        assert!(select_knee(&[]).is_none());
    }

    #[test]
    fn quality_summarizes_the_front() {
        let f = front();
        let q = front_quality(&f, 1.1);
        assert_eq!(q.size, 3);
        assert!(q.hypervolume > 0.0);
        assert!(q.spread > 0.0);
        // a strictly better front dominates more volume
        let better =
            vec![ind(&[9.0, 4.0, 0.25]), ind(&[11.0, 5.0, 0.05]), ind(&[18.0, 8.0, 0.01])];
        // compare against a shared reference by reusing the worse front's margin
        assert!(front_quality(&better, 1.1).hypervolume > 0.0);
        let empty = front_quality(&[], 1.1);
        assert_eq!((empty.size, empty.hypervolume, empty.spread), (0, 0.0, 0.0));
    }
}
