//! Typed run outcomes with JSON serialization — every subcommand's
//! result as data (`--format json [--out <file>]`), so campaigns, CI and
//! downstream tooling consume structured reports instead of scraping
//! tables.

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::coordinator::{OfflineOutcome, OnlineOutcome};
use crate::nsga2::Individual;
use crate::partition::Mapping;
use crate::util::json::{self, Value};

/// Output format of a CLI run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputFormat {
    Text,
    Json,
}

impl OutputFormat {
    /// Parse `--format <text|json>` (default text).
    pub fn from_args(args: &Args) -> Result<OutputFormat> {
        match args.get("format") {
            None | Some("text") => Ok(OutputFormat::Text),
            Some("json") => Ok(OutputFormat::Json),
            Some(other) => bail!("bad --format {other:?} (text, json)"),
        }
    }

    pub fn is_json(self) -> bool {
        self == OutputFormat::Json
    }
}

/// Write a JSON document to `--out <file>` or stdout.
pub fn emit_json(v: &Value, out: Option<&str>) -> Result<()> {
    let text = json::to_string(v);
    match out {
        Some(path) => std::fs::write(path, &text).with_context(|| format!("writing {path}"))?,
        None => println!("{text}"),
    }
    Ok(())
}

/// One scored mapping (a Pareto-front point or a deployed partition).
#[derive(Clone, Debug)]
pub struct MappingScore {
    pub mapping: String,
    pub latency_ms: f64,
    pub energy_mj: f64,
    pub dacc: f64,
}

impl MappingScore {
    pub fn from_individual(ind: &Individual) -> MappingScore {
        MappingScore {
            mapping: Mapping(ind.genome.clone()).display(),
            latency_ms: ind.objectives[0],
            energy_mj: ind.objectives[1],
            dacc: *ind.objectives.get(2).unwrap_or(&0.0),
        }
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("mapping", json::s(&self.mapping)),
            ("latency_ms", json::num(self.latency_ms)),
            ("energy_mj", json::num(self.energy_mj)),
            ("dacc", json::num(self.dacc)),
        ])
    }
}

/// Outcome of `afarepart offline` (and of each campaign cell).
#[derive(Clone, Debug)]
pub struct OfflineReport {
    pub model: String,
    pub scenario: String,
    pub fault_rate: f32,
    pub pop_size: usize,
    pub generations: usize,
    pub mode: String,
    pub eval_threads: usize,
    pub front: Vec<MappingScore>,
    pub deployed: MappingScore,
    pub evaluations: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
    pub cache_hit_rate: f64,
    /// Prometheus text snapshot of the run's metric registry. `None`
    /// when telemetry is off — the key is then absent from the JSON, so
    /// telemetry-disabled reports stay bitwise identical to the
    /// pre-observability format.
    pub telemetry: Option<String>,
}

impl OfflineReport {
    pub fn from_outcome(
        model: &str,
        scenario: &str,
        fault_rate: f32,
        pop_size: usize,
        generations: usize,
        surrogate: bool,
        eval_threads: usize,
        out: &OfflineOutcome,
    ) -> OfflineReport {
        let (hits, misses, rate) = out.cache;
        OfflineReport {
            model: model.to_string(),
            scenario: scenario.to_string(),
            fault_rate,
            pop_size,
            generations,
            mode: (if surrogate { "surrogate" } else { "exact" }).to_string(),
            eval_threads,
            front: out.front.iter().map(MappingScore::from_individual).collect(),
            deployed: MappingScore {
                mapping: out.deployed.display(),
                latency_ms: out.deployed_objectives[0],
                energy_mj: out.deployed_objectives[1],
                dacc: out.deployed_objectives[2],
            },
            evaluations: out.evaluations,
            cache_hits: hits,
            cache_misses: misses,
            cache_hit_rate: rate,
            telemetry: None,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("command", json::s("offline")),
            ("model", json::s(&self.model)),
            ("scenario", json::s(&self.scenario)),
            ("fault_rate", super::schema::f32_json(self.fault_rate)),
            ("pop_size", json::num(self.pop_size as f64)),
            ("generations", json::num(self.generations as f64)),
            ("mode", json::s(&self.mode)),
            ("eval_threads", json::num(self.eval_threads as f64)),
            ("front", json::arr(self.front.iter().map(MappingScore::to_json))),
            ("deployed", self.deployed.to_json()),
            ("evaluations", json::num(self.evaluations as f64)),
            ("cache_hits", json::num(self.cache_hits as f64)),
            ("cache_misses", json::num(self.cache_misses as f64)),
            ("cache_hit_rate", json::num(self.cache_hit_rate)),
        ];
        if let Some(t) = &self.telemetry {
            fields.push(("telemetry", json::s(t)));
        }
        json::obj(fields)
    }
}

/// Outcome of `afarepart online`.
#[derive(Clone, Debug)]
pub struct OnlineReport {
    pub model: String,
    pub theta: f64,
    pub ticks: usize,
    pub lookahead: usize,
    pub initial_mapping: String,
    pub final_mapping: String,
    pub batches_served: usize,
    pub reconfigurations: usize,
    pub speculative_discarded: usize,
    pub cache_lifetime_hits: usize,
    pub cache_lifetime_misses: usize,
    pub worker_respawns: usize,
    pub retries: usize,
    pub transient_errors: usize,
    pub timeouts: usize,
    pub degradations: usize,
    pub degraded_ticks: usize,
    /// Half-open `[start, end)` tick intervals spent degraded.
    pub degraded_intervals: Vec<(usize, usize)>,
    pub exec_mean_ms: Option<f64>,
    pub exec_p95_ms: Option<f64>,
    /// Prometheus text snapshot (key absent when telemetry is off; see
    /// [`OfflineReport::telemetry`]).
    pub telemetry: Option<String>,
    pub timeline: Vec<TimelineEntry>,
}

/// One serving tick in the JSON timeline.
#[derive(Clone, Debug)]
pub struct TimelineEntry {
    pub tick: usize,
    pub sim_time_s: f64,
    pub env_rate_dev0: f32,
    pub batch_accuracy: f64,
    pub rolling_accuracy: f64,
    pub mapping: String,
    pub reconfigured: bool,
    pub degraded: bool,
}

impl OnlineReport {
    pub fn from_outcome(
        model: &str,
        theta: f64,
        lookahead: usize,
        initial: &Mapping,
        out: &OnlineOutcome,
    ) -> OnlineReport {
        let exec = out.metrics.exec_summary();
        OnlineReport {
            model: model.to_string(),
            theta,
            ticks: out.timeline.len(),
            lookahead,
            initial_mapping: initial.display(),
            final_mapping: out.final_mapping.display(),
            batches_served: out.metrics.batches_served,
            reconfigurations: out.metrics.reconfigurations,
            speculative_discarded: out.metrics.speculative_discarded,
            cache_lifetime_hits: out.cache_lifetime.hits,
            cache_lifetime_misses: out.cache_lifetime.misses,
            worker_respawns: out.metrics.worker_respawns,
            retries: out.metrics.retries,
            transient_errors: out.metrics.transient_errors,
            timeouts: out.metrics.timeouts,
            degradations: out.metrics.degradations,
            degraded_ticks: out.metrics.degraded_ticks,
            degraded_intervals: out.metrics.degraded_intervals.clone(),
            exec_mean_ms: exec.as_ref().map(|s| s.mean),
            exec_p95_ms: exec.as_ref().map(|s| s.p95),
            telemetry: None,
            timeline: out
                .timeline
                .iter()
                .map(|p| TimelineEntry {
                    tick: p.tick,
                    sim_time_s: p.sim_time_s,
                    env_rate_dev0: p.env_rate_dev0,
                    batch_accuracy: p.batch_accuracy,
                    rolling_accuracy: p.rolling_accuracy,
                    mapping: p.mapping.display(),
                    reconfigured: p.reconfigured,
                    degraded: p.degraded,
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Value {
        let timeline = self.timeline.iter().map(|p| {
            json::obj(vec![
                ("tick", json::num(p.tick as f64)),
                ("sim_time_s", json::num(p.sim_time_s)),
                ("env_rate_dev0", super::schema::f32_json(p.env_rate_dev0)),
                ("batch_accuracy", json::num(p.batch_accuracy)),
                ("rolling_accuracy", json::num(p.rolling_accuracy)),
                ("mapping", json::s(&p.mapping)),
                ("reconfigured", Value::Bool(p.reconfigured)),
                ("degraded", Value::Bool(p.degraded)),
            ])
        });
        let mut fields = vec![
            ("command", json::s("online")),
            ("model", json::s(&self.model)),
            ("theta", json::num(self.theta)),
            ("ticks", json::num(self.ticks as f64)),
            ("lookahead", json::num(self.lookahead as f64)),
            ("initial_mapping", json::s(&self.initial_mapping)),
            ("final_mapping", json::s(&self.final_mapping)),
            ("batches_served", json::num(self.batches_served as f64)),
            ("reconfigurations", json::num(self.reconfigurations as f64)),
            ("speculative_discarded", json::num(self.speculative_discarded as f64)),
            ("cache_lifetime_hits", json::num(self.cache_lifetime_hits as f64)),
            ("cache_lifetime_misses", json::num(self.cache_lifetime_misses as f64)),
            ("worker_respawns", json::num(self.worker_respawns as f64)),
            ("retries", json::num(self.retries as f64)),
            ("transient_errors", json::num(self.transient_errors as f64)),
            ("timeouts", json::num(self.timeouts as f64)),
            ("degradations", json::num(self.degradations as f64)),
            ("degraded_ticks", json::num(self.degraded_ticks as f64)),
            (
                "degraded_intervals",
                json::arr(self.degraded_intervals.iter().map(|&(s, e)| {
                    json::arr([json::num(s as f64), json::num(e as f64)])
                })),
            ),
            ("timeline", json::arr(timeline)),
        ];
        if let Some(m) = self.exec_mean_ms {
            fields.push(("exec_mean_ms", json::num(m)));
        }
        if let Some(p) = self.exec_p95_ms {
            fields.push(("exec_p95_ms", json::num(p)));
        }
        if let Some(t) = &self.telemetry {
            fields.push(("telemetry", json::s(t)));
        }
        json::obj(fields)
    }
}

/// Outcome of `afarepart sweep`: per-unit accuracy drops over a rate grid.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub model: String,
    pub clean_acc: f64,
    pub rate_grid: Vec<f32>,
    pub units: Vec<SweepUnit>,
}

#[derive(Clone, Debug)]
pub struct SweepUnit {
    pub name: String,
    pub kind: String,
    /// Accuracy drop per grid rate, weight faults.
    pub w_drop: Vec<f64>,
    /// Accuracy drop per grid rate, activation faults.
    pub a_drop: Vec<f64>,
}

impl SweepReport {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("command", json::s("sweep")),
            ("model", json::s(&self.model)),
            ("clean_acc", json::num(self.clean_acc)),
            (
                "rate_grid",
                json::arr(self.rate_grid.iter().map(|&r| super::schema::f32_json(r))),
            ),
            (
                "units",
                json::arr(self.units.iter().map(|u| {
                    json::obj(vec![
                        ("name", json::s(&u.name)),
                        ("kind", json::s(&u.kind)),
                        ("w_drop", json::arr(u.w_drop.iter().map(|&x| json::num(x)))),
                        ("a_drop", json::arr(u.a_drop.iter().map(|&x| json::num(x)))),
                    ])
                })),
            ),
        ])
    }
}

/// Outcome of `afarepart compare`: one row per strategy.
#[derive(Clone, Debug)]
pub struct CompareReport {
    pub model: String,
    pub scenario: String,
    pub fault_rate: f32,
    pub rows: Vec<CompareRow>,
}

#[derive(Clone, Debug)]
pub struct CompareRow {
    pub tool: String,
    pub mapping: String,
    pub faulty_acc: f64,
    pub latency_ms: f64,
    pub energy_mj: f64,
}

impl CompareReport {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("command", json::s("compare")),
            ("model", json::s(&self.model)),
            ("scenario", json::s(&self.scenario)),
            ("fault_rate", super::schema::f32_json(self.fault_rate)),
            (
                "rows",
                json::arr(self.rows.iter().map(|r| {
                    json::obj(vec![
                        ("tool", json::s(&r.tool)),
                        ("mapping", json::s(&r.mapping)),
                        ("faulty_acc", json::num(r.faulty_acc)),
                        ("latency_ms", json::num(r.latency_ms)),
                        ("energy_mj", json::num(r.energy_mj)),
                    ])
                })),
            ),
        ])
    }
}

/// Outcome of `afarepart info`: platform + model + cost tables.
#[derive(Clone, Debug)]
pub struct InfoReport {
    pub platform: String,
    pub device_names: Vec<String>,
    pub model: String,
    pub num_units: usize,
    pub precision: usize,
    pub faulty_bits: usize,
    pub batch: usize,
    pub clean_acc: f64,
    pub units: Vec<InfoUnit>,
}

#[derive(Clone, Debug)]
pub struct InfoUnit {
    pub name: String,
    pub kind: String,
    pub macs: u64,
    pub w_bytes: u64,
    /// Latency (ms) on each platform device, in device order.
    pub latency_ms: Vec<f64>,
    /// Energy (mJ) on each platform device, in device order.
    pub energy_mj: Vec<f64>,
}

impl InfoReport {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("command", json::s("info")),
            ("platform", json::s(&self.platform)),
            ("devices", json::arr(self.device_names.iter().map(|d| json::s(d)))),
            ("model", json::s(&self.model)),
            ("num_units", json::num(self.num_units as f64)),
            ("precision", json::num(self.precision as f64)),
            ("faulty_bits", json::num(self.faulty_bits as f64)),
            ("batch", json::num(self.batch as f64)),
            ("clean_acc", json::num(self.clean_acc)),
            (
                "units",
                json::arr(self.units.iter().map(|u| {
                    json::obj(vec![
                        ("name", json::s(&u.name)),
                        ("kind", json::s(&u.kind)),
                        ("macs", json::num(u.macs as f64)),
                        ("w_bytes", json::num(u.w_bytes as f64)),
                        ("latency_ms", json::arr(u.latency_ms.iter().map(|&x| json::num(x)))),
                        ("energy_mj", json::arr(u.energy_mj.iter().map(|&x| json::num(x)))),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parses() {
        let raw: Vec<String> = ["x", "--format", "json"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&raw, &[]);
        assert_eq!(OutputFormat::from_args(&a).unwrap(), OutputFormat::Json);
        let a = Args::parse(&["x".to_string()], &[]);
        assert_eq!(OutputFormat::from_args(&a).unwrap(), OutputFormat::Text);
        let raw: Vec<String> = ["x", "--format", "yaml"].iter().map(|s| s.to_string()).collect();
        assert!(OutputFormat::from_args(&Args::parse(&raw, &[])).is_err());
    }

    #[test]
    fn offline_report_serializes() {
        let ind = Individual {
            genome: vec![0, 1, 1],
            objectives: vec![1.5, 0.2, 0.03],
            rank: 0,
            crowding: 0.0,
        };
        let out = OfflineOutcome {
            front: vec![ind],
            deployed: Mapping(vec![0, 1, 1]),
            deployed_objectives: vec![1.5, 0.2, 0.03],
            evaluations: 100,
            cache: (80, 20, 0.8),
        };
        let r = OfflineReport::from_outcome("toy", "input+weight", 0.2, 24, 12, false, 2, &out);
        let v = r.to_json();
        assert_eq!(v.get("model").unwrap().as_str(), Some("toy"));
        assert_eq!(v.get("evaluations").unwrap().as_usize(), Some(100));
        assert_eq!(v.path(&["deployed", "mapping"]).unwrap().as_str(), Some("011"));
        // serialized text parses back
        let text = json::to_string(&v);
        assert_eq!(json::parse(&text).unwrap(), v);
    }
}
