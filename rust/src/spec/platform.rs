//! Declarative platform topology: an arbitrary device list (accelerator
//! kind + per-device fault susceptibility) plus inter-device link
//! parameters — the data that used to be hardcoded as
//! `Platform::default_two_device()` / `DeviceFaultProfile::default_two_device()`
//! at every call site.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::schema::*;
use crate::faults::DeviceFaultProfile;
use crate::hw::{Accelerator, Eyeriss, HostCpu, Link, Platform, Simba};
use crate::util::json::{self, Value};

/// A modeled accelerator kind — the single registry mapping spec names
/// to cost models and default fault susceptibility.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccelKind {
    Eyeriss,
    Simba,
    Cpu,
}

impl AccelKind {
    pub const ALL: [AccelKind; 3] = [AccelKind::Eyeriss, AccelKind::Simba, AccelKind::Cpu];

    pub fn as_str(self) -> &'static str {
        match self {
            AccelKind::Eyeriss => "eyeriss",
            AccelKind::Simba => "simba",
            AccelKind::Cpu => "cpu",
        }
    }

    pub fn parse(s: &str) -> Option<AccelKind> {
        Self::ALL.into_iter().find(|k| k.as_str() == s)
    }

    /// Construct this kind's analytical cost model.
    pub fn build_accelerator(self) -> Box<dyn Accelerator + Send + Sync> {
        match self {
            AccelKind::Eyeriss => Box::new(Eyeriss::default()),
            AccelKind::Simba => Box::new(Simba::default()),
            AccelKind::Cpu => Box::new(HostCpu::default()),
        }
    }

    /// Default fault susceptibility (weight, activation multipliers) —
    /// the values of the paper-default platforms: the voltage-scaled edge
    /// part feels the full environment rate, the packaged part a
    /// fraction, the ECC host core none.
    pub fn default_fault_mults(self) -> (f32, f32) {
        match self {
            AccelKind::Eyeriss => (1.0, 1.0),
            AccelKind::Simba => (0.15, 0.15),
            AccelKind::Cpu => (0.0, 0.0),
        }
    }

    fn known_kinds() -> String {
        Self::ALL.map(|k| k.as_str()).join(", ")
    }
}

/// One device of the platform: cost model kind, display name, and fault
/// susceptibility multipliers.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceEntry {
    pub kind: AccelKind,
    pub name: String,
    pub w_mult: f32,
    pub a_mult: f32,
}

impl DeviceEntry {
    pub fn new(kind: AccelKind) -> DeviceEntry {
        let (w_mult, a_mult) = kind.default_fault_mults();
        DeviceEntry { kind, name: kind.as_str().to_string(), w_mult, a_mult }
    }

    fn from_json(v: &Value, ctx: &str) -> Result<DeviceEntry> {
        let obj = expect_obj(v, ctx)?;
        reject_unknown(obj, &["kind", "name", "w_mult", "a_mult"], ctx)?;
        let kind_str = require_str(obj, "kind", ctx)?;
        let Some(kind) = AccelKind::parse(kind_str) else {
            bail!("{ctx}.kind: unknown accelerator kind {kind_str:?} (known: {})",
                AccelKind::known_kinds());
        };
        let mut e = DeviceEntry::new(kind);
        if let Some(name) = str_field(obj, "name", ctx)? {
            e.name = name.to_string();
        }
        if let Some(x) = f32_field(obj, "w_mult", ctx)? {
            e.w_mult = x;
        }
        if let Some(x) = f32_field(obj, "a_mult", ctx)? {
            e.a_mult = x;
        }
        Ok(e)
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("kind", json::s(self.kind.as_str())),
            ("name", json::s(&self.name)),
            ("w_mult", f32_json(self.w_mult)),
            ("a_mult", f32_json(self.a_mult)),
        ])
    }
}

/// Inter-device link parameters (see `crate::hw::Link`).
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSpec {
    pub bandwidth_gbps: f64,
    pub setup_us: f64,
    pub e_pj_byte: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        let l = Link::default();
        LinkSpec { bandwidth_gbps: l.bandwidth_gbps, setup_us: l.setup_us, e_pj_byte: l.e_pj_byte }
    }
}

impl LinkSpec {
    fn apply_json(&mut self, v: &Value, ctx: &str) -> Result<()> {
        let obj = expect_obj(v, ctx)?;
        reject_unknown(obj, &["bandwidth_gbps", "setup_us", "e_pj_byte"], ctx)?;
        if let Some(x) = f64_field(obj, "bandwidth_gbps", ctx)? {
            self.bandwidth_gbps = x;
        }
        if let Some(x) = f64_field(obj, "setup_us", ctx)? {
            self.setup_us = x;
        }
        if let Some(x) = f64_field(obj, "e_pj_byte", ctx)? {
            self.e_pj_byte = x;
        }
        Ok(())
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("bandwidth_gbps", json::num(self.bandwidth_gbps)),
            ("setup_us", json::num(self.setup_us)),
            ("e_pj_byte", json::num(self.e_pj_byte)),
        ])
    }

    pub fn build(&self) -> Link {
        Link {
            bandwidth_gbps: self.bandwidth_gbps,
            setup_us: self.setup_us,
            e_pj_byte: self.e_pj_byte,
        }
    }
}

/// The declarative platform: device list + link.
#[derive(Clone, Debug, PartialEq)]
pub struct PlatformSpec {
    pub devices: Vec<DeviceEntry>,
    pub link: LinkSpec,
}

impl Default for PlatformSpec {
    /// The paper's two-device platform: Eyeriss (fault-prone) + SIMBA
    /// (shielded) — bit-identical cost tables and fault profiles to the
    /// legacy `default_two_device()` constructors.
    fn default() -> Self {
        PlatformSpec {
            devices: vec![DeviceEntry::new(AccelKind::Eyeriss), DeviceEntry::new(AccelKind::Simba)],
            link: LinkSpec::default(),
        }
    }
}

impl PlatformSpec {
    /// The extended three-device platform (+ ECC host core).
    pub fn three_device() -> PlatformSpec {
        let mut p = PlatformSpec::default();
        p.devices.push(DeviceEntry::new(AccelKind::Cpu));
        p
    }

    pub(crate) fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(obj, &["devices", "link"], ctx)?;
        if let Some(v) = obj.get("devices") {
            let arr = expect_arr(v, &format!("{ctx}.devices"))?;
            if arr.len() < 2 {
                bail!("{ctx}.devices: a platform needs at least 2 devices, got {}", arr.len());
            }
            self.devices = arr
                .iter()
                .enumerate()
                .map(|(i, d)| DeviceEntry::from_json(d, &format!("{ctx}.devices[{i}]")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = obj.get("link") {
            self.link.apply_json(v, &format!("{ctx}.link"))?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("devices", json::arr(self.devices.iter().map(DeviceEntry::to_json))),
            ("link", self.link.to_json()),
        ])
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Materialize the cost models + fault profiles this spec describes.
    pub fn build(&self) -> (Platform, Vec<DeviceFaultProfile>) {
        let devices = self.devices.iter().map(|e| e.kind.build_accelerator()).collect();
        let profiles = self
            .devices
            .iter()
            .map(|e| DeviceFaultProfile::new(&e.name, e.w_mult, e.a_mult))
            .collect();
        (Platform { devices, link: self.link.build() }, profiles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_legacy_two_device() {
        let (platform, profiles) = PlatformSpec::default().build();
        let legacy = Platform::default_two_device();
        assert_eq!(platform.num_devices(), legacy.num_devices());
        let legacy_profiles = DeviceFaultProfile::default_two_device();
        for (p, l) in profiles.iter().zip(&legacy_profiles) {
            assert_eq!(p.device, l.device);
            assert_eq!(p.w_mult, l.w_mult);
            assert_eq!(p.a_mult, l.a_mult);
        }
        assert_eq!(platform.link.bandwidth_gbps, legacy.link.bandwidth_gbps);
        assert_eq!(platform.link.setup_us, legacy.link.setup_us);
        assert_eq!(platform.link.e_pj_byte, legacy.link.e_pj_byte);
    }

    #[test]
    fn unknown_device_key_rejected() {
        let mut spec = PlatformSpec::default();
        let v = crate::util::json::parse(
            r#"{"devices": [{"kind": "eyeriss", "wmult": 2.0}, {"kind": "simba"}]}"#,
        )
        .unwrap();
        let err = spec.apply_json(v.as_obj().unwrap(), "platform").unwrap_err();
        assert!(format!("{err}").contains("wmult"), "{err}");
    }

    #[test]
    fn single_device_platform_rejected() {
        let mut spec = PlatformSpec::default();
        let v = crate::util::json::parse(r#"{"devices": [{"kind": "eyeriss"}]}"#).unwrap();
        assert!(spec.apply_json(v.as_obj().unwrap(), "platform").is_err());
    }

    #[test]
    fn custom_three_device_builds() {
        let mut spec = PlatformSpec::default();
        let v = crate::util::json::parse(
            r#"{"devices": [
                {"kind": "eyeriss", "w_mult": 0.8},
                {"kind": "simba", "name": "package0"},
                {"kind": "cpu"}
            ]}"#,
        )
        .unwrap();
        spec.apply_json(v.as_obj().unwrap(), "platform").unwrap();
        let (platform, profiles) = spec.build();
        assert_eq!(platform.num_devices(), 3);
        assert_eq!(profiles[0].w_mult, 0.8);
        assert_eq!(profiles[1].device, "package0");
        assert_eq!(profiles[2].w_mult, 0.0); // ECC host core default
    }
}
