//! Declarative fault environment: base rate, fault scenario, and a
//! composable per-device drift stack (step + sinusoid + decay components
//! may target the same device simultaneously — paper §III-A's threat
//! model as data instead of a hardcoded `StepAttack` in `cmd_online`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::schema::*;
use crate::faults::{DeviceFaultProfile, DriftComponent, DriftWave, FaultEnv, FaultScenario};
use crate::util::json::{self, Value};

pub(crate) fn drift_component_from_json(v: &Value, ctx: &str) -> Result<DriftComponent> {
    let obj = expect_obj(v, ctx)?;
    let kind = require_str(obj, "kind", ctx)?;
    let device = match usize_field(obj, "device", ctx)? {
        Some(d) => d,
        None => bail!("{ctx}: missing required key \"device\""),
    };
    let required = |obj: &BTreeMap<String, Value>, key: &str| -> Result<f64> {
        match f64_field(obj, key, ctx)? {
            Some(x) => Ok(x),
            None => bail!("{ctx}: drift kind {kind:?} requires key {key:?}"),
        }
    };
    let wave = match kind {
        "step" => {
            reject_unknown(obj, &["kind", "device", "at_s", "factor"], ctx)?;
            DriftWave::Step { at_s: required(obj, "at_s")?, factor: required(obj, "factor")? as f32 }
        }
        "sinusoid" => {
            reject_unknown(obj, &["kind", "device", "period_s", "amp"], ctx)?;
            DriftWave::Sinusoid {
                period_s: required(obj, "period_s")?,
                amp: required(obj, "amp")? as f32,
            }
        }
        "decay" => {
            reject_unknown(obj, &["kind", "device", "factor", "tau_s"], ctx)?;
            DriftWave::Decay {
                factor: required(obj, "factor")? as f32,
                tau_s: required(obj, "tau_s")?,
            }
        }
        other => bail!("{ctx}.kind: unknown drift kind {other:?} (known: step, sinusoid, decay)"),
    };
    Ok(DriftComponent { device, wave })
}

pub(crate) fn drift_component_to_json(c: &DriftComponent) -> Value {
    match &c.wave {
        DriftWave::Step { at_s, factor } => json::obj(vec![
            ("kind", json::s("step")),
            ("device", json::num(c.device as f64)),
            ("at_s", json::num(*at_s)),
            ("factor", f32_json(*factor)),
        ]),
        DriftWave::Sinusoid { period_s, amp } => json::obj(vec![
            ("kind", json::s("sinusoid")),
            ("device", json::num(c.device as f64)),
            ("period_s", json::num(*period_s)),
            ("amp", f32_json(*amp)),
        ]),
        DriftWave::Decay { factor, tau_s } => json::obj(vec![
            ("kind", json::s("decay")),
            ("device", json::num(c.device as f64)),
            ("factor", f32_json(*factor)),
            ("tau_s", json::num(*tau_s)),
        ]),
    }
}

pub(crate) fn drift_list_from_json(v: &Value, ctx: &str) -> Result<Vec<DriftComponent>> {
    expect_arr(v, ctx)?
        .iter()
        .enumerate()
        .map(|(i, c)| drift_component_from_json(c, &format!("{ctx}[{i}]")))
        .collect()
}

/// The declarative fault environment.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEnvSpec {
    /// Environment fault rate FR (paper: 0.10–0.40).
    pub fault_rate: f32,
    /// Which fault domains are active (Table II columns).
    pub scenario: FaultScenario,
    /// Composable drift stack for the online phase (empty = static env).
    pub drift: Vec<DriftComponent>,
}

impl Default for FaultEnvSpec {
    /// FR 0.20, input+weight, and the demo EM step attack on device 0 at
    /// t = 30 s — exactly what `cmd_online` used to hardcode. Offline
    /// runs sample the environment at t = 0, where the step has not fired
    /// yet, so the default offline behaviour is unchanged.
    fn default() -> Self {
        FaultEnvSpec {
            fault_rate: 0.20,
            scenario: FaultScenario::InputWeight,
            drift: vec![DriftComponent::step(0, 30.0, 2.0)],
        }
    }
}

impl FaultEnvSpec {
    pub(crate) fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(obj, &["fault_rate", "scenario", "drift"], ctx)?;
        if let Some(x) = f32_field(obj, "fault_rate", ctx)? {
            self.fault_rate = x;
        }
        if let Some(s) = str_field(obj, "scenario", ctx)? {
            self.scenario = match FaultScenario::parse(s) {
                Some(sc) => sc,
                None => bail!("{ctx}.scenario: unknown scenario {s:?} (w, a, iw)"),
            };
        }
        if let Some(v) = obj.get("drift") {
            self.drift = drift_list_from_json(v, &format!("{ctx}.drift"))?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("fault_rate", f32_json(self.fault_rate)),
            ("scenario", json::s(self.scenario.label())),
            ("drift", json::arr(self.drift.iter().map(drift_component_to_json))),
        ])
    }

    /// Materialize the time-varying environment over `profiles`. Drift
    /// components referencing devices beyond the platform are rejected.
    pub fn build(&self, profiles: Vec<DeviceFaultProfile>) -> Result<FaultEnv> {
        for c in &self.drift {
            if c.device >= profiles.len() {
                bail!(
                    "fault_env.drift: component targets device {} but the platform has {} devices",
                    c.device,
                    profiles.len()
                );
            }
        }
        Ok(FaultEnv { base_rate: self.fault_rate, profiles, drift: self.drift.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_demo_step_attack() {
        let spec = FaultEnvSpec::default();
        assert_eq!(spec.drift, vec![DriftComponent::step(0, 30.0, 2.0)]);
        let env = spec.build(DeviceFaultProfile::default_two_device()).unwrap();
        // offline samples t=0: step not fired, rates are the static ones
        assert!((env.dev_w_rates(0.0)[0] - 0.2).abs() < 1e-6);
        assert!((env.dev_w_rates(31.0)[0] - 0.4).abs() < 1e-6);
    }

    #[test]
    fn stacked_drift_parses() {
        let mut spec = FaultEnvSpec::default();
        let v = crate::util::json::parse(
            r#"{"fault_rate": 0.3, "scenario": "weight-only", "drift": [
                {"kind": "step", "device": 0, "at_s": 10.0, "factor": 2.0},
                {"kind": "sinusoid", "device": 0, "period_s": 8.0, "amp": 0.25},
                {"kind": "decay", "device": 1, "factor": 4.0, "tau_s": 12.0}
            ]}"#,
        )
        .unwrap();
        spec.apply_json(v.as_obj().unwrap(), "fault_env").unwrap();
        assert_eq!(spec.drift.len(), 3);
        assert_eq!(spec.scenario, FaultScenario::WeightOnly);
        assert_eq!(spec.drift[1], DriftComponent::sinusoid(0, 8.0, 0.25));
    }

    #[test]
    fn wrong_wave_key_rejected() {
        let v = crate::util::json::parse(
            r#"{"kind": "step", "device": 0, "at_s": 1.0, "factor": 2.0, "period_s": 4.0}"#,
        )
        .unwrap();
        assert!(drift_component_from_json(&v, "d").is_err());
    }

    #[test]
    fn out_of_range_device_rejected_at_build() {
        let spec = FaultEnvSpec {
            drift: vec![DriftComponent::step(5, 1.0, 2.0)],
            ..Default::default()
        };
        assert!(spec.build(DeviceFaultProfile::default_two_device()).is_err());
    }
}
