//! Declarative online-monitor settings: everything `cmd_online` used to
//! hardcode (the stray `--ticks` arg, the re-optimization budget and
//! seed) is spec data, so an online run is fully reproducible from one
//! file.

use std::collections::BTreeMap;

use anyhow::Result;

use super::schema::*;
use crate::coordinator::OnlineConfig;
use crate::nsga2::Nsga2Config;
use crate::util::json::{self, Value};

/// Online phase settings (paper Algorithm 1, lines 13–19).
#[derive(Clone, Debug, PartialEq)]
pub struct OnlineSpec {
    /// Accuracy-drop threshold θ that triggers repartitioning.
    pub theta: f64,
    /// Rolling monitor window (batches).
    pub window: usize,
    /// Simulated seconds per served batch.
    pub tick_seconds: f64,
    /// Number of canary batches to serve.
    pub ticks: usize,
    /// NSGA-II re-optimization budget (smaller than offline) and seed.
    pub reopt_pop: usize,
    pub reopt_gens: usize,
    pub reopt_seed: u64,
    /// Budget factors for P' selection during an attack.
    pub lat_budget: f64,
    pub energy_budget: f64,
    /// Cooldown (ticks) after a reconfiguration.
    pub cooldown: usize,
    /// Seed for the canary PRNG and re-optimization.
    pub seed: u64,
    /// Canary pipeline depth through the inference server (0 = derive
    /// from `eval_threads`; 1 = strictly one batch in flight, the
    /// pre-pipelined serving loop). The timeline is bitwise identical at
    /// any depth — see `coordinator::online`.
    pub lookahead: usize,
    /// Reply deadline per inference attempt (ms); 0 waits forever.
    pub recv_timeout_ms: u64,
    /// Retries per canary batch before its failure becomes terminal.
    pub max_retries: usize,
    /// Base retry backoff (ms), doubled per attempt.
    pub backoff_ms: u64,
    /// Ticks on the safe mapping after a terminal failure before the
    /// degraded configuration is re-admitted.
    pub health_cooldown: usize,
}

impl Default for OnlineSpec {
    fn default() -> Self {
        let c = OnlineConfig::default();
        OnlineSpec {
            theta: c.theta,
            window: c.window,
            tick_seconds: c.tick_seconds,
            ticks: c.ticks,
            reopt_pop: c.reopt.pop_size,
            reopt_gens: c.reopt.generations,
            reopt_seed: c.reopt.seed,
            lat_budget: c.lat_budget,
            energy_budget: c.energy_budget,
            cooldown: c.cooldown,
            seed: c.seed,
            lookahead: 0,
            recv_timeout_ms: c.recv_timeout_ms,
            max_retries: c.max_retries,
            backoff_ms: c.backoff_ms,
            health_cooldown: c.health_cooldown,
        }
    }
}

impl OnlineSpec {
    pub(crate) fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(
            obj,
            &[
                "theta",
                "window",
                "tick_seconds",
                "ticks",
                "reopt_pop",
                "reopt_gens",
                "reopt_seed",
                "lat_budget",
                "energy_budget",
                "cooldown",
                "seed",
                "lookahead",
                "recv_timeout_ms",
                "max_retries",
                "backoff_ms",
                "health_cooldown",
            ],
            ctx,
        )?;
        if let Some(x) = f64_field(obj, "theta", ctx)? {
            self.theta = x;
        }
        if let Some(x) = usize_field(obj, "window", ctx)? {
            self.window = x;
        }
        if let Some(x) = f64_field(obj, "tick_seconds", ctx)? {
            self.tick_seconds = x;
        }
        if let Some(x) = usize_field(obj, "ticks", ctx)? {
            self.ticks = x;
        }
        if let Some(x) = usize_field(obj, "reopt_pop", ctx)? {
            self.reopt_pop = x;
        }
        if let Some(x) = usize_field(obj, "reopt_gens", ctx)? {
            self.reopt_gens = x;
        }
        if let Some(x) = u64_field(obj, "reopt_seed", ctx)? {
            self.reopt_seed = x;
        }
        if let Some(x) = f64_field(obj, "lat_budget", ctx)? {
            self.lat_budget = x;
        }
        if let Some(x) = f64_field(obj, "energy_budget", ctx)? {
            self.energy_budget = x;
        }
        if let Some(x) = usize_field(obj, "cooldown", ctx)? {
            self.cooldown = x;
        }
        if let Some(x) = u64_field(obj, "seed", ctx)? {
            self.seed = x;
        }
        if let Some(x) = usize_field(obj, "lookahead", ctx)? {
            self.lookahead = x;
        }
        if let Some(x) = u64_field(obj, "recv_timeout_ms", ctx)? {
            self.recv_timeout_ms = x;
        }
        if let Some(x) = usize_field(obj, "max_retries", ctx)? {
            self.max_retries = x;
        }
        if let Some(x) = u64_field(obj, "backoff_ms", ctx)? {
            self.backoff_ms = x;
        }
        if let Some(x) = usize_field(obj, "health_cooldown", ctx)? {
            self.health_cooldown = x;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("theta", json::num(self.theta)),
            ("window", json::num(self.window as f64)),
            ("tick_seconds", json::num(self.tick_seconds)),
            ("ticks", json::num(self.ticks as f64)),
            ("reopt_pop", json::num(self.reopt_pop as f64)),
            ("reopt_gens", json::num(self.reopt_gens as f64)),
            ("reopt_seed", json::num(self.reopt_seed as f64)),
            ("lat_budget", json::num(self.lat_budget)),
            ("energy_budget", json::num(self.energy_budget)),
            ("cooldown", json::num(self.cooldown as f64)),
            ("seed", json::num(self.seed as f64)),
            ("lookahead", json::num(self.lookahead as f64)),
            ("recv_timeout_ms", json::num(self.recv_timeout_ms as f64)),
            ("max_retries", json::num(self.max_retries as f64)),
            ("backoff_ms", json::num(self.backoff_ms as f64)),
            ("health_cooldown", json::num(self.health_cooldown as f64)),
        ])
    }

    /// Materialize the runner config. `resolved_eval_threads` fills the
    /// `lookahead = 0` auto setting (one in-flight canary batch per ΔAcc
    /// worker keeps the serving thread fed without unbounded speculation).
    pub fn to_online_config(&self, resolved_eval_threads: usize) -> OnlineConfig {
        OnlineConfig {
            theta: self.theta,
            window: self.window,
            tick_seconds: self.tick_seconds,
            ticks: self.ticks,
            reopt: Nsga2Config {
                pop_size: self.reopt_pop,
                generations: self.reopt_gens,
                seed: self.reopt_seed,
                ..Default::default()
            },
            lat_budget: self.lat_budget,
            energy_budget: self.energy_budget,
            cooldown: self.cooldown,
            seed: self.seed,
            lookahead: if self.lookahead == 0 {
                resolved_eval_threads.max(1)
            } else {
                self.lookahead
            },
            recv_timeout_ms: self.recv_timeout_ms,
            max_retries: self.max_retries,
            backoff_ms: self.backoff_ms,
            health_cooldown: self.health_cooldown,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_mirror_online_config() {
        let spec = OnlineSpec::default();
        let legacy = OnlineConfig::default();
        let cfg = spec.to_online_config(1);
        assert_eq!(cfg.theta, legacy.theta);
        assert_eq!(cfg.window, legacy.window);
        assert_eq!(cfg.ticks, legacy.ticks);
        assert_eq!(cfg.reopt.pop_size, legacy.reopt.pop_size);
        assert_eq!(cfg.reopt.generations, legacy.reopt.generations);
        assert_eq!(cfg.reopt.seed, legacy.reopt.seed);
        assert_eq!(cfg.cooldown, legacy.cooldown);
        assert_eq!(cfg.lookahead, 1);
        assert_eq!(cfg.recv_timeout_ms, legacy.recv_timeout_ms);
        assert_eq!(cfg.max_retries, legacy.max_retries);
        assert_eq!(cfg.backoff_ms, legacy.backoff_ms);
        assert_eq!(cfg.health_cooldown, legacy.health_cooldown);
    }

    #[test]
    fn supervision_keys_parse() {
        let mut spec = OnlineSpec::default();
        let v = crate::util::json::parse(
            r#"{"recv_timeout_ms": 250, "max_retries": 5, "backoff_ms": 2, "health_cooldown": 4}"#,
        )
        .unwrap();
        spec.apply_json(v.as_obj().unwrap(), "online").unwrap();
        assert_eq!(spec.recv_timeout_ms, 250);
        assert_eq!(spec.max_retries, 5);
        assert_eq!(spec.backoff_ms, 2);
        assert_eq!(spec.health_cooldown, 4);
        let cfg = spec.to_online_config(1);
        assert_eq!(cfg.supervisor_policy().recv_timeout_ms, 250);
    }

    #[test]
    fn lookahead_auto_follows_eval_threads() {
        let spec = OnlineSpec::default();
        assert_eq!(spec.to_online_config(4).lookahead, 4);
        let pinned = OnlineSpec { lookahead: 2, ..Default::default() };
        assert_eq!(pinned.to_online_config(8).lookahead, 2);
    }
}
