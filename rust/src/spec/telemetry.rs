//! The declarative `spec.telemetry` section: observability switches.
//!
//! Off by default — a disabled section materializes
//! [`Telemetry::disabled`], every instrumentation site early-outs, and
//! all run outputs stay bitwise identical to a build without the
//! subsystem. `--trace <file>` implies `enabled`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use super::schema::*;
use crate::obs::Telemetry;
use crate::util::json::{self, Value};

/// The declarative telemetry section.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetrySpec {
    /// Master switch: collect registry metrics and fold a Prometheus
    /// snapshot into the run's JSON report.
    pub enabled: bool,
    /// JSONL event-trace path (`--trace <file>`); `None` disables the
    /// trace exporter. Setting a path implies `enabled`.
    pub trace: Option<String>,
    /// Fixed hypervolume reference point for convergence analytics
    /// (one value per objective: latency_ms, energy_mj, dacc). `None`
    /// freezes a reference from the initial population instead; a
    /// spec-declared point makes hypervolume comparable across runs.
    pub hv_reference: Option<Vec<f64>>,
}

impl TelemetrySpec {
    pub(crate) fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(obj, &["enabled", "trace", "hv_reference"], ctx)?;
        if let Some(b) = bool_field(obj, "enabled", ctx)? {
            self.enabled = b;
        }
        match obj.get("trace") {
            None => {}
            Some(Value::Null) => self.trace = None,
            Some(Value::Str(p)) => {
                self.trace = Some(p.clone());
                self.enabled = true;
            }
            Some(_) => bail!("{ctx}.trace: expected a string path or null"),
        }
        match obj.get("hv_reference") {
            None => {}
            Some(Value::Null) => self.hv_reference = None,
            Some(v) => {
                let arr = expect_arr(v, &format!("{ctx}.hv_reference"))?;
                let mut point = Vec::with_capacity(arr.len());
                for (i, x) in arr.iter().enumerate() {
                    match x.as_f64() {
                        Some(f) if f.is_finite() => point.push(f),
                        _ => bail!("{ctx}.hv_reference[{i}]: expected a finite number"),
                    }
                }
                if point.is_empty() {
                    bail!("{ctx}.hv_reference: expected at least one objective bound");
                }
                self.hv_reference = Some(point);
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("enabled", Value::Bool(self.enabled)),
            ("trace", match &self.trace {
                Some(p) => json::s(p),
                None => Value::Null,
            }),
            ("hv_reference", match &self.hv_reference {
                Some(point) => json::arr(point.iter().map(|x| json::num(*x))),
                None => Value::Null,
            }),
        ])
    }

    /// Materialize the run's telemetry handle; a disabled section is a
    /// no-op handle.
    pub fn build(&self) -> Result<Telemetry> {
        match (&self.trace, self.enabled) {
            (Some(p), _) => Telemetry::with_trace(Path::new(p)),
            (None, true) => Ok(Telemetry::enabled()),
            (None, false) => Ok(Telemetry::disabled()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_off_and_builds_a_noop_handle() {
        let spec = TelemetrySpec::default();
        assert!(!spec.enabled);
        assert!(spec.trace.is_none());
        let t = spec.build().unwrap();
        assert!(!t.is_enabled());
        assert!(!t.has_trace());
    }

    #[test]
    fn trace_path_implies_enabled() {
        let mut spec = TelemetrySpec::default();
        let v = crate::util::json::parse(r#"{"trace": "/tmp/run.jsonl"}"#).unwrap();
        spec.apply_json(v.as_obj().unwrap(), "telemetry").unwrap();
        assert!(spec.enabled);
        assert_eq!(spec.trace.as_deref(), Some("/tmp/run.jsonl"));
    }

    #[test]
    fn round_trips_through_json() {
        for spec in [
            TelemetrySpec::default(),
            TelemetrySpec { enabled: true, ..Default::default() },
            TelemetrySpec { enabled: true, trace: Some("t.jsonl".into()), ..Default::default() },
            TelemetrySpec {
                enabled: true,
                hv_reference: Some(vec![250.0, 90.0, 0.2]),
                ..Default::default()
            },
        ] {
            let v = spec.to_json();
            let mut back = TelemetrySpec::default();
            back.apply_json(v.as_obj().unwrap(), "telemetry").unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn unknown_key_and_bad_trace_rejected() {
        let mut spec = TelemetrySpec::default();
        let v = crate::util::json::parse(r#"{"enable": true}"#).unwrap();
        assert!(spec.apply_json(v.as_obj().unwrap(), "telemetry").is_err());
        let v = crate::util::json::parse(r#"{"trace": 7}"#).unwrap();
        assert!(spec.apply_json(v.as_obj().unwrap(), "telemetry").is_err());
    }

    #[test]
    fn hv_reference_parses_and_rejects_bad_points() {
        let mut spec = TelemetrySpec::default();
        let v = crate::util::json::parse(r#"{"hv_reference": [250.0, 90, 0.2]}"#).unwrap();
        spec.apply_json(v.as_obj().unwrap(), "telemetry").unwrap();
        assert_eq!(spec.hv_reference, Some(vec![250.0, 90.0, 0.2]));
        // declaring a reference does not flip the master switch
        assert!(!spec.enabled);

        let v = crate::util::json::parse(r#"{"hv_reference": null}"#).unwrap();
        spec.apply_json(v.as_obj().unwrap(), "telemetry").unwrap();
        assert_eq!(spec.hv_reference, None);

        for bad in [
            r#"{"hv_reference": []}"#,
            r#"{"hv_reference": 3.0}"#,
            r#"{"hv_reference": [1.0, "x"]}"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert!(spec.apply_json(v.as_obj().unwrap(), "telemetry").is_err(), "{bad}");
        }
    }

    #[test]
    fn enabled_without_trace_builds_registry_only() {
        let spec = TelemetrySpec { enabled: true, ..Default::default() };
        let t = spec.build().unwrap();
        assert!(t.is_enabled());
        assert!(!t.has_trace());
    }
}
