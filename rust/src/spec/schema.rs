//! Strict-schema helpers shared by every spec sub-parser.
//!
//! The declarative API rejects unknown keys at *every* nesting level — a
//! typo'd key is a hard error naming the offending key and the allowed
//! set, never a silently ignored override. All field getters type-check
//! and report the full `section.key` path.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Value;

pub(crate) fn expect_obj<'a>(v: &'a Value, ctx: &str) -> Result<&'a BTreeMap<String, Value>> {
    match v.as_obj() {
        Some(o) => Ok(o),
        None => bail!("{ctx}: expected an object"),
    }
}

pub(crate) fn expect_arr<'a>(v: &'a Value, ctx: &str) -> Result<&'a [Value]> {
    match v.as_arr() {
        Some(a) => Ok(a),
        None => bail!("{ctx}: expected an array"),
    }
}

/// Reject any key not in `allowed` (strict unknown-key policy).
pub(crate) fn reject_unknown(
    obj: &BTreeMap<String, Value>,
    allowed: &[&str],
    ctx: &str,
) -> Result<()> {
    for k in obj.keys() {
        if !allowed.contains(&k.as_str()) {
            bail!("{ctx}: unknown key {k:?} (allowed: {})", allowed.join(", "));
        }
    }
    Ok(())
}

pub(crate) fn f64_field(
    obj: &BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<Option<f64>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => match v.as_f64() {
            Some(x) => Ok(Some(x)),
            None => bail!("{ctx}.{key}: expected a number"),
        },
    }
}

pub(crate) fn f32_field(
    obj: &BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<Option<f32>> {
    Ok(f64_field(obj, key, ctx)?.map(|x| x as f32))
}

/// Largest integer exactly representable in the f64-backed JSON parser
/// (2^53) — also the acceptance bound for integer fields, so a stray
/// `1e30` is a hard error instead of an `as`-cast saturating to MAX.
const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0;

pub(crate) fn usize_field(
    obj: &BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<Option<usize>> {
    match f64_field(obj, key, ctx)? {
        None => Ok(None),
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= MAX_SAFE_INT => Ok(Some(x as usize)),
        Some(x) => bail!("{ctx}.{key}: expected a non-negative integer (≤ 2^53), got {x}"),
    }
}

pub(crate) fn u64_field(
    obj: &BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<Option<u64>> {
    match f64_field(obj, key, ctx)? {
        None => Ok(None),
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= MAX_SAFE_INT => Ok(Some(x as u64)),
        Some(x) => bail!("{ctx}.{key}: expected a non-negative integer (≤ 2^53), got {x}"),
    }
}

pub(crate) fn bool_field(
    obj: &BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<Option<bool>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => match v.as_bool() {
            Some(b) => Ok(Some(b)),
            None => bail!("{ctx}.{key}: expected a boolean"),
        },
    }
}

pub(crate) fn str_field<'a>(
    obj: &'a BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<Option<&'a str>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => match v.as_str() {
            Some(s) => Ok(Some(s)),
            None => bail!("{ctx}.{key}: expected a string"),
        },
    }
}

pub(crate) fn require_str<'a>(
    obj: &'a BTreeMap<String, Value>,
    key: &str,
    ctx: &str,
) -> Result<&'a str> {
    match str_field(obj, key, ctx)? {
        Some(s) => Ok(s),
        None => bail!("{ctx}: missing required key {key:?}"),
    }
}

/// Serialize an `f32` through its shortest decimal representation so the
/// emitted JSON reads `0.15`, not `0.15000000596046448`, and survives
/// parse → serialize → parse unchanged.
pub(crate) fn f32_json(x: f32) -> Value {
    Value::Num(format!("{x}").parse::<f64>().expect("f32 display always reparses"))
}
