//! Campaign runner: expand a spec grid (models × fault-rates × scenarios
//! × drift schedules) and drive every cell's offline optimization through
//! the batched evaluation engine (PR 1), emitting one consolidated JSON
//! report.
//!
//! Model names of the form `synthetic-L<n>` use the artifact-free
//! fixtures of `bench::suite` (an `n`-unit manifest + sensitivity table
//! with the exact-cost-shaped `SyntheticExact` ΔAcc backend), so
//! campaigns run end-to-end without PJRT artifacts — the integration
//! tests and CI exercise a 3-model × 2-scenario campaign this way. Real
//! model names load artifacts exactly like `afarepart offline`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::outcome::OfflineReport;
use super::schema::*;
use super::ExperimentSpec;
use crate::bench::suite::{synthetic_manifest, synthetic_sensitivity, synthetic_units};
use crate::experiment::Experiment;
use crate::faults::{DriftComponent, FaultEnv, FaultScenario};
use crate::partition::{DaccMode, EngineConfig, PartitionEvaluator};
use crate::util::json::{self, Value};

/// One drift schedule of the campaign grid: a named component stack plus
/// the probe time at which cells under this schedule sample the
/// environment (a step attack evaluated at `eval_at_s` past its onset
/// sees the attacked rates; at 0 it sees ambient).
#[derive(Clone, Debug, PartialEq)]
pub struct DriftCell {
    pub name: String,
    pub components: Vec<DriftComponent>,
    pub eval_at_s: f64,
}

impl DriftCell {
    pub fn ambient() -> DriftCell {
        DriftCell { name: "ambient".into(), components: Vec::new(), eval_at_s: 0.0 }
    }

    fn from_json(v: &Value, ctx: &str) -> Result<DriftCell> {
        let obj = expect_obj(v, ctx)?;
        reject_unknown(obj, &["name", "components", "eval_at_s"], ctx)?;
        let name = require_str(obj, "name", ctx)?.to_string();
        let components = match obj.get("components") {
            Some(v) => super::faultenv::drift_list_from_json(v, &format!("{ctx}.components"))?,
            None => Vec::new(),
        };
        let eval_at_s = f64_field(obj, "eval_at_s", ctx)?.unwrap_or(0.0);
        Ok(DriftCell { name, components, eval_at_s })
    }
}

/// A declarative experiment grid over one base spec.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    pub base: ExperimentSpec,
    pub models: Vec<String>,
    pub fault_rates: Vec<f32>,
    pub scenarios: Vec<FaultScenario>,
    pub drifts: Vec<DriftCell>,
}

impl CampaignSpec {
    /// A 1×1×1×1 campaign over the base spec (each grid axis defaults to
    /// the base spec's value — including its drift stack, probed at
    /// t = 0 like the offline phase).
    pub fn singleton(base: ExperimentSpec) -> CampaignSpec {
        let drifts = if base.fault_env.drift.is_empty() {
            vec![DriftCell::ambient()]
        } else {
            vec![DriftCell {
                name: "base".into(),
                components: base.fault_env.drift.clone(),
                eval_at_s: 0.0,
            }]
        };
        CampaignSpec {
            models: vec![base.model.clone()],
            fault_rates: vec![base.fault_env.fault_rate],
            scenarios: vec![base.fault_env.scenario],
            drifts,
            base,
        }
    }

    /// Parse a campaign document: `{"base": {...}, "grid": {...}}`,
    /// strict at every level.
    pub fn from_json_str(text: &str) -> Result<CampaignSpec> {
        Self::from_json_str_with(text, |_| Ok(()))
    }

    /// Like [`CampaignSpec::from_json_str`], with a `customize` hook run
    /// over the base spec *after* the file's `base` section but *before*
    /// the grid axes default from it — this is where the CLI applies its
    /// env/flag overrides, so `--fault-rate 0.4` reaches every cell of a
    /// campaign whose grid leaves `fault_rates` implicit. Axes the file
    /// sets explicitly are grid data and are not overridden.
    pub fn from_json_str_with(
        text: &str,
        customize: impl FnOnce(&mut ExperimentSpec) -> Result<()>,
    ) -> Result<CampaignSpec> {
        let v = json::parse(text).context("campaign: invalid json")?;
        let obj = expect_obj(&v, "campaign")?;
        reject_unknown(obj, &["base", "grid"], "campaign")?;
        let mut base = ExperimentSpec::default();
        if let Some(b) = obj.get("base") {
            base.apply_json(b).context("campaign.base")?;
        }
        customize(&mut base)?;
        let mut spec = CampaignSpec::singleton(base);
        if let Some(g) = obj.get("grid") {
            let grid = expect_obj(g, "campaign.grid")?;
            reject_unknown(grid, &["models", "fault_rates", "scenarios", "drifts"], "campaign.grid")?;
            if let Some(v) = grid.get("models") {
                spec.models = expect_arr(v, "campaign.grid.models")?
                    .iter()
                    .map(|m| match m.as_str() {
                        Some(s) => Ok(s.to_string()),
                        None => bail!("campaign.grid.models: expected strings"),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = grid.get("fault_rates") {
                spec.fault_rates = expect_arr(v, "campaign.grid.fault_rates")?
                    .iter()
                    .map(|r| match r.as_f64() {
                        Some(x) => Ok(x as f32),
                        None => bail!("campaign.grid.fault_rates: expected numbers"),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = grid.get("scenarios") {
                spec.scenarios = expect_arr(v, "campaign.grid.scenarios")?
                    .iter()
                    .map(|s| match s.as_str().and_then(FaultScenario::parse) {
                        Some(sc) => Ok(sc),
                        None => bail!("campaign.grid.scenarios: expected scenario names (w, a, iw)"),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = grid.get("drifts") {
                spec.drifts = expect_arr(v, "campaign.grid.drifts")?
                    .iter()
                    .enumerate()
                    .map(|(i, d)| DriftCell::from_json(d, &format!("campaign.grid.drifts[{i}]")))
                    .collect::<Result<Vec<_>>>()?;
            }
        }
        if spec.models.is_empty()
            || spec.fault_rates.is_empty()
            || spec.scenarios.is_empty()
            || spec.drifts.is_empty()
        {
            bail!("campaign.grid: every axis needs at least one entry");
        }
        Ok(spec)
    }

    pub fn from_file(path: &std::path::Path) -> Result<CampaignSpec> {
        Self::from_file_with(path, |_| Ok(()))
    }

    /// [`CampaignSpec::from_file`] with the base-spec `customize` hook of
    /// [`CampaignSpec::from_json_str_with`].
    pub fn from_file_with(
        path: &std::path::Path,
        customize: impl FnOnce(&mut ExperimentSpec) -> Result<()>,
    ) -> Result<CampaignSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading campaign spec {}", path.display()))?;
        Self::from_json_str_with(&text, customize)
            .with_context(|| format!("campaign spec {}", path.display()))
    }

    /// Total number of grid cells.
    pub fn num_cells(&self) -> usize {
        self.models.len() * self.fault_rates.len() * self.scenarios.len() * self.drifts.len()
    }

    /// Expand the grid in deterministic order:
    /// models ▷ fault_rates ▷ scenarios ▷ drifts.
    pub fn expand(&self) -> Vec<CellDesc> {
        let mut cells = Vec::with_capacity(self.num_cells());
        for model in &self.models {
            for &fault_rate in &self.fault_rates {
                for &scenario in &self.scenarios {
                    for (drift_idx, _) in self.drifts.iter().enumerate() {
                        cells.push(CellDesc {
                            model: model.clone(),
                            fault_rate,
                            scenario,
                            drift_idx,
                        });
                    }
                }
            }
        }
        cells
    }
}

/// One expanded grid cell.
#[derive(Clone, Debug)]
pub struct CellDesc {
    pub model: String,
    pub fault_rate: f32,
    pub scenario: FaultScenario,
    pub drift_idx: usize,
}

/// Result of one campaign cell.
#[derive(Clone, Debug)]
pub struct CampaignCellReport {
    pub drift: String,
    pub eval_at_s: f64,
    pub offline: OfflineReport,
}

/// The consolidated campaign outcome.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub cells: Vec<CampaignCellReport>,
    pub engine_threads: usize,
    pub total_evaluations: usize,
    /// Unique backend (exact/synthetic/surrogate) evaluations after
    /// caching + in-batch dedup.
    pub total_backend_evals: usize,
    pub wall_ms: f64,
}

impl CampaignReport {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("command", json::s("campaign")),
            ("num_cells", json::num(self.cells.len() as f64)),
            ("engine_threads", json::num(self.engine_threads as f64)),
            ("total_evaluations", json::num(self.total_evaluations as f64)),
            ("total_backend_evals", json::num(self.total_backend_evals as f64)),
            ("wall_ms", json::num(self.wall_ms)),
            (
                "cells",
                json::arr(self.cells.iter().map(|c| {
                    json::obj(vec![
                        ("drift", json::s(&c.drift)),
                        ("eval_at_s", json::num(c.eval_at_s)),
                        ("offline", c.offline.to_json()),
                    ])
                })),
            ),
        ])
    }
}

/// Run every cell of the campaign through the batched evaluation engine.
/// `on_cell` fires after each cell with (index, total, report) for
/// progress display.
pub fn run_campaign(
    spec: &CampaignSpec,
    mut on_cell: impl FnMut(usize, usize, &CampaignCellReport),
) -> Result<CampaignReport> {
    let cells = spec.expand();
    let total = cells.len();
    let threads = if spec.base.eval_threads == 0 {
        EngineConfig::auto().threads
    } else {
        spec.base.eval_threads
    };
    let nsga2 = spec.base.optimizer.to_nsga2(spec.base.seed);
    let sw = std::time::Instant::now();

    // real-model experiments are loaded (and their HLO compiled) once per
    // model, not once per cell
    let mut experiments: HashMap<String, Experiment> = HashMap::new();
    let mut reports = Vec::with_capacity(total);
    let mut total_evaluations = 0usize;
    let mut total_backend_evals = 0usize;

    for (i, cell) in cells.iter().enumerate() {
        let drift = &spec.drifts[cell.drift_idx];
        let (platform, profiles) = spec.base.platform.build();
        let env = FaultEnv {
            base_rate: cell.fault_rate,
            profiles,
            drift: drift.components.clone(),
        };
        for c in &env.drift {
            if c.device >= env.num_devices() {
                bail!(
                    "campaign drift {:?}: component targets device {} but the platform has {}",
                    drift.name,
                    c.device,
                    env.num_devices()
                );
            }
        }
        let dev_w = env.dev_w_rates(drift.eval_at_s);
        let dev_a = env.dev_a_rates(drift.eval_at_s);

        let outcome = if let Some(n) = synthetic_units(&cell.model) {
            let manifest = synthetic_manifest(n);
            let table = synthetic_sensitivity(n);
            let dacc = if spec.base.surrogate {
                DaccMode::Surrogate(&table)
            } else {
                DaccMode::SyntheticExact { table: &table, cost: std::time::Duration::ZERO }
            };
            let mut ev = PartitionEvaluator::new(
                &manifest,
                &platform,
                dev_w,
                dev_a,
                cell.scenario,
                table.clean_acc,
                spec.base.link_cost,
                dacc,
            )
            .with_parallelism(threads);
            let out = spec.base.selection.optimize_and_deploy(&mut ev, &nsga2, |_| {})?;
            total_backend_evals += ev.counters.exact_evals + ev.counters.surrogate_evals;
            out
        } else {
            if !experiments.contains_key(&cell.model) {
                let mut cfg = spec.base.to_config();
                cfg.model = cell.model.clone();
                let mut exp = Experiment::load(&cfg)
                    .with_context(|| format!("campaign: loading model {:?}", cell.model))?;
                if spec.base.surrogate {
                    // same sensitivity grid as `afarepart offline`
                    exp.measure_sensitivity(&[0.05, 0.1, 0.2, 0.4])?;
                }
                experiments.insert(cell.model.clone(), exp);
            }
            let exp = &experiments[&cell.model];
            let dacc = match (spec.base.surrogate, &exp.sensitivity) {
                (true, Some(table)) => DaccMode::Surrogate(table),
                _ => DaccMode::Exact {
                    model: &exp.model,
                    eval: &exp.acc_eval,
                    key_seed: (spec.base.seed & 0xFFFF_FFFF) as u32,
                    n_batches: spec.base.dacc_batches,
                },
            };
            let mut ev = PartitionEvaluator::new(
                &exp.model.manifest,
                &platform,
                dev_w,
                dev_a,
                cell.scenario,
                exp.clean_acc,
                spec.base.link_cost,
                dacc,
            )
            .with_parallelism(threads);
            let out = spec.base.selection.optimize_and_deploy(&mut ev, &nsga2, |_| {})?;
            total_backend_evals += ev.counters.exact_evals + ev.counters.surrogate_evals;
            out
        };

        total_evaluations += outcome.evaluations;
        let report = CampaignCellReport {
            drift: drift.name.clone(),
            eval_at_s: drift.eval_at_s,
            offline: OfflineReport::from_outcome(
                &cell.model,
                cell.scenario.label(),
                cell.fault_rate,
                nsga2.pop_size,
                nsga2.generations,
                spec.base.surrogate,
                threads,
                &outcome,
            ),
        };
        on_cell(i, total, &report);
        reports.push(report);
    }

    Ok(CampaignReport {
        cells: reports,
        engine_threads: threads,
        total_evaluations,
        total_backend_evals,
        wall_ms: sw.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_expands_to_one_cell() {
        let c = CampaignSpec::singleton(ExperimentSpec::default());
        assert_eq!(c.num_cells(), 1);
        assert_eq!(c.expand().len(), 1);
    }

    #[test]
    fn grid_parses_and_expands() {
        let c = CampaignSpec::from_json_str(
            r#"{
                "base": {"eval_threads": 2, "optimizer": {"pop_size": 8, "generations": 2}},
                "grid": {
                    "models": ["synthetic-L6", "synthetic-L8"],
                    "fault_rates": [0.1, 0.4],
                    "scenarios": ["w", "iw"],
                    "drifts": [
                        {"name": "ambient"},
                        {"name": "attacked", "eval_at_s": 60.0,
                         "components": [{"kind": "step", "device": 0, "at_s": 30.0, "factor": 2.0}]}
                    ]
                }
            }"#,
        )
        .unwrap();
        assert_eq!(c.num_cells(), 2 * 2 * 2 * 2);
        let cells = c.expand();
        assert_eq!(cells.len(), 16);
        assert_eq!(cells[0].model, "synthetic-L6");
        assert_eq!(cells[15].model, "synthetic-L8");
    }

    #[test]
    fn customize_hook_feeds_defaulted_axes() {
        // CLI overrides land on base before the grid defaults from it
        let c = CampaignSpec::from_json_str_with(r#"{"grid": {"models": ["synthetic-L6"]}}"#, |b| {
            b.fault_env.fault_rate = 0.4;
            Ok(())
        })
        .unwrap();
        assert_eq!(c.fault_rates, vec![0.4]);
        // ... but an explicitly pinned axis is grid data and wins
        let c = CampaignSpec::from_json_str_with(
            r#"{"grid": {"models": ["synthetic-L6"], "fault_rates": [0.1]}}"#,
            |b| {
                b.fault_env.fault_rate = 0.4;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(c.fault_rates, vec![0.1f32]);
    }

    #[test]
    fn unknown_grid_key_rejected() {
        let err =
            CampaignSpec::from_json_str(r#"{"grid": {"modelz": ["a"]}}"#).unwrap_err();
        assert!(format!("{err:#}").contains("modelz"), "{err:#}");
    }

    #[test]
    fn synthetic_model_names_parse() {
        assert_eq!(synthetic_units("synthetic-L12"), Some(12));
        assert_eq!(synthetic_units("alexnet"), None);
    }

    #[test]
    fn small_synthetic_campaign_runs() {
        let c = CampaignSpec::from_json_str(
            r#"{
                "base": {"eval_threads": 2, "optimizer": {"pop_size": 8, "generations": 2}},
                "grid": {"models": ["synthetic-L6"], "scenarios": ["w", "iw"]}
            }"#,
        )
        .unwrap();
        let mut seen = 0;
        let report = run_campaign(&c, |_, _, _| seen += 1).unwrap();
        assert_eq!(seen, 2);
        assert_eq!(report.cells.len(), 2);
        assert!(report.total_evaluations > 0);
        assert!(report.total_backend_evals > 0);
        let v = report.to_json();
        assert_eq!(v.get("num_cells").unwrap().as_usize(), Some(2));
    }
}
