//! Campaign runner: expand a spec grid (models × fault-rates × scenarios
//! × drift schedules) and drive every cell's offline optimization through
//! the batched evaluation engine (PR 1), emitting one consolidated JSON
//! report.
//!
//! # Parallel cell scheduler (PR 5)
//!
//! Cells are independent, so [`run_campaign`] schedules them across OS
//! threads with work stealing: `campaign_workers` threads pull the next
//! cell index from a shared atomic counter, and a two-level thread
//! budget ([`resolve_thread_budget`]) splits the machine between cell
//! workers and each cell's engine threads so the product never
//! oversubscribes [`EngineConfig::auto`]. All cells of one model share a
//! per-model ΔAcc cache ([`crate::partition::DaccCache`] keyed by a
//! backend-context tag), so a rates × scenarios grid warms each
//! (model, rate-key) point once instead of once per cell.
//!
//! **Determinism is non-negotiable.** Cell results are pure functions of
//! the spec (per-cell seeds, engine bitwise-invariance), workers send
//! finished cells to the coordinating thread, and the coordinator
//! buffers them so `on_cell` callbacks, trace events, and the report's
//! cell array are always emitted in cell-index order. Every report field
//! is schedule-invariant — per-cell cache statistics come from each
//! cell's *private* cache, `total_backend_evals` is the sum of private
//! misses (numerically what the serial runner reported), and the
//! cross-cell sharing section counts *distinct keys*, not races — so
//! the report JSON (minus `wall_ms`) is bitwise identical at any worker
//! count, including 1.
//!
//! Model names of the form `synthetic-L<n>` use the artifact-free
//! fixtures of `bench::suite` (an `n`-unit manifest + sensitivity table
//! with the exact-cost-shaped `SyntheticExact` ΔAcc backend), so
//! campaigns run end-to-end without PJRT artifacts — the integration
//! tests and CI exercise a 3-model × 2-scenario campaign this way. Real
//! model names load artifacts exactly like `afarepart offline`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::outcome::OfflineReport;
use super::schema::*;
use super::ExperimentSpec;
use crate::bench::suite::{synthetic_manifest, synthetic_sensitivity, synthetic_units};
use crate::experiment::Experiment;
use crate::faults::{DriftComponent, FaultEnv, FaultScenario};
use crate::model::Manifest;
use crate::nsga2::Nsga2Config;
use crate::obs::Telemetry;
use crate::partition::{DaccCache, DaccMode, EngineConfig, PartitionEvaluator, SensitivityTable};
use crate::util::json::{self, Value};

/// One drift schedule of the campaign grid: a named component stack plus
/// the probe time at which cells under this schedule sample the
/// environment (a step attack evaluated at `eval_at_s` past its onset
/// sees the attacked rates; at 0 it sees ambient).
#[derive(Clone, Debug, PartialEq)]
pub struct DriftCell {
    pub name: String,
    pub components: Vec<DriftComponent>,
    pub eval_at_s: f64,
}

impl DriftCell {
    pub fn ambient() -> DriftCell {
        DriftCell { name: "ambient".into(), components: Vec::new(), eval_at_s: 0.0 }
    }

    fn from_json(v: &Value, ctx: &str) -> Result<DriftCell> {
        let obj = expect_obj(v, ctx)?;
        reject_unknown(obj, &["name", "components", "eval_at_s"], ctx)?;
        let name = require_str(obj, "name", ctx)?.to_string();
        let components = match obj.get("components") {
            Some(v) => super::faultenv::drift_list_from_json(v, &format!("{ctx}.components"))?,
            None => Vec::new(),
        };
        let eval_at_s = f64_field(obj, "eval_at_s", ctx)?.unwrap_or(0.0);
        Ok(DriftCell { name, components, eval_at_s })
    }
}

/// A declarative experiment grid over one base spec.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    pub base: ExperimentSpec,
    pub models: Vec<String>,
    pub fault_rates: Vec<f32>,
    pub scenarios: Vec<FaultScenario>,
    pub drifts: Vec<DriftCell>,
}

impl CampaignSpec {
    /// A 1×1×1×1 campaign over the base spec (each grid axis defaults to
    /// the base spec's value — including its drift stack, probed at
    /// t = 0 like the offline phase).
    pub fn singleton(base: ExperimentSpec) -> CampaignSpec {
        let drifts = if base.fault_env.drift.is_empty() {
            vec![DriftCell::ambient()]
        } else {
            vec![DriftCell {
                name: "base".into(),
                components: base.fault_env.drift.clone(),
                eval_at_s: 0.0,
            }]
        };
        CampaignSpec {
            models: vec![base.model.clone()],
            fault_rates: vec![base.fault_env.fault_rate],
            scenarios: vec![base.fault_env.scenario],
            drifts,
            base,
        }
    }

    /// Parse a campaign document: `{"base": {...}, "grid": {...}}`,
    /// strict at every level.
    pub fn from_json_str(text: &str) -> Result<CampaignSpec> {
        Self::from_json_str_with(text, |_| Ok(()))
    }

    /// Like [`CampaignSpec::from_json_str`], with a `customize` hook run
    /// over the base spec *after* the file's `base` section but *before*
    /// the grid axes default from it — this is where the CLI applies its
    /// env/flag overrides, so `--fault-rate 0.4` reaches every cell of a
    /// campaign whose grid leaves `fault_rates` implicit. Axes the file
    /// sets explicitly are grid data and are not overridden.
    pub fn from_json_str_with(
        text: &str,
        customize: impl FnOnce(&mut ExperimentSpec) -> Result<()>,
    ) -> Result<CampaignSpec> {
        let v = json::parse(text).context("campaign: invalid json")?;
        let obj = expect_obj(&v, "campaign")?;
        reject_unknown(obj, &["base", "grid"], "campaign")?;
        let mut base = ExperimentSpec::default();
        if let Some(b) = obj.get("base") {
            base.apply_json(b).context("campaign.base")?;
        }
        customize(&mut base)?;
        let mut spec = CampaignSpec::singleton(base);
        if let Some(g) = obj.get("grid") {
            let grid = expect_obj(g, "campaign.grid")?;
            reject_unknown(grid, &["models", "fault_rates", "scenarios", "drifts"], "campaign.grid")?;
            if let Some(v) = grid.get("models") {
                spec.models = expect_arr(v, "campaign.grid.models")?
                    .iter()
                    .map(|m| match m.as_str() {
                        Some(s) => Ok(s.to_string()),
                        None => bail!("campaign.grid.models: expected strings"),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = grid.get("fault_rates") {
                spec.fault_rates = expect_arr(v, "campaign.grid.fault_rates")?
                    .iter()
                    .map(|r| match r.as_f64() {
                        Some(x) => Ok(x as f32),
                        None => bail!("campaign.grid.fault_rates: expected numbers"),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = grid.get("scenarios") {
                spec.scenarios = expect_arr(v, "campaign.grid.scenarios")?
                    .iter()
                    .map(|s| match s.as_str().and_then(FaultScenario::parse) {
                        Some(sc) => Ok(sc),
                        None => bail!("campaign.grid.scenarios: expected scenario names (w, a, iw)"),
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) = grid.get("drifts") {
                spec.drifts = expect_arr(v, "campaign.grid.drifts")?
                    .iter()
                    .enumerate()
                    .map(|(i, d)| DriftCell::from_json(d, &format!("campaign.grid.drifts[{i}]")))
                    .collect::<Result<Vec<_>>>()?;
            }
        }
        if spec.models.is_empty()
            || spec.fault_rates.is_empty()
            || spec.scenarios.is_empty()
            || spec.drifts.is_empty()
        {
            bail!("campaign.grid: every axis needs at least one entry");
        }
        Ok(spec)
    }

    pub fn from_file(path: &std::path::Path) -> Result<CampaignSpec> {
        Self::from_file_with(path, |_| Ok(()))
    }

    /// [`CampaignSpec::from_file`] with the base-spec `customize` hook of
    /// [`CampaignSpec::from_json_str_with`].
    pub fn from_file_with(
        path: &std::path::Path,
        customize: impl FnOnce(&mut ExperimentSpec) -> Result<()>,
    ) -> Result<CampaignSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading campaign spec {}", path.display()))?;
        Self::from_json_str_with(&text, customize)
            .with_context(|| format!("campaign spec {}", path.display()))
    }

    /// Total number of grid cells.
    pub fn num_cells(&self) -> usize {
        self.models.len() * self.fault_rates.len() * self.scenarios.len() * self.drifts.len()
    }

    /// Expand the grid in deterministic order:
    /// models ▷ fault_rates ▷ scenarios ▷ drifts.
    pub fn expand(&self) -> Vec<CellDesc> {
        let mut cells = Vec::with_capacity(self.num_cells());
        for model in &self.models {
            for &fault_rate in &self.fault_rates {
                for &scenario in &self.scenarios {
                    for (drift_idx, _) in self.drifts.iter().enumerate() {
                        cells.push(CellDesc {
                            model: model.clone(),
                            fault_rate,
                            scenario,
                            drift_idx,
                        });
                    }
                }
            }
        }
        cells
    }
}

/// One expanded grid cell.
#[derive(Clone, Debug)]
pub struct CellDesc {
    pub model: String,
    pub fault_rate: f32,
    pub scenario: FaultScenario,
    pub drift_idx: usize,
}

/// Result of one campaign cell.
#[derive(Clone, Debug)]
pub struct CampaignCellReport {
    pub drift: String,
    pub eval_at_s: f64,
    pub offline: OfflineReport,
}

/// Schedule-invariant cross-cell cache-sharing summary for one model.
///
/// Every field is a pure function of the spec: `requests` and
/// `private_misses` sum the per-cell (deterministic) private-cache
/// counters, and `unique_keys` is the number of *distinct* (context,
/// rate-key) points the model's cells requested — exactly the entries
/// the shared cache holds at the end, independent of which worker got
/// there first. `saved_backend_evals = private_misses - unique_keys` is
/// the dedup the sharing guarantees in cell-index order; concurrent
/// workers may race a key and save slightly less in wall-clock terms,
/// which is visible in the `campaign_cross_cell_hits_total` counter
/// (telemetry, deliberately outside this deterministic report).
#[derive(Clone, Debug)]
pub struct ModelCacheSharing {
    pub model: String,
    /// Σ private-cache lookups over the model's cells.
    pub requests: usize,
    /// Σ private-cache misses over the model's cells.
    pub private_misses: usize,
    /// Distinct (context, rate-key) points across the model's cells.
    pub unique_keys: usize,
    /// Backend evaluations sharing removes versus isolated cells.
    pub saved_backend_evals: usize,
}

impl ModelCacheSharing {
    fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", json::s(&self.model)),
            ("requests", json::num(self.requests as f64)),
            ("private_misses", json::num(self.private_misses as f64)),
            ("unique_keys", json::num(self.unique_keys as f64)),
            ("saved_backend_evals", json::num(self.saved_backend_evals as f64)),
        ])
    }
}

/// The consolidated campaign outcome.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub cells: Vec<CampaignCellReport>,
    pub engine_threads: usize,
    pub total_evaluations: usize,
    /// Unique backend (exact/synthetic/surrogate) evaluations after
    /// per-cell caching + in-batch dedup — the sum of private-cache
    /// misses, which is schedule-invariant (cross-cell sharing shows up
    /// in [`CampaignReport::cache_sharing`], not here).
    pub total_backend_evals: usize,
    /// Per-model cross-cell sharing summary, in `spec.models` order.
    pub cache_sharing: Vec<ModelCacheSharing>,
    pub wall_ms: f64,
}

impl CampaignReport {
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("command", json::s("campaign")),
            ("num_cells", json::num(self.cells.len() as f64)),
            ("engine_threads", json::num(self.engine_threads as f64)),
            ("total_evaluations", json::num(self.total_evaluations as f64)),
            ("total_backend_evals", json::num(self.total_backend_evals as f64)),
            ("cache_sharing", json::arr(self.cache_sharing.iter().map(|m| m.to_json()))),
            ("wall_ms", json::num(self.wall_ms)),
            (
                "cells",
                json::arr(self.cells.iter().map(|c| {
                    json::obj(vec![
                        ("drift", json::s(&c.drift)),
                        ("eval_at_s", json::num(c.eval_at_s)),
                        ("offline", c.offline.to_json()),
                    ])
                })),
            ),
        ])
    }
}

/// Knobs for [`run_campaign_with`] beyond the spec itself.
#[derive(Clone, Debug, Default)]
pub struct CampaignOptions {
    /// Simulated per-backend-evaluation cost for `synthetic-L<n>` models
    /// in non-surrogate mode (the `SyntheticExact` sleep). `bench_perf`
    /// injects an exact-call-shaped cost here so the campaign bench
    /// measures scheduling, not just arithmetic. Zero (the default)
    /// matches `afarepart campaign`.
    pub synthetic_cost: Duration,
    /// Observability handle for the scheduler (disabled by default).
    /// Cell evaluators never receive it — all `campaign.*` spans,
    /// counters, and gauges are emitted from the coordinating thread in
    /// cell-index order, so an attached trace stays bitwise-deterministic
    /// at any worker count.
    pub telemetry: Telemetry,
}

/// Split `machine` engine threads between campaign cell workers and each
/// cell's inner parallelism:
/// `(workers, cell_threads, cell_selection_threads)`.
///
/// Precedence: an explicit `campaign_workers` is honored (clamped to the
/// cell count); explicit inner knobs (`eval_threads`, and
/// `optimizer.selection_threads` when it requests the parallel regime,
/// i.e. ≥ 2) are honored up to the per-worker share `machine / workers`.
/// A cell's evaluation and selection phases alternate rather than
/// overlap, so the two inner knobs share one per-worker budget (the
/// worker divisor uses their max, not their sum). With every knob on
/// auto the machine goes to cell-level parallelism (`workers = machine`,
/// `cell_threads = 1`) — cells are embarrassingly parallel, so outer
/// parallelism dominates inner fan-out. The product
/// `workers × max(cell_threads, cell_selection_threads)` never exceeds
/// `machine` unless the user explicitly pins knobs higher (each side is
/// floored at 1).
///
/// Clamping never demotes the optimizer across the determinism boundary:
/// a request for `selection_threads >= 2` (the self-deterministic forked
/// path, whose results do not depend on the width) is floored at 2, so a
/// narrow share shrinks the fan-out without changing any cell's result —
/// campaign reports stay machine-invariant.
pub(crate) fn resolve_thread_budget(
    campaign_workers: usize,
    eval_threads: usize,
    selection_threads: usize,
    machine: usize,
    num_cells: usize,
) -> (usize, usize, usize) {
    let machine = machine.max(1);
    let cells = num_cells.max(1);
    let sel_request = if selection_threads > 1 { selection_threads } else { 0 };
    let inner = eval_threads.max(sel_request);
    let workers = if campaign_workers != 0 {
        campaign_workers.min(cells)
    } else if inner != 0 {
        (machine / inner).max(1).min(cells)
    } else {
        machine.min(cells)
    };
    let share = (machine / workers).max(1);
    let cell_threads = if eval_threads != 0 { eval_threads.min(share) } else { share };
    let cell_selection_threads =
        if sel_request != 0 { sel_request.min(share).max(2) } else { 1 };
    (workers, cell_threads, cell_selection_threads)
}

/// What one cell's worker sends back to the coordinator. The `report`
/// and the `evaluations`/`private_*` counters are schedule-invariant;
/// `backend_evals`/`shared_hits`/`wall_ms` depend on scheduling and feed
/// telemetry only.
struct CellOutcome {
    report: CampaignCellReport,
    evaluations: usize,
    private_lookups: usize,
    private_misses: usize,
    backend_evals: usize,
    shared_hits: usize,
    wall_ms: f64,
}

/// Everything a cell worker needs by reference. All fields are shared
/// immutably across the scoped workers (per-model `Experiment`s are
/// preloaded, synthetic fixtures prebuilt, shared caches created before
/// the scope opens).
struct CellCtx<'a> {
    spec: &'a CampaignSpec,
    nsga2: &'a Nsga2Config,
    synthetic_cost: Duration,
    /// Actual engine threads each cell runs with (budget split).
    cell_threads: usize,
    /// Worker-invariant thread figure recorded in reports (what the
    /// serial runner reported: `eval_threads`, or the machine auto).
    reported_threads: usize,
    fixtures: &'a HashMap<String, (Manifest, SensitivityTable)>,
    experiments: &'a HashMap<String, Experiment>,
    shared: &'a HashMap<String, Arc<DaccCache>>,
}

/// Run one cell end to end. Pure in `(ctx, cell)` up to the
/// schedule-dependent `backend_evals`/`shared_hits` telemetry fields.
fn run_cell(ctx: &CellCtx<'_>, cell: &CellDesc) -> Result<CellOutcome> {
    let spec = ctx.spec;
    let drift = &spec.drifts[cell.drift_idx];
    let (platform, profiles) = spec.base.platform.build();
    let env =
        FaultEnv { base_rate: cell.fault_rate, profiles, drift: drift.components.clone() };
    for c in &env.drift {
        if c.device >= env.num_devices() {
            bail!(
                "campaign drift {:?}: component targets device {} but the platform has {}",
                drift.name,
                c.device,
                env.num_devices()
            );
        }
    }
    let dev_w = env.dev_w_rates(drift.eval_at_s);
    let dev_a = env.dev_a_rates(drift.eval_at_s);
    let shared_cache = ctx.shared.get(&cell.model);

    let (outcome, counters, cache_stats) = if ctx.fixtures.contains_key(&cell.model) {
        let (manifest, table) = &ctx.fixtures[&cell.model];
        let dacc = if spec.base.surrogate {
            DaccMode::Surrogate(table)
        } else {
            DaccMode::SyntheticExact { table, cost: ctx.synthetic_cost }
        };
        let mut ev = PartitionEvaluator::new(
            manifest,
            &platform,
            dev_w,
            dev_a,
            cell.scenario,
            table.clean_acc,
            spec.base.link_cost,
            dacc,
        )
        .with_parallelism(ctx.cell_threads);
        if let Some(shared) = shared_cache {
            ev.set_shared_cache(Arc::clone(shared));
        }
        let out = spec.base.selection.optimize_and_deploy(&mut ev, ctx.nsga2, |_| {})?;
        (out, ev.counters, ev.cache_stats())
    } else {
        let exp = &ctx.experiments[&cell.model];
        let dacc = match (spec.base.surrogate, &exp.sensitivity) {
            (true, Some(table)) => DaccMode::Surrogate(table),
            _ => DaccMode::Exact {
                model: &exp.model,
                eval: &exp.acc_eval,
                key_seed: (spec.base.seed & 0xFFFF_FFFF) as u32,
                n_batches: spec.base.dacc_batches,
            },
        };
        let mut ev = PartitionEvaluator::new(
            &exp.model.manifest,
            &platform,
            dev_w,
            dev_a,
            cell.scenario,
            exp.clean_acc,
            spec.base.link_cost,
            dacc,
        )
        .with_parallelism(ctx.cell_threads);
        if let Some(shared) = shared_cache {
            ev.set_shared_cache(Arc::clone(shared));
        }
        let out = spec.base.selection.optimize_and_deploy(&mut ev, ctx.nsga2, |_| {})?;
        (out, ev.counters, ev.cache_stats())
    };

    let report = CampaignCellReport {
        drift: drift.name.clone(),
        eval_at_s: drift.eval_at_s,
        offline: OfflineReport::from_outcome(
            &cell.model,
            cell.scenario.label(),
            cell.fault_rate,
            ctx.nsga2.pop_size,
            ctx.nsga2.generations,
            spec.base.surrogate,
            ctx.reported_threads,
            &outcome,
        ),
    };
    let (hits, misses, _) = cache_stats;
    Ok(CellOutcome {
        report,
        evaluations: outcome.evaluations,
        private_lookups: hits + misses,
        private_misses: misses,
        backend_evals: counters.exact_evals + counters.surrogate_evals,
        shared_hits: counters.shared_hits,
        wall_ms: 0.0, // stamped by the worker loop
    })
}

/// Run every cell of the campaign through the batched evaluation engine.
/// `on_cell` fires after each cell with (index, total, report) for
/// progress display, in cell-index order at any worker count.
pub fn run_campaign(
    spec: &CampaignSpec,
    on_cell: impl FnMut(usize, usize, &CampaignCellReport),
) -> Result<CampaignReport> {
    run_campaign_with(spec, &CampaignOptions::default(), on_cell)
}

/// [`run_campaign`] with explicit [`CampaignOptions`] (bench cost
/// injection, scheduler telemetry).
pub fn run_campaign_with(
    spec: &CampaignSpec,
    opts: &CampaignOptions,
    mut on_cell: impl FnMut(usize, usize, &CampaignCellReport),
) -> Result<CampaignReport> {
    let cells = spec.expand();
    let total = cells.len();
    let machine = EngineConfig::auto().threads;
    let (workers, cell_threads, cell_selection_threads) = resolve_thread_budget(
        spec.base.campaign_workers,
        spec.base.eval_threads,
        spec.base.optimizer.selection_threads,
        machine,
        total,
    );
    // Reports record the worker-invariant thread *budget* (exactly what
    // the serial runner reported); the actual split is telemetry.
    let reported_threads =
        if spec.base.eval_threads == 0 { machine } else { spec.base.eval_threads };
    let telemetry = &opts.telemetry;
    telemetry.gauge_set("campaign_workers", workers as f64);
    telemetry.gauge_set("campaign_cell_threads", cell_threads as f64);
    telemetry.gauge_set("campaign_cell_selection_threads", cell_selection_threads as f64);
    let mut nsga2 = spec.base.nsga2_config();
    // Budget-clamped optimizer fan-out. Safe for determinism: either the
    // spec asked for the serial path (stays 1) or the forked path (stays
    // >= 2, whose results are width-invariant).
    nsga2.selection_threads = cell_selection_threads;
    let sw = std::time::Instant::now();

    // Per-model setup runs serially before the scope opens: real-model
    // experiments are loaded (and their HLO compiled) once per model,
    // synthetic fixtures are built once per model, and every model gets
    // one shared cross-cell ΔAcc cache.
    let mut experiments: HashMap<String, Experiment> = HashMap::new();
    let mut fixtures: HashMap<String, (Manifest, SensitivityTable)> = HashMap::new();
    let mut shared: HashMap<String, Arc<DaccCache>> = HashMap::new();
    for model in &spec.models {
        if shared.contains_key(model) {
            continue;
        }
        shared.insert(model.clone(), Arc::new(DaccCache::new()));
        if let Some(n) = synthetic_units(model) {
            fixtures.insert(model.clone(), (synthetic_manifest(n), synthetic_sensitivity(n)));
        } else {
            let mut cfg = spec.base.to_config();
            cfg.model = model.clone();
            let mut exp = Experiment::load(&cfg)
                .with_context(|| format!("campaign: loading model {model:?}"))?;
            if spec.base.surrogate {
                // same sensitivity grid as `afarepart offline`
                exp.measure_sensitivity(&Experiment::SENSITIVITY_RATE_GRID)?;
            }
            experiments.insert(model.clone(), exp);
        }
    }

    let ctx = CellCtx {
        spec,
        nsga2: &nsga2,
        synthetic_cost: opts.synthetic_cost,
        cell_threads,
        reported_threads,
        fixtures: &fixtures,
        experiments: &experiments,
        shared: &shared,
    };

    // Work-stealing scheduler: workers pull the next cell index from a
    // shared counter and send finished cells to this (coordinating)
    // thread, which buffers and emits them in cell-index order. On the
    // first failure the abort flag stops workers from *starting* new
    // cells; in-flight cells drain, so every index below the failing one
    // still arrives and the error surfaced is the lowest-index one —
    // exactly the serial runner's behavior.
    let mut slots: Vec<Option<CellOutcome>> = Vec::with_capacity(total);
    slots.resize_with(total, || None);
    let mut first_err: Option<(usize, anyhow::Error)> = None;
    let mut emitted = 0usize;
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let (tx, rx) = mpsc::channel::<(usize, Result<CellOutcome>)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let (next, abort, ctx, cells) = (&next, &abort, &ctx, &cells);
            scope.spawn(move || loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let cell_sw = std::time::Instant::now();
                let res = run_cell(ctx, &cells[i]).map(|mut out| {
                    out.wall_ms = cell_sw.elapsed().as_secs_f64() * 1e3;
                    out
                });
                if res.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                if tx.send((i, res)).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        let mut done = 0usize;
        for (i, res) in rx {
            done += 1;
            telemetry.gauge_set("campaign_queue_depth", (total - done) as f64);
            match res {
                Ok(out) => slots[i] = Some(out),
                Err(e) => {
                    let lowest_so_far = match &first_err {
                        Some((j, _)) => i < *j,
                        None => true,
                    };
                    if lowest_so_far {
                        first_err = Some((i, e));
                    }
                }
            }
            while emitted < total {
                let Some(out) = &slots[emitted] else { break };
                // Coordinator-side instrumentation, strictly in cell
                // order. Trace fields are logical/deterministic; the
                // schedule-dependent savings go to counters only.
                telemetry.counter_add("campaign_cells_total", 1);
                telemetry.counter_add("campaign_cross_cell_hits_total", out.shared_hits as u64);
                telemetry.counter_add("campaign_backend_evals_total", out.backend_evals as u64);
                telemetry.emit_span(
                    "campaign.cell",
                    out.wall_ms,
                    &[
                        ("cell", json::num(emitted as f64)),
                        ("model", json::s(&cells[emitted].model)),
                        ("drift", json::s(&out.report.drift)),
                        ("evaluations", json::num(out.evaluations as f64)),
                        ("unique_misses", json::num(out.private_misses as f64)),
                    ],
                );
                on_cell(emitted, total, &out.report);
                emitted += 1;
            }
        }
    });
    if let Some((_, e)) = first_err {
        return Err(e);
    }

    // Fold the buffered cells into the consolidated report — all sums
    // below are over deterministic per-cell private counters, so the
    // report is identical at any worker count.
    let mut reports = Vec::with_capacity(total);
    let mut total_evaluations = 0usize;
    let mut total_backend_evals = 0usize;
    let mut per_model: HashMap<&str, (usize, usize)> = HashMap::new();
    for (cell, slot) in cells.iter().zip(slots) {
        let out = slot.expect("scheduler left a cell unfinished without an error");
        total_evaluations += out.evaluations;
        total_backend_evals += out.private_misses;
        let entry = per_model.entry(cell.model.as_str()).or_insert((0, 0));
        entry.0 += out.private_lookups;
        entry.1 += out.private_misses;
        reports.push(out.report);
    }
    let mut cache_sharing = Vec::new();
    let mut seen_models: Vec<&str> = Vec::new();
    for model in &spec.models {
        if seen_models.contains(&model.as_str()) {
            continue;
        }
        seen_models.push(model.as_str());
        let (requests, private_misses) = per_model.get(model.as_str()).copied().unwrap_or((0, 0));
        let unique_keys = shared[model.as_str()].len();
        cache_sharing.push(ModelCacheSharing {
            model: model.clone(),
            requests,
            private_misses,
            unique_keys,
            saved_backend_evals: private_misses.saturating_sub(unique_keys),
        });
    }

    Ok(CampaignReport {
        cells: reports,
        engine_threads: reported_threads,
        total_evaluations,
        total_backend_evals,
        cache_sharing,
        wall_ms: sw.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_expands_to_one_cell() {
        let c = CampaignSpec::singleton(ExperimentSpec::default());
        assert_eq!(c.num_cells(), 1);
        assert_eq!(c.expand().len(), 1);
    }

    #[test]
    fn grid_parses_and_expands() {
        let c = CampaignSpec::from_json_str(
            r#"{
                "base": {"eval_threads": 2, "optimizer": {"pop_size": 8, "generations": 2}},
                "grid": {
                    "models": ["synthetic-L6", "synthetic-L8"],
                    "fault_rates": [0.1, 0.4],
                    "scenarios": ["w", "iw"],
                    "drifts": [
                        {"name": "ambient"},
                        {"name": "attacked", "eval_at_s": 60.0,
                         "components": [{"kind": "step", "device": 0, "at_s": 30.0, "factor": 2.0}]}
                    ]
                }
            }"#,
        )
        .unwrap();
        assert_eq!(c.num_cells(), 2 * 2 * 2 * 2);
        let cells = c.expand();
        assert_eq!(cells.len(), 16);
        assert_eq!(cells[0].model, "synthetic-L6");
        assert_eq!(cells[15].model, "synthetic-L8");
    }

    #[test]
    fn customize_hook_feeds_defaulted_axes() {
        // CLI overrides land on base before the grid defaults from it
        let c = CampaignSpec::from_json_str_with(r#"{"grid": {"models": ["synthetic-L6"]}}"#, |b| {
            b.fault_env.fault_rate = 0.4;
            Ok(())
        })
        .unwrap();
        assert_eq!(c.fault_rates, vec![0.4]);
        // ... but an explicitly pinned axis is grid data and wins
        let c = CampaignSpec::from_json_str_with(
            r#"{"grid": {"models": ["synthetic-L6"], "fault_rates": [0.1]}}"#,
            |b| {
                b.fault_env.fault_rate = 0.4;
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(c.fault_rates, vec![0.1f32]);
    }

    #[test]
    fn unknown_grid_key_rejected() {
        let err =
            CampaignSpec::from_json_str(r#"{"grid": {"modelz": ["a"]}}"#).unwrap_err();
        assert!(format!("{err:#}").contains("modelz"), "{err:#}");
    }

    #[test]
    fn synthetic_model_names_parse() {
        assert_eq!(synthetic_units("synthetic-L12"), Some(12));
        assert_eq!(synthetic_units("alexnet"), None);
    }

    #[test]
    fn thread_budget_never_oversubscribes_on_auto() {
        // all knobs auto: the machine goes to cell-level workers
        assert_eq!(resolve_thread_budget(0, 0, 1, 8, 12), (8, 1, 1));
        // fewer cells than cores: leftover cores go to each cell
        assert_eq!(resolve_thread_budget(0, 0, 0, 8, 2), (2, 4, 1));
        // explicit eval_threads: workers take the remaining share
        assert_eq!(resolve_thread_budget(0, 2, 1, 8, 12), (4, 2, 1));
        assert_eq!(resolve_thread_budget(0, 8, 1, 8, 12), (1, 8, 1));
        // explicit workers: eval_threads clipped to the per-worker share
        assert_eq!(resolve_thread_budget(4, 8, 1, 8, 12), (4, 2, 1));
        assert_eq!(resolve_thread_budget(2, 0, 1, 8, 12), (2, 4, 1));
        // workers clamp to the cell count
        assert_eq!(resolve_thread_budget(16, 0, 1, 8, 3), (3, 2, 1));
        // single-core machine degrades to fully serial
        assert_eq!(resolve_thread_budget(0, 0, 1, 1, 12), (1, 1, 1));
        // selection_threads alone drives the worker split like
        // eval_threads does, and both inner knobs share the budget (max,
        // not sum — selection and evaluation alternate within a cell)
        assert_eq!(resolve_thread_budget(0, 0, 4, 8, 12), (2, 4, 4));
        assert_eq!(resolve_thread_budget(0, 4, 4, 8, 12), (2, 4, 4));
        assert_eq!(resolve_thread_budget(0, 2, 4, 8, 12), (2, 2, 4));
        // clamping to a narrow share never crosses the determinism
        // boundary: a parallel-regime request is floored at 2 ...
        assert_eq!(resolve_thread_budget(8, 0, 4, 8, 12), (8, 1, 2));
        // ... and a serial request is never promoted
        assert_eq!(resolve_thread_budget(2, 0, 1, 8, 12), (2, 4, 1));
        for (cw, et, st, machine, cells) in [
            (0, 0, 1, 8, 12),
            (0, 3, 1, 8, 5),
            (2, 2, 2, 8, 9),
            (0, 0, 4, 6, 2),
            (2, 0, 2, 4, 40),
        ] {
            let (w, t, s) = resolve_thread_budget(cw, et, st, machine, cells);
            assert!(w >= 1 && t >= 1 && s >= 1);
            assert!(
                w * t.max(s) <= machine.max(1),
                "({cw},{et},{st},{machine},{cells}) -> {w}x{t}/{s} oversubscribes"
            );
            // the determinism regime always survives the clamp
            assert_eq!(s > 1, st > 1, "regime changed for ({cw},{et},{st},{machine},{cells})");
        }
    }

    #[test]
    fn small_synthetic_campaign_runs() {
        let c = CampaignSpec::from_json_str(
            r#"{
                "base": {"eval_threads": 2, "optimizer": {"pop_size": 8, "generations": 2}},
                "grid": {"models": ["synthetic-L6"], "scenarios": ["w", "iw"]}
            }"#,
        )
        .unwrap();
        let mut seen = 0;
        let report = run_campaign(&c, |_, _, _| seen += 1).unwrap();
        assert_eq!(seen, 2);
        assert_eq!(report.cells.len(), 2);
        assert!(report.total_evaluations > 0);
        assert!(report.total_backend_evals > 0);
        let v = report.to_json();
        assert_eq!(v.get("num_cells").unwrap().as_usize(), Some(2));
    }
}
