//! The declarative experiment API (ISSUE 2): a fully-typed
//! [`ExperimentSpec`] covering platform topology, fault environment,
//! optimizer budget, selection policy and online-monitor settings — all
//! loadable from one JSON document with strict unknown-key rejection and
//! one documented precedence chain:
//!
//! ```text
//! CLI flags  >  AFARE_* environment  >  --spec/--config file  >  defaults
//! ```
//!
//! enforced in exactly one place ([`ExperimentSpec::resolve`]) instead of
//! the `apply_args`/`apply_env`/`apply_json` call-order roulette the flat
//! config used to play (the old order applied env *after* CLI, silently
//! letting `AFARE_POP` beat an explicit `--pop`).
//!
//! Submodules:
//! * [`platform`] — device list + link parameters ([`PlatformSpec`]).
//! * [`faultenv`] — fault rate, scenario, composable drift
//!   ([`FaultEnvSpec`]).
//! * [`online`] — online-monitor settings ([`OnlineSpec`]).
//! * [`outcome`] — typed JSON run reports ([`outcome::OfflineReport`] & co).
//! * [`campaign`] — spec-grid expansion driving the batched evaluation
//!   engine over models × fault-rates × scenarios × drift schedules.
//!
//! See `docs/spec.md` for the key-by-key schema reference.

pub mod campaign;
pub mod chaos;
pub mod faultenv;
pub mod online;
pub mod outcome;
pub mod platform;
mod schema;
pub mod telemetry;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use self::campaign::{CampaignSpec, DriftCell};
pub use self::chaos::ChaosSpec;
pub use self::faultenv::FaultEnvSpec;
pub use self::online::OnlineSpec;
pub use self::platform::{AccelKind, DeviceEntry, LinkSpec, PlatformSpec};
pub use self::telemetry::TelemetrySpec;

use crate::cli::Args;
use crate::config::ExperimentConfig;
use crate::coordinator::offline::optimize_partitions_counted;
use crate::coordinator::OfflineOutcome;
use crate::faults::FaultScenario;
use crate::nsga2::{GenStats, Individual, Nsga2Config};
use crate::partition::{
    select_knee, select_min_dacc, select_min_dacc_within_budget, Mapping, PartitionEvaluator,
};
use crate::util::json::{self, Value};
use self::schema::*;

/// NSGA-II budget (paper §VI-A: population 60, generations 60).
#[derive(Clone, Debug, PartialEq)]
pub struct OptimizerSpec {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    /// Worker threads for the NSGA-II selection pipeline (sort, crowding,
    /// variation). `0`/`1` = legacy bitwise-exact serial path; `>= 2` =
    /// the self-deterministic parallel path (results depend only on the
    /// seed, not the thread count). See `docs/spec.md` §optimizer.
    pub selection_threads: usize,
}

impl Default for OptimizerSpec {
    fn default() -> Self {
        let c = Nsga2Config::default();
        OptimizerSpec {
            pop_size: c.pop_size,
            generations: c.generations,
            crossover_prob: c.crossover_prob,
            mutation_prob: c.mutation_prob,
            selection_threads: c.selection_threads,
        }
    }
}

impl OptimizerSpec {
    fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(
            obj,
            &["pop_size", "generations", "crossover_prob", "mutation_prob", "selection_threads"],
            ctx,
        )?;
        if let Some(x) = usize_field(obj, "pop_size", ctx)? {
            self.pop_size = x;
        }
        if let Some(x) = usize_field(obj, "generations", ctx)? {
            self.generations = x;
        }
        if let Some(x) = f64_field(obj, "crossover_prob", ctx)? {
            self.crossover_prob = x;
        }
        if let Some(x) = f64_field(obj, "mutation_prob", ctx)? {
            self.mutation_prob = x;
        }
        if let Some(x) = usize_field(obj, "selection_threads", ctx)? {
            self.selection_threads = x;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("pop_size", json::num(self.pop_size as f64)),
            ("generations", json::num(self.generations as f64)),
            ("crossover_prob", json::num(self.crossover_prob)),
            ("mutation_prob", json::num(self.mutation_prob)),
            ("selection_threads", json::num(self.selection_threads as f64)),
        ])
    }

    pub fn to_nsga2(&self, seed: u64) -> Nsga2Config {
        Nsga2Config {
            pop_size: self.pop_size,
            generations: self.generations,
            crossover_prob: self.crossover_prob,
            mutation_prob: self.mutation_prob,
            seed,
            selection_threads: self.selection_threads,
            ..Default::default()
        }
    }
}

/// How the deployed P* is picked from the Pareto front (§V-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionPolicy {
    /// Min ΔAcc within latency/energy budget factors (the paper's
    /// "initial balance" — the default).
    MinDaccWithinBudget,
    /// Pure min ΔAcc (most robust, budgets ignored).
    MinDacc,
    /// Knee point of the normalized front.
    Knee,
}

impl SelectionPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            SelectionPolicy::MinDaccWithinBudget => "min-dacc-within-budget",
            SelectionPolicy::MinDacc => "min-dacc",
            SelectionPolicy::Knee => "knee",
        }
    }

    pub fn parse(s: &str) -> Option<SelectionPolicy> {
        match s {
            "min-dacc-within-budget" => Some(SelectionPolicy::MinDaccWithinBudget),
            "min-dacc" => Some(SelectionPolicy::MinDacc),
            "knee" => Some(SelectionPolicy::Knee),
            _ => None,
        }
    }
}

/// Deployment selection policy + its budget factors.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectionSpec {
    pub policy: SelectionPolicy,
    pub lat_budget: f64,
    pub energy_budget: f64,
}

impl Default for SelectionSpec {
    fn default() -> Self {
        SelectionSpec {
            policy: SelectionPolicy::MinDaccWithinBudget,
            lat_budget: 2.0,
            energy_budget: 3.0,
        }
    }
}

impl SelectionSpec {
    fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(obj, &["policy", "lat_budget", "energy_budget"], ctx)?;
        if let Some(s) = str_field(obj, "policy", ctx)? {
            self.policy = match SelectionPolicy::parse(s) {
                Some(p) => p,
                None => bail!(
                    "{ctx}.policy: unknown policy {s:?} (known: min-dacc-within-budget, min-dacc, knee)"
                ),
            };
        }
        if let Some(x) = f64_field(obj, "lat_budget", ctx)? {
            self.lat_budget = x;
        }
        if let Some(x) = f64_field(obj, "energy_budget", ctx)? {
            self.energy_budget = x;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("policy", json::s(self.policy.as_str())),
            ("lat_budget", json::num(self.lat_budget)),
            ("energy_budget", json::num(self.energy_budget)),
        ])
    }

    /// Apply the policy to a front.
    pub fn select<'f>(&self, front: &'f [Individual]) -> Option<&'f Individual> {
        match self.policy {
            SelectionPolicy::MinDaccWithinBudget => {
                select_min_dacc_within_budget(front, self.lat_budget, self.energy_budget)
            }
            SelectionPolicy::MinDacc => select_min_dacc(front),
            SelectionPolicy::Knee => select_knee(front),
        }
    }

    /// Run one three-objective offline optimization through the batched
    /// evaluation engine and deploy per this policy — the shared driver
    /// behind `afarepart offline` and every campaign cell.
    pub fn optimize_and_deploy(
        &self,
        ev: &mut PartitionEvaluator,
        nsga2: &Nsga2Config,
        on_gen: impl FnMut(&GenStats),
    ) -> Result<OfflineOutcome> {
        let (front, evaluations) = optimize_partitions_counted(ev, nsga2, true, vec![], on_gen);
        let Some(chosen) = self.select(&front) else {
            bail!("NSGA-II returned an empty front");
        };
        let deployed = Mapping(chosen.genome.clone());
        let deployed_objectives = chosen.objectives.clone();
        let cache = ev.cache_stats();
        Ok(OfflineOutcome { front, deployed, deployed_objectives, evaluations, cache })
    }
}

/// The complete, declarative experiment description. One JSON document
/// (or builder chain) describes everything a run needs; `Default` is the
/// paper's setup and reproduces the pre-redesign behaviour bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    /// Artifacts directory (HLO, weights, manifests, eval data).
    pub artifacts_dir: PathBuf,
    /// Model name (must appear in artifacts/index.json).
    pub model: String,
    /// Eval-set sample budget for exact ΔAcc evaluation (0 = all).
    pub eval_limit: usize,
    /// Eval batches per exact ΔAcc evaluation (0 = all prepared).
    pub dacc_batches: usize,
    /// Use the sensitivity surrogate instead of exact injection.
    pub surrogate: bool,
    /// Worker threads for batched ΔAcc evaluation (0 = auto).
    pub eval_threads: usize,
    /// Cell-level worker threads for `afarepart campaign` (0 = auto:
    /// split the machine against `eval_threads`, see
    /// [`campaign::run_campaign`]). Ignored outside campaigns.
    pub campaign_workers: usize,
    /// Include link latency/energy in the objectives (CNNParted mode).
    pub link_cost: bool,
    /// Master seed (offline NSGA-II + exact-mode fault draws).
    pub seed: u64,
    pub platform: PlatformSpec,
    pub fault_env: FaultEnvSpec,
    pub optimizer: OptimizerSpec,
    pub selection: SelectionSpec,
    pub online: OnlineSpec,
    /// Serving-system chaos injection (off by default).
    pub chaos: ChaosSpec,
    /// Observability: metric registry + JSONL trace (off by default).
    pub telemetry: TelemetrySpec,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            artifacts_dir: crate::runtime::ArtifactIndex::default_dir(),
            model: "alexnet".into(),
            eval_limit: 256,
            dacc_batches: 0,
            surrogate: false,
            eval_threads: 0,
            campaign_workers: 0,
            link_cost: false,
            seed: 7,
            platform: PlatformSpec::default(),
            fault_env: FaultEnvSpec::default(),
            optimizer: OptimizerSpec::default(),
            selection: SelectionSpec::default(),
            online: OnlineSpec::default(),
            chaos: ChaosSpec::default(),
            telemetry: TelemetrySpec::default(),
        }
    }
}

const TOP_LEVEL_KEYS: &[&str] = &[
    "artifacts_dir",
    "model",
    "eval_limit",
    "dacc_batches",
    "surrogate",
    "eval_threads",
    "campaign_workers",
    "link_cost",
    "seed",
    "platform",
    "fault_env",
    "optimizer",
    "selection",
    "online",
    "chaos",
    "telemetry",
];

impl ExperimentSpec {
    /// Apply a (possibly partial) JSON document over this spec. Strict:
    /// unknown keys anywhere in the tree are hard errors.
    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        let obj = expect_obj(v, "spec")?;
        reject_unknown(obj, TOP_LEVEL_KEYS, "spec")?;
        if let Some(s) = str_field(obj, "artifacts_dir", "spec")? {
            self.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = str_field(obj, "model", "spec")? {
            self.model = s.to_string();
        }
        if let Some(x) = usize_field(obj, "eval_limit", "spec")? {
            self.eval_limit = x;
        }
        if let Some(x) = usize_field(obj, "dacc_batches", "spec")? {
            self.dacc_batches = x;
        }
        if let Some(b) = bool_field(obj, "surrogate", "spec")? {
            self.surrogate = b;
        }
        if let Some(x) = usize_field(obj, "eval_threads", "spec")? {
            self.eval_threads = x;
        }
        if let Some(x) = usize_field(obj, "campaign_workers", "spec")? {
            self.campaign_workers = x;
        }
        if let Some(b) = bool_field(obj, "link_cost", "spec")? {
            self.link_cost = b;
        }
        if let Some(x) = u64_field(obj, "seed", "spec")? {
            self.seed = x;
        }
        if let Some(v) = obj.get("platform") {
            self.platform.apply_json(expect_obj(v, "spec.platform")?, "spec.platform")?;
        }
        if let Some(v) = obj.get("fault_env") {
            self.fault_env.apply_json(expect_obj(v, "spec.fault_env")?, "spec.fault_env")?;
        }
        if let Some(v) = obj.get("optimizer") {
            self.optimizer.apply_json(expect_obj(v, "spec.optimizer")?, "spec.optimizer")?;
        }
        if let Some(v) = obj.get("selection") {
            self.selection.apply_json(expect_obj(v, "spec.selection")?, "spec.selection")?;
        }
        if let Some(v) = obj.get("online") {
            self.online.apply_json(expect_obj(v, "spec.online")?, "spec.online")?;
        }
        if let Some(v) = obj.get("chaos") {
            self.chaos.apply_json(expect_obj(v, "spec.chaos")?, "spec.chaos")?;
        }
        if let Some(v) = obj.get("telemetry") {
            self.telemetry.apply_json(expect_obj(v, "spec.telemetry")?, "spec.telemetry")?;
        }
        Ok(())
    }

    /// Parse a complete spec from a JSON string (strict).
    pub fn from_json_str(text: &str) -> Result<ExperimentSpec> {
        let v = json::parse(text).context("spec: invalid json")?;
        let mut spec = ExperimentSpec::default();
        spec.apply_json(&v)?;
        Ok(spec)
    }

    /// Load a spec file (strict).
    pub fn from_file(path: &Path) -> Result<ExperimentSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {}", path.display()))?;
        Self::from_json_str(&text).with_context(|| format!("spec {}", path.display()))
    }

    /// Canonical JSON form (every key present; round-trips exactly).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("artifacts_dir", json::s(&self.artifacts_dir.display().to_string())),
            ("model", json::s(&self.model)),
            ("eval_limit", json::num(self.eval_limit as f64)),
            ("dacc_batches", json::num(self.dacc_batches as f64)),
            ("surrogate", Value::Bool(self.surrogate)),
            ("eval_threads", json::num(self.eval_threads as f64)),
            ("campaign_workers", json::num(self.campaign_workers as f64)),
            ("link_cost", Value::Bool(self.link_cost)),
            ("seed", json::num(self.seed as f64)),
            ("platform", self.platform.to_json()),
            ("fault_env", self.fault_env.to_json()),
            ("optimizer", self.optimizer.to_json()),
            ("selection", self.selection.to_json()),
            ("online", self.online.to_json()),
            ("chaos", self.chaos.to_json()),
            ("telemetry", self.telemetry.to_json()),
        ])
    }

    pub fn to_json_string(&self) -> String {
        json::to_string(&self.to_json())
    }

    /// Environment overrides (`AFARE_POP`, `AFARE_GENS`,
    /// `AFARE_EVAL_LIMIT`, `AFARE_EVAL_THREADS`,
    /// `AFARE_CAMPAIGN_WORKERS`, `AFARE_SELECTION_THREADS`) — used to
    /// shrink bench budgets (or force an optimizer code path in CI)
    /// without touching files. Injectable lookup for testability;
    /// [`ExperimentSpec::resolve`] passes the process environment.
    pub fn apply_env_with(&mut self, getenv: impl Fn(&str) -> Option<String>) {
        if let Some(v) = getenv("AFARE_POP").and_then(|v| v.parse().ok()) {
            self.optimizer.pop_size = v;
        }
        if let Some(v) = getenv("AFARE_GENS").and_then(|v| v.parse().ok()) {
            self.optimizer.generations = v;
        }
        if let Some(v) = getenv("AFARE_EVAL_LIMIT").and_then(|v| v.parse().ok()) {
            self.eval_limit = v;
        }
        if let Some(v) = getenv("AFARE_EVAL_THREADS").and_then(|v| v.parse().ok()) {
            self.eval_threads = v;
        }
        if let Some(v) = getenv("AFARE_CAMPAIGN_WORKERS").and_then(|v| v.parse().ok()) {
            self.campaign_workers = v;
        }
        if let Some(v) = getenv("AFARE_SELECTION_THREADS").and_then(|v| v.parse().ok()) {
            self.optimizer.selection_threads = v;
        }
    }

    /// CLI overrides (the highest-precedence layer).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(m) = args.get("model") {
            self.model = m.to_string();
        }
        if let Some(a) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(a);
        }
        self.fault_env.fault_rate = args.get_f32("fault-rate", self.fault_env.fault_rate);
        if let Some(s) = args.get("scenario") {
            self.fault_env.scenario =
                FaultScenario::parse(s).with_context(|| format!("bad --scenario {s:?}"))?;
        }
        self.optimizer.pop_size = args.get_usize("pop", self.optimizer.pop_size);
        self.optimizer.generations = args.get_usize("gens", self.optimizer.generations);
        self.online.theta = args.get_f64("theta", self.online.theta);
        self.online.ticks = args.get_usize("ticks", self.online.ticks);
        self.online.lookahead = args.get_usize("lookahead", self.online.lookahead);
        self.eval_limit = args.get_usize("eval-limit", self.eval_limit);
        self.dacc_batches = args.get_usize("dacc-batches", self.dacc_batches);
        self.eval_threads = args.get_usize("eval-threads", self.eval_threads);
        self.campaign_workers = args.get_usize("campaign-workers", self.campaign_workers);
        self.optimizer.selection_threads =
            args.get_usize("selection-threads", self.optimizer.selection_threads);
        if let Some(s) = args.get("policy") {
            self.selection.policy = SelectionPolicy::parse(s)
                .with_context(|| format!("bad --policy {s:?} (min-dacc-within-budget, min-dacc, knee)"))?;
        }
        self.selection.lat_budget = args.get_f64("lat-budget", self.selection.lat_budget);
        self.selection.energy_budget = args.get_f64("energy-budget", self.selection.energy_budget);
        if args.has_flag("surrogate") {
            self.surrogate = true;
        }
        if args.has_flag("link-cost") {
            self.link_cost = true;
        }
        if args.has_flag("chaos") {
            self.chaos.enabled = true;
        }
        self.chaos.seed = args.get_u64("chaos-seed", self.chaos.seed);
        if let Some(p) = args.get("trace") {
            self.telemetry.trace = Some(p.to_string());
            self.telemetry.enabled = true;
        }
        if args.has_flag("telemetry") {
            self.telemetry.enabled = true;
        }
        self.seed = args.get_u64("seed", self.seed);
        Ok(())
    }

    /// THE precedence chain, in one place: defaults, then the
    /// `--spec`/`--config` file (if given), then `AFARE_*` environment
    /// variables, then CLI flags. Later layers win.
    pub fn resolve(args: &Args) -> Result<ExperimentSpec> {
        Self::resolve_with(args, |k| std::env::var(k).ok())
    }

    /// [`ExperimentSpec::resolve`] with an injectable environment (the
    /// precedence regression tests use this to avoid mutating the real
    /// process environment).
    pub fn resolve_with(
        args: &Args,
        getenv: impl Fn(&str) -> Option<String>,
    ) -> Result<ExperimentSpec> {
        let mut spec = ExperimentSpec::default();
        if let Some(p) = args.get("spec").or_else(|| args.get("config")) {
            let text = std::fs::read_to_string(p).with_context(|| format!("reading spec {p}"))?;
            let v = json::parse(&text).with_context(|| format!("spec {p}: invalid json"))?;
            spec.apply_json(&v).with_context(|| format!("spec {p}"))?;
        }
        spec.apply_env_with(getenv);
        spec.apply_args(args)?;
        Ok(spec)
    }

    /// The optimizer config with the telemetry-declared convergence
    /// reference applied (`telemetry.hv_reference` pins the hypervolume
    /// reference point so convergence analytics compare across runs).
    pub fn nsga2_config(&self) -> Nsga2Config {
        let mut cfg = self.optimizer.to_nsga2(self.seed);
        cfg.hv_reference = self.telemetry.hv_reference.clone();
        cfg
    }

    /// The flat runtime view consumed by [`crate::experiment::Experiment`]
    /// and the benches.
    pub fn to_config(&self) -> ExperimentConfig {
        let nsga2 = self.nsga2_config();
        ExperimentConfig {
            artifacts_dir: self.artifacts_dir.clone(),
            model: self.model.clone(),
            fault_rate: self.fault_env.fault_rate,
            scenario: self.fault_env.scenario,
            nsga2,
            theta: self.online.theta,
            eval_limit: self.eval_limit,
            dacc_batches: self.dacc_batches,
            surrogate: self.surrogate,
            eval_threads: self.eval_threads,
            link_cost: self.link_cost,
            lat_budget: self.selection.lat_budget,
            energy_budget: self.selection.energy_budget,
            seed: self.seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(raw: &[&str]) -> Args {
        let raw: Vec<String> = raw.iter().map(|s| s.to_string()).collect();
        Args::parse(&raw, &["surrogate", "link-cost", "chaos", "telemetry", "verbose", "help"])
    }

    #[test]
    fn default_to_config_matches_legacy_defaults() {
        let cfg = ExperimentSpec::default().to_config();
        let legacy = ExperimentConfig::default();
        assert_eq!(cfg.model, legacy.model);
        assert_eq!(cfg.fault_rate, legacy.fault_rate);
        assert_eq!(cfg.scenario, legacy.scenario);
        assert_eq!(cfg.nsga2.pop_size, legacy.nsga2.pop_size);
        assert_eq!(cfg.nsga2.generations, legacy.nsga2.generations);
        assert_eq!(cfg.nsga2.seed, legacy.nsga2.seed);
        assert_eq!(cfg.theta, legacy.theta);
        assert_eq!(cfg.eval_limit, legacy.eval_limit);
        assert_eq!(cfg.lat_budget, legacy.lat_budget);
        assert_eq!(cfg.energy_budget, legacy.energy_budget);
        assert_eq!(cfg.seed, legacy.seed);
    }

    #[test]
    fn cli_beats_env_beats_defaults() {
        // regression for the old main.rs bug: apply_args() ran *before*
        // apply_env(), so AFARE_POP silently overrode an explicit --pop.
        let a = args(&["offline", "--pop", "10"]);
        let spec = ExperimentSpec::resolve_with(&a, |k| match k {
            "AFARE_POP" => Some("99".into()),
            "AFARE_GENS" => Some("5".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(spec.optimizer.pop_size, 10, "CLI must beat AFARE_POP");
        assert_eq!(spec.optimizer.generations, 5, "env must beat defaults");
    }

    #[test]
    fn unknown_top_level_key_rejected() {
        let mut spec = ExperimentSpec::default();
        let v = json::parse(r#"{"modle": "alexnet"}"#).unwrap();
        let err = spec.apply_json(&v).unwrap_err();
        assert!(format!("{err}").contains("modle"), "{err}");
    }

    #[test]
    fn json_round_trip_is_identity() {
        let spec = ExperimentSpec::default();
        let text = spec.to_json_string();
        let back = ExperimentSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn selection_policy_round_trip() {
        for p in [SelectionPolicy::MinDaccWithinBudget, SelectionPolicy::MinDacc, SelectionPolicy::Knee]
        {
            assert_eq!(SelectionPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(SelectionPolicy::parse("best-effort"), None);
    }

    #[test]
    fn chaos_flag_enables_injection() {
        let a = args(&["online", "--chaos", "--chaos-seed", "77"]);
        let spec = ExperimentSpec::resolve_with(&a, |_| None).unwrap();
        assert!(spec.chaos.enabled);
        assert_eq!(spec.chaos.seed, 77);
        // default: off, with the standard component stack ready to arm
        let quiet = ExperimentSpec::resolve_with(&args(&["online"]), |_| None).unwrap();
        assert!(!quiet.chaos.enabled);
        assert!(!quiet.chaos.to_engine().is_enabled());
    }

    #[test]
    fn trace_flag_enables_telemetry() {
        let a = args(&["online", "--trace", "/tmp/run.jsonl"]);
        let spec = ExperimentSpec::resolve_with(&a, |_| None).unwrap();
        assert!(spec.telemetry.enabled);
        assert_eq!(spec.telemetry.trace.as_deref(), Some("/tmp/run.jsonl"));
        let b = args(&["online", "--telemetry"]);
        let spec = ExperimentSpec::resolve_with(&b, |_| None).unwrap();
        assert!(spec.telemetry.enabled);
        assert!(spec.telemetry.trace.is_none());
        // default stays fully off
        let quiet = ExperimentSpec::resolve_with(&args(&["online"]), |_| None).unwrap();
        assert!(!quiet.telemetry.enabled);
    }

    #[test]
    fn campaign_workers_follows_the_precedence_chain() {
        // env beats defaults
        let spec = ExperimentSpec::resolve_with(&args(&["campaign"]), |k| match k {
            "AFARE_CAMPAIGN_WORKERS" => Some("3".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(spec.campaign_workers, 3);
        // CLI beats env
        let a = args(&["campaign", "--campaign-workers", "2"]);
        let spec = ExperimentSpec::resolve_with(&a, |k| match k {
            "AFARE_CAMPAIGN_WORKERS" => Some("9".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(spec.campaign_workers, 2);
        // default: auto
        assert_eq!(ExperimentSpec::default().campaign_workers, 0);
    }

    #[test]
    fn selection_threads_follows_the_precedence_chain() {
        // default: legacy serial
        let spec = ExperimentSpec::default();
        assert_eq!(spec.optimizer.selection_threads, 1);
        assert_eq!(spec.to_config().nsga2.selection_threads, 1);
        // env beats defaults
        let spec = ExperimentSpec::resolve_with(&args(&["offline"]), |k| match k {
            "AFARE_SELECTION_THREADS" => Some("4".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(spec.optimizer.selection_threads, 4);
        // CLI beats env
        let a = args(&["offline", "--selection-threads", "2"]);
        let spec = ExperimentSpec::resolve_with(&a, |k| match k {
            "AFARE_SELECTION_THREADS" => Some("8".into()),
            _ => None,
        })
        .unwrap();
        assert_eq!(spec.optimizer.selection_threads, 2);
        assert_eq!(spec.to_config().nsga2.selection_threads, 2);
        // JSON file layer parses + round-trips the key
        let spec =
            ExperimentSpec::from_json_str(r#"{"optimizer": {"selection_threads": 3}}"#).unwrap();
        assert_eq!(spec.optimizer.selection_threads, 3);
    }

    #[test]
    fn seed_feeds_optimizer() {
        let a = args(&["offline", "--seed", "123"]);
        let spec = ExperimentSpec::resolve_with(&a, |_| None).unwrap();
        assert_eq!(spec.seed, 123);
        assert_eq!(spec.to_config().nsga2.seed, 123);
    }
}
