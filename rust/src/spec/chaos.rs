//! Declarative chaos injection: the `spec.chaos` section. Off by
//! default; when enabled, composable serving-failure components (worker
//! crashes, transient errors, link drops/delays, reply corruption) are
//! planned per tick by a seeded [`ChaosEngine`] — the serving-system
//! analogue of the `fault_env.drift` stack.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::schema::*;
use crate::faults::{ChaosComponent, ChaosEngine, ChaosKind};
use crate::util::json::{self, Value};

pub(crate) fn chaos_component_from_json(v: &Value, ctx: &str) -> Result<ChaosComponent> {
    let obj = expect_obj(v, ctx)?;
    let kind = require_str(obj, "kind", ctx)?.to_string();
    let rate = match f64_field(obj, "rate", ctx)? {
        Some(x) => x,
        None => bail!("{ctx}: missing required key \"rate\""),
    };
    if !(0.0..=1.0).contains(&rate) {
        bail!("{ctx}.rate: {rate} outside [0, 1]");
    }
    let burst = |obj: &BTreeMap<String, Value>| -> Result<u32> {
        match usize_field(obj, "burst", ctx)? {
            Some(b) if b >= 1 => Ok(b as u32),
            Some(b) => bail!("{ctx}.burst: {b} must be >= 1"),
            None => Ok(1),
        }
    };
    let window_keys: &[&str] = &["from_tick", "until_tick"];
    let with_window = |mut keys: Vec<&'static str>| -> Vec<&'static str> {
        keys.extend_from_slice(window_keys);
        keys
    };
    let chaos_kind = match kind.as_str() {
        "worker-crash" => {
            reject_unknown(obj, &with_window(vec!["kind", "rate"]), ctx)?;
            ChaosKind::WorkerCrash
        }
        "transient-error" => {
            reject_unknown(obj, &with_window(vec!["kind", "rate", "burst"]), ctx)?;
            ChaosKind::TransientError { burst: burst(obj)? }
        }
        "link-drop" => {
            reject_unknown(obj, &with_window(vec!["kind", "rate", "burst"]), ctx)?;
            ChaosKind::LinkDrop { burst: burst(obj)? }
        }
        "link-delay" => {
            reject_unknown(obj, &with_window(vec!["kind", "rate", "ms"]), ctx)?;
            let ms = match f64_field(obj, "ms", ctx)? {
                Some(x) if x >= 0.0 => x,
                Some(x) => bail!("{ctx}.ms: {x} must be >= 0"),
                None => bail!("{ctx}: chaos kind \"link-delay\" requires key \"ms\""),
            };
            ChaosKind::LinkDelay { ms }
        }
        "reply-corrupt" => {
            reject_unknown(obj, &with_window(vec!["kind", "rate"]), ctx)?;
            ChaosKind::ReplyCorrupt
        }
        other => bail!(
            "{ctx}.kind: unknown chaos kind {other:?} (known: worker-crash, \
             transient-error, link-drop, link-delay, reply-corrupt)"
        ),
    };
    let from_tick = usize_field(obj, "from_tick", ctx)?.unwrap_or(0);
    let until_tick = usize_field(obj, "until_tick", ctx)?.unwrap_or(0);
    if until_tick != 0 && until_tick <= from_tick {
        bail!("{ctx}: until_tick {until_tick} must exceed from_tick {from_tick} (or be 0)");
    }
    Ok(ChaosComponent { kind: chaos_kind, rate, from_tick, until_tick })
}

pub(crate) fn chaos_component_to_json(c: &ChaosComponent) -> Value {
    let mut pairs = match &c.kind {
        ChaosKind::WorkerCrash => vec![("kind", json::s("worker-crash"))],
        ChaosKind::TransientError { burst } => vec![
            ("kind", json::s("transient-error")),
            ("burst", json::num(*burst as f64)),
        ],
        ChaosKind::LinkDrop { burst } => {
            vec![("kind", json::s("link-drop")), ("burst", json::num(*burst as f64))]
        }
        ChaosKind::LinkDelay { ms } => {
            vec![("kind", json::s("link-delay")), ("ms", json::num(*ms))]
        }
        ChaosKind::ReplyCorrupt => vec![("kind", json::s("reply-corrupt"))],
    };
    pairs.push(("rate", json::num(c.rate)));
    pairs.push(("from_tick", json::num(c.from_tick as f64)));
    pairs.push(("until_tick", json::num(c.until_tick as f64)));
    json::obj(pairs)
}

/// The declarative chaos section.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosSpec {
    /// Master switch; `false` keeps the serving path chaos-free (and
    /// byte-identical to a build without this module).
    pub enabled: bool,
    /// Chaos PRNG seed — independent of the serving loop's seed, so
    /// toggling chaos never perturbs canary keys.
    pub seed: u64,
    /// Component stack; defaults to [`ChaosEngine::default_stack`].
    pub components: Vec<ChaosComponent>,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec { enabled: false, seed: 1337, components: ChaosEngine::default_stack() }
    }
}

impl ChaosSpec {
    pub(crate) fn apply_json(&mut self, obj: &BTreeMap<String, Value>, ctx: &str) -> Result<()> {
        reject_unknown(obj, &["enabled", "seed", "components"], ctx)?;
        if let Some(b) = bool_field(obj, "enabled", ctx)? {
            self.enabled = b;
        }
        if let Some(s) = u64_field(obj, "seed", ctx)? {
            self.seed = s;
        }
        if let Some(v) = obj.get("components") {
            let ctx = format!("{ctx}.components");
            self.components = expect_arr(v, &ctx)?
                .iter()
                .enumerate()
                .map(|(i, c)| chaos_component_from_json(c, &format!("{ctx}[{i}]")))
                .collect::<Result<Vec<_>>>()?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("enabled", Value::Bool(self.enabled)),
            ("seed", json::num(self.seed as f64)),
            ("components", json::arr(self.components.iter().map(chaos_component_to_json))),
        ])
    }

    /// Materialize the engine; a disabled spec plans nothing.
    pub fn to_engine(&self) -> ChaosEngine {
        if self.enabled {
            ChaosEngine::new(self.seed, self.components.clone())
        } else {
            ChaosEngine::disabled()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_off_with_the_standard_stack() {
        let spec = ChaosSpec::default();
        assert!(!spec.enabled);
        assert_eq!(spec.components, ChaosEngine::default_stack());
        assert!(!spec.to_engine().is_enabled());
        assert!(spec.to_engine().plan(17).is_noop());
    }

    #[test]
    fn components_parse_with_windows_and_bursts() {
        let mut spec = ChaosSpec::default();
        let v = crate::util::json::parse(
            r#"{"enabled": true, "seed": 7, "components": [
                {"kind": "worker-crash", "rate": 0.1},
                {"kind": "transient-error", "rate": 0.5, "burst": 2, "from_tick": 5, "until_tick": 9},
                {"kind": "link-drop", "rate": 0.2},
                {"kind": "link-delay", "rate": 1.0, "ms": 12.5},
                {"kind": "reply-corrupt", "rate": 0.3}
            ]}"#,
        )
        .unwrap();
        spec.apply_json(v.as_obj().unwrap(), "chaos").unwrap();
        assert!(spec.enabled);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.components.len(), 5);
        assert_eq!(
            spec.components[1],
            ChaosComponent::transient(0.5, 2).window(5, 9)
        );
        assert!(spec.to_engine().is_enabled());
    }

    #[test]
    fn component_round_trips_through_json() {
        for comp in [
            ChaosComponent::crash(0.25),
            ChaosComponent::transient(0.5, 3).window(2, 10),
            ChaosComponent::drop(0.1, 2),
            ChaosComponent::delay(1.0, 40.0),
            ChaosComponent::corrupt(0.02),
        ] {
            let v = chaos_component_to_json(&comp);
            let back = chaos_component_from_json(&v, "c").unwrap();
            assert_eq!(back, comp);
        }
    }

    #[test]
    fn bad_components_rejected() {
        for (src, why) in [
            (r#"{"kind": "worker-crash", "rate": 0.1, "burst": 2}"#, "burst on crash"),
            (r#"{"kind": "link-delay", "rate": 0.5}"#, "delay without ms"),
            (r#"{"kind": "meteor", "rate": 0.5}"#, "unknown kind"),
            (r#"{"kind": "worker-crash", "rate": 1.5}"#, "rate out of range"),
            (r#"{"kind": "link-drop", "rate": 0.5, "burst": 0}"#, "zero burst"),
            (r#"{"kind": "worker-crash", "rate": 0.1, "from_tick": 9, "until_tick": 3}"#, "inverted window"),
        ] {
            let v = crate::util::json::parse(src).unwrap();
            assert!(chaos_component_from_json(&v, "c").is_err(), "{why}");
        }
    }

    #[test]
    fn unknown_section_key_rejected() {
        let mut spec = ChaosSpec::default();
        let v = crate::util::json::parse(r#"{"enable": true}"#).unwrap();
        assert!(spec.apply_json(v.as_obj().unwrap(), "chaos").is_err());
    }
}
