//! # AFarePart — Accuracy-aware Fault-resilient DNN Partitioner
//!
//! Reproduction of *"AFarePart: Accuracy-aware Fault-resilient Partitioner
//! for DNN Edge Accelerators"* (Debnath et al., 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: NSGA-II multi-objective
//!   partitioner with fault-injected accuracy as a first-class objective,
//!   analytical Eyeriss/SIMBA hardware cost models, a drifting fault
//!   environment, and an online serving loop with θ-triggered dynamic
//!   repartitioning (paper Algorithm 1).
//! * **L2 (python/compile, build-time)** — quantized CNN forwards with
//!   in-graph probabilistic bit-flip fault injection, AOT-lowered to HLO
//!   text.
//! * **L1 (python/compile/kernels, build-time)** — Pallas kernels for the
//!   bit-flip + dequantize hot spot and the dequant-fused matmul.
//!
//! The rust binary executes the compiled artifacts through PJRT
//! ([`runtime`]); python never runs on the request path.
//!
//! Quickstart: `make artifacts && cargo run --release -- offline --model alexnet`
//! (see examples/ for library usage).

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod experiment;
pub mod faults;
pub mod hw;
pub mod model;
pub mod nsga2;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod spec;
pub mod util;
