//! Deterministic observability subsystem: metric registry, hierarchical
//! span timing, JSONL event trace, and Prometheus snapshot export.
//!
//! Everything routes through one cheap, cloneable [`Telemetry`] handle:
//!
//! ```text
//!   Telemetry ──┬── MetricRegistry   lock-striped counters / gauges /
//!               │                    fixed-bucket histograms (p50/95/99)
//!               ├── Span             scope-guard wall timing -> histograms
//!               │                    + one logical trace event per span
//!               └── TraceWriter      append-only JSONL (--trace <file>),
//!                                    schema-versioned, bitwise-deterministic
//! ```
//!
//! **Off by default.** [`Telemetry::disabled`] carries no allocation and
//! every recording method early-outs on one `Option` branch, so
//! instrumentation sites cost nothing measurable on the hot path (gated
//! <2% by `BENCH_telemetry_overhead.json`, see `benches/bench_perf.rs`).
//!
//! **Determinism contract.** Trace events are emitted only from
//! coordinating threads in logical order (tick, generation, batch
//! ordinal), never from fan-out workers, and never carry wall-clock
//! values; wall times go to registry histograms, which deterministic
//! consumers strip (`scripts/trace_smoke.sh`). Given the same spec and
//! seed, a `--trace` file is bitwise identical at any `eval_threads`.

pub mod analyze;
pub mod prometheus;
pub mod registry;
pub mod span;
pub mod trace;

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

pub use analyze::{analyze_file, analyze_str, TraceAnalysis};
pub use registry::{Histogram, MetricRegistry, MetricSnapshot, MS_BUCKETS};
pub use span::Span;
pub use trace::{TraceWriter, TRACE_SCHEMA_VERSION};

use crate::util::json::Value;

struct TelemetryInner {
    registry: MetricRegistry,
    trace: Option<Mutex<TraceWriter>>,
    /// Latched on the first trace write error so one bad disk doesn't
    /// spam stderr per event.
    trace_failed: AtomicBool,
}

/// Shared handle to the run's telemetry (see module doc). Cloning is a
/// refcount bump; a disabled handle is a `None` and costs one branch
/// per recording call.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Arc<TelemetryInner>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry")
            .field("enabled", &self.is_enabled())
            .field("trace", &self.has_trace())
            .finish()
    }
}

impl Telemetry {
    /// The no-op handle every component starts with.
    pub fn disabled() -> Telemetry {
        Telemetry { inner: None }
    }

    /// Registry-only telemetry (no trace file).
    pub fn enabled() -> Telemetry {
        Telemetry {
            inner: Some(Arc::new(TelemetryInner {
                registry: MetricRegistry::new(),
                trace: None,
                trace_failed: AtomicBool::new(false),
            })),
        }
    }

    /// Registry + JSONL trace appended to `path` (truncated on open).
    pub fn with_trace(path: &Path) -> Result<Telemetry> {
        let writer = TraceWriter::create(path)?;
        Ok(Telemetry {
            inner: Some(Arc::new(TelemetryInner {
                registry: MetricRegistry::new(),
                trace: Some(Mutex::new(writer)),
                trace_failed: AtomicBool::new(false),
            })),
        })
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Whether a trace file is attached.
    pub fn has_trace(&self) -> bool {
        self.inner.as_ref().map(|i| i.trace.is_some()).unwrap_or(false)
    }

    /// Add to a monotonic counter; no-op when disabled.
    pub fn counter_add(&self, name: &str, delta: u64) {
        if let Some(inner) = &self.inner {
            inner.registry.counter_add(name, delta);
        }
    }

    /// Current counter value (0 when disabled or never touched).
    pub fn counter_get(&self, name: &str) -> u64 {
        match &self.inner {
            Some(inner) => inner.registry.counter_get(name),
            None => 0,
        }
    }

    /// Set a gauge; no-op when disabled.
    pub fn gauge_set(&self, name: &str, v: f64) {
        if let Some(inner) = &self.inner {
            inner.registry.gauge_set(name, v);
        }
    }

    /// Record a wall-time histogram observation; no-op when disabled.
    pub fn observe_ms(&self, name: &str, ms: f64) {
        if let Some(inner) = &self.inner {
            inner.registry.observe_ms(name, ms);
        }
    }

    /// Open a scope-guard span (inert when disabled).
    pub fn span(&self, path: &str) -> Span<'_> {
        Span::new(self, path)
    }

    /// Fold an externally-measured span into the registry and trace as
    /// if a [`Span`] guard had closed here: record `ms` into the
    /// `span_<path>_ms` histogram and emit one `span` trace event with
    /// the given logical fields. This is how coordinators surface work
    /// that was *timed on a fan-out worker* without breaking the
    /// determinism contract — the worker measures, the coordinating
    /// thread emits in logical order (the campaign scheduler uses it
    /// for `campaign.cell`). `fields` must carry only deterministic
    /// logical coordinates, never wall-clock values.
    pub fn emit_span(&self, path: &str, ms: f64, fields: &[(&str, Value)]) {
        if !self.is_enabled() {
            return;
        }
        let metric = format!("span_{}_ms", path.replace('.', "_"));
        self.observe_ms(&metric, ms);
        self.trace_event("span", Some(path), fields);
    }

    /// Emit one trace event with deterministic logical fields. No-op
    /// without an attached trace file. Callers must only invoke this
    /// from coordinating threads, in logical order (module doc).
    pub fn trace_event(&self, kind: &str, span: Option<&str>, fields: &[(&str, Value)]) {
        let Some(inner) = &self.inner else { return };
        let Some(trace) = &inner.trace else { return };
        let mut w = trace.lock().unwrap();
        if let Err(e) = w.emit(kind, span, fields) {
            if !inner.trace_failed.swap(true, Ordering::Relaxed) {
                eprintln!("warning: trace disabled after write error: {e:#}");
            }
        }
    }

    /// Point-in-time metric snapshot (`None` when disabled).
    pub fn snapshot(&self) -> Option<MetricSnapshot> {
        self.inner.as_ref().map(|i| i.registry.snapshot())
    }

    /// Prometheus text-format snapshot (`None` when disabled).
    pub fn prometheus(&self) -> Option<String> {
        self.snapshot().map(|s| prometheus::render(&s))
    }

    /// Flush the trace file, if any.
    pub fn flush(&self) -> Result<()> {
        if let Some(inner) = &self.inner {
            if let Some(trace) = &inner.trace {
                trace.lock().unwrap().flush()?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn disabled_handle_is_fully_inert() {
        let t = Telemetry::disabled();
        assert!(!t.is_enabled());
        assert!(!t.has_trace());
        t.counter_add("x_total", 5);
        t.gauge_set("g", 1.0);
        t.observe_ms("h_ms", 0.1);
        t.trace_event("tick", None, &[("tick", num(0.0))]);
        assert_eq!(t.counter_get("x_total"), 0);
        assert!(t.snapshot().is_none());
        assert!(t.prometheus().is_none());
        t.flush().unwrap();
    }

    #[test]
    fn enabled_handle_records_and_renders() {
        let t = Telemetry::enabled();
        t.counter_add("evals_total", 3);
        t.counter_add("evals_total", 4);
        t.gauge_set("front_size", 9.0);
        assert_eq!(t.counter_get("evals_total"), 7);
        let text = t.prometheus().unwrap();
        assert!(text.contains("afare_evals_total 7"));
        assert!(text.contains("afare_front_size 9"));
    }

    #[test]
    fn emit_span_matches_guard_span_shape() {
        let t = Telemetry::enabled();
        t.emit_span("campaign.cell", 3.0, &[("cell", num(0.0))]);
        let snap = t.snapshot().expect("enabled telemetry has a snapshot");
        assert_eq!(snap.histograms["span_campaign_cell_ms"].count, 1);
        // disabled handle: fully inert
        Telemetry::disabled().emit_span("campaign.cell", 1.0, &[]);
    }

    #[test]
    fn clones_share_one_registry() {
        let t = Telemetry::enabled();
        let u = t.clone();
        u.counter_add("shared_total", 2);
        assert_eq!(t.counter_get("shared_total"), 2);
    }

    #[test]
    fn trace_handle_writes_events() {
        let mut path = std::env::temp_dir();
        path.push(format!("afare_obs_mod_test_{}.jsonl", std::process::id()));
        {
            let t = Telemetry::with_trace(&path).unwrap();
            assert!(t.has_trace());
            t.trace_event("tick", Some("online.tick"), &[("tick", num(1.0))]);
            t.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().nth(1).unwrap().contains("\"kind\":\"tick\""));
        std::fs::remove_file(&path).ok();
    }
}
