//! Append-only JSONL event trace (`--trace <file>`).
//!
//! One JSON object per line, every line self-describing with a
//! `schema` version, a strictly increasing `seq`, and a `kind`. The
//! trace is part of the run's *deterministic* output: given the same
//! spec and seed, two runs produce bitwise-identical files at any
//! `eval_threads` — so events carry only logical coordinates (tick,
//! generation, batch ordinal, counts) and never wall-clock durations;
//! wall times go to the registry histograms instead and are quantized
//! out of every golden (see `docs/observability.md`).
//!
//! Determinism is guaranteed structurally: events are emitted only
//! from coordinating threads (the optimizer / online / measurement
//! loops), never from fan-out workers, so `seq` order is a pure
//! function of the run's logical schedule.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{num, obj, s, to_string, Value};

/// Version stamped on every trace line; bump on any schema change.
/// v2 (ISSUE 10): fault-attribution ledger (`chaos_inject`,
/// `server_terminal`, `degrade_extend`; `fault` fields on supervision
/// events) and optimizer `convergence` events.
pub const TRACE_SCHEMA_VERSION: u64 = 2;

/// Keys reserved for the envelope; event fields must not use them.
const RESERVED: [&str; 4] = ["schema", "seq", "kind", "span"];

/// Buffered JSONL writer with a monotonic sequence number.
pub struct TraceWriter {
    out: BufWriter<File>,
    seq: u64,
}

impl TraceWriter {
    /// Create (truncate) `path` and write the `trace_start` header
    /// event.
    pub fn create(path: &Path) -> Result<TraceWriter> {
        let file = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let mut w = TraceWriter { out: BufWriter::new(file), seq: 0 };
        w.emit("trace_start", None, &[])?;
        Ok(w)
    }

    /// Append one event line: envelope (`schema`, `seq`, `kind`,
    /// optional `span` path) plus the given logical fields. Keys are
    /// emitted name-sorted (the JSON layer is BTreeMap-backed), so the
    /// byte form is independent of field order at the call site.
    pub fn emit(&mut self, kind: &str, span: Option<&str>, fields: &[(&str, Value)]) -> Result<()> {
        let mut pairs: Vec<(&str, Value)> = vec![
            ("schema", num(TRACE_SCHEMA_VERSION as f64)),
            ("seq", num(self.seq as f64)),
            ("kind", s(kind)),
        ];
        if let Some(path) = span {
            pairs.push(("span", s(path)));
        }
        for (k, v) in fields {
            debug_assert!(!RESERVED.contains(k), "trace field {k:?} shadows an envelope key");
            pairs.push((k, v.clone()));
        }
        writeln!(self.out, "{}", to_string(&obj(pairs))).context("writing trace event")?;
        self.seq += 1;
        Ok(())
    }

    /// Events written so far (including the header).
    pub fn events(&self) -> u64 {
        self.seq
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush().context("flushing trace file")
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        // Clean shutdown paths call `flush()` and surface the error via
        // anyhow; this is the last-resort flush, where all we can do is
        // warn instead of silently truncating the trace.
        if let Err(e) = self.out.flush() {
            eprintln!("warning: trace file lost buffered events on drop: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("afare_trace_test_{}_{name}.jsonl", std::process::id()));
        p
    }

    #[test]
    fn lines_are_schema_stamped_and_sequenced() {
        let path = tmp("seq");
        {
            let mut w = TraceWriter::create(&path).unwrap();
            w.emit("tick", Some("online.tick"), &[("tick", num(3.0))]).unwrap();
            w.emit("tick", Some("online.tick"), &[("tick", num(4.0))]).unwrap();
            assert_eq!(w.events(), 3);
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for (i, line) in lines.iter().enumerate() {
            let v = json::parse(line).unwrap();
            assert_eq!(
                v.get("schema").and_then(|x| x.as_f64()),
                Some(TRACE_SCHEMA_VERSION as f64)
            );
            assert_eq!(v.get("seq").and_then(|x| x.as_f64()), Some(i as f64));
        }
        let head = json::parse(lines[0]).unwrap();
        assert_eq!(head.get("kind").and_then(|v| v.as_str()), Some("trace_start"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn identical_emission_is_bitwise_identical() {
        let pa = tmp("det_a");
        let pb = tmp("det_b");
        for p in [&pa, &pb] {
            let mut w = TraceWriter::create(p).unwrap();
            for t in 0..5 {
                w.emit("tick", Some("online.tick"), &[("tick", num(t as f64))]).unwrap();
            }
            w.flush().unwrap();
        }
        let a = std::fs::read(&pa).unwrap();
        let b = std::fs::read(&pb).unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(&pa).ok();
        std::fs::remove_file(&pb).ok();
    }
}
