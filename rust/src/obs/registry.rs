//! Lock-striped metric registry: monotonic counters, gauges, and
//! fixed-bucket wall-time histograms with bucket-estimated p50/p95/p99.
//!
//! The registry is the aggregation side of the observability subsystem
//! (DESIGN: `docs/observability.md`). Metrics are keyed by flat
//! snake_case names and sharded across [`NUM_STRIPES`] mutexes by name
//! hash, so worker threads recording into *different* metrics never
//! contend, and threads recording into the *same* metric contend only
//! with each other — never with the evaluation hot path, which records
//! nothing unless telemetry is enabled (see [`crate::obs::Telemetry`]).
//!
//! Everything here is order-insensitive: counters and histograms are
//! commutative aggregates, so concurrent recording from worker threads
//! cannot make a snapshot nondeterministic in *which values* it holds —
//! only wall-clock-derived values themselves are nondeterministic, and
//! those are quantized out of any golden output by the exporters.

use std::collections::hash_map::DefaultHasher;
use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

/// Number of mutex stripes (same sharding degree as `DaccCache`).
const NUM_STRIPES: usize = 16;

/// Fixed histogram bucket upper bounds, in milliseconds. The final
/// implicit bucket is +inf. Chosen to straddle everything from a cache
/// probe (~µs) to a full reoptimization (~seconds).
pub const MS_BUCKETS: [f64; 12] =
    [0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0, 5000.0];

/// One fixed-bucket histogram. `buckets[i]` counts observations with
/// `v <= MS_BUCKETS[i]`; the last slot counts the +inf overflow.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: vec![0; MS_BUCKETS.len() + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    fn observe(&mut self, v: f64) {
        let idx = MS_BUCKETS.iter().position(|&ub| v <= ub).unwrap_or(MS_BUCKETS.len());
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Bucket-resolution quantile estimate: the upper bound of the
    /// first bucket whose cumulative count reaches `q * count` (the
    /// observed max for the overflow bucket). Exact to one bucket.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return if i < MS_BUCKETS.len() { MS_BUCKETS[i] } else { self.max };
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[derive(Default)]
struct Stripe {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Point-in-time copy of every metric, name-sorted (BTreeMap) so all
/// exports iterate in one deterministic order.
#[derive(Clone, Debug, Default)]
pub struct MetricSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

/// Lock-striped metric store (see module doc).
pub struct MetricRegistry {
    stripes: Vec<Mutex<Stripe>>,
}

impl Default for MetricRegistry {
    fn default() -> MetricRegistry {
        MetricRegistry::new()
    }
}

impl MetricRegistry {
    pub fn new() -> MetricRegistry {
        MetricRegistry { stripes: (0..NUM_STRIPES).map(|_| Mutex::new(Stripe::default())).collect() }
    }

    fn stripe(&self, name: &str) -> &Mutex<Stripe> {
        let mut h = DefaultHasher::new();
        name.hash(&mut h);
        &self.stripes[(h.finish() as usize) % NUM_STRIPES]
    }

    /// Add to a monotonic counter (created at 0 on first touch).
    pub fn counter_add(&self, name: &str, delta: u64) {
        let mut s = self.stripe(name).lock().unwrap();
        *s.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter_get(&self, name: &str) -> u64 {
        let s = self.stripe(name).lock().unwrap();
        s.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to an absolute value.
    pub fn gauge_set(&self, name: &str, v: f64) {
        let mut s = self.stripe(name).lock().unwrap();
        s.gauges.insert(name.to_string(), v);
    }

    /// Record one wall-time observation (milliseconds) into a
    /// fixed-bucket histogram.
    pub fn observe_ms(&self, name: &str, ms: f64) {
        let mut s = self.stripe(name).lock().unwrap();
        s.histograms.entry(name.to_string()).or_default().observe(ms);
    }

    /// Consistent point-in-time copy: stripes are locked one at a time
    /// (metrics never span stripes, so per-metric consistency holds).
    pub fn snapshot(&self) -> MetricSnapshot {
        let mut snap = MetricSnapshot::default();
        for stripe in &self.stripes {
            let s = stripe.lock().unwrap();
            for (k, v) in &s.counters {
                snap.counters.insert(k.clone(), *v);
            }
            for (k, v) in &s.gauges {
                snap.gauges.insert(k.clone(), *v);
            }
            for (k, v) in &s.histograms {
                snap.histograms.insert(k.clone(), v.clone());
            }
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot_sorts() {
        let r = MetricRegistry::new();
        r.counter_add("b_total", 2);
        r.counter_add("a_total", 1);
        r.counter_add("b_total", 3);
        assert_eq!(r.counter_get("b_total"), 5);
        assert_eq!(r.counter_get("never"), 0);
        let snap = r.snapshot();
        let names: Vec<&str> = snap.counters.keys().map(|s| s.as_str()).collect();
        assert_eq!(names, vec!["a_total", "b_total"]);
    }

    #[test]
    fn gauges_overwrite() {
        let r = MetricRegistry::new();
        r.gauge_set("g", 1.0);
        r.gauge_set("g", -2.5);
        assert_eq!(r.snapshot().gauges["g"], -2.5);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::default();
        for _ in 0..90 {
            h.observe(0.4); // bucket ub 0.5
        }
        for _ in 0..10 {
            h.observe(400.0); // bucket ub 500
        }
        assert_eq!(h.count, 100);
        assert_eq!(h.p50(), 0.5);
        assert_eq!(h.p95(), 500.0);
        assert_eq!(h.p99(), 500.0);
        assert_eq!(h.min, 0.4);
        assert_eq!(h.max, 400.0);
    }

    #[test]
    fn histogram_overflow_bucket_reports_max() {
        let mut h = Histogram::default();
        h.observe(9_999.0);
        assert_eq!(h.buckets[MS_BUCKETS.len()], 1);
        assert_eq!(h.p99(), 9_999.0);
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.p50(), 0.0);
    }

    #[test]
    fn concurrent_counting_is_exact() {
        let r = std::sync::Arc::new(MetricRegistry::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let r = std::sync::Arc::clone(&r);
                scope.spawn(move || {
                    for _ in 0..1000 {
                        r.counter_add("hits_total", 1);
                        r.observe_ms("lat_ms", 0.2);
                    }
                });
            }
        });
        assert_eq!(r.counter_get("hits_total"), 4000);
        assert_eq!(r.snapshot().histograms["lat_ms"].count, 4000);
    }
}
