//! Hierarchical scope-guard span timing.
//!
//! A [`Span`] measures the wall-clock duration of a lexical scope and,
//! on drop, (a) records the duration into the registry histogram
//! `span_<path>_ms` and (b) emits one `span` trace event carrying the
//! span path plus any logical fields attached with [`Span::note`] —
//! but **never** the wall duration itself, which would break the
//! bitwise-determinism contract of the trace (wall times live only in
//! histograms, which are quantized out of golden outputs).
//!
//! Hierarchy is expressed through dotted paths: `span.child("x")`
//! yields path `parent.x`. On a disabled [`Telemetry`] handle every
//! constructor returns an inert guard whose creation and drop cost is
//! a branch and two empty (non-allocating) containers.

use std::time::Instant;

use crate::obs::Telemetry;
use crate::util::json::Value;

/// Scope guard for one timed region; see module doc.
pub struct Span<'a> {
    t: &'a Telemetry,
    path: String,
    start: Option<Instant>,
    fields: Vec<(String, Value)>,
}

impl<'a> Span<'a> {
    pub(crate) fn new(t: &'a Telemetry, path: &str) -> Span<'a> {
        if t.is_enabled() {
            Span { t, path: path.to_string(), start: Some(Instant::now()), fields: Vec::new() }
        } else {
            Span { t, path: String::new(), start: None, fields: Vec::new() }
        }
    }

    /// Start a child span with path `<self>.<name>`. The child borrows
    /// the same telemetry handle, so it must close before the parent.
    pub fn child(&self, name: &str) -> Span<'a> {
        if self.start.is_none() {
            return Span { t: self.t, path: String::new(), start: None, fields: Vec::new() };
        }
        Span::new(self.t, &format!("{}.{name}", self.path))
    }

    /// Attach a deterministic logical field (tick, generation, counts)
    /// to the span's trace event. No-op when disabled.
    pub fn note(&mut self, key: &str, v: Value) {
        if self.start.is_some() {
            self.fields.push((key.to_string(), v));
        }
    }

    /// The dotted span path ("" when disabled).
    pub fn path(&self) -> &str {
        &self.path
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let ms = start.elapsed().as_secs_f64() * 1e3;
        let metric = format!("span_{}_ms", self.path.replace('.', "_"));
        self.t.observe_ms(&metric, ms);
        let fields: Vec<(&str, Value)> =
            self.fields.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
        self.t.trace_event("span", Some(&self.path), &fields);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn disabled_span_is_inert() {
        let t = Telemetry::disabled();
        let mut sp = t.span("opt.generation");
        sp.note("generation", num(1.0));
        let child = sp.child("evaluate");
        assert_eq!(child.path(), "");
        drop(child);
        drop(sp);
    }

    #[test]
    fn span_records_histogram_and_path() {
        let t = Telemetry::enabled();
        {
            let mut sp = t.span("opt.generation");
            sp.note("generation", num(0.0));
            let c = sp.child("evaluate");
            assert_eq!(c.path(), "opt.generation.evaluate");
        }
        let snap = t.snapshot().expect("enabled telemetry has a snapshot");
        assert_eq!(snap.histograms["span_opt_generation_ms"].count, 1);
        assert_eq!(snap.histograms["span_opt_generation_evaluate_ms"].count, 1);
    }
}
