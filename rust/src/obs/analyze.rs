//! Offline trace analysis (`afarepart trace analyze <file>`).
//!
//! Post-processes a JSONL event trace (see [`super::trace`]) into one
//! deterministic report: span waterfall + critical-path summary,
//! cache-efficiency rollup, fault→degradation attribution chains with
//! per-class blame counts, campaign cell summaries, and optimizer
//! convergence curves. The analyzer is fully offline — it reads bytes,
//! never the live registry — so it can run on traces from other
//! machines and other versions:
//!
//! - lines whose `schema` is newer than [`TRACE_SCHEMA_VERSION`] are
//!   counted (`newer_schema_lines`) and still mined for known kinds;
//! - unknown `kind`s are tallied per kind, never an error;
//! - a truncated final line (no trailing newline, unparseable — the
//!   signature of a killed writer) is detected and reported instead of
//!   panicking; interior garbage lines are counted as `malformed`.
//!
//! Determinism: the report is a pure function of the trace bytes. All
//! aggregation is BTreeMap-backed and every tie-break is lexicographic,
//! so a bitwise-identical trace yields a bitwise-identical report.
//!
//! # Attribution model
//!
//! `chaos_inject` events declare injected faults (one per effect unit)
//! keyed by a stable fault id; supervision events (`server_retry`,
//! `server_respawn`, `server_terminal`) carry the id of the fault they
//! consumed in their `fault` field (null when the action had no
//! injected cause, e.g. a timeout-triggered precautionary respawn).
//! Degradation transitions (`degrade_enter`/`degrade_extend`) are
//! linked to the nearest preceding terminal event in stream order —
//! the terminal that caused them — completing the chain
//! fault → supervision → degradation. Blame rolls up per fault class
//! and per component; actions with a null fault roll up under
//! `unattributed`. Class attribution is whole-file, not stream-order:
//! with pipelined lookahead a drained speculative wait can consume a
//! fault *before* its tick's `chaos_inject` line is written, so the
//! injection ledger is collected in a pre-pass.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::trace::TRACE_SCHEMA_VERSION;
use crate::faults::{fault_component, fault_tick};
use crate::util::json::{self, num, obj, s, Value};

/// Supervision actions blamed on one fault class (or unattributed).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BlameCounts {
    pub retries: usize,
    pub respawns: usize,
    pub terminals: usize,
    pub degradations: usize,
}

impl BlameCounts {
    fn to_json(&self) -> Value {
        obj(vec![
            ("retries", num(self.retries as f64)),
            ("respawns", num(self.respawns as f64)),
            ("terminals", num(self.terminals as f64)),
            ("degradations", num(self.degradations as f64)),
        ])
    }
}

/// One fault's causal chain: injection → supervision → degradation.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultChain {
    pub fault: u64,
    /// Injection tick, recovered from the id (`fault_tick`).
    pub tick: usize,
    /// Component index within the chaos stack (`fault_component`).
    pub component: usize,
    /// Fault class from the matching `chaos_inject` ("unknown" when the
    /// trace holds the consumption but not the injection).
    pub class: String,
    pub retries: usize,
    pub respawns: usize,
    /// Terminal outcome reason, if supervision gave up on this fault.
    pub terminal: Option<String>,
    /// Whether the chain ended in a degradation transition.
    pub degraded: bool,
}

impl FaultChain {
    fn to_json(&self) -> Value {
        obj(vec![
            ("fault", num(self.fault as f64)),
            ("tick", num(self.tick as f64)),
            ("component", num(self.component as f64)),
            ("class", s(&self.class)),
            ("retries", num(self.retries as f64)),
            ("respawns", num(self.respawns as f64)),
            ("terminal", match &self.terminal {
                Some(r) => s(r),
                None => Value::Null,
            }),
            ("degraded", Value::Bool(self.degraded)),
        ])
    }
}

/// Fault→degradation attribution rollup (see module doc).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Attribution {
    /// Injected effect units per class, from `chaos_inject`.
    pub injected_by_class: BTreeMap<String, usize>,
    /// Supervision actions blamed per class (key "unknown" collects
    /// faults whose injection event is missing from the trace).
    pub blame_by_class: BTreeMap<String, BlameCounts>,
    /// Actions whose `fault` field was null.
    pub unattributed: BlameCounts,
    /// Per-fault chains, ordered by fault id.
    pub chains: Vec<FaultChain>,
    /// `server_retry` counts per `reason`.
    pub retry_reasons: BTreeMap<String, usize>,
    /// `server_terminal` counts per `reason`.
    pub terminal_reasons: BTreeMap<String, usize>,
    /// `server_respawn` events with `crashed == true`.
    pub crashed_respawns: usize,
    pub degrade_enters: usize,
    pub degrade_extends: usize,
    pub degrade_exits: usize,
    /// Closed degraded intervals `[start, end)` from `degrade_exit`.
    pub intervals: Vec<(usize, usize)>,
    /// Start tick of a degraded interval still open at trace end.
    pub open_interval_start: Option<usize>,
}

/// Per-generation optimizer convergence curve; a trace holding several
/// optimizer runs (e.g. offline + online re-optimizations) yields one
/// entry per run (a generation reset starts a new run).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConvergenceRun {
    pub generations: usize,
    pub first_hypervolume: f64,
    pub final_hypervolume: f64,
    pub final_spread: f64,
    pub max_stall: usize,
    /// `(generation, hypervolume)` curve.
    pub curve: Vec<(u64, f64)>,
}

/// ΔAcc evaluation-engine cache efficiency, rolled up from `eval.batch`
/// span events.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheRollup {
    pub batch_calls: usize,
    pub genomes: usize,
    pub unique_misses: usize,
    pub cache_answered: usize,
}

impl CacheRollup {
    pub fn hit_rate(&self) -> f64 {
        if self.genomes == 0 {
            0.0
        } else {
            self.cache_answered as f64 / self.genomes as f64
        }
    }
}

/// Serving-loop rollup from `online.tick` / `online.reconfig` spans.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OnlineRollup {
    pub ticks: usize,
    pub degraded_ticks: usize,
    /// Ticks whose mapping actually changed.
    pub reconfigurations: usize,
    /// θ-trigger re-optimizations (mapping may or may not change).
    pub reopt_triggers: usize,
    pub reopt_evaluations: usize,
    pub injected_delay_total: f64,
    pub final_acc_drop: Option<f64>,
}

/// Campaign scheduler rollup from `campaign.cell` span events.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CampaignRollup {
    pub cells: usize,
    pub evaluations: usize,
    pub unique_misses: usize,
    pub cells_by_model: BTreeMap<String, usize>,
    pub cells_by_drift: BTreeMap<String, usize>,
}

/// Every trace-event kind this analyzer version understands; anything
/// else lands in `unknown_kind_counts` (forward compatibility).
const KNOWN_KINDS: [&str; 10] = [
    "trace_start",
    "span",
    "chaos_inject",
    "server_retry",
    "server_respawn",
    "server_terminal",
    "degrade_enter",
    "degrade_exit",
    "degrade_extend",
    "convergence",
];

/// The full deterministic analysis of one trace file (module doc).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceAnalysis {
    /// Non-empty lines seen (including malformed and truncated ones).
    pub total_lines: usize,
    /// Lines successfully parsed into events.
    pub parsed_events: usize,
    /// Final line was cut mid-write (no newline, unparseable).
    pub truncated_tail: bool,
    /// Interior lines that failed to parse (never expected).
    pub malformed_lines: usize,
    /// Events whose `seq` broke the `seq == line index` contract.
    pub seq_gaps: usize,
    /// Events per declared `schema` version.
    pub schema_versions: BTreeMap<u64, usize>,
    /// Events stamped with a schema newer than this build understands.
    pub newer_schema_lines: usize,
    /// Events per kind (known and unknown).
    pub kind_counts: BTreeMap<String, usize>,
    /// Kinds this analyzer version does not understand.
    pub unknown_kind_counts: BTreeMap<String, usize>,
    /// `span` events per dotted span path (the waterfall).
    pub span_counts: BTreeMap<String, usize>,
    /// Dominant span chain: at each hierarchy level the segment with
    /// the most events under it (ties lexicographic).
    pub critical_path: Vec<String>,
    pub cache: CacheRollup,
    pub online: OnlineRollup,
    pub attribution: Attribution,
    pub convergence: Vec<ConvergenceRun>,
    pub campaign: CampaignRollup,
}

/// Analyze a trace file on disk.
pub fn analyze_file(path: &Path) -> Result<TraceAnalysis> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace file {}", path.display()))?;
    Ok(analyze_str(&text))
}

/// Analyze trace text. Infallible by design: damage is reported in the
/// analysis (`truncated_tail`, `malformed_lines`, unknown kinds), not
/// surfaced as an error.
pub fn analyze_str(text: &str) -> TraceAnalysis {
    let mut a = TraceAnalysis::default();
    let complete_tail = text.is_empty() || text.ends_with('\n');
    let lines: Vec<&str> = text.split('\n').filter(|l| !l.is_empty()).collect();
    a.total_lines = lines.len();

    // Injection-ledger pre-pass (module doc: attribution is whole-file,
    // not stream-order). The substring filter just skips the parse for
    // the vast majority of lines; the kind is re-checked after parsing.
    let mut fault_class: BTreeMap<u64, String> = BTreeMap::new();
    for line in &lines {
        if !line.contains("\"chaos_inject\"") {
            continue;
        }
        let Ok(v) = json::parse(line) else { continue };
        if v.get("kind").and_then(|x| x.as_str()) != Some("chaos_inject") {
            continue;
        }
        if let (Some(id), Some(class)) =
            (v.get("fault").and_then(|x| x.as_u64()), v.get("class").and_then(|x| x.as_str()))
        {
            fault_class.insert(id, class.to_string());
        }
    }

    // last server_terminal not yet blamed for a degradation transition
    let mut pending_terminal: Option<Option<u64>> = None;
    let mut chains: BTreeMap<u64, FaultChain> = BTreeMap::new();
    let mut open_degrade: Option<usize> = None;
    let mut prev_generation: Option<u64> = None;

    for (i, line) in lines.iter().enumerate() {
        let v = match json::parse(line) {
            Ok(v) => v,
            Err(_) => {
                if i + 1 == lines.len() && !complete_tail {
                    a.truncated_tail = true;
                } else {
                    a.malformed_lines += 1;
                }
                continue;
            }
        };
        a.parsed_events += 1;
        let schema = v.get("schema").and_then(|x| x.as_u64()).unwrap_or(0);
        *a.schema_versions.entry(schema).or_default() += 1;
        if schema > TRACE_SCHEMA_VERSION {
            a.newer_schema_lines += 1;
        }
        if v.get("seq").and_then(|x| x.as_usize()) != Some(i) {
            a.seq_gaps += 1;
        }
        let kind = v.get("kind").and_then(|x| x.as_str()).unwrap_or("").to_string();
        *a.kind_counts.entry(kind.clone()).or_default() += 1;
        if !KNOWN_KINDS.contains(&kind.as_str()) {
            *a.unknown_kind_counts.entry(kind.clone()).or_default() += 1;
            continue;
        }
        let span = v.get("span").and_then(|x| x.as_str()).unwrap_or("");
        let fault = v.get("fault").and_then(|x| x.as_u64());
        let reason = v.get("reason").and_then(|x| x.as_str()).unwrap_or("").to_string();
        let tick = v.get("tick").and_then(|x| x.as_usize()).unwrap_or(0);

        match kind.as_str() {
            "span" => {
                *a.span_counts.entry(span.to_string()).or_default() += 1;
                match span {
                    "eval.batch" => {
                        a.cache.batch_calls += 1;
                        a.cache.genomes += v.get("genomes").and_then(|x| x.as_usize()).unwrap_or(0);
                        a.cache.unique_misses +=
                            v.get("unique_misses").and_then(|x| x.as_usize()).unwrap_or(0);
                        a.cache.cache_answered +=
                            v.get("cache_answered").and_then(|x| x.as_usize()).unwrap_or(0);
                    }
                    "online.tick" => {
                        a.online.ticks += 1;
                        if v.get("degraded").and_then(|x| x.as_bool()) == Some(true) {
                            a.online.degraded_ticks += 1;
                        }
                        if v.get("reconfigured").and_then(|x| x.as_bool()) == Some(true) {
                            a.online.reconfigurations += 1;
                        }
                        a.online.injected_delay_total +=
                            v.get("injected_delay").and_then(|x| x.as_f64()).unwrap_or(0.0);
                        if let Some(drop) = v.get("acc_drop").and_then(|x| x.as_f64()) {
                            a.online.final_acc_drop = Some(drop);
                        }
                    }
                    "online.reconfig" => {
                        a.online.reopt_triggers += 1;
                        a.online.reopt_evaluations +=
                            v.get("evaluations").and_then(|x| x.as_usize()).unwrap_or(0);
                    }
                    "campaign.cell" => {
                        a.campaign.cells += 1;
                        a.campaign.evaluations +=
                            v.get("evaluations").and_then(|x| x.as_usize()).unwrap_or(0);
                        a.campaign.unique_misses +=
                            v.get("unique_misses").and_then(|x| x.as_usize()).unwrap_or(0);
                        if let Some(m) = v.get("model").and_then(|x| x.as_str()) {
                            *a.campaign.cells_by_model.entry(m.to_string()).or_default() += 1;
                        }
                        if let Some(d) = v.get("drift").and_then(|x| x.as_str()) {
                            *a.campaign.cells_by_drift.entry(d.to_string()).or_default() += 1;
                        }
                    }
                    _ => {}
                }
            }
            "chaos_inject" => {
                let class =
                    v.get("class").and_then(|x| x.as_str()).unwrap_or("unknown").to_string();
                *a.attribution.injected_by_class.entry(class).or_default() += 1;
            }
            "server_retry" => {
                *a.attribution.retry_reasons.entry(reason.clone()).or_default() += 1;
                blame(&mut a.attribution, &fault_class, &mut chains, fault, |b| b.retries += 1);
            }
            "server_respawn" => {
                if v.get("crashed").and_then(|x| x.as_bool()) == Some(true) {
                    a.attribution.crashed_respawns += 1;
                }
                blame(&mut a.attribution, &fault_class, &mut chains, fault, |b| b.respawns += 1);
            }
            "server_terminal" => {
                *a.attribution.terminal_reasons.entry(reason.clone()).or_default() += 1;
                blame(&mut a.attribution, &fault_class, &mut chains, fault, |b| {
                    b.terminals += 1
                });
                if let Some(id) = fault {
                    if let Some(c) = chains.get_mut(&id) {
                        c.terminal = Some(reason.clone());
                    }
                }
                pending_terminal = Some(fault);
            }
            "degrade_enter" | "degrade_extend" => {
                if kind == "degrade_enter" {
                    a.attribution.degrade_enters += 1;
                    open_degrade = Some(tick);
                } else {
                    a.attribution.degrade_extends += 1;
                }
                // blame the terminal that caused this transition;
                // consume it so one terminal explains one transition
                match pending_terminal.take() {
                    Some(Some(id)) => {
                        let class = fault_class
                            .get(&id)
                            .cloned()
                            .unwrap_or_else(|| "unknown".to_string());
                        a.attribution
                            .blame_by_class
                            .entry(class)
                            .or_default()
                            .degradations += 1;
                        if let Some(c) = chains.get_mut(&id) {
                            c.degraded = true;
                        }
                    }
                    _ => a.attribution.unattributed.degradations += 1,
                }
            }
            "degrade_exit" => {
                a.attribution.degrade_exits += 1;
                open_degrade = None;
                let start = v.get("start").and_then(|x| x.as_usize()).unwrap_or(0);
                let end = v.get("end").and_then(|x| x.as_usize()).unwrap_or(0);
                a.attribution.intervals.push((start, end));
            }
            "convergence" => {
                let generation = v.get("generation").and_then(|x| x.as_u64()).unwrap_or(0);
                let hv = v.get("hypervolume").and_then(|x| x.as_f64()).unwrap_or(0.0);
                let spread = v.get("spread").and_then(|x| x.as_f64()).unwrap_or(0.0);
                let stall = v.get("stall").and_then(|x| x.as_usize()).unwrap_or(0);
                let new_run = match prev_generation {
                    None => true,
                    Some(prev) => generation <= prev,
                };
                if new_run {
                    a.convergence.push(ConvergenceRun {
                        first_hypervolume: hv,
                        ..ConvergenceRun::default()
                    });
                }
                prev_generation = Some(generation);
                let run = a.convergence.last_mut().expect("pushed above");
                run.generations += 1;
                run.final_hypervolume = hv;
                run.final_spread = spread;
                run.max_stall = run.max_stall.max(stall);
                run.curve.push((generation, hv));
            }
            _ => {}
        }
    }

    a.attribution.open_interval_start = open_degrade;
    a.attribution.chains = chains.into_values().collect();
    a.critical_path = critical_path(&a.span_counts);
    a
}

/// Charge one supervision action to its fault's class (or to
/// `unattributed` when the event carried a null fault), and grow the
/// per-fault chain.
fn blame(
    attr: &mut Attribution,
    fault_class: &BTreeMap<u64, String>,
    chains: &mut BTreeMap<u64, FaultChain>,
    fault: Option<u64>,
    bump: impl Fn(&mut BlameCounts),
) {
    match fault {
        None => bump(&mut attr.unattributed),
        Some(id) => {
            let class =
                fault_class.get(&id).cloned().unwrap_or_else(|| "unknown".to_string());
            bump(attr.blame_by_class.entry(class.clone()).or_default());
            let chain = chains.entry(id).or_insert_with(|| FaultChain {
                fault: id,
                tick: fault_tick(id),
                component: fault_component(id),
                class,
                retries: 0,
                respawns: 0,
                terminal: None,
                degraded: false,
            });
            // a per-chain view of the same bump
            let mut delta = BlameCounts::default();
            bump(&mut delta);
            chain.retries += delta.retries;
            chain.respawns += delta.respawns;
        }
    }
}

/// Dominant span chain: starting at the root, at each level pick the
/// path segment with the most span events at-or-below it; ties go to
/// the lexicographically smallest segment (BTreeMap order).
fn critical_path(span_counts: &BTreeMap<String, usize>) -> Vec<String> {
    let mut prefix = String::new();
    let mut out = Vec::new();
    loop {
        let mut seg_counts: BTreeMap<&str, usize> = BTreeMap::new();
        for (path, count) in span_counts {
            let rest = if prefix.is_empty() {
                path.as_str()
            } else if let Some(r) =
                path.strip_prefix(&prefix).and_then(|r| r.strip_prefix('.'))
            {
                r
            } else {
                continue;
            };
            if rest.is_empty() {
                continue;
            }
            let seg = rest.split('.').next().unwrap_or(rest);
            *seg_counts.entry(seg).or_default() += count;
        }
        let Some((best, _)) = seg_counts.iter().fold(None, |acc: Option<(&str, usize)>, (k, &c)| {
            match acc {
                Some((_, bc)) if bc >= c => acc,
                _ => Some((k, c)),
            }
        }) else {
            break;
        };
        prefix = if prefix.is_empty() { best.to_string() } else { format!("{prefix}.{best}") };
        out.push(prefix.clone());
    }
    out
}

impl TraceAnalysis {
    /// The deterministic JSON report (`--format json`). Key order is
    /// fixed by the BTreeMap-backed JSON layer, so a bitwise-identical
    /// trace yields a bitwise-identical report.
    pub fn to_json(&self) -> Value {
        let count_map = |m: &BTreeMap<String, usize>| {
            Value::Obj(m.iter().map(|(k, &v)| (k.clone(), num(v as f64))).collect())
        };
        obj(vec![
            ("events", obj(vec![
                ("total_lines", num(self.total_lines as f64)),
                ("parsed", num(self.parsed_events as f64)),
                ("truncated_tail", Value::Bool(self.truncated_tail)),
                ("malformed", num(self.malformed_lines as f64)),
                ("seq_gaps", num(self.seq_gaps as f64)),
                ("schema_versions", Value::Obj(
                    self.schema_versions
                        .iter()
                        .map(|(k, &v)| (k.to_string(), num(v as f64)))
                        .collect(),
                )),
                ("newer_schema_lines", num(self.newer_schema_lines as f64)),
                ("by_kind", count_map(&self.kind_counts)),
                ("unknown_kinds", count_map(&self.unknown_kind_counts)),
            ])),
            ("spans", obj(vec![
                ("waterfall", count_map(&self.span_counts)),
                ("critical_path", json::arr(self.critical_path.iter().map(|p| s(p)))),
            ])),
            ("cache", obj(vec![
                ("batch_calls", num(self.cache.batch_calls as f64)),
                ("genomes", num(self.cache.genomes as f64)),
                ("unique_misses", num(self.cache.unique_misses as f64)),
                ("cache_answered", num(self.cache.cache_answered as f64)),
                ("hit_rate", num(self.cache.hit_rate())),
            ])),
            ("online", obj(vec![
                ("ticks", num(self.online.ticks as f64)),
                ("degraded_ticks", num(self.online.degraded_ticks as f64)),
                ("reconfigurations", num(self.online.reconfigurations as f64)),
                ("reopt_triggers", num(self.online.reopt_triggers as f64)),
                ("reopt_evaluations", num(self.online.reopt_evaluations as f64)),
                ("injected_delay_total", num(self.online.injected_delay_total)),
                ("final_acc_drop", match self.online.final_acc_drop {
                    Some(d) => num(d),
                    None => Value::Null,
                }),
            ])),
            ("attribution", obj(vec![
                ("injected_by_class", count_map(&self.attribution.injected_by_class)),
                ("blame_by_class", Value::Obj(
                    self.attribution
                        .blame_by_class
                        .iter()
                        .map(|(k, v)| (k.clone(), v.to_json()))
                        .collect(),
                )),
                ("unattributed", self.attribution.unattributed.to_json()),
                ("chains", json::arr(self.attribution.chains.iter().map(|c| c.to_json()))),
                ("retry_reasons", count_map(&self.attribution.retry_reasons)),
                ("terminal_reasons", count_map(&self.attribution.terminal_reasons)),
                ("crashed_respawns", num(self.attribution.crashed_respawns as f64)),
                ("degrade_enters", num(self.attribution.degrade_enters as f64)),
                ("degrade_extends", num(self.attribution.degrade_extends as f64)),
                ("degrade_exits", num(self.attribution.degrade_exits as f64)),
                ("intervals", json::arr(self.attribution.intervals.iter().map(
                    |&(lo, hi)| json::arr([num(lo as f64), num(hi as f64)]),
                ))),
                ("open_interval_start", match self.attribution.open_interval_start {
                    Some(t) => num(t as f64),
                    None => Value::Null,
                }),
            ])),
            ("convergence", json::arr(self.convergence.iter().map(|r| {
                obj(vec![
                    ("generations", num(r.generations as f64)),
                    ("first_hypervolume", num(r.first_hypervolume)),
                    ("final_hypervolume", num(r.final_hypervolume)),
                    ("final_spread", num(r.final_spread)),
                    ("max_stall", num(r.max_stall as f64)),
                    ("curve", json::arr(r.curve.iter().map(
                        |&(g, hv)| json::arr([num(g as f64), num(hv)]),
                    ))),
                ])
            }))),
            ("campaign", obj(vec![
                ("cells", num(self.campaign.cells as f64)),
                ("evaluations", num(self.campaign.evaluations as f64)),
                ("unique_misses", num(self.campaign.unique_misses as f64)),
                ("cells_by_model", count_map(&self.campaign.cells_by_model)),
                ("cells_by_drift", count_map(&self.campaign.cells_by_drift)),
            ])),
        ])
    }

    /// Short human-readable summary (`--format text`).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let mut line = |s: String| {
            out.push_str(&s);
            out.push('\n');
        };
        line(format!(
            "events: {} parsed / {} lines{}{}",
            self.parsed_events,
            self.total_lines,
            if self.truncated_tail { " (truncated tail)" } else { "" },
            if self.malformed_lines > 0 {
                format!(" ({} malformed)", self.malformed_lines)
            } else {
                String::new()
            },
        ));
        if self.newer_schema_lines > 0 || !self.unknown_kind_counts.is_empty() {
            line(format!(
                "forward-compat: {} newer-schema lines, {} unknown kinds",
                self.newer_schema_lines,
                self.unknown_kind_counts.len()
            ));
        }
        line(format!("critical path: {}", self.critical_path.join(" > ")));
        if self.cache.batch_calls > 0 {
            line(format!(
                "cache: {} genomes in {} batches, {} misses, hit rate {:.1}%",
                self.cache.genomes,
                self.cache.batch_calls,
                self.cache.unique_misses,
                self.cache.hit_rate() * 100.0
            ));
        }
        if self.online.ticks > 0 {
            line(format!(
                "online: {} ticks, {} degraded, {} reconfigurations ({} triggers)",
                self.online.ticks,
                self.online.degraded_ticks,
                self.online.reconfigurations,
                self.online.reopt_triggers
            ));
        }
        for (class, b) in &self.attribution.blame_by_class {
            line(format!(
                "blame[{class}]: {} retries, {} respawns, {} terminals, {} degradations",
                b.retries, b.respawns, b.terminals, b.degradations
            ));
        }
        let u = &self.attribution.unattributed;
        if *u != BlameCounts::default() {
            line(format!(
                "blame[unattributed]: {} retries, {} respawns, {} terminals, {} degradations",
                u.retries, u.respawns, u.terminals, u.degradations
            ));
        }
        for (i, r) in self.convergence.iter().enumerate() {
            line(format!(
                "convergence[{i}]: {} generations, hv {:.6} -> {:.6}, max stall {}",
                r.generations, r.first_hypervolume, r.final_hypervolume, r.max_stall
            ));
        }
        if self.campaign.cells > 0 {
            line(format!(
                "campaign: {} cells, {} evaluations, {} misses",
                self.campaign.cells, self.campaign.evaluations, self.campaign.unique_misses
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::fault_id;

    fn ev(seq: usize, body: &str) -> String {
        format!("{{\"schema\":2,\"seq\":{seq},\"kind\":{body}}}\n")
    }

    fn sample_trace() -> String {
        let f = fault_id(3, 1);
        let mut t = String::new();
        t.push_str(&ev(0, "\"trace_start\""));
        t.push_str(&ev(
            1,
            &format!(
                "\"chaos_inject\",\"span\":\"online.chaos\",\"class\":\"transient\",\
                 \"component\":1,\"fault\":{f},\"magnitude\":2,\"tick\":3"
            ),
        ));
        t.push_str(&ev(
            2,
            &format!(
                "\"server_retry\",\"span\":\"server.supervise\",\"ticket\":3,\
                 \"attempts\":1,\"reason\":\"transient\",\"fault\":{f}"
            ),
        ));
        t.push_str(&ev(
            3,
            &format!(
                "\"server_terminal\",\"span\":\"server.supervise\",\"ticket\":3,\
                 \"attempts\":2,\"reason\":\"exhausted\",\"fault\":{f}"
            ),
        ));
        t.push_str(&ev(
            4,
            "\"degrade_enter\",\"span\":\"online.degrade\",\"tick\":3,\"reason\":\"exhausted\"",
        ));
        t.push_str(&ev(
            5,
            "\"degrade_exit\",\"span\":\"online.degrade\",\"tick\":7,\"start\":3,\"end\":7",
        ));
        t.push_str(&ev(
            6,
            "\"span\",\"span\":\"eval.batch\",\"batch\":1,\"genomes\":8,\
             \"unique_misses\":3,\"cache_answered\":5",
        ));
        t.push_str(&ev(
            7,
            "\"span\",\"span\":\"online.tick\",\"tick\":3,\"degraded\":true,\
             \"reconfigured\":false,\"acc\":0,\"acc_drop\":0.5,\"injected_delay\":0",
        ));
        t.push_str(&ev(
            8,
            "\"convergence\",\"span\":\"opt.convergence\",\"generation\":0,\
             \"hypervolume\":1.5,\"spread\":0.2,\"progress\":1.5,\"stall\":0,\"front_size\":4",
        ));
        t.push_str(&ev(
            9,
            "\"convergence\",\"span\":\"opt.convergence\",\"generation\":1,\
             \"hypervolume\":2.5,\"spread\":0.3,\"progress\":1,\"stall\":0,\"front_size\":5",
        ));
        t
    }

    #[test]
    fn links_fault_to_degradation_chain() {
        let a = analyze_str(&sample_trace());
        assert_eq!(a.parsed_events, 10);
        assert!(!a.truncated_tail);
        assert_eq!(a.seq_gaps, 0);
        assert_eq!(a.attribution.injected_by_class["transient"], 1);
        let b = &a.attribution.blame_by_class["transient"];
        assert_eq!((b.retries, b.terminals, b.degradations), (1, 1, 1));
        assert_eq!(a.attribution.chains.len(), 1);
        let c = &a.attribution.chains[0];
        assert_eq!((c.tick, c.component, c.class.as_str()), (3, 1, "transient"));
        assert_eq!(c.terminal.as_deref(), Some("exhausted"));
        assert!(c.degraded);
        assert_eq!(a.attribution.intervals, vec![(3, 7)]);
        assert_eq!(a.attribution.open_interval_start, None);
    }

    #[test]
    fn rolls_up_cache_online_and_convergence() {
        let a = analyze_str(&sample_trace());
        assert_eq!(
            (a.cache.batch_calls, a.cache.genomes, a.cache.unique_misses),
            (1, 8, 3)
        );
        assert!((a.cache.hit_rate() - 5.0 / 8.0).abs() < 1e-12);
        assert_eq!((a.online.ticks, a.online.degraded_ticks), (1, 1));
        assert_eq!(a.online.final_acc_drop, Some(0.5));
        assert_eq!(a.convergence.len(), 1);
        let r = &a.convergence[0];
        assert_eq!(r.generations, 2);
        assert_eq!(r.curve, vec![(0, 1.5), (1, 2.5)]);
        assert_eq!(r.final_hypervolume, 2.5);
    }

    #[test]
    fn generation_reset_starts_a_new_convergence_run() {
        let mut t = sample_trace();
        t.push_str(&ev(
            10,
            "\"convergence\",\"span\":\"opt.convergence\",\"generation\":0,\
             \"hypervolume\":0.5,\"spread\":0.1,\"progress\":0.5,\"stall\":0,\"front_size\":2",
        ));
        let a = analyze_str(&t);
        assert_eq!(a.convergence.len(), 2);
        assert_eq!(a.convergence[1].generations, 1);
        assert_eq!(a.convergence[1].first_hypervolume, 0.5);
    }

    #[test]
    fn truncated_tail_detected_not_fatal() {
        let mut t = sample_trace();
        t.push_str("{\"schema\":2,\"seq\":10,\"kind\":\"span\",\"spa"); // cut mid-write
        let a = analyze_str(&t);
        assert!(a.truncated_tail);
        assert_eq!(a.malformed_lines, 0);
        assert_eq!(a.parsed_events, 10);
    }

    #[test]
    fn unknown_kinds_and_newer_schema_counted() {
        let mut t = sample_trace();
        t.push_str("{\"schema\":99,\"seq\":10,\"kind\":\"hologram\",\"x\":1}\n");
        let a = analyze_str(&t);
        assert_eq!(a.unknown_kind_counts["hologram"], 1);
        assert_eq!(a.newer_schema_lines, 1);
        assert_eq!(a.schema_versions[&99], 1);
        // known kinds from newer schemas are still mined
        let mut t2 = sample_trace();
        t2.push_str(
            "{\"schema\":99,\"seq\":10,\"kind\":\"degrade_exit\",\
             \"span\":\"online.degrade\",\"tick\":9,\"start\":8,\"end\":9}\n",
        );
        let a2 = analyze_str(&t2);
        assert_eq!(a2.attribution.intervals.len(), 2);
    }

    #[test]
    fn unattributed_actions_and_open_intervals() {
        let mut t = String::new();
        t.push_str(&ev(0, "\"trace_start\""));
        t.push_str(&ev(
            1,
            "\"server_respawn\",\"span\":\"server.supervise\",\"reason\":\"recv timeout\",\
             \"crashed\":false,\"pending\":2,\"fault\":null",
        ));
        t.push_str(&ev(
            2,
            "\"server_terminal\",\"span\":\"server.supervise\",\"ticket\":1,\
             \"reason\":\"fatal\",\"fault\":null",
        ));
        t.push_str(&ev(
            3,
            "\"degrade_enter\",\"span\":\"online.degrade\",\"tick\":5,\"reason\":\"fatal\"",
        ));
        let a = analyze_str(&t);
        assert_eq!(a.attribution.unattributed.respawns, 1);
        assert_eq!(a.attribution.unattributed.terminals, 1);
        assert_eq!(a.attribution.unattributed.degradations, 1);
        assert_eq!(a.attribution.crashed_respawns, 0);
        assert_eq!(a.attribution.open_interval_start, Some(5));
        assert!(a.attribution.chains.is_empty());
    }

    #[test]
    fn late_injection_still_classifies_blame() {
        // pipelined lookahead: a drained speculative wait can consume a
        // fault before its tick's chaos_inject line is written; the
        // pre-pass must still recover the class
        let f = fault_id(9, 0);
        let mut t = String::new();
        t.push_str(&ev(0, "\"trace_start\""));
        t.push_str(&ev(
            1,
            &format!(
                "\"server_retry\",\"span\":\"server.supervise\",\"ticket\":9,\
                 \"attempts\":1,\"reason\":\"transient\",\"fault\":{f}"
            ),
        ));
        t.push_str(&ev(
            2,
            &format!(
                "\"chaos_inject\",\"span\":\"online.chaos\",\"class\":\"transient\",\
                 \"component\":0,\"fault\":{f},\"magnitude\":2,\"tick\":9"
            ),
        ));
        let a = analyze_str(&t);
        assert_eq!(a.attribution.blame_by_class["transient"].retries, 1);
        assert!(!a.attribution.blame_by_class.contains_key("unknown"));
        assert_eq!(a.attribution.chains.len(), 1);
        assert_eq!(a.attribution.chains[0].class, "transient");
    }

    #[test]
    fn critical_path_follows_dominant_spans() {
        let mut counts = BTreeMap::new();
        counts.insert("online.tick".to_string(), 60);
        counts.insert("online.reconfig".to_string(), 2);
        counts.insert("eval.batch".to_string(), 40);
        assert_eq!(
            critical_path(&counts),
            vec!["online".to_string(), "online.tick".to_string()]
        );
        assert!(critical_path(&BTreeMap::new()).is_empty());
    }

    #[test]
    fn report_is_deterministic_and_reconciles() {
        let a = analyze_str(&sample_trace());
        let j1 = json::to_string(&a.to_json());
        let j2 = json::to_string(&analyze_str(&sample_trace()).to_json());
        assert_eq!(j1, j2);
        let v = a.to_json();
        assert_eq!(
            v.path(&["attribution", "blame_by_class", "transient", "retries"])
                .and_then(|x| x.as_f64()),
            Some(1.0)
        );
        assert_eq!(
            v.path(&["events", "by_kind", "server_retry"]).and_then(|x| x.as_f64()),
            Some(1.0)
        );
        // text rendering mentions the blame rollup
        assert!(a.render_text().contains("blame[transient]"));
    }

    #[test]
    fn empty_trace_is_empty_analysis() {
        let a = analyze_str("");
        assert_eq!(a.total_lines, 0);
        assert!(!a.truncated_tail);
        assert!(a.kind_counts.is_empty());
        assert!(a.critical_path.is_empty());
    }
}
