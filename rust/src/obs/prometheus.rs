//! Prometheus text-exposition rendering of a [`MetricSnapshot`].
//!
//! The snapshot string is folded into `RunOutcome` JSON under the
//! `telemetry` key (only when telemetry is enabled, so disabled-run
//! goldens stay bitwise identical). Names are prefixed `afare_` and
//! sanitized to `[a-zA-Z0-9_]`; histograms render cumulative buckets
//! plus `_sum`/`_count` and bucket-estimated `p50`/`p95`/`p99` gauges.
//!
//! Histogram values are wall-clock-derived and therefore
//! nondeterministic across runs; deterministic consumers (the trace
//! smoke gate) strip histogram families and compare only counters and
//! gauges — see `docs/observability.md`.

use std::fmt::Write as _;

use crate::obs::registry::{MetricSnapshot, MS_BUCKETS};

/// Prometheus-legal metric name: `afare_` prefix, everything outside
/// `[a-zA-Z0-9_]` mapped to `_`.
pub fn metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 6);
    out.push_str("afare_");
    for c in name.chars() {
        out.push(if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' });
    }
    out
}

fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render a snapshot in the text exposition format, families sorted by
/// name (counters, then gauges, then histograms).
pub fn render(snap: &MetricSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = metric_name(name);
        let _ = writeln!(out, "# TYPE {n} counter");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, v) in &snap.gauges {
        let n = metric_name(name);
        let _ = writeln!(out, "# TYPE {n} gauge");
        let _ = writeln!(out, "{n} {}", fmt_f64(*v));
    }
    for (name, h) in &snap.histograms {
        let n = metric_name(name);
        let _ = writeln!(out, "# TYPE {n} histogram");
        let mut cum = 0u64;
        for (i, count) in h.buckets.iter().enumerate() {
            cum += count;
            let le = if i < MS_BUCKETS.len() { fmt_f64(MS_BUCKETS[i]) } else { "+Inf".into() };
            let _ = writeln!(out, "{n}_bucket{{le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(out, "{n}_sum {}", fmt_f64(h.sum));
        let _ = writeln!(out, "{n}_count {}", h.count);
        for (q, v) in [("p50", h.p50()), ("p95", h.p95()), ("p99", h.p99())] {
            let _ = writeln!(out, "# TYPE {n}_{q} gauge");
            let _ = writeln!(out, "{n}_{q} {}", fmt_f64(v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::MetricRegistry;

    #[test]
    fn sanitizes_names() {
        assert_eq!(metric_name("online.tick-ms"), "afare_online_tick_ms");
        assert_eq!(metric_name("evals_total"), "afare_evals_total");
    }

    #[test]
    fn renders_all_families() {
        let r = MetricRegistry::new();
        r.counter_add("evals_total", 7);
        r.gauge_set("front_size", 12.0);
        r.observe_ms("tick_ms", 0.3);
        r.observe_ms("tick_ms", 40.0);
        let text = render(&r.snapshot());
        assert!(text.contains("# TYPE afare_evals_total counter\nafare_evals_total 7\n"));
        assert!(text.contains("# TYPE afare_front_size gauge\nafare_front_size 12\n"));
        assert!(text.contains("afare_tick_ms_bucket{le=\"0.5\"} 1"));
        assert!(text.contains("afare_tick_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("afare_tick_ms_count 2"));
        assert!(text.contains("afare_tick_ms_p50 0.5"));
    }

    #[test]
    fn buckets_are_cumulative() {
        let r = MetricRegistry::new();
        for v in [0.02, 0.02, 0.3, 7.0] {
            r.observe_ms("x_ms", v);
        }
        let text = render(&r.snapshot());
        assert!(text.contains("afare_x_ms_bucket{le=\"0.05\"} 2"));
        assert!(text.contains("afare_x_ms_bucket{le=\"0.5\"} 3"));
        assert!(text.contains("afare_x_ms_bucket{le=\"10\"} 4"));
        assert!(text.contains("afare_x_ms_bucket{le=\"+Inf\"} 4"));
    }
}
