//! Prometheus text-exposition rendering of a [`MetricSnapshot`].
//!
//! The snapshot string is folded into `RunOutcome` JSON under the
//! `telemetry` key (only when telemetry is enabled, so disabled-run
//! goldens stay bitwise identical). Names are prefixed `afare_` and
//! sanitized to `[a-zA-Z0-9_]`; histograms render cumulative buckets
//! plus `_sum`/`_count` and bucket-estimated `p50`/`p95`/`p99` gauges.
//!
//! Histogram values are wall-clock-derived and therefore
//! nondeterministic across runs; deterministic consumers (the trace
//! smoke gate) strip histogram families and compare only counters and
//! gauges — see `docs/observability.md`.

use std::fmt::Write as _;

use crate::obs::registry::{MetricSnapshot, MS_BUCKETS};

/// Prometheus-legal metric name: `afare_` prefix, everything outside
/// `[a-zA-Z0-9_]` mapped to `_`.
pub fn metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 6);
    out.push_str("afare_");
    for c in name.chars() {
        out.push(if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' });
    }
    out
}

fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render a snapshot in the text exposition format, families sorted by
/// name (counters, then gauges, then histograms). Every family gets a
/// `# HELP` line ahead of its `# TYPE` line, as the exposition format
/// expects; the text is derived from the registry name only, so the
/// output stays a pure function of the snapshot.
pub fn render(snap: &MetricSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = metric_name(name);
        let _ = writeln!(out, "# HELP {n} Monotonic counter `{name}`.");
        let _ = writeln!(out, "# TYPE {n} counter");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, v) in &snap.gauges {
        let n = metric_name(name);
        let _ = writeln!(out, "# HELP {n} Gauge `{name}`.");
        let _ = writeln!(out, "# TYPE {n} gauge");
        let _ = writeln!(out, "{n} {}", fmt_f64(*v));
    }
    for (name, h) in &snap.histograms {
        let n = metric_name(name);
        let _ = writeln!(out, "# HELP {n} Wall-time histogram `{name}` (milliseconds).");
        let _ = writeln!(out, "# TYPE {n} histogram");
        let mut cum = 0u64;
        for (i, count) in h.buckets.iter().enumerate() {
            cum += count;
            let le = if i < MS_BUCKETS.len() { fmt_f64(MS_BUCKETS[i]) } else { "+Inf".into() };
            let _ = writeln!(out, "{n}_bucket{{le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(out, "{n}_sum {}", fmt_f64(h.sum));
        let _ = writeln!(out, "{n}_count {}", h.count);
        for (q, v) in [("p50", h.p50()), ("p95", h.p95()), ("p99", h.p99())] {
            let _ = writeln!(out, "# HELP {n}_{q} Bucket-estimated {q} of `{name}` (ms).");
            let _ = writeln!(out, "# TYPE {n}_{q} gauge");
            let _ = writeln!(out, "{n}_{q} {}", fmt_f64(v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::MetricRegistry;

    #[test]
    fn sanitizes_names() {
        assert_eq!(metric_name("online.tick-ms"), "afare_online_tick_ms");
        assert_eq!(metric_name("evals_total"), "afare_evals_total");
    }

    #[test]
    fn renders_all_families() {
        let r = MetricRegistry::new();
        r.counter_add("evals_total", 7);
        r.gauge_set("front_size", 12.0);
        r.observe_ms("tick_ms", 0.3);
        r.observe_ms("tick_ms", 40.0);
        let text = render(&r.snapshot());
        assert!(text.contains("# TYPE afare_evals_total counter\nafare_evals_total 7\n"));
        assert!(text.contains("# TYPE afare_front_size gauge\nafare_front_size 12\n"));
        assert!(text.contains("afare_tick_ms_bucket{le=\"0.5\"} 1"));
        assert!(text.contains("afare_tick_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("afare_tick_ms_count 2"));
        assert!(text.contains("afare_tick_ms_p50 0.5"));
    }

    #[test]
    fn golden_snapshot_with_help_lines() {
        // One family of each kind, fixed values: the full exposition
        // text is pinned byte-for-byte. Any change to HELP/TYPE
        // wording, ordering, or number formatting must update this
        // golden deliberately.
        let r = MetricRegistry::new();
        r.counter_add("evals_total", 7);
        r.gauge_set("front_size", 12.0);
        r.observe_ms("tick_ms", 0.3);
        let text = render(&r.snapshot());
        let golden = "\
# HELP afare_evals_total Monotonic counter `evals_total`.
# TYPE afare_evals_total counter
afare_evals_total 7
# HELP afare_front_size Gauge `front_size`.
# TYPE afare_front_size gauge
afare_front_size 12
# HELP afare_tick_ms Wall-time histogram `tick_ms` (milliseconds).
# TYPE afare_tick_ms histogram
afare_tick_ms_bucket{le=\"0.01\"} 0
afare_tick_ms_bucket{le=\"0.05\"} 0
afare_tick_ms_bucket{le=\"0.1\"} 0
afare_tick_ms_bucket{le=\"0.5\"} 1
afare_tick_ms_bucket{le=\"1\"} 1
afare_tick_ms_bucket{le=\"5\"} 1
afare_tick_ms_bucket{le=\"10\"} 1
afare_tick_ms_bucket{le=\"50\"} 1
afare_tick_ms_bucket{le=\"100\"} 1
afare_tick_ms_bucket{le=\"500\"} 1
afare_tick_ms_bucket{le=\"1000\"} 1
afare_tick_ms_bucket{le=\"5000\"} 1
afare_tick_ms_bucket{le=\"+Inf\"} 1
afare_tick_ms_sum 0.3
afare_tick_ms_count 1
# HELP afare_tick_ms_p50 Bucket-estimated p50 of `tick_ms` (ms).
# TYPE afare_tick_ms_p50 gauge
afare_tick_ms_p50 0.5
# HELP afare_tick_ms_p95 Bucket-estimated p95 of `tick_ms` (ms).
# TYPE afare_tick_ms_p95 gauge
afare_tick_ms_p95 0.5
# HELP afare_tick_ms_p99 Bucket-estimated p99 of `tick_ms` (ms).
# TYPE afare_tick_ms_p99 gauge
afare_tick_ms_p99 0.5
";
        assert_eq!(text, golden);
    }

    #[test]
    fn every_type_line_has_a_help_line() {
        let r = MetricRegistry::new();
        r.counter_add("server_retries_total", 2);
        r.gauge_set("opt_hypervolume", 1.25);
        r.observe_ms("span_online_tick_ms", 3.0);
        r.observe_ms("span_eval_batch_ms", 0.4);
        let text = render(&r.snapshot());
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let family = rest.split(' ').next().unwrap();
                let prev = lines.get(i.wrapping_sub(1)).copied().unwrap_or("");
                assert!(
                    prev.starts_with(&format!("# HELP {family} ")),
                    "TYPE line for {family} not preceded by its HELP line: {prev:?}"
                );
            }
        }
    }

    #[test]
    fn buckets_are_cumulative() {
        let r = MetricRegistry::new();
        for v in [0.02, 0.02, 0.3, 7.0] {
            r.observe_ms("x_ms", v);
        }
        let text = render(&r.snapshot());
        assert!(text.contains("afare_x_ms_bucket{le=\"0.05\"} 2"));
        assert!(text.contains("afare_x_ms_bucket{le=\"0.5\"} 3"));
        assert!(text.contains("afare_x_ms_bucket{le=\"10\"} 4"));
        assert!(text.contains("afare_x_ms_bucket{le=\"+Inf\"} 4"));
    }
}
