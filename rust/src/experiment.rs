//! Experiment harness: wires artifacts → PJRT runtime → eval set →
//! partition evaluator for a given [`ExperimentConfig`] (or, preferably,
//! a declarative [`ExperimentSpec`] via [`Experiment::from_spec`] /
//! [`Experiment::builder`]). Shared by the CLI, the examples and every
//! bench.

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::dataset::EvalSet;
use crate::faults::{DeviceFaultProfile, DriftComponent, FaultEnv, FaultScenario};
use crate::hw::Platform;
use crate::model::Manifest;
use crate::partition::{DaccMode, EngineConfig, PartitionEvaluator, SensitivityTable};
use crate::runtime::{AccuracyEvaluator, ArtifactIndex, CompiledModel, Runtime};
use crate::spec::{ExperimentSpec, PlatformSpec};

/// A fully-loaded experiment: compiled model, eval data, platform.
pub struct Experiment {
    pub index: ArtifactIndex,
    pub runtime: Runtime,
    pub model: CompiledModel,
    pub eval_set: EvalSet,
    pub acc_eval: AccuracyEvaluator,
    pub platform: Platform,
    pub profiles: Vec<DeviceFaultProfile>,
    /// Drift stack of the fault environment (empty = static env). Set by
    /// [`Experiment::from_spec`]; the legacy [`Experiment::load`] path
    /// leaves it empty.
    pub drift: Vec<DriftComponent>,
    /// Clean (zero-rate) quantized accuracy measured on this eval subset.
    pub clean_acc: f64,
    pub sensitivity: Option<SensitivityTable>,
    cfg: ExperimentConfig,
}

impl Experiment {
    /// The canonical fault-rate grid for sensitivity profiling. Every
    /// caller that measures a [`SensitivityTable`] for surrogate ΔAcc
    /// (the CLI's `--surrogate` path, the campaign preload, benches)
    /// uses this one grid: the cross-cell shared cache fingerprints the
    /// table's contents into its context key, so two runs only share
    /// ΔAcc results if they profiled on the same grid.
    pub const SENSITIVITY_RATE_GRID: [f32; 4] = [0.05, 0.1, 0.2, 0.4];

    /// Start a declarative builder over the default spec — the
    /// replacement for mutate-an-`ExperimentConfig`-then-`load`.
    ///
    /// ```no_run
    /// # fn main() -> anyhow::Result<()> {
    /// use afarepart::experiment::Experiment;
    /// use afarepart::faults::FaultScenario;
    /// let exp = Experiment::builder()
    ///     .model("alexnet")
    ///     .fault_rate(0.2)
    ///     .scenario(FaultScenario::InputWeight)
    ///     .pop(24)
    ///     .gens(10)
    ///     .build()?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder { spec: ExperimentSpec::default() }
    }

    /// Load everything a spec describes: artifacts for `spec.model`, the
    /// declared platform topology + fault profiles, and the drift stack
    /// (validated against the platform: a component targeting a device
    /// the platform doesn't have is an error, not a silent no-op).
    pub fn from_spec(spec: &ExperimentSpec) -> Result<Experiment> {
        let mut exp = Experiment::load(&spec.to_config())?;
        let (platform, profiles) = spec.platform.build();
        let env = spec.fault_env.build(profiles)?;
        exp.platform = platform;
        exp.profiles = env.profiles;
        exp.drift = env.drift;
        Ok(exp)
    }

    /// Load everything for `cfg` (compiles the model's HLO once).
    pub fn load(cfg: &ExperimentConfig) -> Result<Experiment> {
        let index = ArtifactIndex::load(&cfg.artifacts_dir)?;
        if !index.models.iter().any(|m| m == &cfg.model) {
            bail!("model {:?} not in artifacts (have: {:?})", cfg.model, index.models);
        }
        let manifest = Manifest::load(&index.manifest_path(&cfg.model))?;
        let runtime = Runtime::cpu()?;
        let model = runtime
            .load_model(&cfg.artifacts_dir, manifest)
            .context("loading compiled model")?;
        let eval_set = EvalSet::load(&index.eval_data_path())?;
        let acc_eval = AccuracyEvaluator::new(&model, &eval_set, cfg.eval_limit)?;
        let clean_acc = acc_eval.clean_accuracy(&model, cfg.dacc_batches)?;
        Ok(Experiment {
            index,
            runtime,
            model,
            eval_set,
            acc_eval,
            platform: Platform::default_two_device(),
            profiles: DeviceFaultProfile::default_two_device(),
            drift: Vec::new(),
            clean_acc,
            sensitivity: None,
            cfg: cfg.clone(),
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The fault environment: base rate + profiles + drift stack. The
    /// offline phase samples it at t = 0; the online phase follows it
    /// over time.
    pub fn fault_env(&self) -> FaultEnv {
        FaultEnv {
            base_rate: self.cfg.fault_rate,
            profiles: self.profiles.clone(),
            drift: self.drift.clone(),
        }
    }

    /// Measure (and cache) the layer sensitivity table for surrogate mode.
    /// The (layer, rate) sweep parallelizes across the configured
    /// `eval_threads`; results are bitwise identical to the serial sweep.
    pub fn measure_sensitivity(&mut self, rate_grid: &[f32]) -> Result<&SensitivityTable> {
        self.measure_sensitivity_with(rate_grid, &crate::obs::Telemetry::disabled())
    }

    /// [`Experiment::measure_sensitivity`] with a telemetry handle: the
    /// sweep emits a `sensitivity.measure` span plus one `sensitivity.cell`
    /// event per (unit, rate, fault-kind) cell, in deterministic order.
    pub fn measure_sensitivity_with(
        &mut self,
        rate_grid: &[f32],
        telemetry: &crate::obs::Telemetry,
    ) -> Result<&SensitivityTable> {
        if self.sensitivity.is_none() {
            let table = SensitivityTable::measure_with(
                &self.model,
                &self.acc_eval,
                rate_grid,
                self.cfg.dacc_batches,
                0xA11CE,
                self.eval_threads(),
                telemetry,
            )?;
            self.sensitivity = Some(table);
        }
        Ok(self.sensitivity.as_ref().unwrap())
    }

    /// Resolved evaluation-engine worker count: `eval_threads` from the
    /// config, with 0 meaning auto-detect ([`EngineConfig::auto`]).
    pub fn eval_threads(&self) -> usize {
        if self.cfg.eval_threads == 0 {
            EngineConfig::auto().threads
        } else {
            self.cfg.eval_threads
        }
    }

    /// Build a partition evaluator for `scenario` under the *current*
    /// (t = 0) environment rates. Uses surrogate mode if configured (and
    /// measured), exact in-graph fault injection otherwise. The batched
    /// evaluation engine is enabled with the configured thread budget —
    /// results are identical at any thread count.
    pub fn partition_evaluator(&self, scenario: FaultScenario) -> PartitionEvaluator<'_> {
        let env = self.fault_env();
        self.partition_evaluator_with_rates(scenario, env.dev_w_rates(0.0), env.dev_a_rates(0.0))
    }

    /// Like [`Experiment::partition_evaluator`] but under explicit
    /// per-device rates — the campaign runner and the online phase probe
    /// the environment at arbitrary times.
    pub fn partition_evaluator_with_rates(
        &self,
        scenario: FaultScenario,
        dev_w: Vec<f32>,
        dev_a: Vec<f32>,
    ) -> PartitionEvaluator<'_> {
        let dacc = match (&self.cfg.surrogate, &self.sensitivity) {
            (true, Some(table)) => DaccMode::Surrogate(table),
            _ => DaccMode::Exact {
                model: &self.model,
                eval: &self.acc_eval,
                key_seed: (self.cfg.seed & 0xFFFF_FFFF) as u32,
                n_batches: self.cfg.dacc_batches,
            },
        };
        PartitionEvaluator::new(
            &self.model.manifest,
            &self.platform,
            dev_w,
            dev_a,
            scenario,
            self.clean_acc,
            self.cfg.link_cost,
            dacc,
        )
        .with_parallelism(self.eval_threads())
    }

    /// Image dims of the eval set (h, w, c).
    pub fn img_dims(&self) -> (usize, usize, usize) {
        (self.eval_set.h, self.eval_set.w, self.eval_set.c)
    }
}

/// Fluent construction of an [`Experiment`] over an [`ExperimentSpec`] —
/// replaces the mutate-an-`ExperimentConfig`-then-`load` idiom. Every
/// method maps onto one spec field; [`ExperimentBuilder::spec`] exposes
/// the whole document for anything without a shorthand.
pub struct ExperimentBuilder {
    spec: ExperimentSpec,
}

impl ExperimentBuilder {
    /// Start from an existing spec instead of the defaults.
    pub fn from_spec(spec: ExperimentSpec) -> ExperimentBuilder {
        ExperimentBuilder { spec }
    }

    pub fn model(mut self, model: &str) -> Self {
        self.spec.model = model.to_string();
        self
    }

    pub fn artifacts_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.spec.artifacts_dir = dir.into();
        self
    }

    pub fn fault_rate(mut self, fr: f32) -> Self {
        self.spec.fault_env.fault_rate = fr;
        self
    }

    pub fn scenario(mut self, scenario: FaultScenario) -> Self {
        self.spec.fault_env.scenario = scenario;
        self
    }

    /// Replace the drift stack (see [`DriftComponent`]).
    pub fn drift(mut self, components: Vec<DriftComponent>) -> Self {
        self.spec.fault_env.drift = components;
        self
    }

    /// Replace the platform topology (see [`PlatformSpec`]).
    pub fn platform(mut self, platform: PlatformSpec) -> Self {
        self.spec.platform = platform;
        self
    }

    pub fn pop(mut self, pop_size: usize) -> Self {
        self.spec.optimizer.pop_size = pop_size;
        self
    }

    pub fn gens(mut self, generations: usize) -> Self {
        self.spec.optimizer.generations = generations;
        self
    }

    pub fn eval_limit(mut self, n: usize) -> Self {
        self.spec.eval_limit = n;
        self
    }

    pub fn dacc_batches(mut self, n: usize) -> Self {
        self.spec.dacc_batches = n;
        self
    }

    pub fn surrogate(mut self, on: bool) -> Self {
        self.spec.surrogate = on;
        self
    }

    pub fn eval_threads(mut self, n: usize) -> Self {
        self.spec.eval_threads = n;
        self
    }

    pub fn link_cost(mut self, on: bool) -> Self {
        self.spec.link_cost = on;
        self
    }

    pub fn theta(mut self, theta: f64) -> Self {
        self.spec.online.theta = theta;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    /// Direct access to the underlying spec for fields without a
    /// dedicated builder method (selection policy, online settings, …).
    pub fn spec(&mut self) -> &mut ExperimentSpec {
        &mut self.spec
    }

    /// The spec this builder has accumulated, without loading artifacts.
    pub fn into_spec(self) -> ExperimentSpec {
        self.spec
    }

    /// Load the experiment (compiles the model's HLO once).
    pub fn build(self) -> Result<Experiment> {
        Experiment::from_spec(&self.spec)
    }
}
