//! Experiment harness: wires artifacts → PJRT runtime → eval set →
//! partition evaluator for a given [`ExperimentConfig`]. Shared by the
//! CLI, the examples and every bench.

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::dataset::EvalSet;
use crate::faults::{DeviceFaultProfile, FaultEnv, FaultScenario};
use crate::hw::Platform;
use crate::model::Manifest;
use crate::partition::{DaccMode, EngineConfig, PartitionEvaluator, SensitivityTable};
use crate::runtime::{AccuracyEvaluator, ArtifactIndex, CompiledModel, Runtime};

/// A fully-loaded experiment: compiled model, eval data, platform.
pub struct Experiment {
    pub index: ArtifactIndex,
    pub runtime: Runtime,
    pub model: CompiledModel,
    pub eval_set: EvalSet,
    pub acc_eval: AccuracyEvaluator,
    pub platform: Platform,
    pub profiles: Vec<DeviceFaultProfile>,
    /// Clean (zero-rate) quantized accuracy measured on this eval subset.
    pub clean_acc: f64,
    pub sensitivity: Option<SensitivityTable>,
    cfg: ExperimentConfig,
}

impl Experiment {
    /// Load everything for `cfg` (compiles the model's HLO once).
    pub fn load(cfg: &ExperimentConfig) -> Result<Experiment> {
        let index = ArtifactIndex::load(&cfg.artifacts_dir)?;
        if !index.models.iter().any(|m| m == &cfg.model) {
            bail!("model {:?} not in artifacts (have: {:?})", cfg.model, index.models);
        }
        let manifest = Manifest::load(&index.manifest_path(&cfg.model))?;
        let runtime = Runtime::cpu()?;
        let model = runtime
            .load_model(&cfg.artifacts_dir, manifest)
            .context("loading compiled model")?;
        let eval_set = EvalSet::load(&index.eval_data_path())?;
        let acc_eval = AccuracyEvaluator::new(&model, &eval_set, cfg.eval_limit)?;
        let clean_acc = acc_eval.clean_accuracy(&model, cfg.dacc_batches)?;
        Ok(Experiment {
            index,
            runtime,
            model,
            eval_set,
            acc_eval,
            platform: Platform::default_two_device(),
            profiles: DeviceFaultProfile::default_two_device(),
            clean_acc,
            sensitivity: None,
            cfg: cfg.clone(),
        })
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// The static fault environment of the offline phase.
    pub fn fault_env(&self) -> FaultEnv {
        FaultEnv::constant(self.cfg.fault_rate, self.profiles.clone())
    }

    /// Measure (and cache) the layer sensitivity table for surrogate mode.
    pub fn measure_sensitivity(&mut self, rate_grid: &[f32]) -> Result<&SensitivityTable> {
        if self.sensitivity.is_none() {
            let table = SensitivityTable::measure(
                &self.model,
                &self.acc_eval,
                rate_grid,
                self.cfg.dacc_batches,
                0xA11CE,
            )?;
            self.sensitivity = Some(table);
        }
        Ok(self.sensitivity.as_ref().unwrap())
    }

    /// Resolved evaluation-engine worker count: `eval_threads` from the
    /// config, with 0 meaning auto-detect ([`EngineConfig::auto`]).
    pub fn eval_threads(&self) -> usize {
        if self.cfg.eval_threads == 0 {
            EngineConfig::auto().threads
        } else {
            self.cfg.eval_threads
        }
    }

    /// Build a partition evaluator for `scenario` under the *current*
    /// (t = 0) environment rates. Uses surrogate mode if configured (and
    /// measured), exact in-graph fault injection otherwise. The batched
    /// evaluation engine is enabled with the configured thread budget —
    /// results are identical at any thread count.
    pub fn partition_evaluator(&self, scenario: FaultScenario) -> PartitionEvaluator<'_> {
        let env = self.fault_env();
        let dacc = match (&self.cfg.surrogate, &self.sensitivity) {
            (true, Some(table)) => DaccMode::Surrogate(table),
            _ => DaccMode::Exact {
                model: &self.model,
                eval: &self.acc_eval,
                key_seed: (self.cfg.seed & 0xFFFF_FFFF) as u32,
                n_batches: self.cfg.dacc_batches,
            },
        };
        PartitionEvaluator::new(
            &self.model.manifest,
            &self.platform,
            env.dev_w_rates(0.0),
            env.dev_a_rates(0.0),
            scenario,
            self.clean_acc,
            self.cfg.link_cost,
            dacc,
        )
        .with_parallelism(self.eval_threads())
    }

    /// Image dims of the eval set (h, w, c).
    pub fn img_dims(&self) -> (usize, usize, usize) {
        (self.eval_set.h, self.eval_set.w, self.eval_set.c)
    }
}
