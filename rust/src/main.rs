//! AFarePart CLI — the L3 coordinator binary.
//!
//! Subcommands:
//!   offline   run the offline multi-objective partitioning (Algorithm 1,
//!             lines 1–12); prints the Pareto front and the deployed P*.
//!   online    serve inference under a drifting fault environment with
//!             θ-triggered dynamic repartitioning (lines 13–19).
//!   sweep     layer-wise fault sensitivity sweep (§V-C methodology).
//!   compare   run AFarePart vs CNNParted vs fault-unaware on one model
//!             (one cell group of Table II).
//!   info      print artifact/platform information.
//!
//! Common options: --model, --fault-rate, --scenario, --pop, --gens,
//! --eval-limit, --surrogate, --link-cost, --seed, --config <json>.

use anyhow::Result;

use afarepart::baselines::{CnnParted, FaultUnaware};
use afarepart::cli::Args;
use afarepart::config::ExperimentConfig;
use afarepart::coordinator::server::InferenceServer;
use afarepart::coordinator::{OfflineRunner, OnlineConfig, OnlineRunner};
use afarepart::experiment::Experiment;
use afarepart::faults::{DriftSchedule, FaultEnv, RateVectors};
use afarepart::model::Manifest;
use afarepart::partition::{Mapping, PartitionEvaluator};
use afarepart::util::fmt::{pct, Table};

const BOOL_FLAGS: &[&str] = &["surrogate", "link-cost", "verbose", "help"];

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, BOOL_FLAGS);
    if args.has_flag("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let mut cfg = ExperimentConfig::default();
    cfg.apply_args(&args)?;
    cfg.apply_env();

    match args.subcommand.as_deref().unwrap() {
        "offline" => cmd_offline(&cfg, &args),
        "online" => cmd_online(&cfg, &args),
        "sweep" => cmd_sweep(&cfg),
        "compare" => cmd_compare(&cfg),
        "info" => cmd_info(&cfg),
        other => {
            eprintln!("unknown subcommand {other:?}");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "afarepart — accuracy-aware fault-resilient DNN partitioner\n\n\
         USAGE: afarepart <offline|online|sweep|compare|info> [options]\n\n\
         OPTIONS:\n\
           --model <alexnet|squeezenet|resnet18>   model artifact (default alexnet)\n\
           --artifacts <dir>        artifacts directory (default ./artifacts)\n\
           --fault-rate <f>         environment fault rate FR (default 0.2)\n\
           --scenario <w|a|iw>      weight-only / input-only / input+weight\n\
           --pop <n> --gens <n>     NSGA-II budget (default 60/60)\n\
           --eval-limit <n>         eval samples for exact dAcc (default 256)\n\
           --eval-threads <n>       ΔAcc eval engine workers (0 = auto; same results at any n)\n\
           --theta <f>              online accuracy-drop threshold (default 0.05)\n\
           --ticks <n>              online serving ticks (default 120)\n\
           --surrogate              use the layer-sensitivity surrogate\n\
           --link-cost              include link costs in objectives\n\
           --seed <n>               master seed\n\
           --config <file.json>     load a config file first"
    );
}

fn cmd_info(cfg: &ExperimentConfig) -> Result<()> {
    let exp = Experiment::load(cfg)?;
    println!("platform: {}", exp.runtime.platform());
    println!("model: {} ({} units)", exp.model.manifest.model, exp.model.num_units());
    println!(
        "precision: int{}  faulty LSBs: {}  batch: {}",
        exp.model.manifest.precision, exp.model.manifest.faulty_bits, exp.model.manifest.batch
    );
    println!("clean quantized top-1 (eval subset): {}", pct(exp.clean_acc));
    let mut t = Table::new(&["unit", "kind", "MACs", "w_bytes", "eyeriss ms/mJ", "simba ms/mJ"]);
    let lat = exp.platform.latency_table(&exp.model.manifest.units);
    let en = exp.platform.energy_table(&exp.model.manifest.units);
    for (i, u) in exp.model.manifest.units.iter().enumerate() {
        t.row(vec![
            u.name.clone(),
            u.kind.clone(),
            u.macs.to_string(),
            u.w_bytes.to_string(),
            format!("{:.3}/{:.4}", lat[i][0], en[i][0]),
            format!("{:.3}/{:.4}", lat[i][1], en[i][1]),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_offline(cfg: &ExperimentConfig, args: &Args) -> Result<()> {
    let verbose = args.has_flag("verbose");
    let mut exp = Experiment::load(cfg)?;
    if cfg.surrogate {
        exp.measure_sensitivity(&[0.05, 0.1, 0.2, 0.4])?;
    }
    println!(
        "offline: model={} FR={} scenario={} pop={} gens={} mode={} eval-threads={}",
        cfg.model,
        cfg.fault_rate,
        cfg.scenario.label(),
        cfg.nsga2.pop_size,
        cfg.nsga2.generations,
        if cfg.surrogate { "surrogate" } else { "exact" },
        exp.eval_threads(),
    );
    let mut ev = exp.partition_evaluator(cfg.scenario);
    let runner = OfflineRunner {
        nsga2: cfg.nsga2.clone(),
        lat_budget: cfg.lat_budget,
        energy_budget: cfg.energy_budget,
    };
    let out = runner.run(&mut ev, vec![], |gs| {
        if verbose {
            println!(
                "  gen {:3}  front={}  best: lat={:.2}ms en={:.3}mJ dAcc={}",
                gs.generation,
                gs.front_size,
                gs.best_per_objective[0],
                gs.best_per_objective[1],
                pct(gs.best_per_objective[2]),
            );
        }
    })?;
    let mut t = Table::new(&["mapping", "latency ms", "energy mJ", "dAcc"]);
    for ind in &out.front {
        t.row(vec![
            Mapping(ind.genome.clone()).display(),
            format!("{:.2}", ind.objectives[0]),
            format!("{:.3}", ind.objectives[1]),
            pct(ind.objectives[2]),
        ]);
    }
    println!("\nPareto front ({} solutions):", out.front.len());
    print!("{}", t.render());
    println!(
        "\ndeployed P* = {}  (lat {:.2} ms, energy {:.3} mJ, dAcc {})",
        out.deployed.display(),
        out.deployed_objectives[0],
        out.deployed_objectives[1],
        pct(out.deployed_objectives[2]),
    );
    let (h, m, r) = out.cache;
    println!(
        "dAcc cache: {h} hits / {m} misses (hit rate {:.1}%) over {} evaluations",
        r * 100.0,
        out.evaluations
    );
    Ok(())
}

fn cmd_sweep(cfg: &ExperimentConfig) -> Result<()> {
    let exp = Experiment::load(cfg)?;
    let grid = [0.1f32, 0.2, 0.4];
    println!(
        "layer-wise fault sweep: model={} clean={} (eval {} samples)",
        cfg.model,
        pct(exp.clean_acc),
        exp.acc_eval.samples(cfg.dacc_batches),
    );
    let l = exp.model.num_units();
    let mut t = Table::new(&["unit", "FR=0.1 w/a", "FR=0.2 w/a", "FR=0.4 w/a"]);
    for unit in 0..l {
        let mut cells = vec![exp.model.manifest.units[unit].name.clone()];
        for &r in &grid {
            let mut rv = RateVectors::zeros(l);
            rv.w_rates[unit] = r;
            let aw = exp.acc_eval.accuracy(&exp.model, &rv, 1, cfg.dacc_batches)?;
            let mut rv = RateVectors::zeros(l);
            rv.a_rates[unit] = r;
            let aa = exp.acc_eval.accuracy(&exp.model, &rv, 1, cfg.dacc_batches)?;
            cells.push(format!(
                "{}/{}",
                pct((exp.clean_acc - aw).max(0.0)),
                pct((exp.clean_acc - aa).max(0.0))
            ));
        }
        t.row(cells);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_compare(cfg: &ExperimentConfig) -> Result<()> {
    let exp = Experiment::load(cfg)?;
    println!(
        "compare: model={} FR={} scenario={} (pop {}, gens {})",
        cfg.model,
        cfg.fault_rate,
        cfg.scenario.label(),
        cfg.nsga2.pop_size,
        cfg.nsga2.generations
    );
    let mut rows = Vec::new();

    // CNNParted
    let mut ev = exp.partition_evaluator(cfg.scenario);
    let mapping = CnnParted::new(cfg.nsga2.clone()).partition(&mut ev)?;
    rows.push(("CNNParted", describe(&mut ev, &mapping)?));

    // Fault-unaware
    let mut ev = exp.partition_evaluator(cfg.scenario);
    let mapping = FaultUnaware::new(cfg.nsga2.clone()).partition(&mut ev)?;
    rows.push(("Flt-unaware", describe(&mut ev, &mapping)?));

    // AFarePart
    let mut ev = exp.partition_evaluator(cfg.scenario);
    let runner = OfflineRunner {
        nsga2: cfg.nsga2.clone(),
        lat_budget: cfg.lat_budget,
        energy_budget: cfg.energy_budget,
    };
    let out = runner.run(&mut ev, vec![], |_| {})?;
    rows.push(("AFarePart", describe(&mut ev, &out.deployed)?));

    let mut t = Table::new(&["tool", "mapping", "acc (faulty)", "latency ms", "energy mJ"]);
    for (name, (m, acc, lat, en)) in rows {
        t.row(vec![name.to_string(), m, pct(acc), format!("{lat:.2}"), format!("{en:.3}")]);
    }
    print!("{}", t.render());
    Ok(())
}

fn describe(ev: &mut PartitionEvaluator, mapping: &Mapping) -> Result<(String, f64, f64, f64)> {
    Ok((
        mapping.display(),
        ev.faulty_accuracy(mapping)?,
        ev.latency_ms(mapping),
        ev.energy_mj(mapping),
    ))
}

fn cmd_online(cfg: &ExperimentConfig, args: &Args) -> Result<()> {
    let ticks = args.get_usize("ticks", 120);
    let exp = Experiment::load(cfg)?;
    println!(
        "online: model={} base FR={} θ={} ticks={ticks} (EM step attack on dev0 at t=30s)",
        cfg.model, cfg.fault_rate, cfg.theta
    );

    // offline phase first for the initial P*
    let mut ev = exp.partition_evaluator(cfg.scenario);
    let runner = OfflineRunner {
        nsga2: cfg.nsga2.clone(),
        lat_budget: cfg.lat_budget,
        energy_budget: cfg.energy_budget,
    };
    let initial = runner.run(&mut ev, vec![], |_| {})?.deployed;
    println!("initial P* = {}", initial.display());

    let manifest = Manifest::load(&exp.index.manifest_path(&cfg.model))?;
    let server = InferenceServer::spawn(cfg.artifacts_dir.clone(), manifest, exp.img_dims())?;
    let env = FaultEnv {
        base_rate: cfg.fault_rate,
        profiles: exp.profiles.clone(),
        drift: DriftSchedule::StepAttack { device: 0, at_s: 30.0, factor: 2.0 },
    };
    // exact-mode re-optimization (see examples/online_reconfig.rs for why
    // the surrogate is not enough); use --surrogate to override.
    let mut reopt_ev = exp.partition_evaluator(cfg.scenario);

    let online_cfg = OnlineConfig { theta: cfg.theta, ticks, ..Default::default() };
    let mut runner = OnlineRunner {
        cfg: online_cfg,
        server: &server,
        evaluator: &mut reopt_ev,
        clean_acc: exp.clean_acc,
    };
    let out = runner.run(&exp.eval_set, &env, initial, |p| {
        if p.tick % 10 == 0 || p.reconfigured {
            println!(
                "  t={:5.1}s FR(dev0)={:.2} acc={} rolling={} map={}{}",
                p.sim_time_s,
                p.env_rate_dev0,
                pct(p.batch_accuracy),
                pct(p.rolling_accuracy),
                p.mapping.display(),
                if p.reconfigured { "  <-- REPARTITIONED" } else { "" }
            );
        }
    })?;
    println!(
        "\nserved {} batches; {} reconfigurations; final mapping {}",
        out.metrics.batches_served,
        out.metrics.reconfigurations,
        out.final_mapping.display()
    );
    println!(
        "dAcc cache lifetime: {} hits / {} misses across {} environment epoch(s)",
        out.cache_lifetime.hits,
        out.cache_lifetime.misses,
        out.metrics.cache_epochs_closed + 1,
    );
    if let Some(s) = out.metrics.exec_summary() {
        println!("PJRT exec: mean {:.2} ms  p95 {:.2} ms", s.mean, s.p95);
    }
    Ok(())
}
