//! AFarePart CLI — the L3 coordinator binary.
//!
//! Subcommands:
//!   offline   run the offline multi-objective partitioning (Algorithm 1,
//!             lines 1–12); prints the Pareto front and the deployed P*.
//!   online    serve inference under a drifting fault environment with
//!             θ-triggered dynamic repartitioning (lines 13–19).
//!   sweep     layer-wise fault sensitivity sweep (§V-C methodology).
//!   compare   run AFarePart vs CNNParted vs fault-unaware on one model
//!             (one cell group of Table II).
//!   campaign  expand a spec grid (models × fault-rates × scenarios ×
//!             drift schedules) and run every cell through the batched
//!             evaluation engine; one consolidated JSON report.
//!   trace     offline trace post-processing: `trace analyze <file>`
//!             turns a JSONL event trace into a deterministic report
//!             (span waterfall, cache rollup, fault-attribution chains,
//!             convergence curves; docs/observability.md).
//!   info      print artifact/platform information.
//!
//! Every run is described by a declarative [`ExperimentSpec`]
//! (docs/spec.md) resolved through one precedence chain:
//! CLI flags > AFARE_* env > --spec/--config file > defaults.
//! Every subcommand supports `--format json [--out <file>]`.

use std::time::Duration;

use anyhow::{bail, Result};

use afarepart::baselines::{CnnParted, FaultUnaware};
use afarepart::bench::suite::{
    synthetic_eval_set, synthetic_manifest, synthetic_sensitivity, synthetic_units,
};
use afarepart::cli::Args;
use afarepart::coordinator::metrics::Metrics;
use afarepart::coordinator::server::InferenceServer;
use afarepart::coordinator::{
    safe_fallback_mapping, BackendSpec, OfflineOutcome, OnlineOutcome, OnlineRunner,
};
use afarepart::experiment::Experiment;
use afarepart::faults::RateVectors;
use afarepart::model::Manifest;
use afarepart::obs::Telemetry;
use afarepart::partition::{DaccMode, EngineConfig, Mapping, PartitionEvaluator};
use afarepart::spec::campaign::{run_campaign_with, CampaignOptions};
use afarepart::spec::outcome::{
    emit_json, CompareReport, CompareRow, InfoReport, InfoUnit, OfflineReport, OnlineReport,
    OutputFormat, SweepReport, SweepUnit,
};
use afarepart::spec::{CampaignSpec, ExperimentSpec};
use afarepart::util::fmt::{pct, Table};
use afarepart::util::json::Value;

const BOOL_FLAGS: &[&str] = &["surrogate", "link-cost", "chaos", "telemetry", "verbose", "help"];

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, BOOL_FLAGS);
    if args.has_flag("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    let format = OutputFormat::from_args(&args)?;

    match args.subcommand.as_deref().unwrap() {
        "campaign" => return cmd_campaign(&args, format),
        "trace" => return cmd_trace(&args, format),
        "offline" | "online" | "sweep" | "compare" | "info" => {}
        other => {
            eprintln!("unknown subcommand {other:?}");
            print_help();
            std::process::exit(2);
        }
    }

    // One resolution point for the whole binary: defaults < file < env < CLI.
    let spec = ExperimentSpec::resolve(&args)?;
    match args.subcommand.as_deref().unwrap() {
        "offline" => cmd_offline(&spec, &args, format),
        "online" => cmd_online(&spec, &args, format),
        "sweep" => cmd_sweep(&spec, &args, format),
        "compare" => cmd_compare(&spec, &args, format),
        "info" => cmd_info(&spec, &args, format),
        _ => unreachable!(),
    }
}

fn print_help() {
    println!(
        "afarepart — accuracy-aware fault-resilient DNN partitioner\n\n\
         USAGE: afarepart <offline|online|sweep|compare|campaign|trace|info> [options]\n\n\
         Every run is a declarative ExperimentSpec (see docs/spec.md).\n\
         Precedence: CLI flags > AFARE_* env > --spec file > defaults.\n\n\
         SPEC & OUTPUT:\n\
           --spec <file.json>       load an ExperimentSpec first (--config is an alias;\n\
                                    for campaign: a CampaignSpec {{base, grid}})\n\
           --format <text|json>     output format (default text)\n\
           --out <file>             write the JSON report to a file\n\
           --telemetry              enable the metric registry; the report gains a\n\
                                    `telemetry` Prometheus snapshot (off by default)\n\
           --trace <file>           also append a deterministic JSONL event trace\n\
                                    (implies --telemetry; see docs/observability.md)\n\n\
         EXPERIMENT:\n\
           --model <alexnet|squeezenet|resnet18>   model artifact (default alexnet)\n\
           --artifacts <dir>        artifacts directory (default ./artifacts)\n\
           --fault-rate <f>         environment fault rate FR (default 0.2)\n\
           --scenario <w|a|iw>      weight-only / input-only / input+weight\n\
           --pop <n> --gens <n>     NSGA-II budget (default 60/60)\n\
           --eval-limit <n>         eval samples for exact dAcc (default 256)\n\
           --eval-threads <n>       ΔAcc eval engine workers (0 = auto; same results at any n)\n\
           --selection-threads <n>  NSGA-II selection/variation workers (default 1 = legacy\n\
                                    bitwise serial path; >=2 = seed-deterministic parallel\n\
                                    path, same results at any n >= 2)\n\
           --campaign-workers <n>   campaign cell workers (0 = auto budget split;\n\
                                    report is identical at any n)\n\
           --surrogate              use the layer-sensitivity surrogate\n\
           --link-cost              include link costs in objectives\n\
           --policy <p>             P* selection: min-dacc-within-budget | min-dacc | knee\n\
           --lat-budget <f> --energy-budget <f>    selection budget factors (2.0 / 3.0)\n\
           --seed <n>               master seed\n\n\
         ONLINE:\n\
           --theta <f>              accuracy-drop threshold (default 0.05)\n\
           --ticks <n>              serving ticks (default 120)\n\
           --lookahead <n>          canary pipeline depth (0 = derive from eval-threads;\n\
                                    timeline is identical at any depth)\n\
           --chaos                  enable the spec's chaos-injection stack\n\
           --chaos-seed <n>         chaos PRNG seed (independent of --seed)\n\n\
         TRACE:\n\
           trace analyze <file.jsonl>   offline trace post-processing: span\n\
                                    waterfall, cache rollup, fault-attribution\n\
                                    chains, convergence curves; deterministic\n\
                                    report (same trace => same bytes)\n\n\
         `--model synthetic-L<n>` serves the artifact-free fixture model\n\
         (no PJRT artifacts needed) — the chaos/resilience smoke path.\n\
         The platform topology (device list, fault multipliers, link),\n\
         composable drift schedules, chaos component stacks, and the\n\
         supervision knobs (recv_timeout_ms, max_retries, backoff_ms,\n\
         health_cooldown) are spec-file-only — see docs/spec.md."
    );
}

/// In text mode with `--out`, the JSON report is still written to the
/// file; in json mode it goes to `--out` or stdout.
fn emit(format: OutputFormat, args: &Args, report: &Value) -> Result<()> {
    match (format, args.get("out")) {
        (OutputFormat::Json, out) => emit_json(report, out),
        (OutputFormat::Text, Some(out)) => emit_json(report, Some(out)),
        (OutputFormat::Text, None) => Ok(()),
    }
}

/// Offline optimization under the spec's environment at t = 0, through
/// the batched evaluation engine, deployed per the spec's selection
/// policy.
fn run_offline(spec: &ExperimentSpec, exp: &Experiment) -> Result<(OfflineOutcome, usize)> {
    run_offline_verbose(spec, exp, false, &Telemetry::disabled())
}

fn run_offline_verbose(
    spec: &ExperimentSpec,
    exp: &Experiment,
    verbose: bool,
    telemetry: &Telemetry,
) -> Result<(OfflineOutcome, usize)> {
    let mut ev = exp.partition_evaluator(spec.fault_env.scenario);
    ev.set_telemetry(telemetry.clone());
    let nsga2 = spec.nsga2_config();
    let out = spec.selection.optimize_and_deploy(&mut ev, &nsga2, |gs| {
        if verbose {
            println!(
                "  gen {:3}  front={}  best: lat={:.2}ms en={:.3}mJ dAcc={}",
                gs.generation,
                gs.front_size,
                gs.best_per_objective[0],
                gs.best_per_objective[1],
                pct(gs.best_per_objective[2]),
            );
        }
    })?;
    Ok((out, ev.parallelism()))
}

/// Load the spec's experiment; in surrogate mode, measure the layer
/// sensitivity table the evaluator composes (otherwise `--surrogate`
/// would silently fall back to exact injection).
fn load_experiment(spec: &ExperimentSpec, telemetry: &Telemetry) -> Result<Experiment> {
    let mut exp = Experiment::from_spec(spec)?;
    if spec.surrogate {
        exp.measure_sensitivity_with(&Experiment::SENSITIVITY_RATE_GRID, telemetry)?;
    }
    Ok(exp)
}

fn cmd_offline(spec: &ExperimentSpec, args: &Args, format: OutputFormat) -> Result<()> {
    let verbose = args.has_flag("verbose") && !format.is_json();
    let telemetry = spec.telemetry.build()?;
    let exp = load_experiment(spec, &telemetry)?;
    if !format.is_json() {
        println!(
            "offline: model={} FR={} scenario={} pop={} gens={} mode={} eval-threads={} policy={}",
            spec.model,
            spec.fault_env.fault_rate,
            spec.fault_env.scenario.label(),
            spec.optimizer.pop_size,
            spec.optimizer.generations,
            if spec.surrogate { "surrogate" } else { "exact" },
            exp.eval_threads(),
            spec.selection.policy.as_str(),
        );
    }
    let (out, threads) = run_offline_verbose(spec, &exp, verbose, &telemetry)?;
    let mut report = OfflineReport::from_outcome(
        &spec.model,
        spec.fault_env.scenario.label(),
        spec.fault_env.fault_rate,
        spec.optimizer.pop_size,
        spec.optimizer.generations,
        spec.surrogate,
        threads,
        &out,
    );
    report.telemetry = telemetry.prometheus();
    telemetry.flush()?;
    if !format.is_json() {
        let mut t = Table::new(&["mapping", "latency ms", "energy mJ", "dAcc"]);
        for ind in &out.front {
            t.row(vec![
                Mapping(ind.genome.clone()).display(),
                format!("{:.2}", ind.objectives[0]),
                format!("{:.3}", ind.objectives[1]),
                pct(ind.objectives[2]),
            ]);
        }
        println!("\nPareto front ({} solutions):", out.front.len());
        print!("{}", t.render());
        println!(
            "\ndeployed P* = {}  (lat {:.2} ms, energy {:.3} mJ, dAcc {})",
            out.deployed.display(),
            out.deployed_objectives[0],
            out.deployed_objectives[1],
            pct(out.deployed_objectives[2]),
        );
        let (h, m, r) = out.cache;
        println!(
            "dAcc cache: {h} hits / {m} misses (hit rate {:.1}%) over {} evaluations",
            r * 100.0,
            out.evaluations
        );
    }
    emit(format, args, &report.to_json())
}

fn cmd_sweep(spec: &ExperimentSpec, args: &Args, format: OutputFormat) -> Result<()> {
    let exp = Experiment::from_spec(spec)?;
    let grid = [0.1f32, 0.2, 0.4];
    if !format.is_json() {
        println!(
            "layer-wise fault sweep: model={} clean={} (eval {} samples)",
            spec.model,
            pct(exp.clean_acc),
            exp.acc_eval.samples(spec.dacc_batches),
        );
    }
    let l = exp.model.num_units();
    let mut units = Vec::with_capacity(l);
    for unit in 0..l {
        let uc = &exp.model.manifest.units[unit];
        let mut w_drop = Vec::with_capacity(grid.len());
        let mut a_drop = Vec::with_capacity(grid.len());
        for &r in &grid {
            let mut rv = RateVectors::zeros(l);
            rv.w_rates[unit] = r;
            let aw = exp.acc_eval.accuracy(&exp.model, &rv, 1, spec.dacc_batches)?;
            w_drop.push((exp.clean_acc - aw).max(0.0));
            let mut rv = RateVectors::zeros(l);
            rv.a_rates[unit] = r;
            let aa = exp.acc_eval.accuracy(&exp.model, &rv, 1, spec.dacc_batches)?;
            a_drop.push((exp.clean_acc - aa).max(0.0));
        }
        units.push(SweepUnit { name: uc.name.clone(), kind: uc.kind.clone(), w_drop, a_drop });
    }
    let report = SweepReport {
        model: spec.model.clone(),
        clean_acc: exp.clean_acc,
        rate_grid: grid.to_vec(),
        units,
    };
    if !format.is_json() {
        let mut t = Table::new(&["unit", "FR=0.1 w/a", "FR=0.2 w/a", "FR=0.4 w/a"]);
        for u in &report.units {
            let mut cells = vec![u.name.clone()];
            for i in 0..grid.len() {
                cells.push(format!("{}/{}", pct(u.w_drop[i]), pct(u.a_drop[i])));
            }
            t.row(cells);
        }
        print!("{}", t.render());
    }
    emit(format, args, &report.to_json())
}

fn cmd_compare(spec: &ExperimentSpec, args: &Args, format: OutputFormat) -> Result<()> {
    let exp = load_experiment(spec, &Telemetry::disabled())?;
    if !format.is_json() {
        println!(
            "compare: model={} FR={} scenario={} (pop {}, gens {})",
            spec.model,
            spec.fault_env.fault_rate,
            spec.fault_env.scenario.label(),
            spec.optimizer.pop_size,
            spec.optimizer.generations
        );
    }
    let scenario = spec.fault_env.scenario;
    let nsga2 = spec.nsga2_config();
    let mut rows = Vec::new();

    // CNNParted
    let mut ev = exp.partition_evaluator(scenario);
    let mapping = CnnParted::new(nsga2.clone()).partition(&mut ev)?;
    rows.push(describe("CNNParted", &mut ev, &mapping)?);

    // Fault-unaware
    let mut ev = exp.partition_evaluator(scenario);
    let mapping = FaultUnaware::new(nsga2.clone()).partition(&mut ev)?;
    rows.push(describe("Flt-unaware", &mut ev, &mapping)?);

    // AFarePart
    let (out, _) = run_offline(spec, &exp)?;
    let mut ev = exp.partition_evaluator(scenario);
    rows.push(describe("AFarePart", &mut ev, &out.deployed)?);

    let report = CompareReport {
        model: spec.model.clone(),
        scenario: scenario.label().to_string(),
        fault_rate: spec.fault_env.fault_rate,
        rows,
    };
    if !format.is_json() {
        let mut t = Table::new(&["tool", "mapping", "acc (faulty)", "latency ms", "energy mJ"]);
        for r in &report.rows {
            t.row(vec![
                r.tool.clone(),
                r.mapping.clone(),
                pct(r.faulty_acc),
                format!("{:.2}", r.latency_ms),
                format!("{:.3}", r.energy_mj),
            ]);
        }
        print!("{}", t.render());
    }
    emit(format, args, &report.to_json())
}

fn describe(tool: &str, ev: &mut PartitionEvaluator, mapping: &Mapping) -> Result<CompareRow> {
    Ok(CompareRow {
        tool: tool.to_string(),
        mapping: mapping.display(),
        faulty_acc: ev.faulty_accuracy(mapping)?,
        latency_ms: ev.latency_ms(mapping),
        energy_mj: ev.energy_mj(mapping),
    })
}

/// Per-tick progress line shared by both online paths.
fn print_tick(p: &afarepart::coordinator::TimelinePoint) {
    if p.tick % 10 == 0 || p.reconfigured || p.degraded {
        println!(
            "  t={:5.1}s FR(dev0)={:.2} acc={} rolling={} map={}{}{}",
            p.sim_time_s,
            p.env_rate_dev0,
            pct(p.batch_accuracy),
            pct(p.rolling_accuracy),
            p.mapping.display(),
            if p.reconfigured { "  <-- REPARTITIONED" } else { "" },
            if p.degraded { "  [DEGRADED]" } else { "" },
        );
    }
}

/// Supervision / degradation counters, printed only when they fired.
fn print_resilience_summary(m: &Metrics) {
    if m.worker_respawns + m.retries + m.transient_errors + m.timeouts > 0 {
        println!(
            "supervision: {} worker respawn(s), {} retry(ies) ({} transient errors, {} timeouts)",
            m.worker_respawns, m.retries, m.transient_errors, m.timeouts,
        );
    }
    if m.degradations > 0 {
        let spans: Vec<String> = m
            .degraded_intervals
            .iter()
            .map(|&(s, e)| format!("[{s}, {e})"))
            .collect();
        println!(
            "degraded: {} outage(s), {} tick(s) on the safe mapping: {}",
            m.degradations,
            m.degraded_ticks,
            spans.join(" "),
        );
    }
}

fn print_online_summary(out: &OnlineOutcome) {
    println!(
        "\nserved {} batches; {} reconfigurations; final mapping {}",
        out.metrics.batches_served,
        out.metrics.reconfigurations,
        out.final_mapping.display()
    );
    if out.metrics.speculative_discarded > 0 {
        println!(
            "speculative canary batches discarded on reconfiguration: {}",
            out.metrics.speculative_discarded
        );
    }
    print_resilience_summary(&out.metrics);
    println!(
        "dAcc cache lifetime: {} hits / {} misses across {} environment epoch(s)",
        out.cache_lifetime.hits,
        out.cache_lifetime.misses,
        out.metrics.cache_epochs_closed + 1,
    );
    if let Some(s) = out.metrics.exec_summary() {
        println!("exec: mean {:.2} ms  p95 {:.2} ms", s.mean, s.p95);
    }
}

fn cmd_online(spec: &ExperimentSpec, args: &Args, format: OutputFormat) -> Result<()> {
    if let Some(n) = synthetic_units(&spec.model) {
        // Artifact-free serving world: no PJRT, pure synthetic backend.
        return cmd_online_synthetic(spec, args, format, n);
    }
    let telemetry = spec.telemetry.build()?;
    let exp = load_experiment(spec, &telemetry)?;
    let online_cfg = spec.online.to_online_config(exp.eval_threads());
    // The complete environment, drift stack included, comes from the
    // spec (build() validates component device indices).
    let env = spec.fault_env.build(exp.profiles.clone())?;
    if !format.is_json() {
        println!(
            "online: model={} base FR={} θ={} ticks={} drift components={} lookahead={}",
            spec.model,
            spec.fault_env.fault_rate,
            online_cfg.theta,
            online_cfg.ticks,
            env.drift.len(),
            online_cfg.lookahead,
        );
        if spec.chaos.enabled {
            println!(
                "chaos: enabled (seed {}, {} components)",
                spec.chaos.seed,
                spec.chaos.components.len()
            );
        }
    }

    // offline phase first for the initial P* (and the front the safe
    // degradation mapping is drawn from)
    let (out, _) = run_offline_verbose(spec, &exp, false, &telemetry)?;
    let safe = safe_fallback_mapping(&out.front, &exp.profiles, exp.model.num_units());
    let initial = out.deployed;
    if !format.is_json() {
        println!("initial P* = {}  (safe fallback {})", initial.display(), safe.display());
    }

    let manifest = Manifest::load(&exp.index.manifest_path(&spec.model))?;
    let server = InferenceServer::spawn_with(
        BackendSpec::Artifacts { artifacts_dir: spec.artifacts_dir.clone(), manifest },
        exp.img_dims(),
        online_cfg.supervisor_policy(),
    )?;
    server.set_telemetry(telemetry.clone());
    // exact-mode re-optimization by default (see examples/online_reconfig.rs
    // for why the surrogate is usually not enough); --surrogate switches the
    // evaluator to the measured sensitivity table (load_experiment measured it).
    let mut reopt_ev = exp.partition_evaluator(spec.fault_env.scenario);
    reopt_ev.set_telemetry(telemetry.clone());

    let theta = online_cfg.theta;
    let lookahead = online_cfg.lookahead;
    let mut runner = OnlineRunner {
        cfg: online_cfg,
        server: &server,
        evaluator: &mut reopt_ev,
        clean_acc: exp.clean_acc,
        chaos: spec.chaos.to_engine(),
        safe_mapping: Some(safe),
        telemetry: telemetry.clone(),
    };
    let quiet = format.is_json();
    let out = runner.run(&exp.eval_set, &env, initial.clone(), |p| {
        if !quiet {
            print_tick(p);
        }
    })?;
    server.shutdown()?;
    let mut report = OnlineReport::from_outcome(&spec.model, theta, lookahead, &initial, &out);
    report.telemetry = telemetry.prometheus();
    telemetry.flush()?;
    if !format.is_json() {
        print_online_summary(&out);
    }
    emit(format, args, &report.to_json())
}

/// `synthetic-L<n>` online serving: the fixture manifest + sensitivity
/// table of `bench::suite` with the deterministic synthetic prediction
/// backend, so chaos/resilience runs (and `make chaos-smoke`) need no
/// compiled artifacts.
fn cmd_online_synthetic(
    spec: &ExperimentSpec,
    args: &Args,
    format: OutputFormat,
    n: usize,
) -> Result<()> {
    const DIMS: (usize, usize, usize) = (4, 4, 3);
    let manifest = synthetic_manifest(n);
    let table = synthetic_sensitivity(n);
    let threads = if spec.eval_threads == 0 {
        EngineConfig::auto().threads
    } else {
        spec.eval_threads
    };
    let online_cfg = spec.online.to_online_config(threads);
    let (platform, profiles) = spec.platform.build();
    let env = spec.fault_env.build(profiles.clone())?;
    if !format.is_json() {
        println!(
            "online: model={} (synthetic) base FR={} θ={} ticks={} drift components={} lookahead={}",
            spec.model,
            spec.fault_env.fault_rate,
            online_cfg.theta,
            online_cfg.ticks,
            env.drift.len(),
            online_cfg.lookahead,
        );
        if spec.chaos.enabled {
            println!(
                "chaos: enabled (seed {}, {} components)",
                spec.chaos.seed,
                spec.chaos.components.len()
            );
        }
    }

    // offline phase at the t = 0 environment for the initial P* and the
    // safe fallback — the same evaluator construction as campaign cells.
    let telemetry = spec.telemetry.build()?;
    let nsga2 = spec.nsga2_config();
    let mut ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        spec.fault_env.scenario,
        table.clean_acc,
        spec.link_cost,
        DaccMode::SyntheticExact { table: &table, cost: Duration::ZERO },
    )
    .with_parallelism(threads)
    .with_telemetry(telemetry.clone());
    let off = spec.selection.optimize_and_deploy(&mut ev, &nsga2, |_| {})?;
    let safe = safe_fallback_mapping(&off.front, &profiles, manifest.num_units);
    let initial = off.deployed;
    if !format.is_json() {
        println!("initial P* = {}  (safe fallback {})", initial.display(), safe.display());
    }

    let server = InferenceServer::spawn_with(
        BackendSpec::Synthetic { manifest: manifest.clone(), exec_cost: Duration::ZERO },
        DIMS,
        online_cfg.supervisor_policy(),
    )?;
    server.set_telemetry(telemetry.clone());
    let eval_set = synthetic_eval_set(
        manifest.batch * 8,
        DIMS.0,
        DIMS.1,
        DIMS.2,
        manifest.num_classes,
        spec.seed,
    );
    let mut reopt_ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        env.dev_w_rates(0.0),
        env.dev_a_rates(0.0),
        spec.fault_env.scenario,
        table.clean_acc,
        spec.link_cost,
        DaccMode::SyntheticExact { table: &table, cost: Duration::ZERO },
    )
    .with_parallelism(threads)
    .with_telemetry(telemetry.clone());

    let theta = online_cfg.theta;
    let lookahead = online_cfg.lookahead;
    let mut runner = OnlineRunner {
        cfg: online_cfg,
        server: &server,
        evaluator: &mut reopt_ev,
        clean_acc: table.clean_acc,
        chaos: spec.chaos.to_engine(),
        safe_mapping: Some(safe),
        telemetry: telemetry.clone(),
    };
    let quiet = format.is_json();
    let out = runner.run(&eval_set, &env, initial.clone(), |p| {
        if !quiet {
            print_tick(p);
        }
    })?;
    server.shutdown()?;
    let mut report = OnlineReport::from_outcome(&spec.model, theta, lookahead, &initial, &out);
    report.telemetry = telemetry.prometheus();
    telemetry.flush()?;
    if !format.is_json() {
        print_online_summary(&out);
    }
    emit(format, args, &report.to_json())
}

fn cmd_info(spec: &ExperimentSpec, args: &Args, format: OutputFormat) -> Result<()> {
    let exp = Experiment::from_spec(spec)?;
    let lat = exp.platform.latency_table(&exp.model.manifest.units);
    let en = exp.platform.energy_table(&exp.model.manifest.units);
    let device_names: Vec<String> = exp.profiles.iter().map(|p| p.device.clone()).collect();
    let report = InfoReport {
        platform: exp.runtime.platform(),
        device_names: device_names.clone(),
        model: exp.model.manifest.model.clone(),
        num_units: exp.model.num_units(),
        precision: exp.model.manifest.precision as usize,
        faulty_bits: exp.model.manifest.faulty_bits as usize,
        batch: exp.model.manifest.batch,
        clean_acc: exp.clean_acc,
        units: exp
            .model
            .manifest
            .units
            .iter()
            .enumerate()
            .map(|(i, u)| InfoUnit {
                name: u.name.clone(),
                kind: u.kind.clone(),
                macs: u.macs,
                w_bytes: u.w_bytes,
                latency_ms: lat[i].clone(),
                energy_mj: en[i].clone(),
            })
            .collect(),
    };
    if !format.is_json() {
        println!("platform: {}", report.platform);
        println!("devices: {}", device_names.join(", "));
        println!("model: {} ({} units)", report.model, report.num_units);
        println!(
            "precision: int{}  faulty LSBs: {}  batch: {}",
            report.precision, report.faulty_bits, report.batch
        );
        println!("clean quantized top-1 (eval subset): {}", pct(report.clean_acc));
        let mut header: Vec<String> =
            vec!["unit".into(), "kind".into(), "MACs".into(), "w_bytes".into()];
        for d in &device_names {
            header.push(format!("{d} ms/mJ"));
        }
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&header_refs);
        for u in &report.units {
            let mut cells = vec![
                u.name.clone(),
                u.kind.clone(),
                u.macs.to_string(),
                u.w_bytes.to_string(),
            ];
            for d in 0..device_names.len() {
                cells.push(format!("{:.3}/{:.4}", u.latency_ms[d], u.energy_mj[d]));
            }
            t.row(cells);
        }
        print!("{}", t.render());
    }
    emit(format, args, &report.to_json())
}

fn cmd_campaign(args: &Args, format: OutputFormat) -> Result<()> {
    let Some(path) = args.get("spec").or_else(|| args.get("config")) else {
        bail!("campaign requires --spec <file.json> (a CampaignSpec: {{\"base\": ..., \"grid\": ...}})");
    };
    // Same precedence chain as every other subcommand, applied to the
    // base spec (file < env < CLI) *before* the grid axes default from
    // it — so `--fault-rate 0.4` reaches every cell unless the file's
    // grid pins `fault_rates` explicitly.
    let cspec = CampaignSpec::from_file_with(std::path::Path::new(path), |base| {
        base.apply_env_with(|k| std::env::var(k).ok());
        base.apply_args(args)
    })?;

    if !format.is_json() {
        println!(
            "campaign: {} models × {} fault-rates × {} scenarios × {} drifts = {} cells",
            cspec.models.len(),
            cspec.fault_rates.len(),
            cspec.scenarios.len(),
            cspec.drifts.len(),
            cspec.num_cells(),
        );
    }
    let telemetry = cspec.base.telemetry.build()?;
    let opts = CampaignOptions { telemetry: telemetry.clone(), ..CampaignOptions::default() };
    let quiet = format.is_json();
    let report = run_campaign_with(&cspec, &opts, |i, total, cell| {
        if !quiet {
            println!(
                "  [{}/{}] {} FR={} {} drift={}: P*={} dAcc={} ({} evals)",
                i + 1,
                total,
                cell.offline.model,
                cell.offline.fault_rate,
                cell.offline.scenario,
                cell.drift,
                cell.offline.deployed.mapping,
                pct(cell.offline.deployed.dacc),
                cell.offline.evaluations,
            );
        }
    })?;
    if !format.is_json() {
        let mut t = Table::new(&[
            "model", "FR", "scenario", "drift", "P*", "lat ms", "energy mJ", "dAcc",
        ]);
        for c in &report.cells {
            t.row(vec![
                c.offline.model.clone(),
                format!("{}", c.offline.fault_rate),
                c.offline.scenario.clone(),
                c.drift.clone(),
                c.offline.deployed.mapping.clone(),
                format!("{:.2}", c.offline.deployed.latency_ms),
                format!("{:.3}", c.offline.deployed.energy_mj),
                pct(c.offline.deployed.dacc),
            ]);
        }
        print!("{}", t.render());
        println!(
            "{} cells, {} fitness evaluations ({} unique backend evals) in {:.1} s @ {} engine threads",
            report.cells.len(),
            report.total_evaluations,
            report.total_backend_evals,
            report.wall_ms / 1e3,
            report.engine_threads,
        );
        for m in &report.cache_sharing {
            if m.saved_backend_evals > 0 {
                println!(
                    "  {}: cross-cell cache saved {} of {} backend evals ({} unique keys)",
                    m.model, m.saved_backend_evals, m.private_misses, m.unique_keys,
                );
            }
        }
    }
    telemetry.flush()?;
    emit(format, args, &report.to_json())
}

/// `trace analyze <file>`: offline post-processing of a JSONL event
/// trace into a deterministic report (docs/observability.md). Needs no
/// spec, artifacts, or backend — it only reads the file.
fn cmd_trace(args: &Args, format: OutputFormat) -> Result<()> {
    let (action, path) = match args.positional.as_slice() {
        [a, p] => (a.as_str(), p.as_str()),
        _ => bail!("usage: trace analyze <file.jsonl> [--format json] [--out <file>]"),
    };
    if action != "analyze" {
        bail!("unknown trace action {action:?} (expected: analyze)");
    }
    let analysis = afarepart::obs::analyze_file(std::path::Path::new(path))?;
    if !format.is_json() {
        print!("{}", analysis.render_text());
    }
    emit(format, args, &analysis.to_json())
}
