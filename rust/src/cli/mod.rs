//! Hand-rolled CLI argument parser (clap is unavailable offline —
//! DESIGN.md §9). Supports subcommands, `--key value`, `--key=value`,
//! and boolean `--flag` switches.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + options + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (exclusive of argv[0]). Keys listed in
    /// `bool_flags` take no value.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&stripped) {
                    args.flags.push(stripped.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        args.flags.push(stripped.to_string());
                    } else {
                        args.opts.insert(stripped.to_string(), it.next().unwrap().clone());
                    }
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a.clone());
            } else {
                args.positional.push(a.clone());
            }
        }
        args
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(
            &sv(&["offline", "--model", "alexnet", "--pop=24", "--verbose", "extra"]),
            &["verbose"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("offline"));
        assert_eq!(a.get("model"), Some("alexnet"));
        assert_eq!(a.get_usize("pop", 0), 24);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn trailing_switch_without_value_is_flag() {
        let a = Args::parse(&sv(&["run", "--fast"]), &[]);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn flag_followed_by_switch_is_flag() {
        let a = Args::parse(&sv(&["run", "--fast", "--model", "x"]), &[]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.get("model"), Some("x"));
    }

    #[test]
    fn hyphenated_value_keys_parse() {
        // campaign scheduler knobs ride the generic `--key value` path
        let a = Args::parse(
            &sv(&["campaign", "--campaign-workers", "4", "--eval-threads=2"]),
            &[],
        );
        assert_eq!(a.get_usize("campaign-workers", 0), 4);
        assert_eq!(a.get_usize("eval-threads", 0), 2);
    }

    #[test]
    fn selection_threads_parses_both_forms() {
        let a = Args::parse(&sv(&["offline", "--selection-threads", "4"]), &[]);
        assert_eq!(a.get_usize("selection-threads", 1), 4);
        let b = Args::parse(&sv(&["offline", "--selection-threads=2"]), &[]);
        assert_eq!(b.get_usize("selection-threads", 1), 2);
    }

    #[test]
    fn typed_getters_fall_back() {
        let a = Args::parse(&sv(&["x", "--n", "abc"]), &[]);
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }
}
