//! Offline shim of the `anyhow` crate (crates.io is unavailable in this
//! environment — DESIGN.md §9). Implements exactly the subset afarepart
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!`/`bail!` macros.
//!
//! Semantics mirror the real crate where it matters to callers:
//! * `{}` displays the outermost message, `{:#}` the full cause chain
//!   joined by `": "` (the format the CLI and tests rely on).
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its source chain as strings.
//! * `Error` itself deliberately does **not** implement
//!   `std::error::Error`, which is what lets the blanket `From`/context
//!   impls coexist (same coherence trick as upstream anyhow).

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the same defaulted form as upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: `chain[0]` is the outermost context message,
/// later entries are successively deeper causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// Root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    fn from_std<E: StdError>(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(err)
    }
}

/// Conversion used by [`Context`]: implemented for every std error *and*
/// for [`Error`] itself so `.context(...)` works on both kinds of Result.
#[doc(hidden)]
pub trait IntoAnyhow {
    fn into_anyhow(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoAnyhow for E {
    fn into_anyhow(self) -> Error {
        Error::from_std(self)
    }
}

impl IntoAnyhow for Error {
    fn into_anyhow(self) -> Error {
        self
    }
}

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T>;
}

impl<T, E: IntoAnyhow> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_anyhow().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_anyhow().context(context())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_stacks_on_anyhow_errors() {
        let e: Error = Err::<(), Error>(anyhow!("inner {}", 3)).context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 3");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause(), "inner 3");
    }

    #[test]
    fn option_context() {
        let v: Result<i32> = Some(5).context("absent");
        assert_eq!(v.unwrap(), 5);
        let e = None::<i32>.with_context(|| format!("absent {}", 1)).unwrap_err();
        assert_eq!(format!("{e}"), "absent 1");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<i32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with code 7");
    }

    #[test]
    fn error_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Error>();
    }
}
