//! Offline stub of the `xla` PJRT bindings (the real crate and the PJRT
//! C-API runtime are unavailable in this environment — DESIGN.md §9).
//!
//! The stub is API-compatible with the subset afarepart's runtime layer
//! uses. [`Literal`] is implemented faithfully (typed byte buffers with
//! shape metadata) so literal construction, round-trips and the accuracy
//! evaluator's batch caching all work and stay unit-testable. The
//! *execution* surface is present but inert: [`PjRtClient::cpu`] returns
//! an error, so every PJRT-dependent path fails fast at client creation
//! with a clear message, and artifact-gated tests skip before reaching it.
//!
//! All handle types are plain data and therefore `Send + Sync`, which is
//! what lets the partition evaluation engine share per-worker handles
//! across its scoped thread pool. A real PJRT backend must keep the
//! one-executable-per-thread discipline documented in coordinator/server.rs.

use std::fmt;

/// Stub error type (mirrors `xla::Error` usage: Display + std::error).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built against the offline xla stub; \
         link the real xla crate to execute compiled artifacts)"
    ))
}

/// Element types of the literals afarepart constructs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Sealed-ish mapping from Rust scalars to [`ElementType`] tags.
pub trait NativeType: Copy {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}

/// A typed host buffer with shape metadata (faithfully implemented).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw little-endian bytes (the constructor the
    /// real crate exposes for untyped data).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expect = dims.iter().product::<usize>() * ty.byte_size();
        if data.len() != expect {
            return Err(Error(format!(
                "literal byte size mismatch: got {}, want {} for dims {:?}",
                data.len(),
                expect,
                dims
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn shape_dims(&self) -> &[usize] {
        &self.dims
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal type mismatch: stored {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        let size = std::mem::size_of::<T>();
        let mut out = Vec::with_capacity(self.data.len() / size);
        for chunk in self.data.chunks_exact(size) {
            // SAFETY: T is a plain-old-data scalar (f32/i32/u32) and the
            // chunk holds exactly size_of::<T>() little-endian host bytes.
            out.push(unsafe { std::ptr::read_unaligned(chunk.as_ptr() as *const T) });
        }
        Ok(out)
    }

    /// Unwrap a 1-tuple literal. The stub never produces tuples (execution
    /// is unavailable), so this only ever reports an error.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("to_tuple1"))
    }
}

/// Parsed HLO module proto (stub: opaque token).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub: opaque token).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. The stub cannot create one; every runtime path
/// fails here, before any executable or buffer exists.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (stub: unreachable without a client).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (stub: unreachable without a client).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
    }

    #[test]
    fn literal_size_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn literal_type_mismatch_rejected() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::U32, &[1], &[1, 0, 0, 0])
                .unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![1]);
    }

    #[test]
    fn client_creation_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }

    #[test]
    fn handles_are_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Literal>();
        check::<PjRtLoadedExecutable>();
        check::<PjRtBuffer>();
        check::<PjRtClient>();
    }
}
