//! Ablations (DESIGN.md §4): the design choices behind AFarePart.
//!
//! A1 — ΔAcc mode: exact in-graph injection vs sensitivity surrogate
//!      (fidelity of the estimate + wall-time difference).
//! A3 — link cost: AFarePart excludes link latency/energy (§VI-E);
//!      measure how including it changes the deployed mapping's metrics.
//! A4 — optimizer: NSGA-II vs random search at the same evaluation budget.
//!
//! Run: `cargo bench --bench bench_ablation`.

use afarepart::baselines::random_search_mapping;
use afarepart::bench::suite::bench_budget;
use afarepart::bench::{bench_header, Stopwatch};
use afarepart::coordinator::OfflineRunner;
use afarepart::experiment::Experiment;
use afarepart::faults::FaultScenario;
use afarepart::partition::{DaccMode, PartitionEvaluator};
use afarepart::util::fmt::{pct, Table};
use afarepart::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let fast = bench_header("Ablations — dAcc mode, link cost, optimizer");
    let (mut cfg, nsga2) = bench_budget(fast);
    cfg.model = "alexnet".into();
    cfg.fault_rate = 0.2;
    let scenario = FaultScenario::InputWeight;
    let mut exp = Experiment::load(&cfg)?;

    // ---------- A1: surrogate fidelity + speed ----------
    println!("[A1] measuring sensitivity table...");
    let sw = Stopwatch::start();
    exp.measure_sensitivity(&[0.05, 0.1, 0.2, 0.4])?;
    let table_ms = sw.ms();
    let table = exp.sensitivity.as_ref().unwrap().clone();

    // fidelity: compare surrogate vs exact dAcc on random mappings
    let mut rng = Rng::new(42);
    let l = exp.model.num_units();
    let mut exact_ev = exp.partition_evaluator(scenario);
    let mut sur_ev = PartitionEvaluator::new(
        &exp.model.manifest,
        &exp.platform,
        exact_ev.dev_w_rates.clone(),
        exact_ev.dev_a_rates.clone(),
        scenario,
        exp.clean_acc,
        false,
        DaccMode::Surrogate(&table),
    );
    let n_cmp = if fast { 8 } else { 16 };
    let mut abs_err = Vec::new();
    let mut order_pairs = 0;
    let mut order_agree = 0;
    let mut points = Vec::new();
    for _ in 0..n_cmp {
        let m = afarepart::partition::Mapping::random(&mut rng, l, 2);
        let de = exact_ev.dacc(&m)?;
        let ds = sur_ev.dacc(&m)?;
        abs_err.push((de - ds).abs());
        points.push((de, ds));
    }
    for i in 0..points.len() {
        for j in (i + 1)..points.len() {
            if (points[i].0 - points[j].0).abs() > 0.02 {
                order_pairs += 1;
                if (points[i].0 < points[j].0) == (points[i].1 < points[j].1) {
                    order_agree += 1;
                }
            }
        }
    }
    let mean_err = abs_err.iter().sum::<f64>() / abs_err.len() as f64;
    println!(
        "[A1] surrogate vs exact on {n_cmp} random mappings: mean |err| = {:.3} ({} of clean), ranking agreement {}/{}",
        mean_err,
        pct(mean_err / exp.clean_acc),
        order_agree,
        order_pairs
    );
    println!("[A1] one-time table cost {:.1}s; per-candidate cost ~0 vs one PJRT exec", table_ms / 1e3);

    // ---------- A3: link cost on/off ----------
    let runner = OfflineRunner { nsga2: nsga2.clone(), ..Default::default() };
    let mut rows = Table::new(&["config", "mapping", "dAcc", "lat ms", "energy mJ", "boundaries"]);
    for link in [false, true] {
        let mut ev = exp.partition_evaluator(scenario);
        ev.include_link_cost = link;
        let out = runner.run(&mut ev, vec![], |_| {})?;
        rows.row(vec![
            if link { "with link cost".into() } else { "no link cost (paper)".to_string() },
            out.deployed.display(),
            pct(out.deployed_objectives[2]),
            format!("{:.2}", out.deployed_objectives[0]),
            format!("{:.3}", out.deployed_objectives[1]),
            out.deployed.boundaries().to_string(),
        ]);
    }
    println!("\n[A3] link-cost ablation:\n{}", rows.render());

    // ---------- A4: NSGA-II vs random search at equal budget ----------
    let mut ev = exp.partition_evaluator(scenario);
    let out = runner.run(&mut ev, vec![], |_| {})?;
    let budget = nsga2.pop_size * (nsga2.generations + 1);
    let mut ev_rs = exp.partition_evaluator(scenario);
    let rs = random_search_mapping(&mut ev_rs, budget, (1.0, 10.0, 100.0), 7)?;
    let mut scorer = exp.partition_evaluator(scenario);
    let rs_acc = scorer.faulty_accuracy(&rs)?;
    let afp_acc = exp.clean_acc - out.deployed_objectives[2];
    println!(
        "[A4] equal budget ({budget} evals): NSGA-II P* acc {} vs random-search {} (scalarized)",
        pct(afp_acc),
        pct(rs_acc)
    );
    Ok(())
}
