//! Performance benches (EXPERIMENTS.md §Perf): the L3 hot paths.
//!
//! * PJRT batched execution latency (clean + faulty) per model.
//! * NSGA-II optimizer throughput on the analytical objectives (no PJRT).
//! * ΔAcc cache effect: NSGA-II wall time with and without memoization.
//! * Evaluator scalar costs (latency/energy models, rate-vector build).
//!
//! Run: `cargo bench --bench bench_perf`.

use afarepart::bench::suite::bench_budget;
use afarepart::bench::{bench_header, bench_ms, BenchConfig, BenchReport, Stopwatch};
use afarepart::coordinator::offline::optimize_partitions;
use afarepart::experiment::Experiment;
use afarepart::faults::{FaultScenario, RateVectors};
use afarepart::nsga2::Nsga2Config;
use afarepart::partition::{DaccMode, Mapping, PartitionEvaluator};
use afarepart::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let fast = bench_header("Perf — runtime exec, optimizer throughput, cache effect");
    let (mut cfg, _) = bench_budget(fast);
    let mut report = BenchReport::new();
    let bc = BenchConfig { warmup_iters: 2, sample_iters: if fast { 5 } else { 10 } };

    for model in ["alexnet", "squeezenet", "resnet18"] {
        cfg.model = model.into();
        let exp = Experiment::load(&cfg)?;
        let l = exp.model.num_units();
        let zero = RateVectors::zeros(l);
        let faulty = RateVectors {
            w_rates: vec![0.2; l],
            a_rates: vec![0.2; l],
        };
        let mut k = 0u32;
        report.add(
            format!("pjrt exec clean  b64 [{model}]"),
            bench_ms(bc, || {
                k += 1;
                exp.acc_eval.accuracy(&exp.model, &zero, k, 1).unwrap();
            }),
        );
        report.add(
            format!("pjrt exec faulty b64 [{model}]"),
            bench_ms(bc, || {
                k += 1;
                exp.acc_eval.accuracy(&exp.model, &faulty, k, 1).unwrap();
            }),
        );
    }

    // optimizer throughput on analytical objectives only (DaccMode::None):
    // isolates the NSGA-II machinery itself.
    cfg.model = "resnet18".into();
    let exp = Experiment::load(&cfg)?;
    let mk_eval = || {
        PartitionEvaluator::new(
            &exp.model.manifest,
            &exp.platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            exp.clean_acc,
            false,
            DaccMode::None,
        )
    };
    let nsga = Nsga2Config { pop_size: 60, generations: 60, ..Default::default() };
    let evals = nsga.pop_size * (nsga.generations + 1);
    let s = bench_ms(bc, || {
        let mut ev = mk_eval();
        optimize_partitions(&mut ev, &nsga, false, vec![], |_| {});
    });
    println!(
        "NSGA-II machinery (pop 60 x gens 60, analytical objectives): {:.2} ms/run = {:.0} evals/ms",
        s.mean,
        evals as f64 / s.mean
    );
    report.add("nsga2 60x60 analytical", s);

    // evaluator scalar costs
    let ev = mk_eval();
    let mut rng = Rng::new(1);
    let maps: Vec<Mapping> =
        (0..1024).map(|_| Mapping::random(&mut rng, exp.model.num_units(), 2)).collect();
    let mut i = 0;
    report.add(
        "latency+energy model x1024",
        bench_ms(bc, || {
            for m in &maps {
                std::hint::black_box(ev.latency_ms(m) + ev.energy_mj(m));
            }
            i += 1;
        }),
    );

    // cache effect on a real exact-mode optimization (small budget)
    let sw = Stopwatch::start();
    let mut ev = exp.partition_evaluator(FaultScenario::InputWeight);
    let small = Nsga2Config { pop_size: 12, generations: 4, ..Default::default() };
    optimize_partitions(&mut ev, &small, true, vec![], |_| {});
    let (hits, misses, rate) = ev.cache_stats();
    println!(
        "exact-mode NSGA-II 12x4 [resnet18]: {:.1}s wall, cache {hits} hits / {misses} misses ({:.0}% hit rate)",
        sw.s(),
        rate * 100.0
    );
    println!(
        "  -> without memoization this run would cost ~{:.0}x more PJRT executions",
        (hits + misses) as f64 / misses.max(1) as f64
    );

    println!("\n{}", report.render());
    Ok(())
}
