//! Performance benches (EXPERIMENTS.md §Perf): the L3 hot paths.
//!
//! * **Evaluation engine** (artifact-free): offline optimization
//!   wall-clock and evals/second at 1, 2 and N worker threads with an
//!   exact-cost-shaped ΔAcc backend, plus the surrogate fast path —
//!   results land in `BENCH_eval_engine.json` so future PRs can track
//!   the perf trajectory. Asserts thread-count determinism as it goes.
//! * **Campaign scheduler** (artifact-free): cells/second of a 3×2
//!   synthetic campaign at 1, 2 and 4 cell workers with an exact-shaped
//!   per-eval cost — lands in `BENCH_campaign.json`; asserts the report
//!   is bitwise identical across worker counts as it goes.
//! * **NSGA-II selection pipeline**: offspring/second of the extracted
//!   tournament+crossover+mutation round plus the 2N-pool non-dominated
//!   sort at pop 128/512/1024 × `selection_threads` 1/2/4 —
//!   `BENCH_variation.json`. Asserts both determinism contracts as it
//!   goes: `selection_threads = 1` bitwise-identical to the frozen
//!   pre-parallelization oracle (`bench::suite::legacy_nsga2`) for the
//!   golden seeds, and the forked path identical across thread counts.
//! * PJRT batched execution latency (clean + faulty) per model.
//! * NSGA-II optimizer throughput on the analytical objectives (no PJRT).
//! * ΔAcc cache effect: NSGA-II wall time with and without memoization.
//!
//! The PJRT sections skip politely when `make artifacts` hasn't run; the
//! eval-engine section always runs.
//!
//! Run: `cargo bench --bench bench_perf`.

use std::time::Duration;

use afarepart::bench::suite::{
    bench_budget, front_fingerprint, legacy_nsga2, synthetic_manifest, synthetic_sensitivity,
};
use afarepart::bench::{
    bench_header, bench_ms, write_json_result, BenchConfig, BenchReport, Stopwatch,
};
use afarepart::coordinator::offline::{optimize_partitions, optimize_partitions_counted};
use afarepart::experiment::Experiment;
use afarepart::faults::{FaultScenario, RateVectors};
use afarepart::hw::Platform;
use afarepart::nsga2::{Individual, Nsga2, Nsga2Config, Problem};
use afarepart::obs::{analyze_str, Telemetry};
use afarepart::partition::{DaccMode, Mapping, PartitionEvaluator, SensitivityTable};
use afarepart::spec::campaign::{run_campaign_with, CampaignOptions, CampaignSpec};
use afarepart::util::fmt::Table;
use afarepart::util::json::{arr, num, obj, s, to_string as json_str, Value};
use afarepart::util::prng::Rng;

/// One timed offline optimization at a given engine thread count.
fn timed_run(
    manifest_units: usize,
    table: &SensitivityTable,
    platform: &Platform,
    nsga2: &Nsga2Config,
    dacc_cost: Duration,
    threads: usize,
) -> (f64, usize, Vec<(Vec<usize>, Vec<u64>)>, (usize, usize)) {
    let manifest = synthetic_manifest(manifest_units);
    let mut ev = PartitionEvaluator::new(
        &manifest,
        platform,
        vec![0.25, 0.04],
        vec![0.25, 0.04],
        FaultScenario::InputWeight,
        0.9,
        false,
        DaccMode::SyntheticExact { table, cost: dacc_cost },
    )
    .with_parallelism(threads);
    let sw = Stopwatch::start();
    let (front, evals) = optimize_partitions_counted(&mut ev, nsga2, true, vec![], |_| {});
    let wall_ms = sw.ms();
    let (h, m, _) = ev.cache_stats();
    (wall_ms, evals, front_fingerprint(&front), (h, m))
}

fn bench_eval_engine(fast: bool) {
    println!("\n-- evaluation engine (synthetic exact backend, no artifacts needed) --");
    let l = 10;
    let table = synthetic_sensitivity(l);
    let platform = Platform::default_two_device();
    let nsga2 = if fast {
        Nsga2Config { pop_size: 12, generations: 4, ..Default::default() }
    } else {
        Nsga2Config { pop_size: 24, generations: 8, ..Default::default() }
    };
    // Emulated PJRT cost per unique ΔAcc evaluation: a blocking ~1.5 ms
    // call, the measured small-model batch execution order of magnitude.
    let dacc_cost = Duration::from_micros(1500);

    let thread_counts = [1usize, 2, 4];
    let mut rows = Vec::new();
    let mut reference: Option<Vec<(Vec<usize>, Vec<u64>)>> = None;
    let mut wall_by_threads = Vec::new();
    for &t in &thread_counts {
        let (wall_ms, evals, key, (hits, misses)) =
            timed_run(l, &table, &platform, &nsga2, dacc_cost, t);
        if reference.is_none() {
            reference = Some(key);
        } else {
            assert_eq!(
                reference.as_ref().unwrap(),
                &key,
                "DETERMINISM VIOLATION: front at {t} threads differs from 1 thread"
            );
        }
        wall_by_threads.push((t, wall_ms));
        rows.push((t, wall_ms, evals, hits, misses));
    }
    let wall_1t = wall_by_threads[0].1;

    let mut t = Table::new(&["threads", "wall ms", "evals", "evals/s", "cache h/m", "speedup"]);
    let mut thread_objs = Vec::new();
    for (threads, wall_ms, evals, hits, misses) in &rows {
        let evals_per_s = *evals as f64 / (wall_ms / 1e3);
        let speedup = wall_1t / wall_ms;
        t.row(vec![
            threads.to_string(),
            format!("{wall_ms:.1}"),
            evals.to_string(),
            format!("{evals_per_s:.0}"),
            format!("{hits}/{misses}"),
            format!("{speedup:.2}x"),
        ]);
        thread_objs.push(obj(vec![
            ("threads", num(*threads as f64)),
            ("wall_ms", num(*wall_ms)),
            ("evals", num(*evals as f64)),
            ("evals_per_s", num(evals_per_s)),
            ("cache_hits", num(*hits as f64)),
            ("cache_misses", num(*misses as f64)),
            ("speedup_vs_1t", num(speedup)),
        ]));
    }
    print!("{}", t.render());
    println!("fronts identical across all thread counts (bitwise) ✓");

    // surrogate fast path: misses are sub-microsecond, the engine must
    // stay serial and the whole optimization is pure optimizer overhead
    let manifest = synthetic_manifest(l);
    let mut sur_ev = PartitionEvaluator::new(
        &manifest,
        &platform,
        vec![0.25, 0.04],
        vec![0.25, 0.04],
        FaultScenario::InputWeight,
        0.9,
        false,
        DaccMode::Surrogate(&table),
    )
    .with_parallelism(4);
    let sw = Stopwatch::start();
    let (sur_front, _) = optimize_partitions_counted(&mut sur_ev, &nsga2, true, vec![], |_| {});
    let surrogate_wall_ms = sw.ms();
    println!("surrogate mode (4 threads configured, serial fast path): {surrogate_wall_ms:.1} ms");
    assert_eq!(
        reference.as_ref().unwrap(),
        &front_fingerprint(&sur_front),
        "synthetic-exact and surrogate backends disagree (same table => same front)"
    );

    let speedup_4t = wall_1t / wall_by_threads.last().unwrap().1;
    println!("speedup at 4 threads vs 1: {speedup_4t:.2}x");
    let doc: Value = obj(vec![
        ("bench", s("eval_engine")),
        ("model", s(&format!("synthetic-L{l}"))),
        ("pop_size", num(nsga2.pop_size as f64)),
        ("generations", num(nsga2.generations as f64)),
        ("dacc_cost_us", num(dacc_cost.as_micros() as f64)),
        ("threads", arr(thread_objs)),
        ("speedup_4t_vs_1t", num(speedup_4t)),
        ("surrogate_wall_ms", num(surrogate_wall_ms)),
        ("deterministic_across_threads", Value::Bool(true)),
    ]);
    write_json_result("BENCH_eval_engine.json", &doc);
}

/// Telemetry overhead on the eval-engine hot path (ISSUE acceptance:
/// disabled-path regression < 2%).
///
/// Two measurements, both on the surrogate fast path — the *worst case*
/// for relative overhead because every objective evaluation is
/// sub-microsecond pure CPU with no PJRT/sleep cost to hide behind:
///
/// 1. **Micro**: ns per telemetry call on a *disabled* handle (one
///    `Option` branch). Combined with the number of telemetry call sites
///    an instrumented run actually hits (counted from an enabled run's
///    registry snapshot), this yields the gated `disabled_overhead_pct` —
///    a deterministic estimate immune to run-to-run scheduler noise.
/// 2. **Macro**: min-of-samples wall clock of the same optimization with
///    telemetry disabled vs enabled (registry, no trace). Reported as
///    `enabled_overhead_pct` for the record; not gated (small absolute
///    walls make the macro delta noisy in CI).
fn bench_telemetry_overhead(fast: bool) {
    println!("\n-- telemetry overhead (surrogate fast path — worst case, no artifacts needed) --");
    let l = 10;
    let manifest = synthetic_manifest(l);
    let table = synthetic_sensitivity(l);
    let platform = Platform::default_two_device();
    let nsga2 = if fast {
        Nsga2Config { pop_size: 12, generations: 8, ..Default::default() }
    } else {
        Nsga2Config { pop_size: 24, generations: 20, ..Default::default() }
    };
    let samples = if fast { 5 } else { 9 };

    // min-of-samples: the stable statistic for overhead comparison
    let min_wall_ms = |telemetry: &Telemetry| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..samples {
            let mut ev = PartitionEvaluator::new(
                &manifest,
                &platform,
                vec![0.25, 0.04],
                vec![0.25, 0.04],
                FaultScenario::InputWeight,
                0.9,
                false,
                DaccMode::Surrogate(&table),
            )
            .with_telemetry(telemetry.clone());
            let sw = Stopwatch::start();
            optimize_partitions(&mut ev, &nsga2, true, vec![], |_| {});
            best = best.min(sw.ms());
        }
        best
    };
    min_wall_ms(&Telemetry::disabled()); // warm-up (page in code + caches)
    let min_disabled_ms = min_wall_ms(&Telemetry::disabled());
    let enabled = Telemetry::enabled();
    let min_enabled_ms = min_wall_ms(&enabled);
    let enabled_overhead_pct = (min_enabled_ms - min_disabled_ms) / min_disabled_ms * 100.0;

    // telemetry call sites actually hit per instrumented run: counter
    // increments + histogram observations (= closed spans) + gauge sets.
    // The enabled handle above accumulated `samples` identical runs.
    let snap = enabled.snapshot().expect("enabled registry has a snapshot");
    let counter_ops: u64 = snap.counters.values().sum();
    let span_ops: u64 = snap.histograms.values().map(|h| h.count).sum();
    let gauge_ops = snap.gauges.len() as u64 * snap.histograms.values().map(|h| h.count).max().unwrap_or(1);
    let ops_per_run = (counter_ops + span_ops + gauge_ops) as f64 / samples as f64;

    // disabled-path cost per call: one refcounted-handle branch
    let disabled = Telemetry::disabled();
    let micro_iters: u64 = 2_000_000;
    let sw = Stopwatch::start();
    for i in 0..micro_iters {
        disabled.counter_add("bench_noop_total", 1);
        if i % 4 == 0 {
            std::hint::black_box(disabled.span("bench.noop"));
        }
    }
    let ns_per_disabled_call = sw.ms() * 1e6 / (micro_iters as f64 * 1.25);
    let disabled_overhead_pct =
        ops_per_run * ns_per_disabled_call / (min_disabled_ms * 1e6) * 100.0;

    let threshold_pct = 2.0;
    let pass = disabled_overhead_pct < threshold_pct;
    println!("wall (min of {samples}): disabled {min_disabled_ms:.2} ms, enabled {min_enabled_ms:.2} ms ({enabled_overhead_pct:+.2}%)");
    println!(
        "disabled path: {ns_per_disabled_call:.1} ns/call x {ops_per_run:.0} calls/run = {disabled_overhead_pct:.4}% of eval-engine wall [{}]",
        if pass { "PASS <2%" } else { "FAIL >=2%" }
    );
    let doc: Value = obj(vec![
        ("bench", s("telemetry_overhead")),
        ("model", s(&format!("synthetic-L{l}"))),
        ("pop_size", num(nsga2.pop_size as f64)),
        ("generations", num(nsga2.generations as f64)),
        ("samples", num(samples as f64)),
        ("min_disabled_ms", num(min_disabled_ms)),
        ("min_enabled_ms", num(min_enabled_ms)),
        ("enabled_overhead_pct", num(enabled_overhead_pct)),
        ("ns_per_disabled_call", num(ns_per_disabled_call)),
        ("telemetry_ops_per_run", num(ops_per_run)),
        ("disabled_overhead_pct", num(disabled_overhead_pct)),
        ("threshold_pct", num(threshold_pct)),
        ("pass", Value::Bool(pass)),
    ]);
    write_json_result("BENCH_telemetry_overhead.json", &doc);
    assert!(pass, "telemetry disabled-path overhead {disabled_overhead_pct:.4}% >= {threshold_pct}%");
}

/// Campaign scheduler throughput and cross-worker determinism
/// (ISSUE acceptance: >=2x at 4 workers, bitwise-identical report).
fn bench_campaign(fast: bool) {
    println!("\n-- campaign scheduler (3x2 synthetic grid, no artifacts needed) --");
    let (pop, gens) = if fast { (8, 2) } else { (12, 3) };
    let base = CampaignSpec::from_json_str(&format!(
        r#"{{
            "base": {{"eval_threads": 1,
                      "optimizer": {{"pop_size": {pop}, "generations": {gens}}}}},
            "grid": {{"models": ["synthetic-L8"],
                      "fault_rates": [0.1, 0.2, 0.4],
                      "scenarios": ["w", "iw"]}}
        }}"#
    ))
    .expect("static campaign spec parses");
    // Exact-call-shaped cost per unique backend evaluation, so the bench
    // measures cell scheduling rather than surrogate arithmetic. The six
    // cells have pairwise-distinct rate vectors, so cross-cell sharing
    // does not blur the worker-count comparison.
    let opts = CampaignOptions {
        synthetic_cost: Duration::from_micros(if fast { 1000 } else { 2000 }),
        ..CampaignOptions::default()
    };

    let worker_counts = [1usize, 2, 4];
    let mut reference: Option<String> = None;
    let mut rows = Vec::new();
    for &w in &worker_counts {
        let mut spec = base.clone();
        spec.base.campaign_workers = w;
        let sw = Stopwatch::start();
        let mut report = run_campaign_with(&spec, &opts, |_, _, _| {})
            .expect("synthetic campaign runs");
        let wall_ms = sw.ms();
        let num_cells = report.cells.len();
        // wall_ms is the single nondeterministic report field
        report.wall_ms = 0.0;
        let fp = json_str(&report.to_json());
        match &reference {
            None => reference = Some(fp),
            Some(r) => assert_eq!(
                r, &fp,
                "DETERMINISM VIOLATION: report at {w} workers differs from 1 worker"
            ),
        }
        rows.push((w, wall_ms, num_cells as f64 / (wall_ms / 1e3)));
    }
    let wall_1w = rows[0].1;

    let mut t = Table::new(&["workers", "wall ms", "cells/s", "speedup"]);
    let mut worker_objs = Vec::new();
    for (w, wall_ms, cells_per_s) in &rows {
        let speedup = wall_1w / wall_ms;
        t.row(vec![
            w.to_string(),
            format!("{wall_ms:.1}"),
            format!("{cells_per_s:.1}"),
            format!("{speedup:.2}x"),
        ]);
        worker_objs.push(obj(vec![
            ("workers", num(*w as f64)),
            ("wall_ms", num(*wall_ms)),
            ("cells_per_s", num(*cells_per_s)),
            ("speedup_vs_1w", num(speedup)),
        ]));
    }
    print!("{}", t.render());
    println!("reports identical across all worker counts (bitwise) ✓");
    let speedup_4w = wall_1w / rows.last().unwrap().1;
    println!("speedup at 4 workers vs serial: {speedup_4w:.2}x");

    let doc: Value = obj(vec![
        ("bench", s("campaign")),
        ("num_cells", num(6.0)),
        ("pop_size", num(pop as f64)),
        ("generations", num(gens as f64)),
        ("synthetic_cost_us", num(opts.synthetic_cost.as_micros() as f64)),
        ("workers", arr(worker_objs)),
        ("speedup_4w_vs_1w", num(speedup_4w)),
        ("deterministic_across_workers", Value::Bool(true)),
    ]);
    write_json_result("BENCH_campaign.json", &doc);
}

/// NSGA-II selection-pipeline throughput: the extracted tournament +
/// crossover + mutation round plus the 2N-pool non-dominated sort
/// (exactly the per-generation optimizer work between evaluations),
/// isolated from evaluation, swept over `selection_threads` 1/2/4.
/// Asserts both determinism contracts before writing the JSON the
/// `scripts/check.sh` monotonicity gate reads.
fn bench_variation(fast: bool) {
    println!("\n-- NSGA-II selection pipeline (variation + 2N-pool sort) --");
    let genome_len = 24;
    let alphabet = 3;
    let rounds = if fast { 5 } else { 20 };
    let samples = 3; // min-of-samples: the stable statistic for the gate

    // One timed sample: `rounds` iterations of one generation's optimizer
    // work — produce a full offspring batch from a ranked parent pool,
    // then rank a 2N parents+offspring pool.
    let timed_sample = |pop_size: usize, sel_threads: usize| -> f64 {
        let mut rng = Rng::new(0xC0FFEE);
        // ranked parent pool with a plausible rank/crowding structure
        let parents: Vec<Individual> = (0..pop_size)
            .map(|i| Individual {
                genome: (0..genome_len).map(|_| rng.below(alphabet)).collect(),
                objectives: vec![i as f64, (pop_size - i) as f64],
                rank: i % 5,
                crowding: if i % 7 == 0 { f64::INFINITY } else { (i % 11) as f64 },
            })
            .collect();
        // 2N elitist pool with layered 3-objective structure for the sort
        let mut pool: Vec<Individual> = (0..2 * pop_size)
            .map(|_| Individual {
                genome: vec![0; genome_len],
                objectives: (0..3).map(|_| (rng.below(64) as f64) * 0.5).collect(),
                rank: usize::MAX,
                crowding: 0.0,
            })
            .collect();
        let mut opt = Nsga2::new(Nsga2Config {
            pop_size,
            selection_threads: sel_threads,
            ..Default::default()
        });
        // warm-up (spawn threads, fault in code paths)
        std::hint::black_box(opt.produce_offspring(&parents, alphabet));
        std::hint::black_box(Nsga2::rank_population_threads(&mut pool, sel_threads));
        let sw = Stopwatch::start();
        for _ in 0..rounds {
            std::hint::black_box(opt.produce_offspring(&parents, alphabet));
            std::hint::black_box(Nsga2::rank_population_threads(&mut pool, sel_threads));
        }
        sw.ms()
    };

    let thread_counts = [1usize, 2, 4];
    let mut t = Table::new(&["pop", "threads", "ms/round", "offspring/s", "speedup"]);
    let mut pop_objs = Vec::new();
    for pop_size in [128usize, 512, 1024] {
        let mut wall_1t = f64::NAN;
        for &sel in &thread_counts {
            let mut wall_ms = f64::INFINITY;
            for _ in 0..samples {
                wall_ms = wall_ms.min(timed_sample(pop_size, sel));
            }
            if sel == 1 {
                wall_1t = wall_ms;
            }
            let ms_per_round = wall_ms / rounds as f64;
            let offspring_per_s = (pop_size * rounds) as f64 / (wall_ms / 1e3);
            let speedup = wall_1t / wall_ms;
            t.row(vec![
                pop_size.to_string(),
                sel.to_string(),
                format!("{ms_per_round:.3}"),
                format!("{offspring_per_s:.0}"),
                format!("{speedup:.2}x"),
            ]);
            pop_objs.push(obj(vec![
                ("pop_size", num(pop_size as f64)),
                ("selection_threads", num(sel as f64)),
                ("wall_ms", num(wall_ms)),
                ("ms_per_round", num(ms_per_round)),
                ("offspring_per_s", num(offspring_per_s)),
                ("speedup_vs_1t", num(speedup)),
            ]));
        }
    }
    print!("{}", t.render());

    // Determinism contract 1: `selection_threads = 1` replays the golden
    // seeds bitwise-identically to the frozen pre-parallelization oracle.
    struct Toy;
    impl Problem for Toy {
        fn genome_len(&self) -> usize {
            12
        }
        fn alphabet(&self) -> usize {
            3
        }
        fn evaluate(&mut self, g: &[usize]) -> Vec<f64> {
            let sum = g.iter().sum::<usize>() as f64;
            let twos = g.iter().filter(|&&x| x == 2).count() as f64;
            vec![sum, 12.0 - twos]
        }
    }
    let golden_seeds = [7u64, 11, 23];
    for &seed in &golden_seeds {
        let cfg = Nsga2Config { pop_size: 16, generations: 8, seed, ..Default::default() };
        let current = front_fingerprint(&Nsga2::new(cfg.clone()).run(&mut Toy, |_| {}));
        let legacy = front_fingerprint(&legacy_nsga2::run(&cfg, &mut Toy));
        assert_eq!(
            current, legacy,
            "LEGACY CONTRACT VIOLATION: selection_threads=1 front at seed {seed} \
             differs from the frozen pre-PR serial NSGA-II"
        );
    }
    println!("serial path bitwise-identical to pre-PR fronts (golden seeds {golden_seeds:?}) ✓");

    // Determinism contract 2: the forked path is a pure function of the
    // seed — identical fronts at selection_threads 2 and 4.
    for &seed in &golden_seeds {
        let forked = |sel: usize| {
            let cfg = Nsga2Config {
                pop_size: 16,
                generations: 8,
                seed,
                selection_threads: sel,
                ..Default::default()
            };
            front_fingerprint(&Nsga2::new(cfg).run(&mut Toy, |_| {}))
        };
        assert_eq!(
            forked(2),
            forked(4),
            "FORKED CONTRACT VIOLATION: front at seed {seed} depends on the thread count"
        );
    }
    println!("forked path identical across thread counts (bitwise) ✓");

    let doc: Value = obj(vec![
        ("bench", s("variation")),
        ("genome_len", num(genome_len as f64)),
        ("alphabet", num(alphabet as f64)),
        ("rounds", num(rounds as f64)),
        ("samples", num(samples as f64)),
        ("pops", arr(pop_objs)),
        ("golden_seeds", arr(golden_seeds.iter().map(|&x| num(x as f64)))),
        ("serial_bitwise_identical", Value::Bool(true)),
        ("forked_deterministic", Value::Bool(true)),
    ]);
    write_json_result("BENCH_variation.json", &doc);
}

/// Offline trace-analyzer throughput: synthesize a realistic JSONL
/// trace in memory (chaos ledger + supervision + tick spans +
/// convergence, seeded PRNG so the workload is reproducible), then
/// measure `analyze_str` events/s. Also asserts the analyzer report is
/// bitwise repeat-deterministic before writing the JSON the
/// `scripts/check.sh` gate reads (`BENCH_trace_analyze.json`).
fn bench_trace_analyze(fast: bool) {
    println!("\n-- offline trace analyzer (`trace analyze`) throughput --");
    let ticks = if fast { 4_000 } else { 40_000 };
    let mut rng = Rng::new(0xA11A_11CE);
    let mut text = String::new();
    let mut seq = 0usize;
    let push = |text: &mut String, seq: &mut usize, body: String| {
        text.push_str(&format!("{{\"schema\":2,\"seq\":{seq},\"kind\":{body}}}\n"));
        *seq += 1;
    };
    push(&mut text, &mut seq, "\"trace_start\"".into());
    let classes = ["crash", "transient", "drop", "delay", "corrupt"];
    for tick in 0..ticks {
        if rng.chance(0.3) {
            let ci = rng.below(classes.len());
            let fault = ((tick as u64) << 8) | ci as u64;
            push(
                &mut text,
                &mut seq,
                format!(
                    "\"chaos_inject\",\"span\":\"online.chaos\",\"class\":\"{}\",\
                     \"component\":{ci},\"fault\":{fault},\"magnitude\":1,\"tick\":{tick}",
                    classes[ci]
                ),
            );
            if rng.chance(0.5) {
                push(
                    &mut text,
                    &mut seq,
                    format!(
                        "\"server_retry\",\"span\":\"server.supervise\",\"ticket\":{tick},\
                         \"attempts\":1,\"reason\":\"transient\",\"fault\":{fault}"
                    ),
                );
            }
            if rng.chance(0.1) {
                push(
                    &mut text,
                    &mut seq,
                    format!(
                        "\"server_terminal\",\"span\":\"server.supervise\",\"ticket\":{tick},\
                         \"attempts\":3,\"reason\":\"exhausted\",\"fault\":{fault}"
                    ),
                );
                push(
                    &mut text,
                    &mut seq,
                    format!(
                        "\"degrade_enter\",\"span\":\"online.degrade\",\
                         \"tick\":{tick},\"reason\":\"exhausted\""
                    ),
                );
                push(
                    &mut text,
                    &mut seq,
                    format!(
                        "\"degrade_exit\",\"span\":\"online.degrade\",\"tick\":{},\
                         \"start\":{tick},\"end\":{}",
                        tick + 3,
                        tick + 3
                    ),
                );
            }
        }
        push(
            &mut text,
            &mut seq,
            format!(
                "\"span\",\"span\":\"eval.batch\",\"batch\":{tick},\"genomes\":16,\
                 \"unique_misses\":4,\"cache_answered\":12"
            ),
        );
        push(
            &mut text,
            &mut seq,
            format!(
                "\"span\",\"span\":\"online.tick\",\"tick\":{tick},\"degraded\":false,\
                 \"reconfigured\":false,\"acc\":0.9,\"acc_drop\":0.01,\"injected_delay\":0"
            ),
        );
        if tick % 10 == 0 {
            push(
                &mut text,
                &mut seq,
                format!(
                    "\"convergence\",\"span\":\"opt.convergence\",\"generation\":{},\
                     \"hypervolume\":1.5,\"spread\":0.2,\"progress\":0.01,\"stall\":0,\
                     \"front_size\":8",
                    (tick / 10) % 60
                ),
            );
        }
    }
    let events = seq;
    let bytes = text.len();

    let a = analyze_str(&text);
    assert_eq!(a.parsed_events, events, "analyzer dropped events");
    assert!(!a.truncated_tail && a.malformed_lines == 0 && a.seq_gaps == 0);
    assert_eq!(
        json_str(&a.to_json()),
        json_str(&analyze_str(&text).to_json()),
        "analyzer report is not repeat-deterministic"
    );

    let bc = BenchConfig { warmup_iters: 1, sample_iters: if fast { 3 } else { 5 } };
    let summary = bench_ms(bc, || {
        let a = analyze_str(&text);
        std::hint::black_box(a.parsed_events);
    });
    let events_per_sec = events as f64 / (summary.min / 1e3);
    println!(
        "{events} events ({:.1} MiB): {:.1} ms min -> {:.0} events/s",
        bytes as f64 / (1024.0 * 1024.0),
        summary.min,
        events_per_sec
    );

    let doc: Value = obj(vec![
        ("bench", s("trace_analyze")),
        ("events", num(events as f64)),
        ("bytes", num(bytes as f64)),
        ("mean_ms", num(summary.mean)),
        ("min_ms", num(summary.min)),
        ("events_per_sec", num(events_per_sec)),
        ("deterministic", Value::Bool(true)),
    ]);
    write_json_result("BENCH_trace_analyze.json", &doc);
}

fn bench_pjrt_sections(fast: bool) -> anyhow::Result<()> {
    let (mut cfg, _) = bench_budget(fast);
    let mut report = BenchReport::new();
    let bc = BenchConfig { warmup_iters: 2, sample_iters: if fast { 5 } else { 10 } };

    for model in ["alexnet", "squeezenet", "resnet18"] {
        cfg.model = model.into();
        let exp = Experiment::load(&cfg)?;
        let l = exp.model.num_units();
        let zero = RateVectors::zeros(l);
        let faulty = RateVectors {
            w_rates: vec![0.2; l],
            a_rates: vec![0.2; l],
        };
        let mut k = 0u32;
        report.add(
            format!("pjrt exec clean  b64 [{model}]"),
            bench_ms(bc, || {
                k += 1;
                exp.acc_eval.accuracy(&exp.model, &zero, k, 1).unwrap();
            }),
        );
        report.add(
            format!("pjrt exec faulty b64 [{model}]"),
            bench_ms(bc, || {
                k += 1;
                exp.acc_eval.accuracy(&exp.model, &faulty, k, 1).unwrap();
            }),
        );
    }

    // optimizer throughput on analytical objectives only (DaccMode::None):
    // isolates the NSGA-II machinery itself.
    cfg.model = "resnet18".into();
    let exp = Experiment::load(&cfg)?;
    let mk_eval = || {
        PartitionEvaluator::new(
            &exp.model.manifest,
            &exp.platform,
            vec![0.2, 0.03],
            vec![0.2, 0.03],
            FaultScenario::InputWeight,
            exp.clean_acc,
            false,
            DaccMode::None,
        )
    };
    let nsga = Nsga2Config { pop_size: 60, generations: 60, ..Default::default() };
    let evals = nsga.pop_size * (nsga.generations + 1);
    let s = bench_ms(bc, || {
        let mut ev = mk_eval();
        optimize_partitions(&mut ev, &nsga, false, vec![], |_| {});
    });
    println!(
        "NSGA-II machinery (pop 60 x gens 60, analytical objectives): {:.2} ms/run = {:.0} evals/ms",
        s.mean,
        evals as f64 / s.mean
    );
    report.add("nsga2 60x60 analytical", s);

    // evaluator scalar costs
    let ev = mk_eval();
    let mut rng = Rng::new(1);
    let maps: Vec<Mapping> =
        (0..1024).map(|_| Mapping::random(&mut rng, exp.model.num_units(), 2)).collect();
    let mut i = 0;
    report.add(
        "latency+energy model x1024",
        bench_ms(bc, || {
            for m in &maps {
                std::hint::black_box(ev.latency_ms(m) + ev.energy_mj(m));
            }
            i += 1;
        }),
    );

    // cache effect + engine threads on a real exact-mode optimization
    let small = Nsga2Config { pop_size: 12, generations: 4, ..Default::default() };
    for threads in [1usize, 4] {
        let sw = Stopwatch::start();
        let mut ev = exp.partition_evaluator(FaultScenario::InputWeight).with_parallelism(threads);
        optimize_partitions(&mut ev, &small, true, vec![], |_| {});
        let (hits, misses, rate) = ev.cache_stats();
        println!(
            "exact-mode NSGA-II 12x4 [resnet18] @{threads}T: {:.1}s wall, cache {hits} hits / {misses} misses ({:.0}% hit rate)",
            sw.s(),
            rate * 100.0
        );
        println!(
            "  -> without memoization this run would cost ~{:.0}x more PJRT executions",
            (hits + misses) as f64 / misses.max(1) as f64
        );
    }

    println!("\n{}", report.render());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let fast = bench_header("Perf — eval engine, runtime exec, optimizer throughput, cache effect");

    bench_eval_engine(fast);
    bench_telemetry_overhead(fast);
    bench_campaign(fast);
    bench_variation(fast);
    bench_trace_analyze(fast);

    if let Err(e) = bench_pjrt_sections(fast) {
        println!("\nskipping PJRT-backed sections: {e:#}");
        println!("(run `make artifacts` with a real xla backend to enable them)");
    }
    Ok(())
}
