//! Reproduces paper Fig. 3: top-1 accuracy of CNNParted, Fault-unaware
//! and AFarePart across the three CNNs at fault rate 20% in weights.
//!
//! Paper's series (weight faults, FR = 0.2):
//!   AlexNet    : CNNParted 74.2, Flt-unaware 72.0, AFarePart 81.0
//!   SqueezeNet : CNNParted 67.7, Flt-unaware 68.3, AFarePart 76.5
//!   ResNet18   : CNNParted 83.9, Flt-unaware 82.1, AFarePart 88.4
//! The shape to reproduce: AFarePart's bar is the tallest for every model.
//!
//! Run: `cargo bench --bench bench_fig3` (AFARE_BENCH_FAST=1 to shrink).

use afarepart::bench::suite::{bench_budget, run_cell, Tool};
use afarepart::bench::{bench_header, Stopwatch};
use afarepart::experiment::Experiment;
use afarepart::faults::FaultScenario;
use afarepart::util::fmt::{pct, Table};

fn main() -> anyhow::Result<()> {
    let fast = bench_header("Fig. 3 — top-1 accuracy @ FR=20% weight faults, 3 CNNs x 3 tools");
    let (mut cfg, nsga2) = bench_budget(fast);
    cfg.fault_rate = 0.2;
    cfg.scenario = FaultScenario::WeightOnly;

    let mut table = Table::new(&[
        "model",
        "clean",
        "CNNParted",
        "Flt-unware",
        "AFarePart",
        "AFP gain vs best baseline",
    ]);
    let sw = Stopwatch::start();
    for model in ["alexnet", "squeezenet", "resnet18"] {
        cfg.model = model.into();
        let exp = Experiment::load(&cfg)?;
        let mut accs = Vec::new();
        for tool in Tool::all() {
            let cell = run_cell(&exp, FaultScenario::WeightOnly, &nsga2, tool)?;
            println!(
                "  {model:10} {:10} -> map {} acc {} ({} evals)",
                tool.label(),
                cell.mapping.display(),
                pct(cell.acc),
                cell.evaluations
            );
            accs.push(cell.acc);
        }
        let gain = accs[2] - accs[0].max(accs[1]);
        table.row(vec![
            model.to_string(),
            pct(exp.clean_acc),
            pct(accs[0]),
            pct(accs[1]),
            pct(accs[2]),
            format!("{:+.1} pts", gain * 100.0),
        ]);
    }
    println!("\n{}", table.render());
    println!("total wall: {:.1}s", sw.s());
    println!("shape check: AFarePart column must dominate both baselines per row.");
    Ok(())
}
