//! Reproduces paper Table II: accuracy / latency / energy at FR = 20%
//! across the three fault scenarios (weight-only, input-only,
//! input+weight) for the three CNNs and three tools — 27 cells.
//!
//! Paper headline: AFarePart has the best faulty accuracy in every cell
//! (up to +27.7 pts vs CNNParted under input+weight), at ~+9.7% latency
//! and ~+4.3% energy vs CNNParted. The *shape* (who wins accuracy, modest
//! overhead) is the reproduction target; absolute values differ (mini
//! models + analytical cost substrate — DESIGN.md §1).
//!
//! Run: `cargo bench --bench bench_table2` (AFARE_BENCH_FAST=1 to shrink).

use afarepart::bench::suite::{bench_budget, run_cell, CellResult, Tool};
use afarepart::bench::{bench_header, Stopwatch};
use afarepart::experiment::Experiment;
use afarepart::faults::FaultScenario;
use afarepart::util::fmt::Table;

fn main() -> anyhow::Result<()> {
    let fast = bench_header("Table II — FR=20% across fault scenarios (3 models x 3 tools x 3 scenarios)");
    let (mut cfg, nsga2) = bench_budget(fast);
    cfg.fault_rate = 0.2;

    let mut table = Table::new(&[
        "model", "tool", "W acc%", "W lat", "W mJ", "I acc%", "I lat", "I mJ", "IW acc%",
        "IW lat", "IW mJ",
    ]);
    let sw = Stopwatch::start();
    let mut afp_wins = 0usize;
    let mut cells_checked = 0usize;
    let mut overheads: Vec<(f64, f64)> = Vec::new();

    for model in ["alexnet", "squeezenet", "resnet18"] {
        cfg.model = model.into();
        let exp = Experiment::load(&cfg)?;
        // results[tool][scenario]
        let mut results: Vec<Vec<CellResult>> = Vec::new();
        for tool in Tool::all() {
            let mut per_scenario = Vec::new();
            for scenario in FaultScenario::all() {
                let cell = run_cell(&exp, scenario, &nsga2, tool)?;
                println!(
                    "  {model:10} {:10} {:12} acc {:5.1}% lat {:5.2} en {:6.3}  map {}",
                    tool.label(),
                    scenario.label(),
                    cell.acc * 100.0,
                    cell.latency_ms,
                    cell.energy_mj,
                    cell.mapping.display()
                );
                per_scenario.push(cell);
            }
            results.push(per_scenario);
        }
        for (ti, tool) in Tool::all().into_iter().enumerate() {
            let r = &results[ti];
            table.row(vec![
                model.to_string(),
                tool.label().to_string(),
                format!("{:.1}", r[0].acc * 100.0),
                format!("{:.2}", r[0].latency_ms),
                format!("{:.3}", r[0].energy_mj),
                format!("{:.1}", r[1].acc * 100.0),
                format!("{:.2}", r[1].latency_ms),
                format!("{:.3}", r[1].energy_mj),
                format!("{:.1}", r[2].acc * 100.0),
                format!("{:.2}", r[2].latency_ms),
                format!("{:.3}", r[2].energy_mj),
            ]);
        }
        // shape accounting: AFarePart (index 2) vs baselines per scenario
        for si in 0..3 {
            cells_checked += 1;
            if results[2][si].acc + 1e-9 >= results[0][si].acc.max(results[1][si].acc) {
                afp_wins += 1;
            }
        }
        // overhead vs CNNParted in the combined scenario (paper's quote)
        let lat_ovh = results[2][2].latency_ms / results[0][2].latency_ms - 1.0;
        let en_ovh = results[2][2].energy_mj / results[0][2].energy_mj - 1.0;
        overheads.push((lat_ovh, en_ovh));
    }

    println!("\n{}", table.render());
    println!("AFarePart best-accuracy cells: {afp_wins}/{cells_checked}");
    let mean_lat = overheads.iter().map(|o| o.0).sum::<f64>() / overheads.len() as f64;
    let mean_en = overheads.iter().map(|o| o.1).sum::<f64>() / overheads.len() as f64;
    println!(
        "mean overhead vs CNNParted (input+weight): latency {:+.1}%, energy {:+.1}% (paper: +9.7% / +4.3%)",
        mean_lat * 100.0,
        mean_en * 100.0
    );
    println!("total wall: {:.1}s", sw.s());
    Ok(())
}
