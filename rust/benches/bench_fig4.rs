//! Reproduces paper Fig. 4: accuracy vs. fault rate (weight faults) for
//! ResNet18 under the three partitioning strategies, FR in 10%..40%.
//!
//! Shape to reproduce: every curve decays as FR grows; the AFarePart curve
//! dominates (sits above) both fault-agnostic baselines at every FR.
//!
//! Run: `cargo bench --bench bench_fig4` (AFARE_BENCH_FAST=1 to shrink).

use afarepart::bench::suite::{bench_budget, run_cell, Tool};
use afarepart::bench::{bench_header, Stopwatch};
use afarepart::experiment::Experiment;
use afarepart::faults::FaultScenario;
use afarepart::util::fmt::{pct, Table};

fn main() -> anyhow::Result<()> {
    let fast = bench_header("Fig. 4 — accuracy vs fault rate (ResNet18, weight faults)");
    let (mut cfg, nsga2) = bench_budget(fast);
    cfg.model = "resnet18".into();
    cfg.scenario = FaultScenario::WeightOnly;

    let rates = [0.1f32, 0.2, 0.3, 0.4];
    let mut series: Vec<Vec<f64>> = vec![Vec::new(); 3];
    let sw = Stopwatch::start();
    for &fr in &rates {
        cfg.fault_rate = fr;
        let exp = Experiment::load(&cfg)?;
        for (ti, tool) in Tool::all().into_iter().enumerate() {
            let cell = run_cell(&exp, FaultScenario::WeightOnly, &nsga2, tool)?;
            println!("  FR={fr:.1} {:10} -> {}", tool.label(), pct(cell.acc));
            series[ti].push(cell.acc);
        }
    }

    let mut table = Table::new(&["tool", "FR=10%", "FR=20%", "FR=30%", "FR=40%"]);
    for (ti, tool) in Tool::all().into_iter().enumerate() {
        let mut row = vec![tool.label().to_string()];
        row.extend(series[ti].iter().map(|&a| pct(a)));
        table.row(row);
    }
    println!("\n{}", table.render());

    // shape checks
    let afp = &series[2];
    let monotone_ok = afp.windows(2).all(|w| w[1] <= w[0] + 0.03);
    let dominates =
        (0..rates.len()).all(|i| afp[i] + 1e-9 >= series[0][i].min(series[1][i]));
    println!("monotone decay (AFarePart, 3pt tolerance): {monotone_ok}");
    println!("AFarePart >= min(baselines) at every FR:  {dominates}");
    println!("total wall: {:.1}s", sw.s());
    Ok(())
}
