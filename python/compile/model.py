"""L2 export entry: the faulty quantized forward pass.

This is the computation AOT-lowered to artifacts/<model>.hlo.txt and
executed from the rust request path. It implements quantized inference
with the paper's in-graph probabilistic bit-flip fault injection
(Algorithm 2) on BOTH domains of §III-B:

  * weight faults  — every quantized weight tensor passes through the L1
    Pallas bitflip+dequant kernel (dense layers use the fused qmatmul);
  * activation faults — each unit's input activation is quantized with its
    calibrated scale, bit-flipped, and dequantized.

Traced inputs (= HLO parameter order; rust mirrors this via the manifest):
  images      f32[B,32,32,3]
  wq_0..wq_T  int32 quantized weight tensors (weight_tensor_order)
  w_rates     f32[L] per-unit weight fault rate (device-dependent, from L3)
  a_rates     f32[L] per-unit activation fault rate
  key_data    u32[2] PRNG key (fresh per batch, from L3)
Output: logits f32[B,10].

Setting both rate vectors to zero yields clean *quantized* inference —
A_clean of the paper's ΔAcc = A_clean − A_faulty is the deployed quantized
model's accuracy, so the same artifact serves both evaluations.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers as ly
from . import models as M
from .quantize import _prefixed, weight_tensor_order
from .kernels.bitflip import bitflip_dequant
from .kernels.qmatmul import qmatmul_bitflip


def _rnd_for(key, ctr: int, shape):
    """Per-tensor random draws; ctr is a static per-tensor counter."""
    return jax.random.bits(jax.random.fold_in(key, ctr), shape, dtype=jnp.uint32)


def faulty_forward(
    mdef: M.ModelDef,
    qparams: Dict[str, dict],
    act_scales: Dict[str, float],
    images,
    wq_inputs: Dict[tuple, jax.Array],
    w_rates,
    a_rates,
    key_data,
    *,
    bits: int,
    precision: int,
):
    """Quantized forward with per-unit fault injection. Returns logits."""
    key = jax.random.wrap_key_data(key_data)
    x = images
    ctr = 0

    def faulty_weight(unit_name: str, prefix: str, rate):
        nonlocal ctr
        wq = wq_inputs[(unit_name, prefix)]
        scale = qparams[unit_name][_prefixed(prefix, "scale")]
        rnd = _rnd_for(key, ctr, wq.shape)
        ctr += 1
        return bitflip_dequant(wq, rnd, rate, scale, bits=bits)

    def conv(x, unit_name, prefix, stride, pad, rate, relu=True):
        w = faulty_weight(unit_name, prefix, rate)
        y = ly.conv2d(x, w, stride, pad) + qparams[unit_name][_prefixed(prefix, "b")]
        return jax.nn.relu(y) if relu else y

    for i, unit in enumerate(mdef.units):
        cfg = unit.cfg
        qp = qparams[unit.name]
        wr, ar = w_rates[i], a_rates[i]

        # --- activation quantize + fault at the unit input (§III-B data faults)
        if unit.kind == "dense" and x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        a_scale = act_scales[unit.name]
        xq = ly.quantize_act(x, a_scale, precision)
        rnd = _rnd_for(key, ctr, xq.shape)
        ctr += 1
        x = bitflip_dequant(xq, rnd, ar, a_scale, bits=bits)

        # --- unit compute with faulty weights
        if unit.kind == "conv":
            x = conv(x, unit.name, "", cfg["stride"], cfg["pad"], wr, cfg["relu"])
            if cfg.get("pool", 1) == 2:
                x = ly.maxpool2(x)
        elif unit.kind == "fire":
            sq = conv(x, unit.name, "s", 1, 0, wr)
            e1 = conv(sq, unit.name, "e1", 1, 0, wr)
            e3 = conv(sq, unit.name, "e3", 1, 1, wr)
            x = jnp.concatenate([e1, e3], axis=-1)
            if cfg.get("pool", 1) == 2:
                x = ly.maxpool2(x)
        elif unit.kind == "block":
            idn = x
            y = conv(x, unit.name, "c1", cfg["stride"], 1, wr)
            y = conv(y, unit.name, "c2", 1, 1, wr, relu=False)
            if "p_wq" in qp:
                idn = conv(x, unit.name, "p", cfg["stride"], 0, wr, relu=False)
            x = jax.nn.relu(y + idn)
        elif unit.kind in ("dense", "gap_dense"):
            if unit.kind == "gap_dense":
                x = ly.global_avg_pool(x)
            wq = wq_inputs[(unit.name, "")]
            rnd = _rnd_for(key, ctr, wq.shape)
            ctr += 1
            x = qmatmul_bitflip(x, wq, rnd, wr, qp["scale"], bits=bits) + qp["b"]
            if cfg.get("relu", False):
                x = jax.nn.relu(x)
        elif unit.kind == "conv_gap":
            x = ly.global_avg_pool(conv(x, unit.name, "", 1, 0, wr, relu=False))
        else:  # pragma: no cover
            raise ValueError(unit.kind)
    return x


def make_export_fn(mdef: M.ModelDef, qparams, act_scales, *, bits: int, precision: int):
    """Bind static config; return (fn, ordered weight keys) for lowering.

    fn(images, *wqs, w_rates, a_rates, key_data) -> (logits,)
    """
    order = weight_tensor_order(mdef, qparams)

    def fn(images, *rest):
        wqs = rest[: len(order)]
        w_rates, a_rates, key_data = rest[len(order) :]
        wq_inputs = {k: v for k, v in zip(order, wqs)}
        logits = faulty_forward(
            mdef,
            qparams,
            act_scales,
            images,
            wq_inputs,
            w_rates,
            a_rates,
            key_data,
            bits=bits,
            precision=precision,
        )
        return (logits,)

    return fn, order
