"""Deterministic synthetic image-classification dataset ("synthshapes").

Stand-in for Tiny-ImageNet (unavailable offline; see DESIGN.md §1): ten
visually distinct classes of 32x32x3 images built from oriented gratings,
colored blobs and checker patterns, plus per-sample noise, random phase,
brightness jitter and translation so the task is learnable but not trivial.

Everything is generated from an explicit integer seed so the artifact
pipeline is reproducible bit-for-bit.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMG_SIZE = 32
IMG_SHAPE = (IMG_SIZE, IMG_SIZE, 3)

# (kind, param, color) per class. Kinds: grating / blob / checker / ring.
_CLASS_DEFS = [
    ("grating", {"angle": 0.0, "freq": 4.0}, (1.0, 0.2, 0.2)),
    ("grating", {"angle": 90.0, "freq": 4.0}, (0.2, 1.0, 0.2)),
    ("grating", {"angle": 45.0, "freq": 6.0}, (0.2, 0.4, 1.0)),
    ("grating", {"angle": 135.0, "freq": 6.0}, (1.0, 1.0, 0.2)),
    ("blob", {"cx": 0.3, "cy": 0.3, "sigma": 0.15}, (1.0, 0.4, 0.8)),
    ("blob", {"cx": 0.7, "cy": 0.7, "sigma": 0.15}, (0.3, 1.0, 1.0)),
    ("blob", {"cx": 0.5, "cy": 0.5, "sigma": 0.28}, (1.0, 0.7, 0.2)),
    ("checker", {"cells": 4}, (0.8, 0.8, 0.8)),
    ("ring", {"r0": 0.25, "w": 0.08}, (0.5, 1.0, 0.4)),
    ("ring", {"r0": 0.40, "w": 0.06}, (0.7, 0.5, 1.0)),
]


def _base_pattern(kind: str, p: dict, rng: np.random.Generator) -> np.ndarray:
    """Render one grayscale 32x32 pattern with randomized phase/offset."""
    xs = np.linspace(0.0, 1.0, IMG_SIZE, dtype=np.float64)
    xx, yy = np.meshgrid(xs, xs, indexing="xy")
    # random translation so location alone never identifies the class
    dx, dy = rng.uniform(-0.15, 0.15, size=2)
    if kind == "grating":
        theta = np.deg2rad(p["angle"] + rng.uniform(-15.0, 15.0))
        phase = rng.uniform(0.0, 2.0 * np.pi)
        freq = p["freq"] * rng.uniform(0.85, 1.15)
        u = (xx + dx) * np.cos(theta) + (yy + dy) * np.sin(theta)
        img = 0.5 + 0.5 * np.sin(2.0 * np.pi * freq * u + phase)
    elif kind == "blob":
        cx, cy = p["cx"] + dx, p["cy"] + dy
        r2 = (xx - cx) ** 2 + (yy - cy) ** 2
        img = np.exp(-r2 / (2.0 * p["sigma"] ** 2))
    elif kind == "checker":
        n = p["cells"]
        phase = rng.integers(0, 2)
        img = ((np.floor((xx + dx) * n) + np.floor((yy + dy) * n) + phase) % 2).astype(
            np.float64
        )
    elif kind == "ring":
        r = np.sqrt((xx - 0.5 - dx) ** 2 + (yy - 0.5 - dy) ** 2)
        img = np.exp(-((r - p["r0"]) ** 2) / (2.0 * p["w"] ** 2))
    else:  # pragma: no cover - defensive
        raise ValueError(f"unknown pattern kind {kind!r}")
    return img


def _distractors(rng: np.random.Generator, k: int) -> np.ndarray:
    """Class-independent clutter: random colored blobs shared by all classes."""
    xs = np.linspace(0.0, 1.0, IMG_SIZE, dtype=np.float64)
    xx, yy = np.meshgrid(xs, xs, indexing="xy")
    img = np.zeros(IMG_SHAPE)
    for _ in range(k):
        cx, cy = rng.uniform(0.1, 0.9, size=2)
        sigma = rng.uniform(0.05, 0.12)
        col = rng.uniform(0.2, 1.0, size=3)
        blob = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sigma**2))
        img += blob[..., None] * col[None, None, :] * rng.uniform(0.4, 0.9)
    return img


def make_sample(label: int, rng: np.random.Generator, noise: float = 0.5) -> np.ndarray:
    """One (32,32,3) float32 image in [0,1] for class `label`.

    Deliberately hard: heavy pixel noise, clutter blobs, color/brightness
    jitter — trained models land at ~85-95% clean top-1 instead of
    saturating, so fault-induced degradation is measurable (the regime the
    paper's evaluation needs).
    """
    kind, p, color = _CLASS_DEFS[label]
    gray = _base_pattern(kind, p, rng)
    brightness = rng.uniform(0.45, 1.0)
    col = np.asarray(color) * brightness + rng.normal(0.0, 0.08, size=3)
    img = gray[..., None] * col[None, None, :]
    img = img + 0.6 * _distractors(rng, rng.integers(2, 5))
    img = img + rng.normal(0.0, noise, size=IMG_SHAPE)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_dataset(n: int, seed: int, noise: float = 0.5):
    """Return (images [n,32,32,3] f32, labels [n] int32), class-balanced."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int32) % NUM_CLASSES
    rng.shuffle(labels)
    images = np.stack([make_sample(int(l), rng, noise) for l in labels])
    return images, labels


def train_eval_split(n_train: int, n_eval: int, seed: int = 1234, noise: float = 0.5):
    """Disjoint train/eval sets drawn from independent RNG streams."""
    tr = make_dataset(n_train, seed=seed, noise=noise)
    ev = make_dataset(n_eval, seed=seed + 777, noise=noise)
    return tr, ev
