"""Post-training quantization: BN fold + fixed-point weights + calibrated
activation scales.

Quantization scheme (validated empirically; see EXPERIMENTS.md §Fault-Signal):

* **Weights: one global power-of-two scale per model.** Edge accelerators
  with a shared fixed-point datapath (the paper's §III-B "fixed-point
  integer representations (e.g., INT8)") run every tensor through the same
  Q-format; tensors whose dynamic range under-fills the format carry
  proportionally larger LSB steps — which is exactly why LSB bit-flips
  degrade accuracy *differently per layer*, the signal AFarePart optimizes.
* **Activations: per-unit power-of-two scales** (per-layer configurable
  activation formats, as in Eyeriss). With a single global activation
  format the input image is quantized to ~4 levels and every strategy
  collapses to chance — no partitioning signal at all.

Produces the deployment-form model consumed by model.faulty_forward:
  qparams[unit] = {"<conv>_wq": int32, "<conv>_scale": float,
                   "<conv>_b": f32 folded bias}
  act_scales[unit] = float scale of the unit's input activation tensor.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax.numpy as jnp

from . import layers as ly
from . import models as M

# conv sub-names per unit kind (order fixed — it defines the HLO input order)
UNIT_CONVS = {
    "conv": [""],
    "fire": ["s", "e1", "e3"],
    "block": ["c1", "c2", "p"],  # "p" only if present
    "dense": [""],
    "gap_dense": [""],
    "conv_gap": [""],
}


def _prefixed(prefix: str, attr: str) -> str:
    return f"{prefix}_{attr}" if prefix else attr


def pow2_scale(max_abs: float, qmax: int) -> float:
    """Smallest power-of-two scale whose qmax covers max_abs."""
    return 2.0 ** math.ceil(math.log2(max(max_abs, 1e-12) / qmax))


def fold_all(mdef: M.ModelDef, params, state) -> Dict[Tuple[str, str], tuple]:
    """BN-fold every conv; returns {(unit, prefix): (w, b)} in f32."""
    folded = {}
    for unit in mdef.units:
        p, s = params[unit.name], state[unit.name]
        for prefix in UNIT_CONVS[unit.kind]:
            wk = _prefixed(prefix, "w")
            if wk not in p:
                continue  # e.g. absent projection conv
            w, b = p[wk], p[_prefixed(prefix, "b")]
            gk = _prefixed(prefix, "gamma")
            if gk in p:
                w, b = ly.fold_bn(
                    w,
                    b,
                    p[gk],
                    p[_prefixed(prefix, "beta")],
                    s[_prefixed(prefix, "mean")],
                    s[_prefixed(prefix, "var")],
                )
            folded[(unit.name, prefix)] = (w, b)
    return folded


def quantize_model(mdef: M.ModelDef, params, state, precision: int):
    """Fold BN and quantize all weights with the global pow2 model scale.

    Returns (qparams, weight_scale).
    """
    qmin, qmax = ly.quant_range(precision)
    folded = fold_all(mdef, params, state)
    gmax = max(float(jnp.max(jnp.abs(w))) for (w, _) in folded.values())
    scale = pow2_scale(gmax, qmax)
    qparams: Dict[str, dict] = {u.name: {} for u in mdef.units}
    for (uname, prefix), (w, b) in folded.items():
        q = jnp.clip(jnp.round(w / scale), qmin, qmax).astype(jnp.int32)
        qparams[uname][_prefixed(prefix, "wq")] = q
        qparams[uname][_prefixed(prefix, "scale")] = float(scale)
        qparams[uname][_prefixed(prefix, "b")] = b
    return qparams, scale


def calibrate_act_scales(
    mdef: M.ModelDef, params, state, images, precision: int
) -> Dict[str, float]:
    """Per-unit input-activation pow2 scales from a f32 calibration run."""
    _, qmax = ly.quant_range(precision)
    scales: Dict[str, float] = {}
    x = jnp.asarray(images)
    for unit in mdef.units:
        flat = x.reshape(x.shape[0], -1) if unit.kind == "dense" and x.ndim > 2 else x
        scales[unit.name] = pow2_scale(float(jnp.max(jnp.abs(flat))), qmax)
        x = _unit_forward_f32(mdef, unit, params[unit.name], state[unit.name], x)
    return scales


def _unit_forward_f32(mdef, unit, p, s, x):
    """Single-unit eval-mode forward (helper for calibration)."""
    one = M.ModelDef(mdef.name, (unit,), mdef.num_classes)
    y, _ = M.forward_f32(one, {unit.name: p}, {unit.name: s}, x, train=False)
    return y


def weight_tensor_order(mdef: M.ModelDef, qparams) -> List[Tuple[str, str]]:
    """Deterministic (unit, conv-prefix) order of quantized weight inputs.

    This order defines both the HLO parameter order after `images` and the
    layout of <model>_weights.bin; the rust manifest loader mirrors it.
    """
    order = []
    for unit in mdef.units:
        for prefix in UNIT_CONVS[unit.kind]:
            if _prefixed(prefix, "wq") in qparams[unit.name]:
                order.append((unit.name, prefix))
    return order
