"""Build-time f32 training loop (hand-rolled Adam — optax unavailable offline).

Trains each mini model on the synthetic dataset to a clean top-1 well above
chance; checkpoints are cached under artifacts/ckpt/ so `make artifacts` is
idempotent. Runs once at artifact-build time; never on the request path.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import models as M


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, tf)
    bc2 = 1.0 - jnp.power(b2, tf)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps), params, m, v
    )
    return new_params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@functools.partial(jax.jit, static_argnames=("mdef",))
def _train_step(mdef, params, state, opt, images, labels, lr):
    def loss_fn(p):
        logits, new_state = M.forward_f32(mdef, p, state, images, train=True)
        return cross_entropy(logits, labels), new_state

    (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt = adam_update(params, grads, opt, lr)
    return params, new_state, opt, loss


@functools.partial(jax.jit, static_argnames=("mdef",))
def _eval_logits(mdef, params, state, images):
    logits, _ = M.forward_f32(mdef, params, state, images, train=False)
    return logits


def accuracy_f32(mdef, params, state, images, labels, batch: int = 256) -> float:
    hits = 0
    for i in range(0, len(images), batch):
        logits = _eval_logits(mdef, params, state, images[i : i + batch])
        hits += int(jnp.sum(jnp.argmax(logits, -1) == labels[i : i + batch]))
    return hits / len(images)


def train_model(
    mdef: M.ModelDef,
    train_images: np.ndarray,
    train_labels: np.ndarray,
    steps: int = 500,
    batch: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 100,
):
    """Train a model; returns (params, bn_state, final_loss)."""
    params, state = M.init_params(mdef, seed)
    opt = adam_init(params)
    rng = np.random.default_rng(seed + 99)
    n = len(train_images)
    loss = float("nan")
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        cur_lr = lr * (0.1 if step > int(steps * 0.7) else 1.0)
        params, state, opt, loss = _train_step(
            mdef, params, state, opt, train_images[idx], train_labels[idx], cur_lr
        )
        if log_every and (step % log_every == 0 or step == steps - 1):
            print(f"  [{mdef.name}] step {step:4d} loss {float(loss):.4f}")
    return params, state, float(loss)


def flatten_tree(tree, prefix=""):
    """Flatten nested dict-of-arrays to {dotted.name: array} for npz I/O."""
    out = {}
    for k, v in tree.items():
        name = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flatten_tree(v, name))
        else:
            out[name] = np.asarray(v)
    return out


def unflatten_tree(flat):
    out: dict = {}
    for name, v in flat.items():
        parts = name.split(".")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jnp.asarray(v)
    return out
