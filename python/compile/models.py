"""Model zoo: architecturally faithful mini variants of the paper's CNNs.

AlexNet-mini (conv stack + 3 FC), SqueezeNet-mini (fire modules),
ResNet18-mini (4 stages x 2 basic blocks). Channel counts are scaled for
the 32x32 synthetic dataset (DESIGN.md §1) but the topologies — and hence
the partitioning problem structure — match the originals.

A model is a list of *units*; the unit is the paper's partitioning
granularity (P : {1..L} -> devices maps units to accelerators). Each unit
carries everything the L3 cost models need: MACs, weight bytes, activation
bytes (see profile_units).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import layers as ly


@dataclasses.dataclass(frozen=True, eq=False)
class Unit:
    """One mappable layer (paper's l in {1..L})."""

    name: str
    kind: str  # conv | fire | block | dense | gap_dense | conv_gap
    cfg: dict

    # hashable despite the dict cfg, so ModelDef can be a jit static arg
    def _key(self):
        return (self.name, self.kind, tuple(sorted(self.cfg.items())))

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return isinstance(other, Unit) and self._key() == other._key()


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    units: Tuple[Unit, ...]
    num_classes: int = 10

    @property
    def num_units(self) -> int:
        return len(self.units)


def alexnet_mini() -> ModelDef:
    u = [
        Unit("conv1", "conv", dict(out=32, k=5, stride=1, pad=2, relu=True, pool=2)),
        Unit("conv2", "conv", dict(out=64, k=5, stride=1, pad=2, relu=True, pool=2)),
        Unit("conv3", "conv", dict(out=96, k=3, stride=1, pad=1, relu=True, pool=1)),
        Unit("conv4", "conv", dict(out=96, k=3, stride=1, pad=1, relu=True, pool=1)),
        Unit("conv5", "conv", dict(out=64, k=3, stride=1, pad=1, relu=True, pool=2)),
        Unit("fc1", "dense", dict(out=256, relu=True)),
        Unit("fc2", "dense", dict(out=128, relu=True)),
        Unit("fc3", "dense", dict(out=10, relu=False)),
    ]
    return ModelDef("alexnet", tuple(u))


def squeezenet_mini() -> ModelDef:
    u = [
        Unit("conv1", "conv", dict(out=32, k=3, stride=1, pad=1, relu=True, pool=2)),
        Unit("fire2", "fire", dict(squeeze=8, expand=16, pool=1)),
        Unit("fire3", "fire", dict(squeeze=8, expand=16, pool=2)),
        Unit("fire4", "fire", dict(squeeze=16, expand=32, pool=1)),
        Unit("fire5", "fire", dict(squeeze=16, expand=32, pool=2)),
        Unit("conv10", "conv_gap", dict()),
    ]
    return ModelDef("squeezenet", tuple(u))


def resnet18_mini() -> ModelDef:
    u = [
        Unit("conv1", "conv", dict(out=24, k=3, stride=1, pad=1, relu=True, pool=1, bn=True)),
        Unit("block1", "block", dict(out=24, stride=1)),
        Unit("block2", "block", dict(out=24, stride=1)),
        Unit("block3", "block", dict(out=48, stride=2)),
        Unit("block4", "block", dict(out=48, stride=1)),
        Unit("block5", "block", dict(out=96, stride=2)),
        Unit("block6", "block", dict(out=96, stride=1)),
        Unit("block7", "block", dict(out=96, stride=2)),
        Unit("block8", "block", dict(out=96, stride=1)),
        Unit("fc", "gap_dense", dict(out=10)),
    ]
    return ModelDef("resnet18", tuple(u))


MODELS = {
    "alexnet": alexnet_mini,
    "squeezenet": squeezenet_mini,
    "resnet18": resnet18_mini,
}

# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _he(key, shape):
    fan_in = math.prod(shape[:-1])
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in)


def _conv_params(key, cin, cout, k, bn: bool):
    kw, kb = jax.random.split(key)
    p = {"w": _he(kw, (k, k, cin, cout)), "b": jnp.zeros((cout,), jnp.float32)}
    s = {}
    if bn:
        p["gamma"] = jnp.ones((cout,), jnp.float32)
        p["beta"] = jnp.zeros((cout,), jnp.float32)
        s["mean"] = jnp.zeros((cout,), jnp.float32)
        s["var"] = jnp.ones((cout,), jnp.float32)
    return p, s


def init_params(mdef: ModelDef, seed: int, input_shape=(32, 32, 3)):
    """Returns (params, bn_state) pytrees keyed by unit name."""
    key = jax.random.key(seed)
    params: Dict[str, dict] = {}
    state: Dict[str, dict] = {}
    h, w, c = input_shape
    for unit in mdef.units:
        key, uk = jax.random.split(key)
        cfg = unit.cfg
        if unit.kind == "conv":
            p, s = _conv_params(uk, c, cfg["out"], cfg["k"], cfg.get("bn", False))
            params[unit.name], state[unit.name] = p, s
            h = (h + 2 * cfg["pad"] - cfg["k"]) // cfg["stride"] + 1
            w = (w + 2 * cfg["pad"] - cfg["k"]) // cfg["stride"] + 1
            c = cfg["out"]
            if cfg.get("pool", 1) == 2:
                h, w = h // 2, w // 2
        elif unit.kind == "fire":
            ks = jax.random.split(uk, 3)
            sq, ex = cfg["squeeze"], cfg["expand"]
            p = {}
            s = {}
            for nm, kk, (ci, co, ksz) in [
                ("s", ks[0], (c, sq, 1)),
                ("e1", ks[1], (sq, ex, 1)),
                ("e3", ks[2], (sq, ex, 3)),
            ]:
                pp, ss = _conv_params(kk, ci, co, ksz, bn=True)
                for a, v in pp.items():
                    p[f"{nm}_{a}"] = v
                for a, v in ss.items():
                    s[f"{nm}_{a}"] = v
            params[unit.name], state[unit.name] = p, s
            c = 2 * ex
            if cfg.get("pool", 1) == 2:
                h, w = h // 2, w // 2
        elif unit.kind == "block":
            ks = jax.random.split(uk, 3)
            out, stride = cfg["out"], cfg["stride"]
            p = {}
            s = {}
            convs = [("c1", c, out, 3), ("c2", out, out, 3)]
            if stride != 1 or c != out:
                convs.append(("p", c, out, 1))
            for (nm, ci, co, ksz), kk in zip(convs, ks):
                pp, ss = _conv_params(kk, ci, co, ksz, bn=True)
                for a, v in pp.items():
                    p[f"{nm}_{a}"] = v
                for a, v in ss.items():
                    s[f"{nm}_{a}"] = v
            params[unit.name], state[unit.name] = p, s
            c = out
            h, w = (h + stride - 1) // stride, (w + stride - 1) // stride
        elif unit.kind == "dense":
            fan_in = h * w * c if h > 0 else c
            params[unit.name] = {
                "w": _he(uk, (fan_in, cfg["out"])),
                "b": jnp.zeros((cfg["out"],), jnp.float32),
            }
            state[unit.name] = {}
            h, w, c = 0, 0, cfg["out"]  # flattened from here on
        elif unit.kind == "gap_dense":
            params[unit.name] = {
                "w": _he(uk, (c, cfg["out"])),
                "b": jnp.zeros((cfg["out"],), jnp.float32),
            }
            state[unit.name] = {}
            h, w, c = 0, 0, cfg["out"]
        elif unit.kind == "conv_gap":
            params[unit.name] = {
                "w": _he(uk, (1, 1, c, mdef.num_classes)),
                "b": jnp.zeros((mdef.num_classes,), jnp.float32),
            }
            state[unit.name] = {}
            h, w, c = 0, 0, mdef.num_classes
        else:  # pragma: no cover
            raise ValueError(unit.kind)
    return params, state


# ---------------------------------------------------------------------------
# f32 forward (training / calibration)
# ---------------------------------------------------------------------------


def _conv_bn_act(x, p, s, prefix, stride, pad, train, relu=True):
    """conv [+bn] [+relu]; returns (y, new_bn_state_items)."""
    pre = f"{prefix}_" if prefix else ""
    y = ly.conv2d(x, p[f"{pre}w"], stride, pad) + p[f"{pre}b"]
    new = {}
    if f"{pre}gamma" in p:
        if train:
            y, nm, nv = ly.batchnorm_train(
                y, p[f"{pre}gamma"], p[f"{pre}beta"], s[f"{pre}mean"], s[f"{pre}var"]
            )
            new[f"{pre}mean"], new[f"{pre}var"] = nm, nv
        else:
            y = ly.batchnorm_eval(
                y, p[f"{pre}gamma"], p[f"{pre}beta"], s[f"{pre}mean"], s[f"{pre}var"]
            )
    if relu:
        y = jax.nn.relu(y)
    return y, new


def forward_f32(mdef: ModelDef, params, state, x, train: bool = False):
    """Float32 forward pass. Returns (logits, new_bn_state)."""
    new_state = {}
    for unit in mdef.units:
        p, s = params[unit.name], state[unit.name]
        cfg = unit.cfg
        ns: dict = {}
        if unit.kind == "conv":
            x, ns = _conv_bn_act(x, p, s, "", cfg["stride"], cfg["pad"], train, cfg["relu"])
            if cfg.get("pool", 1) == 2:
                x = ly.maxpool2(x)
        elif unit.kind == "fire":
            x, n1 = _conv_bn_act(x, p, s, "s", 1, 0, train)
            e1, n2 = _conv_bn_act(x, p, s, "e1", 1, 0, train)
            e3, n3 = _conv_bn_act(x, p, s, "e3", 1, 1, train)
            x = jnp.concatenate([e1, e3], axis=-1)
            ns = {**n1, **n2, **n3}
        elif unit.kind == "block":
            idn = x
            y, n1 = _conv_bn_act(x, p, s, "c1", cfg["stride"], 1, train)
            y, n2 = _conv_bn_act(y, p, s, "c2", 1, 1, train, relu=False)
            ns = {**n1, **n2}
            if "p_w" in p:
                idn, n3 = _conv_bn_act(x, p, s, "p", cfg["stride"], 0, train, relu=False)
                ns.update(n3)
            x = jax.nn.relu(y + idn)
        elif unit.kind == "dense":
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = x @ p["w"] + p["b"]
            if cfg["relu"]:
                x = jax.nn.relu(x)
        elif unit.kind == "gap_dense":
            x = ly.global_avg_pool(x) @ p["w"] + p["b"]
        elif unit.kind == "conv_gap":
            x = ly.global_avg_pool(ly.conv2d(x, p["w"], 1, 0) + p["b"])
        new_state[unit.name] = {**s, **ns}
    return x, new_state


# ---------------------------------------------------------------------------
# Per-unit cost metadata for the L3 hardware models
# ---------------------------------------------------------------------------


def profile_units(mdef: ModelDef, input_shape=(32, 32, 3), precision: int = 8):
    """Per-unit cost descriptors (per single sample).

    Returns a list of dicts: name, kind, macs, w_params, w_bytes,
    in_bytes, out_bytes, out_shape — the inputs of the Eyeriss/SIMBA
    analytical models and the link cost model (DESIGN.md §2).
    """
    h, w, c = input_shape
    rows = []
    for unit in mdef.units:
        cfg = unit.cfg
        in_elems = h * w * c if h else c
        macs = 0
        wp = 0
        if unit.kind == "conv":
            oh = (h + 2 * cfg["pad"] - cfg["k"]) // cfg["stride"] + 1
            ow = (w + 2 * cfg["pad"] - cfg["k"]) // cfg["stride"] + 1
            macs = oh * ow * cfg["out"] * cfg["k"] * cfg["k"] * c
            wp = cfg["k"] * cfg["k"] * c * cfg["out"]
            h, w, c = oh, ow, cfg["out"]
            if cfg.get("pool", 1) == 2:
                h, w = h // 2, w // 2
        elif unit.kind == "fire":
            sq, ex = cfg["squeeze"], cfg["expand"]
            macs = h * w * (c * sq + sq * ex + 9 * sq * ex)
            wp = c * sq + sq * ex + 9 * sq * ex
            c = 2 * ex
            if cfg.get("pool", 1) == 2:
                h, w = h // 2, w // 2
        elif unit.kind == "block":
            out, stride = cfg["out"], cfg["stride"]
            oh, ow = (h + stride - 1) // stride, (w + stride - 1) // stride
            macs = oh * ow * out * 9 * c + oh * ow * out * 9 * out
            wp = 9 * c * out + 9 * out * out
            if stride != 1 or c != out:
                macs += oh * ow * out * c
                wp += c * out
            h, w, c = oh, ow, out
        elif unit.kind == "dense":
            fan_in = in_elems
            macs = fan_in * cfg["out"]
            wp = fan_in * cfg["out"]
            h, w, c = 0, 0, cfg["out"]
        elif unit.kind == "gap_dense":
            macs = c * cfg["out"]
            wp = c * cfg["out"]
            h, w, c = 0, 0, cfg["out"]
        elif unit.kind == "conv_gap":
            macs = h * w * c * mdef.num_classes
            wp = c * mdef.num_classes
            h, w, c = 0, 0, mdef.num_classes
        out_elems = h * w * c if h else c
        rows.append(
            dict(
                name=unit.name,
                kind=unit.kind,
                macs=int(macs),
                w_params=int(wp),
                w_bytes=int(wp * precision // 8),
                in_bytes=int(in_elems * precision // 8),
                out_bytes=int(out_elems * precision // 8),
                out_shape=[int(h), int(w), int(c)] if h else [int(c)],
            )
        )
    return rows
