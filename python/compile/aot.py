"""AOT pipeline: train → quantize → lower to HLO text → write artifacts.

Runs once at build time (`make artifacts`); python never appears on the
rust request path. Per model (alexnet, squeezenet, resnet18) it emits:

  artifacts/<model>.hlo.txt       faulty quantized forward (see model.py)
  artifacts/<model>_weights.bin   quantized int32 weight tensors (AFWB)
  artifacts/<model>_manifest.json unit costs, weight order, scales, accs
plus once:
  artifacts/eval_data.bin         held-out eval set (AFED)
  artifacts/index.json            model index + global config

Interchange is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Checkpoints are cached in artifacts/ckpt/ so re-running is cheap.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models as M
from . import synthdata, train, quantize
from .model import make_export_fn
from .quantize import _prefixed

WEIGHTS_MAGIC = b"AFWB"
EVAL_MAGIC = b"AFED"

DEFAULTS = dict(
    precision=8,
    faulty_bits=4,
    batch=64,
    n_train=8192,
    n_eval=512,
    steps=500,
    seed=2026,
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format).

    print_large_constants=True is load-bearing: the default printer elides
    big constant arrays as `constant({...})`, and xla_extension 0.5.1's
    text parser silently reads those as ZEROS — the baked (BN-folded)
    biases vanish and accuracy collapses on the rust side. See
    EXPERIMENTS.md §Debugging.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def write_weights_bin(path: str, tensors) -> None:
    """AFWB format: magic, version, count, then [ndim, dims..., i32 data]."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for t in tensors:
            a = np.asarray(t, dtype=np.int32)
            f.write(struct.pack("<I", a.ndim))
            f.write(struct.pack(f"<{a.ndim}I", *a.shape))
            f.write(a.tobytes())


def write_eval_bin(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    """AFED format: magic, version, n, h, w, c, f32 images, i32 labels."""
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        f.write(EVAL_MAGIC)
        f.write(struct.pack("<IIIII", 1, n, h, w, c))
        f.write(images.astype(np.float32).tobytes())
        f.write(labels.astype(np.int32).tobytes())


def train_or_load(mdef, train_set, ckpt_dir, steps, seed):
    """Train the f32 model or load the cached checkpoint."""
    path = os.path.join(ckpt_dir, f"{mdef.name}.npz")
    if os.path.exists(path):
        data = dict(np.load(path))
        params = train.unflatten_tree(
            {k[2:]: v for k, v in data.items() if k.startswith("p.")}
        )
        state = train.unflatten_tree(
            {k[2:]: v for k, v in data.items() if k.startswith("s.")}
        )
        # ensure every unit has a (possibly empty) state entry
        state = {u.name: state.get(u.name, {}) for u in mdef.units}
        print(f"  [{mdef.name}] loaded checkpoint {path}")
        return params, state
    params, state, _ = train.train_model(
        mdef, train_set[0], train_set[1], steps=steps, seed=seed
    )
    flat = {}
    flat.update({f"p.{k}": v for k, v in train.flatten_tree(params).items()})
    flat.update({f"s.{k}": v for k, v in train.flatten_tree(state).items()})
    os.makedirs(ckpt_dir, exist_ok=True)
    np.savez(path, **flat)
    return params, state


def quant_accuracy(mdef, qparams, act_scales, images, labels, cfg, batch=64) -> float:
    """Clean (rates=0) quantized accuracy — A_clean of the paper's ΔAcc."""
    fn, order = make_export_fn(
        mdef, qparams, act_scales, bits=cfg["faulty_bits"], precision=cfg["precision"]
    )
    jfn = jax.jit(fn)
    L = mdef.num_units
    zeros = jnp.zeros((L,), jnp.float32)
    key = jnp.zeros((2,), jnp.uint32)
    wqs = [qparams[u][_prefixed(p, "wq")] for (u, p) in order]
    hits, total = 0, 0
    for i in range(0, (len(images) // batch) * batch, batch):
        (logits,) = jfn(jnp.asarray(images[i : i + batch]), *wqs, zeros, zeros, key)
        hits += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(labels[i : i + batch])))
        total += batch
    return hits / max(total, 1)


def export_model(mdef, train_set, eval_set, out_dir, cfg) -> dict:
    """Full per-model pipeline; returns its manifest dict."""
    print(f"[aot] === {mdef.name} ===")
    params, state = train_or_load(
        mdef, train_set, os.path.join(out_dir, "ckpt"), cfg["steps"], cfg["seed"]
    )
    ev_images, ev_labels = eval_set
    acc_f32 = train.accuracy_f32(mdef, params, state, jnp.asarray(ev_images), ev_labels)
    print(f"  [{mdef.name}] clean f32 top-1 = {acc_f32:.4f}")

    qparams, w_scale = quantize.quantize_model(mdef, params, state, cfg["precision"])
    act_scales = quantize.calibrate_act_scales(
        mdef, params, state, train_set[0][:256], cfg["precision"]
    )
    acc_q = quant_accuracy(
        mdef, qparams, act_scales, ev_images, ev_labels, cfg, batch=cfg["batch"]
    )
    print(f"  [{mdef.name}] clean int{cfg['precision']} top-1 = {acc_q:.4f}")

    # ---- lower to HLO text
    fn, order = make_export_fn(
        mdef, qparams, act_scales, bits=cfg["faulty_bits"], precision=cfg["precision"]
    )
    B, L = cfg["batch"], mdef.num_units
    ex_images = jax.ShapeDtypeStruct((B, 32, 32, 3), jnp.float32)
    ex_wqs = [
        jax.ShapeDtypeStruct(qparams[u][_prefixed(p, "wq")].shape, jnp.int32)
        for (u, p) in order
    ]
    ex_rates = jax.ShapeDtypeStruct((L,), jnp.float32)
    ex_key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    lowered = jax.jit(fn).lower(ex_images, *ex_wqs, ex_rates, ex_rates, ex_key)
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{mdef.name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    print(f"  [{mdef.name}] wrote {hlo_path} ({len(hlo)/1e6:.2f} MB)")

    # ---- weights blob + manifest
    wq_tensors = [qparams[u][_prefixed(p, "wq")] for (u, p) in order]
    write_weights_bin(os.path.join(out_dir, f"{mdef.name}_weights.bin"), wq_tensors)

    manifest = dict(
        model=mdef.name,
        num_units=L,
        num_classes=mdef.num_classes,
        precision=cfg["precision"],
        faulty_bits=cfg["faulty_bits"],
        batch=B,
        hlo=f"{mdef.name}.hlo.txt",
        weights=f"{mdef.name}_weights.bin",
        clean_acc_f32=acc_f32,
        clean_acc_quant=acc_q,
        weight_scale=w_scale,
        units=M.profile_units(mdef, precision=cfg["precision"]),
        weight_tensors=[
            dict(
                unit=u,
                prefix=p,
                shape=list(qparams[u][_prefixed(p, "wq")].shape),
                scale=qparams[u][_prefixed(p, "scale")],
            )
            for (u, p) in order
        ],
        act_scales={u.name: act_scales[u.name] for u in mdef.units},
    )
    with open(os.path.join(out_dir, f"{mdef.name}_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="AFarePart AOT artifact builder")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="alexnet,squeezenet,resnet18")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("AFARE_STEPS", DEFAULTS["steps"])))
    ap.add_argument("--precision", type=int, default=DEFAULTS["precision"], choices=[8, 16])
    ap.add_argument("--faulty-bits", type=int, default=DEFAULTS["faulty_bits"])
    ap.add_argument("--batch", type=int, default=DEFAULTS["batch"])
    ap.add_argument("--n-train", type=int, default=DEFAULTS["n_train"])
    ap.add_argument("--n-eval", type=int, default=DEFAULTS["n_eval"])
    ap.add_argument("--seed", type=int, default=DEFAULTS["seed"])
    args = ap.parse_args(argv)

    cfg = dict(
        precision=args.precision,
        faulty_bits=args.faulty_bits,
        batch=args.batch,
        steps=args.steps,
        seed=args.seed,
    )
    os.makedirs(args.out, exist_ok=True)

    print(f"[aot] generating synthetic dataset (train={args.n_train}, eval={args.n_eval})")
    train_set, eval_set = synthdata.train_eval_split(args.n_train, args.n_eval)
    write_eval_bin(os.path.join(args.out, "eval_data.bin"), eval_set[0], eval_set[1])

    names = [n.strip() for n in args.models.split(",") if n.strip()]
    manifests = []
    for name in names:
        mdef = M.MODELS[name]()
        manifests.append(export_model(mdef, train_set, eval_set, args.out, cfg))

    index = dict(
        models=names,
        eval_data="eval_data.bin",
        batch=args.batch,
        precision=args.precision,
        faulty_bits=args.faulty_bits,
        n_eval=args.n_eval,
        clean_acc={m["model"]: m["clean_acc_quant"] for m in manifests},
    )
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print("[aot] done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
