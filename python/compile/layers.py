"""L2 building blocks: f32 training ops + fixed-point quantization helpers.

NHWC layout throughout. Convolutions use XLA's native conv (the Pallas
story lives in the elementwise fault-injection kernel that feeds every conv
its faulty dequantized weights, and in the fused qmatmul that runs the
dense layers — see DESIGN.md §8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


def conv2d(x, w, stride: int = 1, pad: int = 0):
    """NHWC conv. w: [kh, kw, cin, cout]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def maxpool2(x):
    """2x2 max pool, stride 2."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def global_avg_pool(x):
    """[B,H,W,C] -> [B,C]."""
    return jnp.mean(x, axis=(1, 2))


def batchnorm_train(x, gamma, beta, mean, var):
    """Batch norm with batch statistics; returns (y, new_mean, new_var)."""
    mu = jnp.mean(x, axis=(0, 1, 2))
    sig2 = jnp.var(x, axis=(0, 1, 2))
    y = (x - mu) / jnp.sqrt(sig2 + BN_EPS) * gamma + beta
    new_mean = BN_MOMENTUM * mean + (1.0 - BN_MOMENTUM) * mu
    new_var = BN_MOMENTUM * var + (1.0 - BN_MOMENTUM) * sig2
    return y, new_mean, new_var


def batchnorm_eval(x, gamma, beta, mean, var):
    """Batch norm with running statistics (inference)."""
    return (x - mean) / jnp.sqrt(var + BN_EPS) * gamma + beta


def fold_bn(w, b, gamma, beta, mean, var):
    """Fold a trained BN into the preceding conv: returns (w', b').

    Standard deployment transform — the quantized inference graph is
    BN-free: y = conv(x, w') + b' == bn(conv(x, w) + b).
    """
    k = gamma / jnp.sqrt(var + BN_EPS)
    return w * k[None, None, None, :], beta + (b - mean) * k


def quant_range(precision: int):
    """(qmin, qmax) of a signed `precision`-bit two's-complement value."""
    qmax = (1 << (precision - 1)) - 1
    return -qmax - 1, qmax


def quantize_tensor(w, precision: int):
    """Symmetric per-tensor fixed-point quantization -> (int32 q, f32 scale)."""
    qmin, qmax = quant_range(precision)
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) / qmax
    q = jnp.clip(jnp.round(w / scale), qmin, qmax).astype(jnp.int32)
    return q, scale.astype(jnp.float32)


def quantize_act(x, scale, precision: int):
    """Quantize activations with a pre-calibrated scale -> int32."""
    qmin, qmax = quant_range(precision)
    return jnp.clip(jnp.round(x / scale), qmin, qmax).astype(jnp.int32)
