"""L1 Pallas kernel: tiled matmul with fused weight bit-flip + dequantize.

The dense (fully-connected) layers of the quantized models run through this
kernel: activations are f32, weights arrive quantized (int32 lanes holding
b-bit fixed-point values); the kernel flips the vulnerable LSBs of the
weight tile, dequantizes it in VMEM and feeds the MXU-sized tile straight
into a f32-accumulating dot.

TPU mapping (DESIGN.md §8): grid tiles the output as (bm, bn) blocks with
the full K dimension resident per block (K <= a few thousand for the FC
layers here, comfortably inside VMEM: bm*K + K*bn + bm*bn floats). The
fusion means faulty weights never make a round trip to HBM — this is where
a CUDA implementation would have used a shared-memory staging buffer, and
the BlockSpec index_map plays the role of the threadblock schedule.

interpret=True for CPU PJRT execution (Mosaic is TPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 64
DEFAULT_BN = 128


def _qmatmul_kernel(rate_ref, scale_ref, x_ref, w_ref, rnd_ref, o_ref, *, bits: int):
    """o[bm,bn] = x[bm,K] @ dequant(bitflip(w[K,bn]))."""
    wq = w_ref[...]
    rnd = rnd_ref[...]
    thr = jnp.round(rate_ref[0, 0] * 256.0).astype(jnp.uint32)
    flip = jnp.zeros_like(wq)
    for i in range(bits):
        sl = (rnd >> jnp.uint32(8 * i)) & jnp.uint32(0xFF)
        flip = flip | jnp.where(sl < thr, jnp.int32(1 << i), jnp.int32(0))
    w = (wq ^ flip).astype(jnp.float32) * scale_ref[0, 0]
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bn"))
def qmatmul_bitflip(x, wq, rnd, rate, scale, *, bits: int = 4,
                    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """Faulty quantized matmul: x[M,K] @ dequant(flip(wq[K,N])) -> f32[M,N].

    Args:
      x:     f32[M, K] activations.
      wq:    int32[K, N] quantized weights.
      rnd:   uint32[K, N] random draws (one per weight element).
      rate:  scalar f32 per-bit flip probability.
      scale: scalar f32 weight dequantization scale.
      bits:  static vulnerable-LSB count.
      bm/bn: static output tile shape.
    """
    if x.ndim != 2 or wq.ndim != 2 or x.shape[1] != wq.shape[0]:
        raise ValueError(f"bad shapes x{x.shape} wq{wq.shape}")
    if wq.shape != rnd.shape:
        raise ValueError(f"shape mismatch wq{wq.shape} vs rnd{rnd.shape}")
    m, k = x.shape
    _, n = wq.shape
    mp, np_ = (-m) % bm, (-n) % bn
    xp = jnp.pad(x, ((0, mp), (0, 0)))
    wp = jnp.pad(wq, ((0, 0), (0, np_)))
    rp = jnp.pad(rnd, ((0, 0), (0, np_)))
    rate2 = jnp.asarray(rate, jnp.float32).reshape(1, 1)
    scale2 = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, bits=bits),
        grid=((m + mp) // bm, (n + np_) // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),   # rate
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),   # scale
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # x row-tile
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # w col-tile
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # rnd col-tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + mp, n + np_), jnp.float32),
        interpret=True,
    )(rate2, scale2, xp, wp, rp)
    return out[:m, :n]
