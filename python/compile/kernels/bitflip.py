"""L1 Pallas kernel: fused probabilistic LSB bit-flip + dequantize.

This is the paper's Algorithm 2 (bit-flip fault injection) fused with the
dequantize step of quantized inference. It is the fault-injection hot spot:
it runs once per weight tensor and once per activation tensor on every
forward pass evaluated inside the NSGA-II loop.

TPU mapping (see DESIGN.md §8): the tensor is streamed through VMEM in
(block_rows, 128)-shaped blocks (lane dimension 128); the flip + dequant is
pure VPU elementwise work, so the kernel is memory-bound and the fusion
saves one full HBM round-trip versus flip-then-dequant as separate ops.

Randomness contract (shared bit-exactly with ref.py and the rust mirror in
rust/src/util/bits.rs): each element consumes one uint32 of externally
supplied random bits; bit i < `bits` flips iff the i-th 8-bit slice of that
uint32 is < round(rate * 256). Flip probabilities are therefore quantized
to 1/256 granularity, and up to 4 independent-ish uniforms come from a
single draw.

Lowered with interpret=True: CPU PJRT cannot execute Mosaic custom-calls,
so the kernel body becomes plain HLO (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width of the TPU VPU; blocks are shaped (BLOCK_ROWS, LANES).
#
# BLOCK_ROWS was tuned in the L1 performance pass (EXPERIMENTS.md §Perf):
# interpret-mode lowering turns each grid step into a dynamic-slice +
# kernel-body + dynamic-update-slice sequence, so CPU execution time is
# dominated by grid-step count — (8,128) blocks cost 1071 ms per alexnet
# batch vs 81 ms at (4096,128). (2048,128) int32 blocks are 1 MiB per
# buffer (3 MiB with in/out + double buffering), comfortably inside a
# 16 MiB TPU VMEM budget, so the same shape serves both targets.
LANES = 128
BLOCK_ROWS = 2048


def _bitflip_dequant_kernel(rate_ref, scale_ref, q_ref, rnd_ref, o_ref, *, bits: int):
    """One (BLOCK_ROWS, LANES) block: flip `bits` LSBs, dequantize to f32."""
    q = q_ref[...]
    rnd = rnd_ref[...]
    # Threshold on an 8-bit slice: P(flip) = round(rate*256)/256.
    thr = jnp.round(rate_ref[0, 0] * 256.0).astype(jnp.uint32)
    flip = jnp.zeros_like(q)
    for i in range(bits):
        sl = (rnd >> jnp.uint32(8 * i)) & jnp.uint32(0xFF)
        flip = flip | jnp.where(sl < thr, jnp.int32(1 << i), jnp.int32(0))
    o_ref[...] = (q ^ flip).astype(jnp.float32) * scale_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("bits",))
def bitflip_dequant(q, rnd, rate, scale, *, bits: int = 4):
    """Flip up to `bits` LSBs of quantized tensor `q` and dequantize.

    Args:
      q:     int32 tensor (any shape) holding quantized values.
      rnd:   uint32 tensor, same shape: one random draw per element.
      rate:  scalar f32, per-bit flip probability (paper's FR).
      scale: scalar f32, dequantization scale.
      bits:  static number of vulnerable LSBs (paper's b, default 4).

    Returns:
      float32 tensor, same shape as q: dequantized faulty values.
    """
    if q.shape != rnd.shape:
        raise ValueError(f"shape mismatch: q{q.shape} vs rnd{rnd.shape}")
    orig_shape = q.shape
    n = q.size
    # Flatten and pad to a whole number of (BLOCK_ROWS, LANES) blocks.
    block = BLOCK_ROWS * LANES
    n_pad = (-n) % block
    qf = jnp.concatenate([q.reshape(-1), jnp.zeros((n_pad,), jnp.int32)])
    rf = jnp.concatenate([rnd.reshape(-1), jnp.zeros((n_pad,), jnp.uint32)])
    rows = (n + n_pad) // LANES
    qf = qf.reshape(rows, LANES)
    rf = rf.reshape(rows, LANES)
    rate2 = jnp.asarray(rate, jnp.float32).reshape(1, 1)
    scale2 = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_bitflip_dequant_kernel, bits=bits),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # rate (scalar)
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # scale (scalar)
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(rate2, scale2, qf, rf)
    return out.reshape(-1)[:n].reshape(orig_shape)
