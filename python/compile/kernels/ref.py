"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness contract: `bitflip.py` / `qmatmul.py` must match
these bit-for-bit (integers) / exactly (f32 elementwise ops). pytest
(python/tests/) sweeps shapes, rates and bit counts with hypothesis; the
rust mirror (rust/src/util/bits.rs) is cross-checked against the same
vectors via golden files emitted by python/tests/test_cross_vectors.py.
"""

from __future__ import annotations

import jax.numpy as jnp


def flip_mask(rnd, rate, bits: int):
    """int32 mask of bits to flip, given uint32 draws and per-bit rate.

    Bit i of the mask is set iff the i-th 8-bit slice of the draw is below
    round(rate*256) — the shared randomness contract (see bitflip.py).
    """
    thr = jnp.round(jnp.asarray(rate, jnp.float32) * 256.0).astype(jnp.uint32)
    mask = jnp.zeros(rnd.shape, jnp.int32)
    for i in range(bits):
        sl = (rnd >> jnp.uint32(8 * i)) & jnp.uint32(0xFF)
        mask = mask | jnp.where(sl < thr, jnp.int32(1 << i), jnp.int32(0))
    return mask


def bitflip_dequant_ref(q, rnd, rate, scale, *, bits: int = 4):
    """Oracle for bitflip.bitflip_dequant."""
    return (q ^ flip_mask(rnd, rate, bits)).astype(jnp.float32) * jnp.asarray(
        scale, jnp.float32
    )


def qmatmul_bitflip_ref(x, wq, rnd, rate, scale, *, bits: int = 4):
    """Oracle for qmatmul.qmatmul_bitflip."""
    w = bitflip_dequant_ref(wq, rnd, rate, scale, bits=bits)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
