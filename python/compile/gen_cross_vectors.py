"""Generate rust/tests/data/bitflip_golden.json from the ref.py oracle.

The golden vectors pin the Algorithm-2 randomness contract across the three
implementations (Pallas kernel, jnp reference, rust mirror). They are
deterministic: a fixed numpy seed drives the draws, and the expected
outputs come straight from ref.flip_mask. Regenerate only when the
*contract* intentionally changes (see python/tests/test_cross_vectors.py):

    python python/compile/gen_cross_vectors.py
"""

from __future__ import annotations

import json
import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from compile.kernels import ref  # noqa: E402

OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "data", "bitflip_golden.json"
)

RATES = [0.0, 0.05, 0.1, 0.2, 0.4, 0.6, 1.0]
BITS = [1, 2, 4]
N = 64


def main() -> None:
    rng = np.random.RandomState(20250728)
    cases = []
    for rate in RATES:
        for bits in BITS:
            q = rng.randint(-128, 128, size=N).astype(np.int32)
            rnd = rng.randint(0, 2**32, size=N, dtype=np.uint64).astype(np.uint32)
            mask = np.asarray(ref.flip_mask(jnp.asarray(rnd), rate, bits))
            expected = (q ^ mask).astype(np.int32)
            cases.append(
                {
                    "rate": rate,
                    "bits": bits,
                    "q": q.tolist(),
                    "rnd": rnd.tolist(),
                    "expected": expected.tolist(),
                }
            )
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(cases, f)
    print(f"wrote {len(cases)} cases to {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
