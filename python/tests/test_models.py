"""L2 model structure tests: shapes, parameter trees, unit profiling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as M


@pytest.fixture(scope="module", params=["alexnet", "squeezenet", "resnet18"])
def model(request):
    mdef = M.MODELS[request.param]()
    params, state = M.init_params(mdef, seed=0)
    return mdef, params, state


def test_forward_shapes(model):
    mdef, params, state = model
    x = jnp.zeros((4, 32, 32, 3), jnp.float32)
    logits, new_state = M.forward_f32(mdef, params, state, x, train=False)
    assert logits.shape == (4, 10)
    assert set(new_state) == {u.name for u in mdef.units}


def test_train_mode_updates_bn_state(model):
    mdef, params, state = model
    has_bn = any("mean" in k for s in state.values() for k in s)
    if not has_bn:
        pytest.skip("model has no BN units")
    x = jax.random.normal(jax.random.key(0), (8, 32, 32, 3), jnp.float32)
    _, new_state = M.forward_f32(mdef, params, state, x, train=True)
    changed = False
    for uname, s in state.items():
        for k, v in s.items():
            if k.endswith("mean") and not np.allclose(v, new_state[uname][k]):
                changed = True
    assert changed


def test_eval_mode_preserves_bn_state(model):
    mdef, params, state = model
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3), jnp.float32)
    _, new_state = M.forward_f32(mdef, params, state, x, train=False)
    for uname, s in state.items():
        for k, v in s.items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(new_state[uname][k]))


def test_profile_units_consistency(model):
    mdef, params, state = model
    rows = M.profile_units(mdef, precision=8)
    assert len(rows) == mdef.num_units
    for r in rows:
        assert r["macs"] > 0
        assert r["in_bytes"] > 0 and r["out_bytes"] > 0
    # final unit emits the logits
    assert rows[-1]["out_shape"] == [10]
    # w_params matches the actual parameter count of quantizable weights
    from compile.quantize import UNIT_CONVS, _prefixed

    for unit, row in zip(mdef.units, rows):
        wp = sum(
            params[unit.name][_prefixed(p, "w")].size
            for p in UNIT_CONVS[unit.kind]
            if _prefixed(p, "w") in params[unit.name]
        )
        assert wp == row["w_params"], unit.name


def test_profile_in_out_bytes_chain(model):
    """Unit i's out_bytes equals unit i+1's in_bytes (same activation)."""
    mdef, _, _ = model
    rows = M.profile_units(mdef, precision=8)
    for a, b in zip(rows, rows[1:]):
        assert a["out_bytes"] == b["in_bytes"], (a["name"], b["name"])


def test_num_units_match_paper_granularity():
    assert M.alexnet_mini().num_units == 8  # 5 conv + 3 fc
    assert M.squeezenet_mini().num_units == 6  # conv1 + 4 fire + conv10
    assert M.resnet18_mini().num_units == 10  # conv1 + 8 blocks + fc


def test_init_deterministic():
    mdef = M.alexnet_mini()
    p1, _ = M.init_params(mdef, seed=42)
    p2, _ = M.init_params(mdef, seed=42)
    for u in p1:
        for k in p1[u]:
            np.testing.assert_array_equal(np.asarray(p1[u][k]), np.asarray(p2[u][k]))
