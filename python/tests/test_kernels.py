"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes, rates and bit counts; integer outputs must match
bit-for-bit, f32 matmuls to tight tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bitflip import bitflip_dequant
from compile.kernels.qmatmul import qmatmul_bitflip


def _rand_q(key, shape, precision=8):
    lim = 1 << (precision - 1)
    return jax.random.randint(key, shape, -lim, lim, dtype=jnp.int32)


def _rand_bits(key, shape):
    return jax.random.bits(key, shape, dtype=jnp.uint32)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 40),
    rate=st.floats(0.0, 1.0),
    bits=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitflip_matches_ref(rows, cols, rate, bits, seed):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    q = _rand_q(k1, (rows, cols))
    rnd = _rand_bits(k2, (rows, cols))
    got = bitflip_dequant(q, rnd, rate, 0.015625, bits=bits)
    want = ref.bitflip_dequant_ref(q, rnd, rate, 0.015625, bits=bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 64),
    n=st.integers(1, 150),
    rate=st.floats(0.0, 1.0),
    bits=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, n, rate, bits, seed):
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    wq = _rand_q(k2, (k, n))
    rnd = _rand_bits(k3, (k, n))
    got = qmatmul_bitflip(x, wq, rnd, rate, 0.0078125, bits=bits)
    want = ref.qmatmul_bitflip_ref(x, wq, rnd, rate, 0.0078125, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_bitflip_3d_shape_roundtrip():
    key = jax.random.key(3)
    q = _rand_q(key, (5, 7, 11))
    rnd = _rand_bits(key, (5, 7, 11))
    out = bitflip_dequant(q, rnd, 0.25, 0.5, bits=4)
    assert out.shape == (5, 7, 11)
    want = ref.bitflip_dequant_ref(q, rnd, 0.25, 0.5, bits=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_rate_zero_is_identity_dequant():
    key = jax.random.key(4)
    q = _rand_q(key, (33, 65))
    rnd = _rand_bits(key, (33, 65))
    out = bitflip_dequant(q, rnd, 0.0, 2.0, bits=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(q, np.float32) * 2.0)


def test_rate_one_flips_all_bits():
    key = jax.random.key(5)
    q = _rand_q(key, (16, 128))
    rnd = _rand_bits(key, (16, 128))
    out = bitflip_dequant(q, rnd, 1.0, 1.0, bits=4)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(np.asarray(q) ^ 0xF, np.float32)
    )


def test_flip_statistics_match_rate():
    """Empirical per-bit flip frequency ~= round(rate*256)/256."""
    key = jax.random.key(6)
    n = 200_000
    q = jnp.zeros((n,), jnp.int32)
    rnd = _rand_bits(key, (n,))
    rate = 0.2
    out = np.asarray(bitflip_dequant(q, rnd, rate, 1.0, bits=4)).astype(np.int64)
    expect = round(rate * 256) / 256
    for i in range(4):
        freq = ((out >> i) & 1).mean()
        assert abs(freq - expect) < 0.005, (i, freq, expect)


def test_flips_limited_to_lsbs():
    key = jax.random.key(7)
    q = _rand_q(key, (4096,), precision=8)
    rnd = _rand_bits(key, (4096,))
    for bits in (1, 2, 3, 4):
        out = np.asarray(bitflip_dequant(q, rnd, 1.0, 1.0, bits=bits)).astype(np.int64)
        diff = out ^ np.asarray(q)
        assert (diff & ~((1 << bits) - 1)).max() == 0


def test_qmatmul_identity_weights():
    """rate=0 with identity-matrix weights reproduces x * scale."""
    x = jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4)
    wq = jnp.eye(4, dtype=jnp.int32) * 64
    rnd = jnp.zeros((4, 4), jnp.uint32) | jnp.uint32(0xFFFFFFFF)
    out = qmatmul_bitflip(x, wq, rnd, 0.0, 0.25, bits=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 16.0, rtol=1e-6)


def test_bitflip_negative_values_twos_complement():
    """LSB flips on negative values behave as int16/int8 two's complement."""
    q = jnp.array([-1, -128, -37, 127], jnp.int32)
    rnd = jnp.zeros((4,), jnp.uint32)  # all slices 0 -> all bits flip at rate 1
    out = np.asarray(bitflip_dequant(q, rnd, 1.0, 1.0, bits=4)).astype(np.int64)
    np.testing.assert_array_equal(out, np.array([-1, -128, -37, 127]) ^ 0xF)
