"""Cross-language contract: the golden bit-flip vectors consumed by the
rust mirror (rust/tests/data/bitflip_golden.json, asserted by the rust test
suite against rust/src/util/bits.rs) must match ref.py forever.

If this test fails, the Algorithm-2 randomness contract drifted — fix the
implementation, do NOT regenerate the goldens casually.
"""

import json
import os

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref

GOLDEN = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "data", "bitflip_golden.json"
)


def test_golden_vectors_match_ref():
    with open(GOLDEN) as f:
        cases = json.load(f)
    assert len(cases) >= 18
    for c in cases:
        q = np.asarray(c["q"], np.int32)
        rnd = np.asarray(c["rnd"], np.uint32)
        got = np.asarray(ref.flip_mask(jnp.asarray(rnd), c["rate"], c["bits"])) ^ q
        np.testing.assert_array_equal(got, np.asarray(c["expected"], np.int32), err_msg=str(c["rate"]))
